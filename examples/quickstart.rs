//! Quickstart — the end-to-end driver.
//!
//! Exercises the full stack on a real small workload and reports the
//! paper's headline metrics:
//!
//! 1. generate an ogbn-arxiv-shaped dataset (20k points by default);
//! 2. bootstrap Dynamic GUS (offline preprocessing §4.3: bucket stats,
//!    IDF table, popular-bucket filter; index warm-up; XLA scorer from
//!    `artifacts/` if present, else the native model);
//! 3. serve a mixed dynamic workload (inserts / updates / deletes /
//!    neighborhood queries) through the real coordinator;
//! 4. report: query latency percentiles (paper: median 10–20 ms at this
//!    scale class), insertion latency (paper: 0.29–0.42 ms median),
//!    staleness p99, neighborhood quality vs the latent clusters;
//! 5. round-trip the same service over the v1 wire protocol (TCP server +
//!    `GusClient` envelopes) to show the RPC path end to end.
//!
//! Run:  cargo run --release --example quickstart -- [--n 20000] [--ops 5000]

use std::sync::Arc;
use std::time::Instant;

use dynamic_gus::client::GusClient;
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::trace::{Op, TraceConfig};
use dynamic_gus::loadgen::scenario::CorpusSpec;
use dynamic_gus::server::{serve, ServerConfig};
use dynamic_gus::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.get_usize("n", 20_000);
    let n_ops = args.get_usize("ops", 5_000);
    let k = args.get_usize("k", 10);

    println!("== Dynamic GUS quickstart ==");
    println!("[1/5] generating arxiv_like dataset (n={n})...");
    // The shared corpus helper the load scenarios use (`gus loadgen`).
    let mut corpus = CorpusSpec::new("arxiv_like", n, 0xa1, k);
    corpus.idf_s = Some(0);
    let ds = corpus.generate()?;

    println!("[2/5] bootstrapping service (preprocess + index + scorer)...");
    let config = corpus.gus_config();
    let t0 = Instant::now();
    // Hold out 20% of points to drive inserts from the stream.
    let trace = TraceConfig {
        initial_fraction: 0.8,
        n_ops,
        insert_prob: 0.10,
        update_prob: 0.05,
        delete_prob: 0.02,
        query_k: k,
        seed: 7,
    }
    .build(&ds);
    let gus = DynamicGus::bootstrap(ds.schema.clone(), config, &trace.initial, 8)?;
    println!(
        "       ready in {:.1}s ({} points, scorer={})",
        t0.elapsed().as_secs_f64(),
        gus.len(),
        if dynamic_gus::scorer::XlaScorer::artifacts_available(
            &dynamic_gus::runtime::artifacts_dir(),
            &ds.schema.name
        ) {
            "xla (AOT artifacts)"
        } else {
            "native (run `make artifacts` for the XLA path)"
        }
    );

    println!("[3/5] running {} mixed operations...", trace.ops.len());
    let mut cluster_hits = 0u64;
    let mut cluster_total = 0u64;
    let t1 = Instant::now();
    for op in &trace.ops {
        match op {
            Op::Insert(p) | Op::Update(p) => {
                gus.insert(p.clone())?;
            }
            Op::Delete(id) => {
                gus.delete(*id)?;
            }
            Op::Query { point, k } => {
                let res = gus.query(point, *k)?;
                // Quality probe: neighbors sharing the latent cluster.
                let qc = ds.cluster_of[point.id as usize];
                for nb in &res {
                    if let Some(&c) = ds.cluster_of.get(nb.id as usize) {
                        cluster_total += 1;
                        if c == qc {
                            cluster_hits += 1;
                        }
                    }
                }
            }
        }
    }
    let wall = t1.elapsed();

    println!("[4/5] results");
    let (ins, upd, del, q) = trace_mix(&trace.ops);
    println!("  ops: {ins} inserts, {upd} updates, {del} deletes, {q} queries");
    println!(
        "  throughput: {:.0} ops/s (wall {:.1}s, sequential)",
        trace.ops.len() as f64 / wall.as_secs_f64(),
        wall.as_secs_f64()
    );
    let ql = gus.metrics.query_latency.summary();
    println!(
        "  query latency:    p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms   (paper: median 5-25 ms)",
        ql.p50_ns as f64 / 1e6,
        ql.p90_ns as f64 / 1e6,
        ql.p99_ns as f64 / 1e6
    );
    let ml = gus.metrics.mutation_latency.summary();
    println!(
        "  mutation latency: p50 {:.3} ms  p95 {:.3} ms              (paper: 0.29-0.42 / 0.54-0.78 ms)",
        ml.p50_ns as f64 / 1e6,
        ml.p95_ns as f64 / 1e6
    );
    println!(
        "  staleness p99:    {:.3} ms (mutations visible to the next query immediately)",
        gus.metrics.staleness.p99_ms()
    );
    if cluster_total > 0 {
        println!(
            "  neighborhood quality: {:.1}% of returned neighbors share the query's latent cluster ({}/{})",
            100.0 * cluster_hits as f64 / cluster_total as f64,
            cluster_hits,
            cluster_total
        );
    }
    println!("  service stats: {}", gus.stats_json().dump());

    // --- the same service over the wire: v1 pipelined envelopes ---
    println!("[5/5] v1 wire protocol round trip...");
    let gus = Arc::new(gus);
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::from_gus(gus.config()))?;
    let addr = handle.addr.to_string();
    let mut client = GusClient::connect(&addr)?;
    client.set_deadline_ms(Some(1_000));
    let sampler = corpus.sampler()?;
    let mut srng = dynamic_gus::util::rng::Rng::seeded(0xa1a1);
    let fresh = sampler.sample(ds.points.len() as u64 + 1, &mut srng);
    let t2 = Instant::now();
    anyhow::ensure!(client.insert(&fresh)?, "RPC insert of a fresh point must report created");
    let shelf = client.query_id(fresh.id, k)?;
    let rpc_ms = t2.elapsed().as_secs_f64() * 1e3;
    println!(
        "  served on {addr}: insert + query_id round trip {:.2} ms, {} neighbors via JSON envelopes",
        rpc_ms,
        shelf.len()
    );
    println!("  server-side stats over RPC: {}", client.stats()?.dump());
    handle.shutdown();
    Ok(())
}

fn trace_mix(ops: &[Op]) -> (usize, usize, usize, usize) {
    let mut mix = (0, 0, 0, 0);
    for op in ops {
        match op {
            Op::Insert(_) => mix.0 += 1,
            Op::Update(_) => mix.1 += 1,
            Op::Delete(_) => mix.2 += 1,
            Op::Query { .. } => mix.3 += 1,
        }
    }
    mix
}
