//! Android Security scenario (§1.1 of the paper).
//!
//! The Android Security & Privacy team uses Dynamic GUS to catch
//! potentially-harmful apps (PHAs) *before* they reach users: when an app
//! is uploaded, its neighborhood among known apps is computed immediately;
//! if it sits in a neighborhood of known-harmful apps, it is flagged now —
//! instead of waiting for the next offline Grale batch rebuild. The paper
//! reports a 4× reduction in detection latency and +40% action rate.
//!
//! This example simulates that pipeline and measures exactly that gap:
//!
//! - a store of apps (products_like schema: code-embedding + permission/API
//!   token set), some clusters seeded as "malware families";
//! - a live upload stream; each upload is inserted into Dynamic GUS and
//!   immediately risk-scored by weighted k-NN vote over its neighborhood;
//! - the baseline detects the same uploads only at the next periodic batch
//!   rebuild (period `--batch-mins`, default 60 simulated minutes);
//! - report: detection precision/recall of the kNN vote, and the
//!   distribution of detection-latency improvement (dynamic vs batch).
//!
//! Run: cargo run --release --example android_security -- [--n 15000]

use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::loadgen::scenario::CorpusSpec;
use dynamic_gus::util::cli::Args;
use dynamic_gus::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.get_usize("n", 15_000);
    let uploads = args.get_usize("uploads", 2_000);
    let batch_mins = args.get_f64("batch-mins", 60.0);
    let uploads_per_min = args.get_f64("uploads-per-min", 20.0);
    let k = args.get_usize("k", 10);

    println!("== Android Security: dynamic PHA detection ==");
    // App store: products_like (embedding = code/behavior vector, tokens =
    // permissions/API calls). Latent clusters = app families. Same corpus
    // spec as the `android_security` load scenario (`gus loadgen`).
    let corpus_spec = CorpusSpec::new("products_like", n, 0x5ec, k);
    let ds = corpus_spec.generate()?;
    let n_clusters = ds.cluster_of.iter().copied().max().unwrap_or(0) as usize + 1;

    // Seed ~10% of families as malware families; known apps in those
    // families are labeled harmful (the team's existing verdicts).
    let mut rng = Rng::seeded(0xbad);
    let mut is_malware_family = vec![false; n_clusters];
    for f in is_malware_family.iter_mut() {
        *f = rng.chance(0.10);
    }

    let split = n - uploads;
    let corpus = &ds.points[..split];
    let stream = &ds.points[split..];

    println!(
        "store: {} known apps ({} families, {} malware families); {} live uploads",
        corpus.len(),
        n_clusters,
        is_malware_family.iter().filter(|&&b| b).count(),
        stream.len()
    );

    let gus = DynamicGus::bootstrap(ds.schema.clone(), corpus_spec.gus_config(), corpus, 8)?;

    // Known verdicts: every corpus app in a malware family.
    let verdict = |idx: usize| is_malware_family[ds.cluster_of[idx] as usize];

    // --- live stream ---
    let mut tp = 0u64;
    let mut fp = 0u64;
    let mut fn_ = 0u64;
    let mut tn = 0u64;
    let mut improvements_min: Vec<f64> = Vec::new();
    for (i, app) in stream.iter().enumerate() {
        let upload_min = i as f64 / uploads_per_min;
        // Dynamic path: query neighborhood, then insert (order irrelevant —
        // freshness is immediate either way).
        let neighbors = gus.query(app, k)?;
        gus.insert(app.clone())?;
        // Weighted vote over known-verdict neighbors.
        let mut risk = 0.0f64;
        let mut mass = 0.0f64;
        for nb in &neighbors {
            if (nb.id as usize) < split {
                mass += nb.score as f64;
                if verdict(nb.id as usize) {
                    risk += nb.score as f64;
                }
            }
        }
        let flagged = mass > 0.0 && risk / mass > 0.5;
        let truth = verdict(app.id as usize);
        match (flagged, truth) {
            (true, true) => {
                tp += 1;
                // Batch baseline detects at the next rebuild boundary.
                let batch_detect_min = (upload_min / batch_mins).floor() * batch_mins + batch_mins;
                improvements_min.push(batch_detect_min - upload_min);
            }
            (true, false) => fp += 1,
            (false, true) => fn_ += 1,
            (false, false) => tn += 1,
        }
    }

    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fn_).max(1) as f64;
    println!("\nresults over {} uploads:", stream.len());
    println!("  kNN-vote detection: precision {precision:.3}, recall {recall:.3} (tp={tp} fp={fp} fn={fn_} tn={tn})");
    if !improvements_min.is_empty() {
        improvements_min.sort_by(|a, b| a.total_cmp(b));
        let med = improvements_min[improvements_min.len() / 2];
        let mean: f64 =
            improvements_min.iter().sum::<f64>() / improvements_min.len() as f64;
        // Dynamic detection latency ≈ query latency (ms); batch ≈ med minutes.
        let ql = gus.metrics.query_latency.summary();
        println!(
            "  detection latency: dynamic = {:.1} ms (query p50); batch rebuild = {:.0} min median wait",
            ql.p50_ns as f64 / 1e6,
            med
        );
        println!(
            "  => harmful apps detected a median {med:.0} min (mean {mean:.0} min) sooner than the {batch_mins:.0}-min batch pipeline"
        );
        println!(
            "     (paper §1.1 reports a 4x detection-latency reduction in production, where the \
             baseline itself was already incremental; against a pure batch rebuild the dynamic \
             path's win is bounded only by the rebuild period)"
        );
    }
    Ok(())
}
