//! Recommendation-system scenario: "finding similar items in
//! recommendation systems with thousands of new entities per second" (§1).
//!
//! A product catalog (products_like schema) receives a continuous stream of
//! new listings; for every new product the service returns related items
//! immediately (the "customers also considered" shelf). This example
//! drives Dynamic GUS through the TCP RPC server — the full wire path —
//! with several concurrent client threads, and reports:
//!
//! - end-to-end RPC latency percentiles (client-observed, including JSON +
//!   TCP) vs in-process service latency;
//! - sustained mutation + query throughput over the run;
//! - shelf quality: fraction of recommended items from the product's
//!   latent category.
//!
//! Run: cargo run --release --example recsys_stream -- [--n 10000] [--clients 4]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use dynamic_gus::client::GusClient;
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::loadgen::scenario::CorpusSpec;
use dynamic_gus::metrics::LatencyHistogram;
use dynamic_gus::server::{serve, ServerConfig};
use dynamic_gus::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.get_usize("n", 10_000);
    let n_clients = args.get_usize("clients", 4);
    let per_client = args.get_usize("per-client", 250);
    let k = args.get_usize("k", 10);

    println!("== RecSys stream over the RPC server ==");
    // Same corpus spec as the `recsys_stream` load scenario (`gus loadgen`).
    let corpus_spec = CorpusSpec::new("products_like", n, 0x0ec, k);
    let ds = corpus_spec.generate()?;
    let held_out = n_clients * per_client;
    let corpus = &ds.points[..n - held_out];

    let gus =
        Arc::new(DynamicGus::bootstrap(ds.schema.clone(), corpus_spec.gus_config(), corpus, 8)?);
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::default())?;
    let addr = handle.addr.to_string();
    println!("serving {} products on {addr}", corpus.len());

    // Concurrent "merchant" clients: insert a new listing, immediately ask
    // for its shelf, check the category.
    let rpc_latency = Arc::new(LatencyHistogram::new());
    let hits = Arc::new(AtomicU64::new(0));
    let total = Arc::new(AtomicU64::new(0));
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let addr = addr.clone();
            let ds = &ds;
            let rpc_latency = Arc::clone(&rpc_latency);
            let hits = Arc::clone(&hits);
            let total = Arc::clone(&total);
            s.spawn(move || {
                let mut client = GusClient::connect(&addr).expect("connect");
                let base = n - held_out + c * per_client;
                for i in 0..per_client {
                    let p = &ds.points[base + i];
                    let t = std::time::Instant::now();
                    client.insert(p).expect("insert");
                    let shelf = client.query_id(p.id, k).expect("query");
                    rpc_latency.record(t.elapsed());
                    let cat = ds.cluster_of[p.id as usize];
                    for item in shelf {
                        total.fetch_add(1, Ordering::Relaxed);
                        if ds
                            .cluster_of
                            .get(item.id as usize)
                            .map(|&cc| cc == cat)
                            .unwrap_or(false)
                        {
                            hits.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();

    let listings = (n_clients * per_client) as f64;
    println!("\nresults:");
    println!(
        "  {} listings over {:.1}s with {n_clients} concurrent clients = {:.0} listing+shelf pairs/s",
        listings,
        wall.as_secs_f64(),
        listings / wall.as_secs_f64()
    );
    let rl = rpc_latency.summary();
    println!(
        "  client-observed insert+query RPC: p50 {:.2} ms  p99 {:.2} ms (incl. JSON + TCP)",
        rl.p50_ns as f64 / 1e6,
        rl.p99_ns as f64 / 1e6
    );
    let ql = gus.metrics.query_latency.summary();
    println!(
        "  in-process query latency:         p50 {:.2} ms  p99 {:.2} ms",
        ql.p50_ns as f64 / 1e6,
        ql.p99_ns as f64 / 1e6
    );
    let h = hits.load(Ordering::Relaxed);
    let t = total.load(Ordering::Relaxed).max(1);
    println!(
        "  shelf quality: {:.1}% of recommended items share the listing's category ({h}/{t})",
        100.0 * h as f64 / t as f64
    );
    handle.shutdown();
    Ok(())
}
