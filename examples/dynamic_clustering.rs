//! Downstream graph mining on the dynamic graph: clustering + label
//! propagation (the paper's §1: the computed neighborhoods "enable more
//! involved graph mining algorithms, including ... Clustering, Label
//! Propagation, and GNNs").
//!
//! Builds the neighborhood graph through Dynamic GUS queries, then:
//!
//! 1. **Connected-component clustering** over edges with score ≥ τ —
//!    compared against the latent clusters (adjusted match rate);
//! 2. **Label propagation**: seed 2% of points with their true cluster
//!    label, propagate over the weighted graph, report accuracy on the
//!    unlabeled rest;
//! 3. re-runs both after a burst of live mutations (new points appear in
//!    existing clusters) to show the graph stays mine-able under churn.
//!
//! Run: cargo run --release --example dynamic_clustering -- [--n 8000]

use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::Dataset;
use dynamic_gus::loadgen::scenario::CorpusSpec;
use dynamic_gus::graph::Graph;
use dynamic_gus::util::cli::Args;
use dynamic_gus::util::hash::FxHashMap;
use dynamic_gus::util::rng::Rng;

fn build_graph(gus: &DynamicGus, ds: &Dataset, ids: &[u64], k: usize, tau: f32) -> Graph {
    let mut g = Graph::new();
    for &id in ids {
        g.add_node(id);
        let Ok(neighbors) = gus.query(&ds.points[id as usize], k) else {
            continue;
        };
        for nb in neighbors {
            if nb.score >= tau && id < nb.id {
                g.add_edge(id, nb.id, nb.score);
            }
        }
    }
    g
}

fn cluster_agreement(g: &Graph, ds: &Dataset) -> f64 {
    // For each graph component, its purity-weighted share: how well do
    // components recover latent clusters?
    let cc = g.connected_components();
    let mut by_comp: FxHashMap<usize, Vec<u64>> = FxHashMap::default();
    for (&id, &comp) in &cc {
        by_comp.entry(comp).or_default().push(id);
    }
    let mut agree = 0usize;
    let mut total = 0usize;
    for members in by_comp.values() {
        let mut counts: FxHashMap<u32, usize> = FxHashMap::default();
        for &id in members {
            *counts.entry(ds.cluster_of[id as usize]).or_insert(0) += 1;
        }
        let majority = counts.values().copied().max().unwrap_or(0);
        agree += majority;
        total += members.len();
    }
    agree as f64 / total.max(1) as f64
}

fn label_prop_accuracy(g: &Graph, ds: &Dataset, seed_frac: f64, rng: &mut Rng) -> f64 {
    let ids: Vec<u64> = g.nodes().collect();
    let mut seeds: FxHashMap<u64, u32> = FxHashMap::default();
    for &id in &ids {
        if rng.chance(seed_frac) {
            seeds.insert(id, ds.cluster_of[id as usize]);
        }
    }
    let labels = g.label_propagation(&seeds, 10);
    let mut correct = 0usize;
    let mut total = 0usize;
    for &id in &ids {
        if seeds.contains_key(&id) {
            continue;
        }
        if let Some(&l) = labels.get(&id) {
            total += 1;
            if l == ds.cluster_of[id as usize] {
                correct += 1;
            }
        }
    }
    correct as f64 / total.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.get_usize("n", 8_000);
    let k = args.get_usize("k", 10);
    let tau = args.get_f64("tau", 0.7) as f32;

    println!("== Dynamic graph mining: clustering + label propagation ==");
    // Same corpus spec as the `dynamic_clustering` load scenario
    // (`gus loadgen`).
    let corpus_spec = CorpusSpec::new("arxiv_like", n, 0xc1, k);
    let ds = corpus_spec.generate()?;
    let burst = n / 10;
    let corpus_ids: Vec<u64> = (0..(n - burst) as u64).collect();
    let gus = DynamicGus::bootstrap(
        ds.schema.clone(),
        corpus_spec.gus_config(),
        &ds.points[..n - burst],
        8,
    )?;

    println!("[1] building neighborhood graph (k={k}, tau={tau})...");
    let g = build_graph(&gus, &ds, &corpus_ids, k, tau);
    println!("    {} nodes, {} edges", g.n_nodes(), g.n_edges());
    let agree = cluster_agreement(&g, &ds);
    let mut rng = Rng::seeded(0x5eed);
    let lp = label_prop_accuracy(&g, &ds, 0.02, &mut rng);
    println!("    component/cluster agreement: {:.1}%", agree * 100.0);
    println!("    label propagation accuracy (2% seeds): {:.1}%", lp * 100.0);

    println!("[2] applying a burst of {} live inserts...", burst);
    for p in &ds.points[n - burst..] {
        gus.insert(p.clone())?;
    }
    let all_ids: Vec<u64> = (0..n as u64).collect();
    let g2 = build_graph(&gus, &ds, &all_ids, k, tau);
    println!("    {} nodes, {} edges", g2.n_nodes(), g2.n_edges());
    let agree2 = cluster_agreement(&g2, &ds);
    let lp2 = label_prop_accuracy(&g2, &ds, 0.02, &mut rng);
    println!("    component/cluster agreement: {:.1}%", agree2 * 100.0);
    println!("    label propagation accuracy: {:.1}%", lp2 * 100.0);
    println!(
        "    (new points absorbed without rebuild; mutation p95 {:.3} ms)",
        gus.metrics.mutation_latency.summary().p95_ns as f64 / 1e6
    );

    anyhow::ensure!(agree2 > 0.5, "clustering collapsed after churn");
    Ok(())
}
