import os
import sys

# Allow running pytest from the repo root: make `compile.*` importable.
sys.path.insert(0, os.path.dirname(__file__))
