"""L1 kernel structure analysis: block-size sweep + VMEM/MXU estimates.

`interpret=True` wallclock is NOT a TPU proxy (it measures the HLO
while-loop the interpreter lowers to) — but it does expose the grid-step
*overhead* structure, and the VMEM/MXU table is computed analytically from
the BlockSpec. This script documents the `block_b = min(B, 512)` choice in
aot.py and DESIGN.md §Perf.

Run: cd python && python -m compile.bench_kernel
"""

from __future__ import annotations

import time

import jax
import numpy as np

from compile.kernels.scorer_kernel import pallas_score
from compile.model import ARXIV, HIDDEN, SchemaSpec

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on recent TPUs


def make_args(spec: SchemaSpec, b: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    d, ke, h = spec.dense_dim, spec.extra_dim, HIDDEN
    return (
        rng.normal(size=(d,)).astype(np.float32),
        rng.normal(size=(b, d)).astype(np.float32),
        rng.normal(size=(b, ke)).astype(np.float32),
        (rng.normal(size=(d, h)) * 0.1).astype(np.float32),
        (rng.normal(size=(d, h)) * 0.1).astype(np.float32),
        (rng.normal(size=(ke, h)) * 0.1).astype(np.float32),
        np.zeros(h, np.float32),
        (rng.normal(size=(h, h)) * 0.1).astype(np.float32),
        np.zeros(h, np.float32),
        (rng.normal(size=(h,)) * 0.1).astype(np.float32),
        np.float32(0.0),
    )


def vmem_estimate(spec: SchemaSpec, block_b: int) -> dict:
    """Per-grid-step VMEM residency for the BlockSpec in scorer_kernel."""
    d, ke, h = spec.dense_dim, spec.extra_dim, HIDDEN
    f = 4  # f32 bytes
    tile = block_b * d * f + block_b * ke * f  # C tile + E tile
    weights = (2 * d * h + ke * h + 3 * h + h * h) * f + d * f  # + q
    out = block_b * f
    total = tile + weights + out
    return {
        "tile_bytes": tile,
        "weights_bytes": weights,
        "total_bytes": total,
        "fits_double_buffered": 2 * tile + weights + out < VMEM_BYTES,
        # Arithmetic intensity: FLOPs per byte of streamed candidate tile.
        "flops_per_cand_byte": (2 * (2 * d + ke) * h + 2 * h * h + 2 * h)
        / ((d + ke) * f),
        "mxu_util_bound": h / 128.0,  # contraction width H vs 128x128 MXU
    }


def main() -> None:
    spec = ARXIV
    print(f"schema={spec.name} d={spec.dense_dim} ke={spec.extra_dim} H={HIDDEN}")
    print(f"{'B':>6} {'block':>6} {'steps':>6} {'ms/call':>9} {'tileKiB':>8} "
          f"{'2xbuf?':>7} {'AI':>6} {'MXUcap':>7}")
    for b in (128, 512, 2048):
        args = make_args(spec, b)
        for block in (32, 128, 512, 2048):
            if block > b:
                continue
            f = jax.jit(lambda *a, blk=block: pallas_score(*a, block_b=blk))
            f(*args).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(10):
                f(*args).block_until_ready()
            dt = (time.perf_counter() - t0) / 10
            est = vmem_estimate(spec, block)
            print(
                f"{b:>6} {block:>6} {b // block:>6} {dt * 1e3:>9.2f} "
                f"{est['tile_bytes'] / 1024:>8.0f} "
                f"{str(est['fits_double_buffered']):>7} "
                f"{est['flops_per_cand_byte']:>6.1f} "
                f"{est['mxu_util_bound']:>6.1%}"
            )


if __name__ == "__main__":
    main()
