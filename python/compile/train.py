"""Offline model training (§4.3: "train a model ... in the same fashion as
in Grale", periodically retrainable).

Trains the paper's 2-layer/10-unit MLP on balanced synthetic similarity
pairs with Adam + binary cross-entropy, and exports weights as JSON for the
Rust runtime (``artifacts/weights_<schema>.json``). Runs once at `make
artifacts`; a production deployment would re-run it periodically and hot-
swap the JSON (the Rust side passes weights as execute-time buffers, so no
HLO recompilation is needed).
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import datagen
from compile.kernels import ref
from compile.model import HIDDEN, SCHEMAS, SchemaSpec, weights_to_json


def init_params(input_dim: int, hidden: int, seed: int):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    s1 = (2.0 / (input_dim + hidden)) ** 0.5
    s2 = (2.0 / (2 * hidden)) ** 0.5
    return {
        "w1": jax.random.normal(k1, (input_dim, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, hidden)) * s2,
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(k3, (hidden,)) * s2,
        "b3": jnp.zeros(()),
    }


def bce_loss(params, x, y):
    logits = ref.mlp_logits(
        x, params["w1"], params["b1"], params["w2"], params["b2"],
        params["w3"], params["b3"],
    )
    # Stable BCE-with-logits.
    return jnp.mean(jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


@functools.partial(jax.jit, static_argnames=("lr",))
def adam_step(params, m, v, t, x, y, lr=1e-3):
    b1m, b2m, eps = 0.9, 0.999, 1e-8
    loss, grads = jax.value_and_grad(bce_loss)(params, x, y)
    m = jax.tree.map(lambda a, g: b1m * a + (1 - b1m) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2m * a + (1 - b2m) * g * g, v, grads)
    mhat = jax.tree.map(lambda a: a / (1 - b1m**t), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2m**t), v)
    params = jax.tree.map(
        lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mhat, vhat
    )
    return params, m, v, loss


def train(
    spec: SchemaSpec,
    n_pairs: int = 40_000,
    steps: int = 1500,
    batch: int = 256,
    seed: int = 0,
    verbose: bool = True,
):
    """Train and return (params, metrics)."""
    x, y = datagen.make_pairs(spec, n_pairs, seed)
    n_train = int(0.9 * len(y))
    x_train, y_train = x[:n_train], y[:n_train]
    x_val, y_val = x[n_train:], y[n_train:]

    params = init_params(spec.input_dim, HIDDEN, seed)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed + 7)
    loss = None
    for t in range(1, steps + 1):
        idx = rng.integers(0, n_train, size=batch)
        params, m, v, loss = adam_step(params, m, v, t, x_train[idx], y_train[idx])
        if verbose and t % 500 == 0:
            print(f"  [{spec.name}] step {t}: loss {float(loss):.4f}")

    scores = ref.mlp_apply(
        x_val, params["w1"], params["b1"], params["w2"], params["b2"],
        params["w3"], params["b3"],
    )
    acc = float(jnp.mean((scores > 0.5) == (y_val > 0.5)))
    auc = _auc(np.asarray(scores), np.asarray(y_val))
    metrics = {"val_acc": acc, "val_auc": auc, "final_loss": float(loss)}
    if verbose:
        print(f"  [{spec.name}] val acc {acc:.3f}, val auc {auc:.3f}")
    return params, metrics


def _auc(scores: np.ndarray, labels: np.ndarray) -> float:
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels > 0.5
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--pairs", type=int, default=40_000)
    ap.add_argument("--schemas", default="arxiv_like,products_like")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name in args.schemas.split(","):
        spec = SCHEMAS[name]
        print(f"training {name} (D={spec.input_dim}, H={HIDDEN})")
        params, metrics = train(spec, n_pairs=args.pairs, steps=args.steps)
        assert metrics["val_auc"] > 0.75, f"{name}: model failed to learn: {metrics}"
        path = os.path.join(args.out_dir, f"weights_{name}.json")
        with open(path, "w") as f:
            f.write(
                weights_to_json(
                    spec, params["w1"], params["b1"], params["w2"],
                    params["b2"], params["w3"], params["b3"],
                )
            )
        print(f"wrote {path} ({metrics})")


if __name__ == "__main__":
    main()
