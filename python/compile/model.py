"""L2: the JAX scorer model (build-time only; never on the request path).

Defines the schema contracts shared with the Rust coordinator (dense dim
``d``, extras width ``ke``, hidden width H=10 — the paper's architecture)
and the jittable inference graph ``scorer_fn`` that calls the L1 Pallas
kernel. ``aot.py`` lowers this graph to HLO text per (schema, batch)
variant; the Rust runtime executes it via PJRT.

Graph signature (frozen contract with rust/src/scorer/xla.rs):

    scorer(q[d], C[B,d], E[B,ke],
           w1p[d,H], w1d[d,H], w1e[ke,H], b1[H], w2[H,H], b2[H], w3[H], b3[])
      -> scores[B]
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.scorer_kernel import pallas_score

# The paper's model: two layers, 10 hidden units per layer.
HIDDEN = 10

# Candidate batch variants compiled AOT (must match BATCH_SIZES in
# rust/src/scorer/xla.rs).
BATCH_SIZES = (32, 128, 512, 2048)


@dataclasses.dataclass(frozen=True)
class SchemaSpec:
    """Scorer-relevant shape info for one dataset schema."""

    name: str
    dense_dim: int
    extra_dim: int

    @property
    def input_dim(self) -> int:
        return 2 * self.dense_dim + self.extra_dim


# Mirrors rust features::Schema::{arxiv_like, products_like} and
# scorer::featurize extras: arxiv = scalar year (1 extra); products =
# co-purchase tokens (jaccard + log-intersection = 2 extras).
ARXIV = SchemaSpec(name="arxiv_like", dense_dim=128, extra_dim=1)
PRODUCTS = SchemaSpec(name="products_like", dense_dim=100, extra_dim=2)
SCHEMAS = {s.name: s for s in (ARXIV, PRODUCTS)}


def scorer_fn(q, c, e, w1p, w1d, w1e, b1, w2, b2, w3, b3, *, block_b=None):
    """The L2 graph: delegates the fused compute to the Pallas kernel."""
    kwargs = {} if block_b is None else {"block_b": block_b}
    return (pallas_score(q, c, e, w1p, w1d, w1e, b1, w2, b2, w3, b3, **kwargs),)


def scorer_ref_fn(q, c, e, w1p, w1d, w1e, b1, w2, b2, w3, b3):
    """Reference graph (materialized phi) — for tests and ablations."""
    return (ref.ref_score(q, c, e, w1p, w1d, w1e, b1, w2, b2, w3, b3),)


def example_args(spec: SchemaSpec, batch: int, hidden: int = HIDDEN):
    """ShapeDtypeStructs for lowering one variant."""
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    d, ke = spec.dense_dim, spec.extra_dim
    return (
        sd((d,), f32),  # q
        sd((batch, d), f32),  # C
        sd((batch, ke), f32),  # E
        sd((d, hidden), f32),  # w1p
        sd((d, hidden), f32),  # w1d
        sd((ke, hidden), f32),  # w1e
        sd((hidden,), f32),  # b1
        sd((hidden, hidden), f32),  # w2
        sd((hidden,), f32),  # b2
        sd((hidden,), f32),  # w3
        sd((), f32),  # b3
    )


def split_w1(w1, spec: SchemaSpec):
    """Split a full [D, H] W1 into the kernel's (w1p, w1d, w1e) blocks."""
    d, ke = spec.dense_dim, spec.extra_dim
    assert w1.shape[0] == 2 * d + ke, (w1.shape, spec)
    return w1[:d], w1[d : 2 * d], w1[2 * d :]


def weights_to_json(spec: SchemaSpec, w1, b1, w2, b2, w3, b3) -> str:
    """Serialize weights in the format rust's MlpWeights::load expects.

    W1 is stored row-major as [input_dim][hidden] — numpy C-order flatten of
    a [D, H] array matches.
    """
    import json

    def flat(a):
        return [float(x) for x in jnp.asarray(a, jnp.float32).reshape(-1)]

    return json.dumps(
        {
            "input_dim": spec.input_dim,
            "hidden": int(b1.shape[0]),
            "w1": flat(w1),
            "b1": flat(b1),
            "w2": flat(w2),
            "b2": flat(b2),
            "w3": flat(w3),
            "b3": float(jnp.asarray(b3).reshape(())),
        }
    )
