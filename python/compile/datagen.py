"""Synthetic training data for the pairwise similarity model.

Mirrors the generative family of ``rust/src/data/synthetic.rs`` (the
cross-language contract is the *distribution*, not bitwise identity — the
offline-trained model must generalize to the Rust-generated serving data,
which it does because both draw from the same family):

- clusters with lognormal sizes; hierarchical centers (n_clusters/5 parent
  topics, cluster center = parent + 0.6*N(0,I)) so cross-cluster similarity
  is graded; unit-normalized Gaussian embeddings around the centers (noise
  sigma 0.55 / 0.5);
- arxiv_like: cluster base year in [1995, 2023] + N(0, 3);
- products_like: 3-12 tokens from a 40-token cluster pool + 2-8 Zipf(1.1)
  tokens from a global 2000-token popular pool (the junk mega-buckets that
  Filter-P exists to ban).

Training pairs: positives are same-cluster pairs, negatives are
cross-cluster pairs, balanced 50/50, with LABEL_NOISE of labels flipped —
production Grale trains on noisy weak labels, and the noise floor keeps the
model calibrated (graded scores) instead of saturating at 0/1, which is
what gives the paper-like edge-weight distributions. Features are computed
with the same formulas as rust/src/scorer/featurize.rs (golden-tested in
tests/test_featurize_contract.py).
"""

from __future__ import annotations

import numpy as np

from compile.model import ARXIV, PRODUCTS, SchemaSpec

SCALAR_SCALE = 10.0  # rust scorer::featurize::SCALAR_SCALE

# Fraction of training labels flipped (weak-label noise floor).
LABEL_NOISE = 0.1


def make_dataset(spec: SchemaSpec, n_points: int, seed: int):
    """Returns (dense [n,d], extras_raw, cluster [n]).

    extras_raw: for arxiv, years [n]; for products, a list of token sets.
    """
    rng = np.random.default_rng(seed)
    d = spec.dense_dim
    n_clusters = max(4, n_points // 200 if spec.name == "arxiv_like" else n_points // 150)

    weights = rng.lognormal(0.0, 1.0, size=n_clusters)
    sizes = np.floor(weights / weights.sum() * n_points).astype(int)
    while sizes.sum() < n_points:
        sizes[rng.integers(0, n_clusters)] += 1

    n_parents = max(1, n_clusters // 5)
    parents = rng.normal(size=(n_parents, d))
    centers = parents[np.arange(n_clusters) % n_parents] + 0.6 * rng.normal(
        size=(n_clusters, d)
    )
    noise = 0.55 if spec.name == "arxiv_like" else 0.5
    base_years = 1995 + rng.integers(0, 29, size=n_clusters)

    dense, clusters = [], []
    years, token_sets = [], []
    for c, size in enumerate(sizes):
        x = centers[c][None, :] + noise * rng.normal(size=(size, d))
        x = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
        dense.append(x)
        clusters.extend([c] * size)
        if spec.name == "arxiv_like":
            y = np.clip(base_years[c] + 3.0 * rng.normal(size=size), 1995, 2023)
            years.extend(y.tolist())
        else:
            pool = 1_000_000 + c * 1000 + np.arange(40)
            for _ in range(size):
                n_tok = rng.integers(3, 13)
                toks = set(rng.choice(pool, size=min(n_tok, 40), replace=False).tolist())
                n_pop = rng.integers(2, 9)
                for _ in range(n_pop):
                    # Zipf-ish rank over the 2000-token popular pool.
                    r = min(int(rng.zipf(1.1)), 2000)
                    toks.add(r)
                token_sets.append(toks)
    dense = np.concatenate(dense, axis=0).astype(np.float32)
    clusters = np.asarray(clusters)
    if spec.name == "arxiv_like":
        return dense, np.asarray(years, np.float32), clusters
    return dense, token_sets, clusters


def pair_extras(spec: SchemaSpec, extras_raw, i: int, j: int) -> list[float]:
    """Extra features for a pair — same formulas as the rust featurizer."""
    if spec.name == "arxiv_like":
        return [abs(float(extras_raw[i]) - float(extras_raw[j])) / SCALAR_SCALE]
    a, b = extras_raw[i], extras_raw[j]
    inter = len(a & b)
    union = len(a | b)
    jaccard = inter / union if union else 0.0
    return [jaccard, float(np.log1p(inter))]


def make_pairs(spec: SchemaSpec, n_pairs: int, seed: int, n_points: int = 4000):
    """Balanced labeled pairs: returns (phi [n,D], labels [n]).

    phi layout matches kernels.ref.phi: [q*c, |q-c|, extras].
    """
    dense, extras_raw, clusters = make_dataset(spec, n_points, seed)
    rng = np.random.default_rng(seed + 1)
    n = len(clusters)
    by_cluster: dict[int, np.ndarray] = {
        c: np.flatnonzero(clusters == c) for c in np.unique(clusters)
    }
    multi = [c for c, idx in by_cluster.items() if len(idx) >= 2]

    feats = np.empty((n_pairs, spec.input_dim), np.float32)
    labels = np.empty(n_pairs, np.float32)
    for row in range(n_pairs):
        positive = row % 2 == 0
        if positive:
            c = multi[rng.integers(0, len(multi))]
            i, j = rng.choice(by_cluster[c], size=2, replace=False)
        else:
            while True:
                i, j = rng.integers(0, n, size=2)
                if clusters[i] != clusters[j]:
                    break
        qi, cj = dense[i], dense[j]
        ex = pair_extras(spec, extras_raw, int(i), int(j))
        feats[row, : spec.dense_dim] = qi * cj
        feats[row, spec.dense_dim : 2 * spec.dense_dim] = np.abs(qi - cj)
        feats[row, 2 * spec.dense_dim :] = ex
        label = 1.0 if positive else 0.0
        if rng.random() < LABEL_NOISE:
            label = 1.0 - label
        labels[row] = label
    return feats, labels


if __name__ == "__main__":
    for spec in (ARXIV, PRODUCTS):
        x, y = make_pairs(spec, 1000, 0)
        print(spec.name, x.shape, y.mean())
