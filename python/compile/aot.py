"""AOT lowering: JAX scorer graph -> HLO text artifacts for the Rust runtime.

Emits, per schema (arxiv_like, products_like) and per candidate-batch
variant B in (32, 128, 512, 2048):

    artifacts/scorer_<schema>_b<B>.hlo.txt

plus (with --train) the trained weights ``artifacts/weights_<schema>.json``.

HLO *text* is the interchange format — NOT ``lowered.compiler_ir("hlo")
.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and aot_recipe.md).
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile.model import BATCH_SIZES, SCHEMAS, example_args, scorer_fn


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(spec, batch: int) -> str:
    # Tile size per variant: interpret-mode grid steps lower to an HLO
    # while-loop with dynamic-slice bookkeeping whose per-step overhead
    # dominates at small tiles (measured: B=2048 goes 12.3ms -> 1.5ms when
    # the tile grows 32 -> 512; see EXPERIMENTS.md §Perf). 512 keeps the
    # per-tile VMEM footprint at 512·d·4B ≈ 256 KiB for d=128 — comfortably
    # inside a TPU core's ~16 MiB VMEM with double buffering.
    block_b = min(batch, 512)
    fn = lambda *args: scorer_fn(*args, block_b=block_b)  # noqa: E731
    lowered = jax.jit(fn).lower(*example_args(spec, batch))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--schemas", default="arxiv_like,products_like")
    ap.add_argument(
        "--batches", default=",".join(str(b) for b in BATCH_SIZES)
    )
    ap.add_argument("--train", action="store_true", help="also train weights")
    ap.add_argument("--train-steps", type=int, default=1500)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    batches = [int(b) for b in args.batches.split(",")]
    for name in args.schemas.split(","):
        spec = SCHEMAS[name]
        for b in batches:
            text = lower_variant(spec, b)
            path = os.path.join(args.out_dir, f"scorer_{name}_b{b}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    if args.train:
        from compile import train as train_mod

        from compile.model import weights_to_json

        for name in args.schemas.split(","):
            spec = SCHEMAS[name]
            params, metrics = train_mod.train(spec, steps=args.train_steps)
            assert metrics["val_auc"] > 0.75, f"{name}: {metrics}"
            path = os.path.join(args.out_dir, f"weights_{name}.json")
            with open(path, "w") as f:
                f.write(
                    weights_to_json(
                        spec, params["w1"], params["b1"], params["w2"],
                        params["b2"], params["w3"], params["b3"],
                    )
                )
            print(f"wrote {path} ({metrics})", file=sys.stderr)


if __name__ == "__main__":
    main()
