"""L1: fused pairwise-featurize + 2-layer-MLP Pallas kernel.

The similarity scorer is Dynamic GUS's only dense-compute hot spot: for each
neighborhood query, the retrieved candidate set (ScaNN-NN rows) is scored
against the query point by the paper's model (a 2-layer MLP, 10 hidden units
per layer, over pairwise features).

The pairwise feature vector is

    phi(q, c) = [ q * c, |q - c|, extras ]          (width 2*d + ke)

This kernel never materializes phi in HBM: each grid step loads one
``(BLOCK_B, d)`` tile of candidates into VMEM, forms the product/abs-diff
terms in registers, and contracts them directly against the row-blocks of
W1 (W1p for the product block, W1d for the difference block, W1e for the
extras) — a 2x HBM-traffic saving over materializing the ``(B, 2d+ke)``
feature matrix at d=128. The MLP weights (~11 KiB at H=10) stay resident in
VMEM across all grid steps.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the matmuls use
``preferred_element_type=float32`` so they lower onto the MXU; the candidate
tile is the unit of HBM->VMEM streaming expressed via BlockSpec (the role
threadblock tiling would play in a CUDA formulation). ``interpret=True`` is
mandatory here: the CPU PJRT plugin cannot execute Mosaic custom-calls, and
interpret-mode lowering produces plain HLO that both pytest and the Rust
runtime run bit-identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile of candidates processed per grid step. 32 divides every AOT batch
# variant (32/128/512/2048) and keeps the VMEM footprint tiny (32*128 floats
# = 16 KiB for the candidate tile at d=128).
BLOCK_B = 32


def _scorer_kernel(
    q_ref,
    c_ref,
    e_ref,
    w1p_ref,
    w1d_ref,
    w1e_ref,
    b1_ref,
    w2_ref,
    b2_ref,
    w3_ref,
    b3_ref,
    o_ref,
):
    """One grid step: score BLOCK_B candidates against the query."""
    q = q_ref[...]  # [d]
    c = c_ref[...]  # [BLOCK_B, d]
    e = e_ref[...]  # [BLOCK_B, ke]

    prod = c * q[None, :]
    diff = jnp.abs(c - q[None, :])

    # z1 = phi @ W1 + b1, computed blockwise so phi never exists.
    z1 = (
        jnp.dot(prod, w1p_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(diff, w1d_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(e, w1e_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...][None, :]
    )
    z1 = jnp.maximum(z1, 0.0)
    z2 = (
        jnp.dot(z1, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...][None, :]
    )
    z2 = jnp.maximum(z2, 0.0)
    logit = jnp.dot(z2, w3_ref[...], preferred_element_type=jnp.float32) + b3_ref[0]
    o_ref[...] = jax.nn.sigmoid(logit)


@functools.partial(jax.jit, static_argnames=("block_b",))
def pallas_score(q, c, e, w1p, w1d, w1e, b1, w2, b2, w3, b3, *, block_b=BLOCK_B):
    """Score a batch of candidates against a query point.

    Args:
      q:   [d]      query dense features.
      c:   [B, d]   candidate dense features; B % block_b == 0.
      e:   [B, ke]  per-pair extra features (tokens/scalar channels).
      w1p: [d, H]   W1 rows for the product block.
      w1d: [d, H]   W1 rows for the |difference| block.
      w1e: [ke, H]  W1 rows for the extras block.
      b1:  [H]; w2: [H, H]; b2: [H]; w3: [H]; b3: [] or [1].

    Returns:
      [B] similarity scores in (0, 1).
    """
    b, d = c.shape
    ke = e.shape[1]
    h = b1.shape[0]
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b {block_b}")
    b3v = jnp.reshape(b3, (1,)).astype(jnp.float32)

    grid = (b // block_b,)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: tuple(0 for _ in dims))
    return pl.pallas_call(
        _scorer_kernel,
        grid=grid,
        in_specs=[
            full(d),  # q broadcast to every step
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),  # candidate tile
            pl.BlockSpec((block_b, ke), lambda i: (i, 0)),  # extras tile
            full(d, h),
            full(d, h),
            full(ke, h),
            full(h),
            full(h, h),
            full(h),
            full(h),
            full(1),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        q.astype(jnp.float32),
        c.astype(jnp.float32),
        e.astype(jnp.float32),
        w1p.astype(jnp.float32),
        w1d.astype(jnp.float32),
        w1e.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
        w3.astype(jnp.float32),
        b3v,
    )
