"""Pure-jnp oracle for the scorer kernel — the CORE correctness signal.

``ref_score`` materializes phi explicitly and applies the MLP with plain
jax.numpy ops; pytest asserts the Pallas kernel matches it to float32
tolerance across a hypothesis sweep of shapes. It is also the apply
function used by training (``train.py``) so the trained weights are, by
construction, weights for exactly this computation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def phi(q, c, e):
    """Materialized pairwise features: [B, 2d + ke].

    q: [d]; c: [B, d]; e: [B, ke].
    """
    prod = c * q[None, :]
    diff = jnp.abs(c - q[None, :])
    return jnp.concatenate([prod, diff, e], axis=1)


def mlp_apply(x, w1, b1, w2, b2, w3, b3):
    """score = sigmoid(relu(relu(x @ W1 + b1) @ W2 + b2) @ w3 + b3).

    x: [B, D]; w1: [D, H]; w2: [H, H]; w3: [H]; b3 scalar.
    """
    z1 = jnp.maximum(x @ w1 + b1[None, :], 0.0)
    z2 = jnp.maximum(z1 @ w2 + b2[None, :], 0.0)
    return jax.nn.sigmoid(z2 @ w3 + b3)


def mlp_logits(x, w1, b1, w2, b2, w3, b3):
    """Pre-sigmoid logits (numerically stable BCE in training)."""
    z1 = jnp.maximum(x @ w1 + b1[None, :], 0.0)
    z2 = jnp.maximum(z1 @ w2 + b2[None, :], 0.0)
    return z2 @ w3 + b3


def ref_score(q, c, e, w1p, w1d, w1e, b1, w2, b2, w3, b3):
    """Same signature as ``pallas_score`` with split W1 blocks."""
    w1 = jnp.concatenate([w1p, w1d, w1e], axis=0)
    x = phi(q.astype(jnp.float32), c.astype(jnp.float32), e.astype(jnp.float32))
    return mlp_apply(
        x,
        w1.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
        w3.astype(jnp.float32),
        jnp.asarray(b3, jnp.float32).reshape(()),
    )
