"""Training pipeline sanity: data generation + quick training run."""

import numpy as np

from compile import datagen, train
from compile.model import ARXIV, PRODUCTS


def test_make_dataset_shapes():
    dense, years, clusters = datagen.make_dataset(ARXIV, 500, seed=0)
    assert dense.shape == (500, 128)
    assert years.shape == (500,)
    assert clusters.shape == (500,)
    # Unit-norm embeddings.
    norms = np.linalg.norm(dense, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
    assert years.min() >= 1995 and years.max() <= 2023


def test_make_dataset_products_tokens():
    dense, token_sets, clusters = datagen.make_dataset(PRODUCTS, 400, seed=1)
    assert dense.shape == (400, 100)
    assert len(token_sets) == 400
    assert all(len(t) >= 3 for t in token_sets)
    # Popular (global) tokens 1..50 appear somewhere.
    popular = sum(1 for t in token_sets for tok in t if tok <= 2000)
    assert popular > 50


def test_make_pairs_balanced_and_noisy():
    x, y = datagen.make_pairs(ARXIV, 2000, seed=2, n_points=1000)
    assert x.shape == (2000, ARXIV.input_dim)
    # Balanced up to the 10% label noise.
    assert 0.4 < y.mean() < 0.6
    assert np.isfinite(x).all()


def test_quick_training_learns():
    params, metrics = train.train(
        ARXIV, n_pairs=4000, steps=200, batch=128, seed=0, verbose=False
    )
    # 10% label noise caps achievable accuracy near 0.9.
    assert metrics["val_auc"] > 0.7, metrics
    assert metrics["final_loss"] < 0.69, "no better than chance"
    assert params["w1"].shape == (ARXIV.input_dim, 10)


def test_training_deterministic():
    _, m1 = train.train(ARXIV, n_pairs=2000, steps=50, seed=3, verbose=False)
    _, m2 = train.train(ARXIV, n_pairs=2000, steps=50, seed=3, verbose=False)
    assert m1["final_loss"] == m2["final_loss"]


def test_auc_helper():
    scores = np.array([0.9, 0.8, 0.3, 0.1])
    labels = np.array([1.0, 1.0, 0.0, 0.0])
    assert train._auc(scores, labels) == 1.0
    assert abs(train._auc(scores, labels[::-1]) - 0.0) < 1e-9
    assert train._auc(scores, np.ones(4)) == 0.5
