"""L2 model-level tests: graph shapes, lowering, weight export."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import (
    ARXIV,
    BATCH_SIZES,
    HIDDEN,
    PRODUCTS,
    SCHEMAS,
    example_args,
    scorer_fn,
    scorer_ref_fn,
    split_w1,
    weights_to_json,
)


def rand_weights(spec, seed=0):
    rng = np.random.default_rng(seed)
    d, ke, h = spec.dense_dim, spec.extra_dim, HIDDEN
    w1 = (rng.normal(size=(spec.input_dim, h)) * 0.1).astype(np.float32)
    return dict(
        w1=w1,
        b1=np.zeros(h, np.float32),
        w2=(rng.normal(size=(h, h)) * 0.1).astype(np.float32),
        b2=np.zeros(h, np.float32),
        w3=(rng.normal(size=(h,)) * 0.1).astype(np.float32),
        b3=np.float32(0.0),
    )


def test_schema_specs():
    assert ARXIV.input_dim == 2 * 128 + 1
    assert PRODUCTS.input_dim == 2 * 100 + 2
    assert set(SCHEMAS) == {"arxiv_like", "products_like"}


def test_split_w1_blocks():
    w = rand_weights(ARXIV)
    w1p, w1d, w1e = split_w1(w["w1"], ARXIV)
    assert w1p.shape == (128, HIDDEN)
    assert w1d.shape == (128, HIDDEN)
    assert w1e.shape == (1, HIDDEN)
    np.testing.assert_array_equal(np.concatenate([w1p, w1d, w1e]), w["w1"])


def test_scorer_fn_matches_ref_fn():
    spec = PRODUCTS
    rng = np.random.default_rng(1)
    w = rand_weights(spec, 1)
    w1p, w1d, w1e = split_w1(w["w1"], spec)
    b = 32
    args = (
        rng.normal(size=(spec.dense_dim,)).astype(np.float32),
        rng.normal(size=(b, spec.dense_dim)).astype(np.float32),
        rng.normal(size=(b, spec.extra_dim)).astype(np.float32),
        w1p, w1d, w1e, w["b1"], w["w2"], w["b2"], w["w3"], w["b3"],
    )
    (got,) = scorer_fn(*args)
    (want,) = scorer_ref_fn(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_example_args_shapes():
    args = example_args(ARXIV, 128)
    assert args[0].shape == (128,)
    assert args[1].shape == (128, 128)
    assert args[2].shape == (128, 1)
    assert args[-1].shape == ()


@pytest.mark.parametrize("spec", [ARXIV, PRODUCTS])
def test_lowering_produces_hlo_text(spec):
    text = aot.lower_variant(spec, BATCH_SIZES[0])
    assert text.startswith("HloModule")
    # The entry layout mentions the candidate matrix shape.
    assert f"f32[{BATCH_SIZES[0]},{spec.dense_dim}]" in text
    # Output is a 1-tuple of [B] scores.
    assert f"(f32[{BATCH_SIZES[0]}]" in text


def test_lowered_graph_is_executable_and_matches_ref():
    # Compile the lowered stablehlo via jax and compare numerics — guards
    # against lowering-time constant folding bugs.
    spec = ARXIV
    rng = np.random.default_rng(2)
    w = rand_weights(spec, 2)
    w1p, w1d, w1e = split_w1(w["w1"], spec)
    b = 32
    args = (
        rng.normal(size=(spec.dense_dim,)).astype(np.float32),
        rng.normal(size=(b, spec.dense_dim)).astype(np.float32),
        rng.normal(size=(b, spec.extra_dim)).astype(np.float32),
        w1p, w1d, w1e, w["b1"], w["w2"], w["b2"], w["w3"], w["b3"],
    )
    compiled = jax.jit(scorer_fn).lower(*example_args(spec, b)).compile()
    (got,) = compiled(*args)
    (want,) = scorer_ref_fn(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_weights_json_contract():
    spec = PRODUCTS
    w = rand_weights(spec, 3)
    text = weights_to_json(spec, w["w1"], w["b1"], w["w2"], w["b2"], w["w3"], w["b3"])
    j = json.loads(text)
    assert j["input_dim"] == spec.input_dim
    assert j["hidden"] == HIDDEN
    assert len(j["w1"]) == spec.input_dim * HIDDEN
    assert len(j["w2"]) == HIDDEN * HIDDEN
    # Row-major: first HIDDEN entries are w1[0, :].
    np.testing.assert_allclose(j["w1"][:HIDDEN], w["w1"][0], rtol=1e-6)
    assert isinstance(j["b3"], float)


def test_batch_sizes_match_rust_contract():
    # rust/src/scorer/xla.rs BATCH_SIZES
    assert BATCH_SIZES == (32, 128, 512, 2048)
    assert all(b % 32 == 0 for b in BATCH_SIZES)
