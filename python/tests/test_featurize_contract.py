"""Cross-language featurizer contract.

These GOLDEN values are mirrored bit-for-bit in
rust/src/scorer/featurize.rs::tests::{golden_arxiv_like,
golden_products_like}. If either side changes, both tests fail — the
trained weights are only valid for this exact feature map.
"""

import math

import numpy as np

from compile.datagen import SCALAR_SCALE, pair_extras
from compile.kernels.ref import phi
from compile.model import ARXIV, PRODUCTS, SchemaSpec


def test_scalar_scale_matches_rust():
    assert SCALAR_SCALE == 10.0


def test_golden_arxiv_like():
    q = np.array([1.0, -2.0, 0.5], np.float32)
    c = np.array([2.0, 1.0, 0.5], np.float32)
    years = np.array([2020.0, 2015.0], np.float32)
    ex = pair_extras(
        SchemaSpec(name="arxiv_like", dense_dim=3, extra_dim=1), years, 0, 1
    )
    full = phi(q, c[None, :], np.array([ex], np.float32))[0]
    np.testing.assert_allclose(
        np.asarray(full),
        # q*c               |q-c|           |Δyear|/10
        [2.0, -2.0, 0.25, 1.0, 3.0, 0.0, 0.5],
        rtol=1e-6,
    )


def test_golden_products_like():
    q = np.array([1.0, 0.0], np.float32)
    c = np.array([0.5, 0.5], np.float32)
    token_sets = [{10, 20, 30}, {20, 30, 40, 50}]
    ex = pair_extras(
        SchemaSpec(name="products_like", dense_dim=2, extra_dim=2), token_sets, 0, 1
    )
    full = np.asarray(phi(q, c[None, :], np.array([ex], np.float32))[0])
    np.testing.assert_allclose(full[:4], [0.5, 0.0, 0.5, 0.5], rtol=1e-6)
    assert abs(full[4] - 0.4) < 1e-6  # jaccard 2/5
    assert abs(full[5] - math.log(3.0)) < 1e-6  # ln(1 + |∩|)


def test_token_edge_cases_match_rust():
    spec = SchemaSpec(name="products_like", dense_dim=1, extra_dim=2)
    # Both empty: jaccard 0, log1p(0) = 0 (no NaN).
    assert pair_extras(spec, [set(), set()], 0, 1) == [0.0, 0.0]
    # Identical sets: jaccard 1.
    ex = pair_extras(spec, [{5}, {5}], 0, 1)
    assert abs(ex[0] - 1.0) < 1e-9


def test_input_dims_match_rust_schemas():
    # rust Schema::arxiv_like(128) -> featurizer input_dim 257;
    # products_like(100) -> 202.
    assert ARXIV.input_dim == 257
    assert PRODUCTS.input_dim == 202
