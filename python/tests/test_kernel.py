"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes (d, ke, B, H) and value distributions; every case
asserts allclose between ``pallas_score`` and ``ref_score``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_score
from compile.kernels.scorer_kernel import pallas_score


def make_case(rng, d, ke, b, h, scale=1.0):
    return dict(
        q=(rng.normal(size=(d,)) * scale).astype(np.float32),
        c=(rng.normal(size=(b, d)) * scale).astype(np.float32),
        e=(rng.normal(size=(b, ke)) * scale).astype(np.float32),
        w1p=(rng.normal(size=(d, h)) * 0.2).astype(np.float32),
        w1d=(rng.normal(size=(d, h)) * 0.2).astype(np.float32),
        w1e=(rng.normal(size=(ke, h)) * 0.2).astype(np.float32),
        b1=(rng.normal(size=(h,)) * 0.1).astype(np.float32),
        w2=(rng.normal(size=(h, h)) * 0.2).astype(np.float32),
        b2=(rng.normal(size=(h,)) * 0.1).astype(np.float32),
        w3=(rng.normal(size=(h,)) * 0.2).astype(np.float32),
        b3=np.float32(rng.normal() * 0.1),
    )


def assert_kernel_matches_ref(case, block_b=None):
    kwargs = {} if block_b is None else {"block_b": block_b}
    got = np.asarray(pallas_score(**case, **kwargs))
    want = np.asarray(ref_score(**case))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert got.shape == (case["c"].shape[0],)
    assert np.all((got >= 0.0) & (got <= 1.0))


def test_basic_batch32():
    rng = np.random.default_rng(0)
    assert_kernel_matches_ref(make_case(rng, d=16, ke=2, b=32, h=10))


def test_paper_shapes_arxiv():
    # d=128, ke=1: the arxiv_like AOT variant shape.
    rng = np.random.default_rng(1)
    assert_kernel_matches_ref(make_case(rng, d=128, ke=1, b=128, h=10))


def test_paper_shapes_products():
    rng = np.random.default_rng(2)
    assert_kernel_matches_ref(make_case(rng, d=100, ke=2, b=64, h=10))


def test_multi_tile_grid():
    # B spans several grid steps; each tile must land in the right slice.
    rng = np.random.default_rng(3)
    case = make_case(rng, d=8, ke=1, b=160, h=10)
    assert_kernel_matches_ref(case)
    # Tiles are independent: permuting candidates permutes scores.
    perm = rng.permutation(160)
    case2 = dict(case)
    case2["c"] = case["c"][perm]
    case2["e"] = case["e"][perm]
    got = np.asarray(pallas_score(**case2))
    want = np.asarray(pallas_score(**case))[perm]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_block_b_equals_batch():
    rng = np.random.default_rng(4)
    assert_kernel_matches_ref(make_case(rng, d=4, ke=3, b=16, h=6), block_b=16)


def test_non_divisible_batch_rejected():
    rng = np.random.default_rng(5)
    case = make_case(rng, d=4, ke=1, b=33, h=4)
    with pytest.raises(ValueError, match="not a multiple"):
        pallas_score(**case)


def test_large_magnitudes_saturate_not_nan():
    rng = np.random.default_rng(6)
    case = make_case(rng, d=8, ke=1, b=32, h=10, scale=100.0)
    got = np.asarray(pallas_score(**case))
    assert np.all(np.isfinite(got))
    assert_kernel_matches_ref(case)


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=96),
    ke=st.integers(min_value=1, max_value=4),
    tiles=st.integers(min_value=1, max_value=4),
    h=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_shape_sweep(d, ke, tiles, h, seed):
    """Property: kernel == oracle for arbitrary shapes (B multiple of 8)."""
    rng = np.random.default_rng(seed)
    b = 8 * tiles
    case = make_case(rng, d=d, ke=ke, b=b, h=h)
    kwargs = {"block_b": 8}
    got = np.asarray(pallas_score(**case, **kwargs))
    want = np.asarray(ref_score(**case))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31),
    dtype=st.sampled_from([np.float32, np.float64, np.float16]),
)
def test_hypothesis_dtype_coercion(seed, dtype):
    """Inputs in other float dtypes are coerced to f32 inside the kernel."""
    rng = np.random.default_rng(seed)
    case = make_case(rng, d=8, ke=1, b=32, h=8)
    cast = {k: (np.asarray(v, dtype) if k in ("q", "c", "e") else v) for k, v in case.items()}
    got = np.asarray(pallas_score(**cast))
    want = np.asarray(ref_score(**cast))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
