//! End-to-end RPC tests: real TCP server + client over the wire protocol.
//!
//! The pre-envelope tests (everything up to
//! `backpressure_refuses_excess_connections`) are behaviorally unchanged
//! from before the protocol-v1 redesign — they prove legacy clients and
//! the one-shot client API keep working against the reworked server.
//! (One mechanical edit: a `ServerConfig` literal gained
//! `..ServerConfig::default()` for the new scheduling fields.) The v1
//! tests after them cover pipelining, deadlines, overload shedding, and
//! mixed-dialect connections.

use std::sync::Arc;

use dynamic_gus::client::GusClient;
use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::protocol::Request;
use dynamic_gus::server::{serve, ServerConfig};

fn boot_server(
    n: usize,
) -> (
    dynamic_gus::server::ServerHandle,
    Arc<DynamicGus>,
    dynamic_gus::data::Dataset,
) {
    let ds = SyntheticConfig::arxiv_like(n, 0x51).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap());
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::default()).unwrap();
    (handle, gus, ds)
}

#[test]
fn full_rpc_round_trip() {
    let (handle, _gus, ds) = boot_server(200);
    let addr = handle.addr.to_string();
    let mut client = GusClient::connect(&addr).unwrap();

    // Query a known point.
    let res = client.query_id(ds.points[0].id, 5).unwrap();
    assert!(!res.is_empty());
    assert!(res.len() <= 5);
    for w in res.windows(2) {
        assert!(w[0].score >= w[1].score);
    }

    // Query a brand-new point by features.
    let mut newp = ds.points[0].clone();
    newp.id = 77_000;
    let res2 = client.query(&newp, 5).unwrap();
    assert!(!res2.is_empty());

    // Insert → appears in queries; delete → disappears.
    assert!(!client.insert(&newp).unwrap());
    let res3 = client.query_id(ds.points[0].id, 50).unwrap();
    assert!(res3.iter().any(|n| n.id == 77_000));
    assert!(client.delete(77_000).unwrap());
    assert!(!client.delete(77_000).unwrap());

    // Stats reflect the traffic.
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("points").as_usize(), Some(200));
    assert!(stats.get("counters").get("queries").as_u64().unwrap() >= 3);

    handle.shutdown();
}

#[test]
fn batch_rpcs_round_trip() {
    let (handle, gus, ds) = boot_server(200);
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();

    // Batch insert of fresh points over the wire.
    let fresh: Vec<_> = ds
        .points
        .iter()
        .take(20)
        .enumerate()
        .map(|(i, p)| {
            let mut p = p.clone();
            p.id = 80_000 + i as u64;
            p
        })
        .collect();
    let existed = client.insert_batch(&fresh).unwrap();
    assert_eq!(existed.len(), 20);
    assert!(existed.iter().all(|&e| !e));
    assert_eq!(gus.len(), 220);
    // Re-sending the batch reports every point as an update.
    let existed = client.insert_batch(&fresh).unwrap();
    assert!(existed.iter().all(|&e| e));

    // Batch query matches per-point queries.
    let queries: Vec<_> = ds.points.iter().take(6).cloned().collect();
    let batch = client.query_batch(&queries, 5).unwrap();
    assert_eq!(batch.len(), 6);
    for (i, p) in queries.iter().enumerate() {
        let single = client.query(p, 5).unwrap();
        assert_eq!(batch[i].len(), single.len(), "query {i}");
        for (x, y) in batch[i].iter().zip(&single) {
            assert_eq!(x.id, y.id, "query {i}");
        }
    }

    // A batch with a malformed point is rejected whole.
    let mut bad = fresh.clone();
    bad.push(dynamic_gus::features::Point::new(90_000, vec![]));
    assert!(client.insert_batch(&bad).is_err());
    assert!(!client.delete(90_000).unwrap());

    // Batch delete over the wire removes the fresh points again.
    let ids: Vec<u64> = fresh.iter().map(|p| p.id).collect();
    let removed = client.delete_batch(&ids).unwrap();
    assert!(removed.iter().all(|&e| e));
    assert!(!client.delete_batch(&ids).unwrap().iter().any(|&e| e));
    assert_eq!(gus.len(), 200);
    handle.shutdown();
}

#[test]
fn unknown_id_is_rpc_error_not_crash() {
    let (handle, _gus, _ds) = boot_server(50);
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();
    let err = client.query_id(987_654_321, 5).unwrap_err();
    assert!(format!("{err}").contains("unknown point"), "{err}");
    // Connection still usable after the error.
    assert!(client.stats().is_ok());
    handle.shutdown();
}

#[test]
fn many_concurrent_connections() {
    let (handle, gus, ds) = boot_server(300);
    let addr = handle.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let addr = addr.clone();
        let ids: Vec<u64> = ds.points.iter().map(|p| p.id).collect();
        handles.push(std::thread::spawn(move || {
            let mut client = GusClient::connect(&addr).unwrap();
            for i in 0..50usize {
                let id = ids[(t as usize * 37 + i * 13) % ids.len()];
                let res = client.query_id(id, 5).unwrap();
                assert!(res.len() <= 5);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    use std::sync::atomic::Ordering;
    assert_eq!(gus.metrics.counters.queries.load(Ordering::Relaxed), 8 * 50);
    handle.shutdown();
}

#[test]
fn malformed_requests_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let (handle, _gus, _ds) = boot_server(50);
    let stream = std::net::TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    for bad in ["garbage", "{}", r#"{"op":"nope"}"#] {
        writeln!(w, "{bad}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = dynamic_gus::util::json::Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false), "{bad}");
    }
    handle.shutdown();
}

#[test]
fn backpressure_refuses_excess_connections() {
    let ds = SyntheticConfig::arxiv_like(50, 0x52).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 1).unwrap());
    let handle = serve(
        Arc::clone(&gus),
        "127.0.0.1:0",
        ServerConfig { max_concurrent_connections: 1, ..ServerConfig::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    // First connection sticks around (held open by the server thread).
    let mut c1 = GusClient::connect(&addr).unwrap();
    assert!(c1.stats().is_ok());
    // Burst: some of these must be refused (EOF on first call) while c1
    // holds the only slot. Refusal manifests as an error, not a hang.
    let mut refused = 0;
    for _ in 0..10 {
        let mut c = GusClient::connect(&addr).unwrap();
        if c.stats().is_err() {
            refused += 1;
        }
        // tiny pause to let the server account the connection close
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(refused > 0, "backpressure never engaged");
    // The admitted connection still works.
    assert!(c1.stats().is_ok());
    handle.shutdown();
}

// ---------- protocol v1: pipelining, deadlines, overload ----------

/// Acceptance: one v1 connection, pipeline depth 64, mixed insert/query
/// workload. Responses complete out of order on the worker pool and are
/// matched back by correlation id; mutations apply in submission order
/// (proved by the deterministic existed-flag sequence on a reused id).
#[test]
fn pipelined_v1_depth64_mixed_workload() {
    let (handle, gus, ds) = boot_server(300);
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();

    #[derive(Debug)]
    enum Want {
        Existed(bool),
        Neighbors,
    }
    let mut expected: Vec<(u64, Want)> = Vec::new();

    // Fill the pipe with 64 requests before reading anything back:
    // 4 rounds of (insert fresh → insert again → delete → delete again)
    // interleaved with 48 queries. The submission-order guarantee makes
    // every existed flag deterministic even though workers run
    // concurrently.
    for round in 0..4u64 {
        let mut fresh = ds.points[round as usize].clone();
        fresh.id = 90_000 + round;
        let id = client.submit(Request::Insert { point: fresh.clone() }).unwrap();
        expected.push((id, Want::Existed(false)));
        for q in 0..6 {
            let id = client
                .submit(Request::QueryId { id: ds.points[(round as usize) * 7 + q].id, k: Some(5) })
                .unwrap();
            expected.push((id, Want::Neighbors));
        }
        let id = client.submit(Request::Insert { point: fresh.clone() }).unwrap();
        expected.push((id, Want::Existed(true))); // second insert = update
        for q in 6..12 {
            let id = client
                .submit(Request::QueryId { id: ds.points[(round as usize) * 7 + q].id, k: Some(5) })
                .unwrap();
            expected.push((id, Want::Neighbors));
        }
        let id = client.submit(Request::Delete { id: fresh.id }).unwrap();
        expected.push((id, Want::Existed(true))); // was present
        let id = client.submit(Request::Delete { id: fresh.id }).unwrap();
        expected.push((id, Want::Existed(false))); // already gone
    }
    assert_eq!(expected.len(), 64);

    // Drain in an order unrelated to submission (largest id first, then
    // the evens, then the rest) — the parking buffer must hand every
    // response to the wait() that asked for its id.
    expected.reverse();
    let (evens, odds): (Vec<_>, Vec<_>) = expected.into_iter().partition(|(id, _)| id % 2 == 0);
    for (id, want) in evens.into_iter().chain(odds) {
        match want {
            Want::Existed(want) => {
                let got = client.wait_existed(id).unwrap();
                assert_eq!(got, want, "request {id}");
            }
            Want::Neighbors => {
                let ns = client.wait_neighbors(id).unwrap();
                assert!(ns.len() <= 5);
                assert!(!ns.is_empty(), "request {id}");
            }
        }
    }
    // All mutations net out: corpus back to its boot size.
    assert_eq!(gus.len(), 300);
    handle.shutdown();
}

/// Legacy (un-enveloped) and v1 requests interleave on one socket:
/// legacy lines get legacy-shaped responses in order, v1 lines get
/// id-echoing envelope responses.
#[test]
fn mixed_legacy_and_v1_on_one_connection() {
    use std::io::{BufRead, BufReader, Write};
    let (handle, _gus, _ds) = boot_server(100);
    let stream = std::net::TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let read_json = |reader: &mut BufReader<std::net::TcpStream>| {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        dynamic_gus::util::json::Json::parse(line.trim()).unwrap()
    };

    // Legacy request → legacy response (no v/id header).
    writeln!(w, r#"{{"op":"query_id","id":3,"k":4}}"#).unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("ok").as_bool(), Some(true), "{j:?}");
    assert!(j.get("v").is_null());
    assert!(j.get("id").is_null());

    // v1 request on the same socket → envelope response echoing the id.
    writeln!(w, r#"{{"v":1,"id":501,"req":{{"op":"query_id","id":3,"k":4}}}}"#).unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("ok").as_bool(), Some(true), "{j:?}");
    assert_eq!(j.get("v").as_u64(), Some(1));
    assert_eq!(j.get("id").as_u64(), Some(501));

    // Back to legacy: still served, still un-enveloped.
    writeln!(w, r#"{{"op":"stats"}}"#).unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("ok").as_bool(), Some(true));
    assert!(j.get("v").is_null());
    assert_eq!(j.get("stats").get("points").as_usize(), Some(100));

    // v1 error: unknown op inside a valid envelope echoes the id with a
    // machine-readable code.
    writeln!(w, r#"{{"v":1,"id":502,"req":{{"op":"warp"}}}}"#).unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("ok").as_bool(), Some(false));
    assert_eq!(j.get("id").as_u64(), Some(502));
    assert_eq!(j.get("code").as_str(), Some("BAD_REQUEST"));
    handle.shutdown();
}

/// An already-expired deadline is answered DEADLINE_EXCEEDED without
/// touching the index, and the rejection is visible in `stats`.
#[test]
fn expired_deadline_is_rejected_before_execution() {
    let (handle, gus, ds) = boot_server(100);
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();

    client.set_deadline_ms(Some(0)); // expired on arrival
    let mut fresh = ds.points[0].clone();
    fresh.id = 91_000;
    let id = client.submit(Request::Insert { point: fresh }).unwrap();
    let err = client.wait(id).unwrap_err();
    assert!(format!("{err}").contains("DEADLINE_EXCEEDED"), "{err}");
    assert_eq!(gus.len(), 100, "expired mutation reached the index");
    assert!(!gus.contains(91_000));

    // A generous deadline passes.
    client.set_deadline_ms(Some(60_000));
    let ns = client.query_id(ds.points[1].id, 5).unwrap();
    assert!(!ns.is_empty());

    // The rejection is observable as a counter in stats.
    client.set_deadline_ms(None);
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.get("counters").get("deadline_exceeded").as_u64(),
        Some(1),
        "{stats:?}"
    );
    handle.shutdown();
}

/// With a single worker and a single queue slot, a pipelined burst must
/// be shed with structured OVERLOADED responses (never a dropped
/// connection), while admitted requests still complete.
#[test]
fn saturation_sheds_with_overloaded_response() {
    let ds = SyntheticConfig::arxiv_like(500, 0x53).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap());
    let handle = serve(
        Arc::clone(&gus),
        "127.0.0.1:0",
        ServerConfig {
            max_concurrent_connections: 4,
            worker_threads: 1,
            queue_capacity: 1,
        },
    )
    .unwrap();
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();

    let n = 200usize;
    let ids: Vec<u64> = (0..n)
        .map(|i| {
            client
                .submit(Request::QueryId { id: ds.points[i % 500].id, k: Some(20) })
                .unwrap()
        })
        .collect();
    let mut ok = 0usize;
    let mut overloaded = 0usize;
    for id in ids {
        match client.wait_neighbors(id) {
            Ok(_) => ok += 1,
            Err(e) => {
                let msg = format!("{e}");
                assert!(msg.contains("OVERLOADED"), "unexpected error: {msg}");
                overloaded += 1;
            }
        }
    }
    assert_eq!(ok + overloaded, n);
    assert!(ok >= 1, "nothing was admitted");
    assert!(overloaded >= 1, "nothing was shed: queue never saturated");
    // The shed count is observable in stats (the connection is still
    // perfectly usable after 200 mixed outcomes).
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.get("counters").get("overloaded").as_u64(),
        Some(overloaded as u64),
        "{stats:?}"
    );
    handle.shutdown();
}

/// Connections beyond the cap receive one final OVERLOADED error
/// response before the socket closes — a structured refusal, not a
/// silent drop — and are counted in the `refused` stat.
#[test]
fn refused_connection_gets_final_overloaded_response() {
    use std::io::{BufRead, BufReader};
    let ds = SyntheticConfig::arxiv_like(50, 0x54).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 1).unwrap());
    let handle = serve(
        Arc::clone(&gus),
        "127.0.0.1:0",
        ServerConfig { max_concurrent_connections: 1, ..ServerConfig::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    // Hold the only slot.
    let mut c1 = GusClient::connect(&addr).unwrap();
    assert!(c1.stats().is_ok());
    // Refused connections read a structured OVERLOADED line, then EOF.
    let mut saw_refusal = false;
    for _ in 0..10 {
        let stream = std::net::TcpStream::connect(handle.addr).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap() == 0 {
            // Raced an accept; not a refusal.
            continue;
        }
        let j = dynamic_gus::util::json::Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false), "{line}");
        assert_eq!(j.get("code").as_str(), Some("OVERLOADED"), "{line}");
        // EOF follows the refusal line.
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "socket stayed open");
        saw_refusal = true;
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(saw_refusal, "backpressure never engaged");
    let stats = c1.stats().unwrap();
    assert!(
        stats.get("counters").get("refused").as_u64().unwrap() >= 1,
        "{stats:?}"
    );
    handle.shutdown();
}

/// k bounds are enforced at decode time over the wire: k=0 and absurd k
/// are BAD_REQUEST; the connection stays usable and the index is never
/// queried.
#[test]
fn k_bounds_are_rejected_over_the_wire() {
    let (handle, gus, ds) = boot_server(100);
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();
    for bad_k in [0usize, dynamic_gus::protocol::MAX_K + 1, 1 << 40] {
        let id = client
            .submit(Request::QueryId { id: ds.points[0].id, k: Some(bad_k) })
            .unwrap();
        let err = client.wait(id).unwrap_err();
        assert!(format!("{err}").contains("BAD_REQUEST"), "k={bad_k}: {err}");
        let id = client
            .submit(Request::QueryBatch { points: vec![ds.points[0].clone()], k: Some(bad_k) })
            .unwrap();
        assert!(client.wait(id).is_err(), "k={bad_k}");
    }
    use std::sync::atomic::Ordering;
    assert_eq!(gus.metrics.counters.queries.load(Ordering::Relaxed), 0);
    // Valid k still answers on the same connection.
    assert!(!client.query_id(ds.points[0].id, 5).unwrap().is_empty());
    handle.shutdown();
}
