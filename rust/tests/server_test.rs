//! End-to-end RPC tests: real TCP server + client over the wire protocol.

use std::sync::Arc;

use dynamic_gus::client::GusClient;
use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::server::{serve, ServerConfig};

fn boot_server(
    n: usize,
) -> (
    dynamic_gus::server::ServerHandle,
    Arc<DynamicGus>,
    dynamic_gus::data::Dataset,
) {
    let ds = SyntheticConfig::arxiv_like(n, 0x51).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap());
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::default()).unwrap();
    (handle, gus, ds)
}

#[test]
fn full_rpc_round_trip() {
    let (handle, _gus, ds) = boot_server(200);
    let addr = handle.addr.to_string();
    let mut client = GusClient::connect(&addr).unwrap();

    // Query a known point.
    let res = client.query_id(ds.points[0].id, 5).unwrap();
    assert!(!res.is_empty());
    assert!(res.len() <= 5);
    for w in res.windows(2) {
        assert!(w[0].score >= w[1].score);
    }

    // Query a brand-new point by features.
    let mut newp = ds.points[0].clone();
    newp.id = 77_000;
    let res2 = client.query(&newp, 5).unwrap();
    assert!(!res2.is_empty());

    // Insert → appears in queries; delete → disappears.
    assert!(!client.insert(&newp).unwrap());
    let res3 = client.query_id(ds.points[0].id, 50).unwrap();
    assert!(res3.iter().any(|n| n.id == 77_000));
    assert!(client.delete(77_000).unwrap());
    assert!(!client.delete(77_000).unwrap());

    // Stats reflect the traffic.
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("points").as_usize(), Some(200));
    assert!(stats.get("counters").get("queries").as_u64().unwrap() >= 3);

    handle.shutdown();
}

#[test]
fn batch_rpcs_round_trip() {
    let (handle, gus, ds) = boot_server(200);
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();

    // Batch insert of fresh points over the wire.
    let fresh: Vec<_> = ds
        .points
        .iter()
        .take(20)
        .enumerate()
        .map(|(i, p)| {
            let mut p = p.clone();
            p.id = 80_000 + i as u64;
            p
        })
        .collect();
    let existed = client.insert_batch(&fresh).unwrap();
    assert_eq!(existed.len(), 20);
    assert!(existed.iter().all(|&e| !e));
    assert_eq!(gus.len(), 220);
    // Re-sending the batch reports every point as an update.
    let existed = client.insert_batch(&fresh).unwrap();
    assert!(existed.iter().all(|&e| e));

    // Batch query matches per-point queries.
    let queries: Vec<_> = ds.points.iter().take(6).cloned().collect();
    let batch = client.query_batch(&queries, 5).unwrap();
    assert_eq!(batch.len(), 6);
    for (i, p) in queries.iter().enumerate() {
        let single = client.query(p, 5).unwrap();
        assert_eq!(batch[i].len(), single.len(), "query {i}");
        for (x, y) in batch[i].iter().zip(&single) {
            assert_eq!(x.id, y.id, "query {i}");
        }
    }

    // A batch with a malformed point is rejected whole.
    let mut bad = fresh.clone();
    bad.push(dynamic_gus::features::Point::new(90_000, vec![]));
    assert!(client.insert_batch(&bad).is_err());
    assert!(!client.delete(90_000).unwrap());

    // Batch delete over the wire removes the fresh points again.
    let ids: Vec<u64> = fresh.iter().map(|p| p.id).collect();
    let removed = client.delete_batch(&ids).unwrap();
    assert!(removed.iter().all(|&e| e));
    assert!(!client.delete_batch(&ids).unwrap().iter().any(|&e| e));
    assert_eq!(gus.len(), 200);
    handle.shutdown();
}

#[test]
fn unknown_id_is_rpc_error_not_crash() {
    let (handle, _gus, _ds) = boot_server(50);
    let mut client = GusClient::connect(&handle.addr.to_string()).unwrap();
    let err = client.query_id(987_654_321, 5).unwrap_err();
    assert!(format!("{err}").contains("unknown point"), "{err}");
    // Connection still usable after the error.
    assert!(client.stats().is_ok());
    handle.shutdown();
}

#[test]
fn many_concurrent_connections() {
    let (handle, gus, ds) = boot_server(300);
    let addr = handle.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let addr = addr.clone();
        let ids: Vec<u64> = ds.points.iter().map(|p| p.id).collect();
        handles.push(std::thread::spawn(move || {
            let mut client = GusClient::connect(&addr).unwrap();
            for i in 0..50usize {
                let id = ids[(t as usize * 37 + i * 13) % ids.len()];
                let res = client.query_id(id, 5).unwrap();
                assert!(res.len() <= 5);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    use std::sync::atomic::Ordering;
    assert_eq!(gus.metrics.counters.queries.load(Ordering::Relaxed), 8 * 50);
    handle.shutdown();
}

#[test]
fn malformed_requests_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let (handle, _gus, _ds) = boot_server(50);
    let stream = std::net::TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    for bad in ["garbage", "{}", r#"{"op":"nope"}"#] {
        writeln!(w, "{bad}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = dynamic_gus::util::json::Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false), "{bad}");
    }
    handle.shutdown();
}

#[test]
fn backpressure_refuses_excess_connections() {
    let ds = SyntheticConfig::arxiv_like(50, 0x52).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 1).unwrap());
    let handle = serve(
        Arc::clone(&gus),
        "127.0.0.1:0",
        ServerConfig { max_concurrent_connections: 1 },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    // First connection sticks around (held open by the server thread).
    let mut c1 = GusClient::connect(&addr).unwrap();
    assert!(c1.stats().is_ok());
    // Burst: some of these must be refused (EOF on first call) while c1
    // holds the only slot. Refusal manifests as an error, not a hang.
    let mut refused = 0;
    for _ in 0..10 {
        let mut c = GusClient::connect(&addr).unwrap();
        if c.stats().is_err() {
            refused += 1;
        }
        // tiny pause to let the server account the connection close
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(refused > 0, "backpressure never engaged");
    // The admitted connection still works.
    assert!(c1.stats().is_ok());
    handle.shutdown();
}
