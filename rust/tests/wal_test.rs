//! Durability integration: crash-recovery equivalence.
//!
//! The contract under test (ISSUE 2's acceptance criterion): a service
//! killed mid-ingest — simulated by dropping the process handle without a
//! checkpoint — recovers from latest-checkpoint + WAL-replay and answers a
//! fixed query workload **byte-identically** to a service that was never
//! interrupted. Including when the final WAL record is torn.

use std::path::{Path, PathBuf};

use dynamic_gus::config::{FsyncPolicy, GusConfig, ScorerKind};
use dynamic_gus::coordinator::{snapshot, wal, DynamicGus};
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::data::Dataset;
use dynamic_gus::features::Point;
use dynamic_gus::testing::proptest_cases;
use dynamic_gus::util::rng::Rng;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("gus-wal-int").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn wal_cfg() -> GusConfig {
    GusConfig {
        scorer: ScorerKind::Native,
        filter_p: 10.0,
        n_shards: 2,
        // Process crashes (the scenario under test) lose nothing at any
        // fsync policy; Never keeps the tests fast.
        fsync: FsyncPolicy::Never,
        ..GusConfig::default()
    }
}

/// Assert two services answer a fixed workload identically: single
/// queries, a batch query, corpus size and membership.
fn assert_equivalent(recovered: &DynamicGus, reference: &DynamicGus, ds: &Dataset, tag: &str) {
    assert_eq!(recovered.len(), reference.len(), "{tag}: corpus size");
    for qi in (0..ds.points.len()).step_by(17) {
        assert_eq!(
            recovered.query(&ds.points[qi], 10).unwrap(),
            reference.query(&ds.points[qi], 10).unwrap(),
            "{tag}: query {qi} diverged"
        );
    }
    let probes: Vec<Point> = ds.points.iter().step_by(29).cloned().collect();
    assert_eq!(
        recovered.query_batch(&probes, 10).unwrap(),
        reference.query_batch(&probes, 10).unwrap(),
        "{tag}: query_batch diverged"
    );
}

/// The acceptance scenario: mixed mutations through every entry point
/// (insert, delete, insert_batch, delete_batch, refresh_tables), then a
/// simulated `kill -9` with everything still WAL-only.
#[test]
fn kill_mid_ingest_recovers_identically() {
    let ds = SyntheticConfig::arxiv_like(400, 0x4a1).generate();
    let dir = tmpdir("kill-mid-ingest");
    let live =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..250], 2).unwrap();
    wal::init_fresh(&live, &dir).unwrap();
    let twin =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..250], 2).unwrap();

    let mutate = |gus: &DynamicGus| {
        for p in &ds.points[250..300] {
            gus.insert(p.clone()).unwrap();
        }
        gus.insert_batch(ds.points[300..360].to_vec()).unwrap();
        for p in &ds.points[360..370] {
            gus.delete(p.id).unwrap();
        }
        let victims: Vec<u64> = ds.points[5..25].iter().map(|p| p.id).collect();
        gus.delete_batch(&victims).unwrap();
        gus.refresh_tables(2).unwrap();
        // An update after the refresh: moves point 30 onto 31's features.
        let mut moved = ds.points[31].clone();
        moved.id = ds.points[30].id;
        gus.insert(moved).unwrap();
        gus.insert_batch(ds.points[360..400].to_vec()).unwrap();
    };
    mutate(&live);
    mutate(&twin);

    // `kill -9`: drop the handle — no checkpoint, no graceful shutdown.
    // Everything past bootstrap exists only in the WAL.
    let logged = live.wal_pending();
    assert!(logged > 0);
    drop(live);

    let rec = wal::recover(&dir, 2).unwrap();
    assert_eq!(rec.snapshot_points, 250, "checkpoint 0 holds the bootstrap corpus");
    assert!(rec.replayed > 0);
    assert!(!rec.torn_tail);
    assert!(!rec.gus.contains(ds.points[5].id), "batch-deleted point resurrected");
    assert!(rec.gus.contains(ds.points[399].id), "WAL-only insert lost");
    assert_equivalent(&rec.gus, &twin, &ds, "kill-mid-ingest");
}

/// A checkpoint mid-stream bounds replay to the post-checkpoint delta and
/// empties the log.
#[test]
fn checkpoint_bounds_replay_to_delta() {
    let ds = SyntheticConfig::arxiv_like(300, 0x4a2).generate();
    let dir = tmpdir("checkpoint-delta");
    let live =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..200], 2).unwrap();
    wal::init_fresh(&live, &dir).unwrap();
    let twin =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..200], 2).unwrap();

    for p in &ds.points[200..280] {
        live.insert(p.clone()).unwrap();
        twin.insert(p.clone()).unwrap();
    }
    let wal_before = std::fs::metadata(dir.join(wal::WAL_FILE)).unwrap().len();
    assert!(wal_before > 0);
    let seq = live.checkpoint().unwrap();
    assert_eq!(seq, 80);
    assert_eq!(live.wal_pending(), 0);
    assert_eq!(
        std::fs::metadata(dir.join(wal::WAL_FILE)).unwrap().len(),
        0,
        "checkpoint must truncate the WAL"
    );
    // Post-checkpoint delta: the only records replay has to process.
    for p in &ds.points[280..300] {
        live.insert(p.clone()).unwrap();
        twin.insert(p.clone()).unwrap();
    }
    drop(live);

    let rec = wal::recover(&dir, 2).unwrap();
    assert_eq!(rec.snapshot_points, 280, "snapshot covers everything up to the checkpoint");
    assert_eq!(rec.replayed, 20, "replay is O(delta), not O(corpus)");
    assert_equivalent(&rec.gus, &twin, &ds, "checkpoint-delta");
}

/// Crash window between snapshot commit and WAL truncation: the snapshot's
/// `last_seq` makes the still-full WAL harmless (stale records are
/// skipped, not replayed on top of newer state).
#[test]
fn snapshot_commit_without_truncation_is_safe() {
    let ds = SyntheticConfig::arxiv_like(260, 0x4a3).generate();
    let dir = tmpdir("untruncated");
    let live =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..200], 2).unwrap();
    wal::init_fresh(&live, &dir).unwrap();
    let twin =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..200], 2).unwrap();

    for p in &ds.points[200..240] {
        live.insert(p.clone()).unwrap();
        twin.insert(p.clone()).unwrap();
    }
    live.delete(ds.points[0].id).unwrap();
    twin.delete(ds.points[0].id).unwrap();
    // Re-insert point 0 with point 1's features, so a stale replay of the
    // delete above would visibly corrupt state.
    let mut back = ds.points[1].clone();
    back.id = ds.points[0].id;
    live.insert(back.clone()).unwrap();
    twin.insert(back).unwrap();

    // Simulate a checkpoint that crashed after committing the snapshot
    // but before truncating the log.
    snapshot::save_with_seq(&live, &dir, live.wal_seq()).unwrap();
    assert!(std::fs::metadata(dir.join(wal::WAL_FILE)).unwrap().len() > 0);
    drop(live);

    let rec = wal::recover(&dir, 2).unwrap();
    assert_eq!(rec.replayed, 0, "records ≤ last_seq must be skipped");
    assert!(rec.gus.contains(ds.points[0].id));
    assert_equivalent(&rec.gus, &twin, &ds, "untruncated-wal");
}

/// A WAL whose final record is torn (crash mid-append) recovers the
/// complete prefix; the torn record was never acknowledged, so the result
/// equals a service that never saw that mutation. Recovery also truncates
/// the tail so the log keeps working.
#[test]
fn torn_tail_recovers_acknowledged_prefix() {
    let ds = SyntheticConfig::arxiv_like(300, 0x4a4).generate();
    let dir = tmpdir("torn-tail");
    let wal_path = dir.join(wal::WAL_FILE);
    let live =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..200], 2).unwrap();
    wal::init_fresh(&live, &dir).unwrap();

    // Apply 12 single-record mutations, recording the log length after
    // each so we can cut precisely inside the final record.
    let ops: Vec<Point> = ds.points[200..212].to_vec();
    let mut offsets = Vec::new();
    for p in &ops {
        live.insert(p.clone()).unwrap();
        offsets.push(std::fs::metadata(&wal_path).unwrap().len());
    }
    drop(live);

    // Tear the last record: keep 11 complete records + 7 bytes of the 12th.
    let cut = offsets[10] + 7;
    assert!(cut < offsets[11]);
    let f = std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap();
    f.set_len(cut).unwrap();
    drop(f);

    let twin =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..200], 2).unwrap();
    for p in &ops[..11] {
        twin.insert(p.clone()).unwrap();
    }

    let rec = wal::recover(&dir, 2).unwrap();
    assert!(rec.torn_tail);
    assert_eq!(rec.replayed, 11);
    assert!(!rec.gus.contains(ops[11].id));
    assert_eq!(
        std::fs::metadata(&wal_path).unwrap().len(),
        offsets[10],
        "recovery must truncate the torn tail"
    );
    assert_equivalent(&rec.gus, &twin, &ds, "torn-tail");

    // The recovered service keeps logging where the log left off: one more
    // mutation, another crash, another recovery.
    rec.gus.insert(ops[11].clone()).unwrap();
    twin.insert(ops[11].clone()).unwrap();
    drop(rec);
    let rec2 = wal::recover(&dir, 2).unwrap();
    assert!(!rec2.torn_tail);
    assert_eq!(rec2.replayed, 12);
    assert_equivalent(&rec2.gus, &twin, &ds, "torn-tail-continued");
}

/// WAL-only recovery (no usable checkpoint): `wal_meta.json` boots an
/// empty service and the log replays the entire history.
#[test]
fn recovers_from_wal_alone_when_checkpoint_is_lost() {
    let ds = SyntheticConfig::arxiv_like(150, 0x4a5).generate();
    let dir = tmpdir("wal-only");
    let live = DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &[], 2).unwrap();
    wal::init_fresh(&live, &dir).unwrap();
    let twin = DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &[], 2).unwrap();
    for p in &ds.points[..120] {
        live.insert(p.clone()).unwrap();
        twin.insert(p.clone()).unwrap();
    }
    live.delete_batch(&[ds.points[3].id, ds.points[4].id]).unwrap();
    twin.delete_batch(&[ds.points[3].id, ds.points[4].id]).unwrap();
    drop(live);

    // Lose the checkpoint (e.g. a corrupted volume restore kept only the
    // log): snapshot.json + its corpus file are gone.
    std::fs::remove_file(dir.join(snapshot::SNAPSHOT_META)).unwrap();
    for e in std::fs::read_dir(&dir).unwrap().flatten() {
        if e.file_name().to_string_lossy().starts_with("points-") {
            std::fs::remove_file(e.path()).unwrap();
        }
    }

    let rec = wal::recover(&dir, 2).unwrap();
    assert_eq!(rec.snapshot_points, 0);
    assert_eq!(rec.replayed, 121, "120 inserts + 1 delete_batch record");
    assert_eq!(rec.gus.len(), 118);
    assert_equivalent(&rec.gus, &twin, &ds, "wal-only");
}

/// Lost-checkpoint recovery must *refuse* when the WAL alone cannot
/// reconstruct the acknowledged state — never silently serve a partial
/// corpus.
#[test]
fn lost_checkpoint_with_unreconstructible_history_is_refused() {
    // Case A: non-empty bootstrap corpus. The WAL never contained those
    // points, so with the checkpoint gone they are unrecoverable.
    let ds = SyntheticConfig::arxiv_like(120, 0x4a8).generate();
    let dir = tmpdir("lost-nonempty");
    let live =
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..50], 2).unwrap();
    wal::init_fresh(&live, &dir).unwrap();
    live.insert(ds.points[60].clone()).unwrap();
    drop(live);
    std::fs::remove_file(dir.join(snapshot::SNAPSHOT_META)).unwrap();
    let err = wal::recover(&dir, 2).unwrap_err();
    assert!(format!("{err}").contains("cannot reconstruct"), "{err}");

    // Case B: empty bootstrap, but a checkpoint truncated the log before
    // being lost — the WAL's first surviving record exposes the gap.
    let dir = tmpdir("lost-truncated");
    let live = DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &[], 2).unwrap();
    wal::init_fresh(&live, &dir).unwrap();
    for p in &ds.points[..10] {
        live.insert(p.clone()).unwrap();
    }
    live.checkpoint().unwrap();
    for p in &ds.points[10..15] {
        live.insert(p.clone()).unwrap();
    }
    drop(live);
    std::fs::remove_file(dir.join(snapshot::SNAPSHOT_META)).unwrap();
    for e in std::fs::read_dir(&dir).unwrap().flatten() {
        if e.file_name().to_string_lossy().starts_with("points-") {
            std::fs::remove_file(e.path()).unwrap();
        }
    }
    let err = wal::recover(&dir, 2).unwrap_err();
    assert!(format!("{err}").contains("missing"), "{err}");
}

/// The background checkpointer folds the WAL into snapshots once
/// `checkpoint_every` mutations accumulate.
#[test]
fn background_checkpointer_compacts() {
    use std::sync::Arc;
    use std::time::Duration;
    let ds = SyntheticConfig::arxiv_like(200, 0x4a6).generate();
    let dir = tmpdir("checkpointer");
    let gus = Arc::new(
        DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..100], 2).unwrap(),
    );
    wal::init_fresh(&gus, &dir).unwrap();
    let ckpt = wal::Checkpointer::spawn(Arc::clone(&gus), 10, Duration::from_millis(10));
    for p in &ds.points[100..150] {
        gus.insert(p.clone()).unwrap();
    }
    // Wait (bounded) for the trigger to fire at least once.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while gus.wal_pending() >= 10 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    ckpt.stop();
    assert!(gus.wal_pending() < 10, "checkpointer never fired");
    // The checkpoint it wrote is a valid restore point.
    let (restored, last_seq) = snapshot::restore_with_seq(&dir, 2).unwrap();
    assert!(last_seq > 0);
    assert!(restored.len() >= 100);
}

/// `init_fresh` refuses a directory that already holds state (that state
/// must be recovered, not silently overwritten).
#[test]
fn init_fresh_refuses_existing_state() {
    let ds = SyntheticConfig::arxiv_like(60, 0x4a7).generate();
    let dir = tmpdir("refuse");
    let gus = DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points, 2).unwrap();
    wal::init_fresh(&gus, &dir).unwrap();
    let gus2 = DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points, 2).unwrap();
    let err = wal::init_fresh(&gus2, &dir).unwrap_err();
    assert!(format!("{err}").contains("recover"), "{err}");
    assert!(wal::recover(&dir, 2).is_ok());
}

/// Property: for a random mutation stream crashed at a random point —
/// possibly mid-record — recovery answers `query`/`query_batch`
/// byte-identically to an uninterrupted service that executed exactly the
/// acknowledged prefix. Covers all four mutation entry points, updates,
/// table refreshes and random checkpoint placement.
#[test]
fn prop_crash_recovery_equals_uninterrupted() {
    #[derive(Clone)]
    enum MutOp {
        Insert(Point),
        Delete(u64),
        InsertBatch(Vec<Point>),
        DeleteBatch(Vec<u64>),
        Refresh,
        Checkpoint,
    }

    fn apply(gus: &DynamicGus, op: &MutOp, durable: bool) {
        match op {
            MutOp::Insert(p) => {
                gus.insert(p.clone()).unwrap();
            }
            MutOp::Delete(id) => {
                gus.delete(*id).unwrap();
            }
            MutOp::InsertBatch(ps) => {
                gus.insert_batch(ps.clone()).unwrap();
            }
            MutOp::DeleteBatch(ids) => {
                gus.delete_batch(ids).unwrap();
            }
            MutOp::Refresh => gus.refresh_tables(2).unwrap(),
            MutOp::Checkpoint => {
                // Only meaningful (and only possible) on the durable side.
                if durable {
                    gus.checkpoint().unwrap();
                }
            }
        }
    }

    proptest_cases(6, |rng: &mut Rng| {
        let tag = rng.next_u64();
        let ds = SyntheticConfig::arxiv_like(120, tag ^ 0x9e37).generate();
        let dir = tmpdir(&format!("prop-{tag:016x}"));
        let wal_path = dir.join(wal::WAL_FILE);

        // Generate the op stream up front (it is data, so the surviving
        // prefix can be re-executed on a fresh twin).
        let mut next_id = 500_000u64;
        let mut live_ids: Vec<u64> = ds.points[..80].iter().map(|p| p.id).collect();
        let n_ops = 15 + rng.below_usize(20);
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            let roll = rng.f64();
            let op = if roll < 0.40 {
                // Insert a fresh point or update an existing one.
                let mut p = rng.choose(&ds.points).clone();
                if rng.chance(0.3) && !live_ids.is_empty() {
                    p.id = *rng.choose(&live_ids);
                } else {
                    next_id += 1;
                    p.id = next_id;
                    live_ids.push(p.id);
                }
                MutOp::Insert(p)
            } else if roll < 0.55 {
                let n = 2 + rng.below_usize(4);
                let mut ps = Vec::with_capacity(n);
                for _ in 0..n {
                    let mut p = rng.choose(&ds.points).clone();
                    next_id += 1;
                    p.id = next_id;
                    live_ids.push(p.id);
                    ps.push(p);
                }
                MutOp::InsertBatch(ps)
            } else if roll < 0.70 {
                // Sometimes a no-op delete of an unknown id.
                let id = if rng.chance(0.8) && !live_ids.is_empty() {
                    *rng.choose(&live_ids)
                } else {
                    999_999_999
                };
                MutOp::Delete(id)
            } else if roll < 0.82 {
                let n = 1 + rng.below_usize(4);
                let ids = (0..n)
                    .map(|_| {
                        if live_ids.is_empty() {
                            999_999_998
                        } else {
                            *rng.choose(&live_ids)
                        }
                    })
                    .collect();
                MutOp::DeleteBatch(ids)
            } else if roll < 0.90 {
                MutOp::Refresh
            } else {
                MutOp::Checkpoint
            };
            ops.push(op);
        }

        // Durable service: bootstrap + WAL, run the whole stream,
        // recording the log length after every op.
        let live =
            DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..80], 2).unwrap();
        wal::init_fresh(&live, &dir).unwrap();
        let mut offsets = Vec::with_capacity(ops.len());
        for op in &ops {
            apply(&live, op, true);
            offsets.push(std::fs::metadata(&wal_path).unwrap().len());
        }
        drop(live); // crash: no final checkpoint

        // Crash point: after op `cut` — and, half the time, with a torn
        // fragment of op `cut`'s record left behind. The snapshot on disk
        // covers everything up to the *last* Checkpoint op (which also
        // truncated the log, resetting offsets), so the earliest valid
        // cut keeps that checkpoint inside the surviving prefix; later
        // ops each appended exactly one record, making per-op offsets an
        // exact map from cut position to file length.
        let lo = ops
            .iter()
            .rposition(|o| matches!(o, MutOp::Checkpoint))
            .map(|k| k + 1)
            .unwrap_or(0);
        let mut cut = ops.len();
        if rng.chance(0.5) && lo < ops.len() {
            cut = lo + rng.below_usize(ops.len() - lo);
            let base = if cut == 0 { 0 } else { offsets[cut - 1] };
            let next = offsets[cut];
            assert!(next > base, "op {cut} appended no record?");
            // Leave 1..(record_len) bytes of the next record: a torn tail.
            let torn = base + 1 + rng.below(next - base - 1);
            let f = std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap();
            f.set_len(torn).unwrap();
            drop(f);
        }

        // Uninterrupted twin: executes exactly the surviving prefix.
        let twin =
            DynamicGus::bootstrap(ds.schema.clone(), wal_cfg(), &ds.points[..80], 2).unwrap();
        for op in &ops[..cut] {
            apply(&twin, op, false);
        }

        let rec = wal::recover(&dir, 2).unwrap();
        assert_eq!(rec.gus.len(), twin.len(), "corpus size diverged (cut={cut})");
        for qi in (0..ds.points.len()).step_by(13) {
            assert_eq!(
                rec.gus.query(&ds.points[qi], 8).unwrap(),
                twin.query(&ds.points[qi], 8).unwrap(),
                "query {qi} diverged (cut={cut}/{})",
                ops.len()
            );
        }
        let probes: Vec<Point> = ds.points.iter().step_by(23).cloned().collect();
        assert_eq!(
            rec.gus.query_batch(&probes, 8).unwrap(),
            twin.query_batch(&probes, 8).unwrap(),
            "query_batch diverged (cut={cut})"
        );
        let _ = std::fs::remove_dir_all(&dir);
    });
}

/// Restores must fail loudly, not silently serve partial state, when the
/// directory has nothing to recover.
#[test]
fn recover_empty_dir_errors() {
    let dir = tmpdir("empty");
    let err = wal::recover(&dir, 1).unwrap_err();
    assert!(format!("{err}").contains("nothing to recover"), "{err}");
    assert!(wal::recover(Path::new("/nonexistent/gus-wal"), 1).is_err());
}
