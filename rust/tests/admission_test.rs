//! Socket-level admission-control acceptance: mixed-class load against a
//! deliberately starved server (one worker, tiny run queue) proving the
//! graceful-degradation contract end to end:
//!
//! 1. batch traffic is shed strictly before interactive traffic, and
//!    controller sheds carry a usable `retry_after_ms` hint,
//! 2. interactive answers on the pressure ramp come back marked
//!    `degraded` with a budget fraction inside the configured floor,
//!    while unclassed and batch answers are never degraded,
//! 3. `GusClient::call_with_retry` honors the server's hint and gets the
//!    request through once the surge drains,
//! 4. the read router keeps answering through a replica kill and its
//!    stats expose the dead replica's opened circuit breaker.
//!
//! Budget-fraction *monotonicity* in pressure is proven at the unit
//! level in `admission::controller`; here we only assert the band, since
//! concurrent worker pops can wiggle instantaneous queue depth.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dynamic_gus::admission::{AdmissionConfig, Class};
use dynamic_gus::client::GusClient;
use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::data::Dataset;
use dynamic_gus::features::Point;
use dynamic_gus::protocol::{ErrorCode, Request, Response};
use dynamic_gus::replication::{run_router, RouterOpts};
use dynamic_gus::server::{serve, ServerConfig, ServerHandle};

fn corpus(n: usize, seed: u64) -> Dataset {
    SyntheticConfig::arxiv_like(n, seed).generate()
}

fn boot(ds: &Dataset, config: ServerConfig) -> (ServerHandle, Arc<DynamicGus>) {
    let cfg = GusConfig { scorer: ScorerKind::Native, n_shards: 2, ..GusConfig::default() };
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap());
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", config).unwrap();
    (handle, gus)
}

/// One worker and a small queue so a single pipelined burst saturates
/// the server without any wall-clock coupling.
fn starved(queue: usize) -> ServerConfig {
    ServerConfig {
        worker_threads: 1,
        queue_capacity: queue,
        admission: AdmissionConfig { target_sojourn_ms: 50, min_budget_frac: 0.25 },
        ..ServerConfig::default()
    }
}

/// Mixed-class pipelined burst behind an unclassed "occupier" job that
/// pins the single worker: admission decisions during the burst are
/// driven purely by queue depth, which only grows while the worker is
/// pinned — deterministic, no sleeps.
#[test]
fn batch_sheds_before_interactive_and_degraded_budgets_stay_in_band() {
    let ds = corpus(1_200, 0xad1);
    let (handle, gus) = boot(&ds, starved(16));
    let mut c = GusClient::connect(&handle.addr.to_string()).unwrap();

    let probes: Vec<Point> = ds.points.iter().take(16).cloned().collect();
    let occupier_points: Vec<Point> = ds.points.iter().take(256).cloned().collect();
    let occupier =
        c.submit(Request::QueryBatch { points: occupier_points, k: Some(10) }).unwrap();

    // Strict batch-then-interactive pairs: each batch request is decided
    // immediately before its interactive twin at (nearly) the same
    // depth, and the batch shed band strictly contains the interactive
    // one — so per pair, batch can only shed at least as often.
    let mut batch_ids = Vec::new();
    let mut interactive_ids = Vec::new();
    for _ in 0..60 {
        c.set_class(Some(Class::Batch));
        batch_ids
            .push(c.submit(Request::QueryBatch { points: probes.clone(), k: Some(10) }).unwrap());
        c.set_class(Some(Class::Interactive));
        interactive_ids
            .push(c.submit(Request::QueryBatch { points: probes.clone(), k: Some(10) }).unwrap());
    }

    match c.wait_response(occupier).unwrap() {
        Response::Results { results, degraded } => {
            assert_eq!(results.len(), 256);
            assert!(degraded.is_none(), "unclassed requests must never be served degraded");
        }
        other => panic!("occupier got {other:?}"),
    }

    let mut shed_batch = 0u64;
    let mut shed_interactive = 0u64;
    let mut shed_interactive_hinted = 0u64;
    let mut degraded_fracs: Vec<f64> = Vec::new();
    for id in batch_ids {
        match c.wait_response(id).unwrap() {
            Response::Error { code: ErrorCode::Overloaded, retry_after_ms, .. } => {
                shed_batch += 1;
                // Batch is only ever shed by the controller (it is
                // admitted solely below pressure 0.5, where the queue
                // has room), so the hint must always be present.
                let ms = retry_after_ms.expect("controller sheds carry retry_after_ms");
                assert!((10..=5_000).contains(&ms), "retry hint out of band: {ms}");
            }
            Response::Results { results, degraded } => {
                assert_eq!(results.len(), 16);
                assert!(degraded.is_none(), "batch gets full answers or none, never degraded");
            }
            other => panic!("batch request got {other:?}"),
        }
    }
    for id in interactive_ids {
        match c.wait_response(id).unwrap() {
            // Interactive sheds split two ways: controller sheds carry a
            // hint, the queue-full backstop does not.
            Response::Error { code: ErrorCode::Overloaded, retry_after_ms, .. } => {
                shed_interactive += 1;
                if retry_after_ms.is_some() {
                    shed_interactive_hinted += 1;
                }
            }
            Response::Results { results, degraded } => {
                assert_eq!(results.len(), 16);
                if let Some(f) = degraded {
                    degraded_fracs.push(f);
                }
            }
            other => panic!("interactive request got {other:?}"),
        }
    }

    assert!(shed_batch > 0, "a saturated queue must shed batch traffic");
    assert!(
        shed_batch >= shed_interactive,
        "priority inversion: shed {shed_batch} batch vs {shed_interactive} interactive"
    );
    // Depths 9..16 put pressure in (1.0, 2.0): interactive is admitted
    // there with budget 1/pressure — the ramp must produce at least one
    // degraded answer, and every fraction must respect the floor.
    assert!(
        !degraded_fracs.is_empty(),
        "interactive must be served degraded on the ramp between full budget and the floor"
    );
    for f in &degraded_fracs {
        assert!((0.25..1.0).contains(f), "degraded fraction out of band: {f}");
    }

    // The served-side counters agree with what the client saw: per-class
    // shed counters track controller sheds (hinted); backstop sheds land
    // in `overloaded` instead.
    let m = &gus.metrics.counters;
    assert_eq!(m.shed_batch.load(Ordering::Relaxed), shed_batch);
    assert_eq!(m.shed_interactive.load(Ordering::Relaxed), shed_interactive_hinted);
    assert!(m.degraded_responses.load(Ordering::Relaxed) >= degraded_fracs.len() as u64);

    handle.shutdown();
}

/// `call_with_retry` sleeps the server-provided hint between attempts
/// and succeeds once the surge drains — no client-side tuning.
#[test]
fn call_with_retry_honors_retry_after_ms_until_readmitted() {
    let ds = corpus(800, 0xad2);
    let (handle, gus) = boot(&ds, starved(8));
    let addr = handle.addr.to_string();

    // Conn A saturates the single worker with unclassed bulk reads.
    let mut a = GusClient::connect(&addr).unwrap();
    let heavy: Vec<Point> = ds.points.iter().take(256).cloned().collect();
    let mut a_ids = Vec::new();
    for _ in 0..30 {
        a_ids.push(a.submit(Request::QueryBatch { points: heavy.clone(), k: Some(10) }).unwrap());
    }
    // The tail of A's burst necessarily overran the queue (30 jobs into
    // capacity 8 plus whatever the pinned worker popped): once the last
    // job's backstop rejection has come back, we *know* the queue was
    // full moments ago, so a batch probe decided now sees high pressure.
    match a.wait_response(*a_ids.last().unwrap()).unwrap() {
        Response::Error { code: ErrorCode::Overloaded, .. } => {}
        other => panic!("expected the burst tail to overrun the queue, got {other:?}"),
    }

    let mut b = GusClient::connect(&addr).unwrap();
    b.set_class(Some(Class::Batch));
    let probe = b.submit(Request::Query { point: ds.points[0].clone(), k: Some(10) }).unwrap();
    match b.wait_response(probe).unwrap() {
        Response::Error { code: ErrorCode::Overloaded, retry_after_ms, .. } => {
            let ms = retry_after_ms.expect("controller sheds carry retry_after_ms");
            assert!((10..=5_000).contains(&ms), "retry hint out of band: {ms}");
        }
        other => panic!("expected the saturated server to shed the batch probe, got {other:?}"),
    }

    let done = Arc::new(AtomicBool::new(false));
    let waiter = {
        let done = Arc::clone(&done);
        let point = ds.points[1].clone();
        std::thread::spawn(move || {
            let out = b.call_with_retry(Request::Query { point, k: Some(10) }, 500);
            done.store(true, Ordering::SeqCst);
            out
        })
    };

    // Drain A's admitted jobs, then keep trickling unclassed queries.
    // Admitted traffic observing small sojourns is the fast decay path;
    // the controller also decays on its own (a shed against an empty
    // queue counts as a zero-sojourn observation), so either way the
    // retrying batch call must eventually be re-admitted.
    for id in a_ids.iter().take(a_ids.len() - 1) {
        let _ = a.wait_response(*id).unwrap();
    }
    let mut spins = 0u32;
    while !done.load(Ordering::SeqCst) {
        let _ = a.query(&ds.points[2], 10);
        spins += 1;
        // Liveness backstop only — each spin is a full RPC roundtrip, so
        // this is tens of seconds of decay traffic, far beyond the worst
        // case of a few hinted retry sleeps.
        assert!(spins < 300_000, "batch retry never re-admitted after the surge drained");
    }
    match waiter.join().unwrap().expect("retrying batch call must eventually succeed") {
        Response::Neighbors { neighbors, degraded } => {
            assert!(!neighbors.is_empty());
            assert!(degraded.is_none(), "batch is never served degraded");
        }
        other => panic!("expected neighbors from the retried call, got {other:?}"),
    }
    assert!(gus.metrics.counters.shed_batch.load(Ordering::Relaxed) >= 1);

    handle.shutdown();
}

/// Kill the router's primary replica mid-stream: reads keep succeeding
/// (failover inside the deadline, answers byte-identical to the live
/// node), and router stats report the dead replica's breaker as tripped
/// with its consecutive-failure count.
#[test]
fn router_survives_replica_death_and_reports_breaker_state() {
    let ds = corpus(400, 0xad3);
    let (h1, gus1) = boot(&ds, ServerConfig::default());
    let (h2, _gus2) = boot(&ds, ServerConfig::default());
    let a1 = h1.addr.to_string();
    let a2 = h2.addr.to_string();

    // Reserve a loopback port for the router (run_router serves forever
    // and cannot hand back its bound address).
    let reserve = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let router_addr = reserve.local_addr().unwrap().to_string();
    drop(reserve);
    let opts = RouterOpts {
        listen: router_addr.clone(),
        // a2 first: the latency ranking breaks ties toward the lower
        // index, making a2 the primary we later kill.
        targets: vec![a2.clone(), a1.clone()],
        health_interval: Duration::from_millis(100),
        fail_threshold: 3,
        deadline_ms: 2_000,
    };
    std::thread::spawn(move || {
        let _ = run_router(opts);
    });

    let mut rc = None;
    for _ in 0..200 {
        match GusClient::connect(&router_addr) {
            Ok(c) => {
                rc = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let mut rc = rc.expect("router did not come up");

    // Routed reads answer exactly like the node itself.
    for qi in [0usize, 7, 23] {
        assert_eq!(rc.query(&ds.points[qi], 10).unwrap(), gus1.query(&ds.points[qi], 10).unwrap());
    }

    h2.shutdown();
    for qi in 0..12usize {
        let got = rc
            .query(&ds.points[qi], 10)
            .unwrap_or_else(|e| panic!("query {qi} failed after replica death: {e}"));
        assert_eq!(got, gus1.query(&ds.points[qi], 10).unwrap(), "failover answer diverged");
    }

    let stats = rc.stats().unwrap();
    let router = stats.get("router");
    let replicas = router.get("replicas").as_arr().expect("router stats expose replicas");
    assert_eq!(replicas.len(), 2);
    let dead = replicas
        .iter()
        .find(|r| r.get("addr").as_str() == Some(a2.as_str()))
        .expect("dead replica entry present");
    assert_ne!(
        dead.get("breaker").as_str(),
        Some("closed"),
        "dead replica's breaker must have tripped"
    );
    assert!(dead.get("consecutive_failures").as_u64().unwrap_or(0) >= 3);
    let live = replicas
        .iter()
        .find(|r| r.get("addr").as_str() == Some(a1.as_str()))
        .expect("live replica entry present");
    assert_eq!(live.get("breaker").as_str(), Some("closed"));
    assert!(router.get("hedges").as_u64().is_some(), "router stats expose hedge counters");

    h1.shutdown();
}
