//! Fault-injection integration: disk faults, not just SIGKILL.
//!
//! The contract under test (ISSUE 9): under a deterministic fault plan —
//! ENOSPC mid-append, a torn short write, a failed fsync, an error in
//! the checkpoint's commit/truncate window — the service fails *loudly*,
//! never acknowledges a mutation it cannot recover, and a restart
//! converges to exactly the acknowledged state. Each test hands a
//! private [`FaultInjector`] to one writer (never the process-global
//! plan), so parallel `cargo test` threads cannot share firing state.
//! The chaosproxy half is covered too: passthrough relays verbatim,
//! partition and truncate windows fail the way real networks do, and
//! the drill's per-link schedule derivation replays bit-for-bit from
//! its seed.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dynamic_gus::config::{FsyncPolicy, GusConfig, ScorerKind};
use dynamic_gus::coordinator::{snapshot, wal, DynamicGus};
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::data::Dataset;
use dynamic_gus::fault::{proxy, FaultInjector, FaultPlan, NetFault, Schedule, Window};
use dynamic_gus::util::hash::mix2;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("gus-fault-int").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn cfg(fsync: FsyncPolicy) -> GusConfig {
    GusConfig {
        scorer: ScorerKind::Native,
        filter_p: 10.0,
        n_shards: 2,
        fsync,
        ..GusConfig::default()
    }
}

/// Boot a WAL-backed service plus its uninterrupted twin on the first
/// `boot` points of `ds`.
fn booted(ds: &Dataset, dir: &PathBuf, boot: usize, fsync: FsyncPolicy) -> (DynamicGus, DynamicGus) {
    let live =
        DynamicGus::bootstrap(ds.schema.clone(), cfg(fsync), &ds.points[..boot], 2).unwrap();
    wal::init_fresh(&live, dir).unwrap();
    let twin =
        DynamicGus::bootstrap(ds.schema.clone(), cfg(fsync), &ds.points[..boot], 2).unwrap();
    (live, twin)
}

/// Arm one service's writer with a private plan (no process-global state).
fn arm(gus: &DynamicGus, spec: &str) -> Arc<FaultInjector> {
    let inj = FaultInjector::new(FaultPlan::parse(spec).unwrap());
    gus.wal().unwrap().set_fault_injector(Some(Arc::clone(&inj)));
    inj
}

fn wal_len(dir: &PathBuf) -> u64 {
    std::fs::metadata(dir.join(wal::WAL_FILE)).unwrap().len()
}

/// Two services answer a fixed query workload identically.
fn assert_equivalent(recovered: &DynamicGus, reference: &DynamicGus, ds: &Dataset, tag: &str) {
    assert_eq!(recovered.len(), reference.len(), "{tag}: corpus size");
    for qi in (0..ds.points.len()).step_by(19) {
        assert_eq!(
            recovered.query(&ds.points[qi], 10).unwrap(),
            reference.query(&ds.points[qi], 10).unwrap(),
            "{tag}: query {qi} diverged"
        );
    }
}

/// ENOSPC mid-append: the short write is rolled back to the previous
/// record boundary, the failed mutation is not acknowledged and not
/// applied, and a retry reuses the same sequence number — recovery sees
/// a gap-free log holding exactly the acknowledged mutations.
#[test]
fn enospc_mid_append_rolls_back_to_record_boundary() {
    let ds = SyntheticConfig::arxiv_like(160, 0xf41).generate();
    let dir = tmpdir("enospc");
    let (live, twin) = booted(&ds, &dir, 100, FsyncPolicy::Never);
    let inj = arm(&live, "wal_append:enospc@seq=3");

    for p in &ds.points[100..102] {
        live.insert(p.clone()).unwrap();
        twin.insert(p.clone()).unwrap();
    }
    let boundary = wal_len(&dir);

    let err = live.insert(ds.points[102].clone()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "{msg}");
    assert!(msg.contains("No space left"), "{msg}");
    assert_eq!(inj.fired_total(), 1);
    assert_eq!(wal_len(&dir), boundary, "partial frame must be trimmed");
    assert!(!live.contains(ds.points[102].id), "failed insert must not apply");
    assert_eq!(live.wal_seq(), 2, "failed append must not consume a seq");

    // The rule is spent: the retry succeeds and reuses seq 3.
    live.insert(ds.points[102].clone()).unwrap();
    twin.insert(ds.points[102].clone()).unwrap();
    assert_eq!(live.wal_seq(), 3);
    live.insert(ds.points[103].clone()).unwrap();
    twin.insert(ds.points[103].clone()).unwrap();
    drop(live);

    let rec = wal::recover(&dir, 2).unwrap();
    assert!(!rec.torn_tail);
    assert_eq!(rec.replayed, 4);
    assert!(rec.gus.contains(ds.points[103].id));
    assert_equivalent(&rec.gus, &twin, &ds, "enospc");
}

/// A torn short write (`wal_append:torn`) behaves like ENOSPC from the
/// caller's side: loud error, clean rollback, clean retry, no torn tail
/// left for recovery.
#[test]
fn torn_append_rolls_back_and_retries_cleanly() {
    let ds = SyntheticConfig::arxiv_like(140, 0xf42).generate();
    let dir = tmpdir("torn");
    let (live, twin) = booted(&ds, &dir, 100, FsyncPolicy::Never);
    arm(&live, "wal_append:torn@seq=2");

    live.insert(ds.points[100].clone()).unwrap();
    twin.insert(ds.points[100].clone()).unwrap();
    let boundary = wal_len(&dir);

    let err = live.insert(ds.points[101].clone()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("torn"), "{msg}");
    assert_eq!(wal_len(&dir), boundary);
    assert!(!live.contains(ds.points[101].id));

    live.insert(ds.points[101].clone()).unwrap();
    twin.insert(ds.points[101].clone()).unwrap();
    drop(live);

    let rec = wal::recover(&dir, 2).unwrap();
    assert!(!rec.torn_tail, "rollback must leave no partial frame");
    assert_eq!(rec.replayed, 2);
    assert_equivalent(&rec.gus, &twin, &ds, "torn");
}

/// fsyncgate: a failed fsync poisons the writer — every further append
/// is refused with a message telling the operator to restart — and the
/// restart recovers cleanly and accepts appends again.
#[test]
fn fsync_failure_poisons_writer_until_restart() {
    let ds = SyntheticConfig::arxiv_like(130, 0xf43).generate();
    let dir = tmpdir("fsync-poison");
    let (live, _twin) = booted(&ds, &dir, 100, FsyncPolicy::Always);
    arm(&live, "fsync:err@nth=1");

    let err = live.insert(ds.points[100].clone()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "{msg}");
    assert!(msg.contains("fsync"), "{msg}");
    assert!(!live.contains(ds.points[100].id), "unacked mutation must not apply");

    // The rule is spent, but the writer must stay poisoned anyway: after
    // a failed fsync the kernel's dirty-page state is unknowable.
    let err = live.insert(ds.points[101].clone()).unwrap_err();
    assert!(format!("{err:#}").contains("poisoned"), "{err:#}");
    drop(live);

    // Restart: recovery re-scans the log (the unacked record survived in
    // the page cache — surviving is allowed, losing acked ones is not)
    // and the recovered writer is unpoisoned.
    let rec = wal::recover(&dir, 2).unwrap();
    assert_eq!(rec.replayed, 1);
    rec.gus.insert(ds.points[101].clone()).unwrap();
    assert!(rec.gus.contains(ds.points[101].id));
}

/// The crash window *between* checkpoint commit and WAL truncation: the
/// snapshot rename has committed when truncation fails, so a restart
/// must treat the checkpoint as authoritative and skip every stale
/// record still in the log.
#[test]
fn failed_truncate_after_commit_recovers_exactly() {
    let ds = SyntheticConfig::arxiv_like(150, 0xf44).generate();
    let dir = tmpdir("truncate-window");
    let (live, twin) = booted(&ds, &dir, 100, FsyncPolicy::Never);
    arm(&live, "wal_truncate:err@nth=1");

    for p in &ds.points[100..130] {
        live.insert(p.clone()).unwrap();
        twin.insert(p.clone()).unwrap();
    }
    let err = live.checkpoint().unwrap_err();
    assert!(format!("{err:#}").contains("truncating WAL"), "{err:#}");
    assert!(wal_len(&dir) > 0, "failed truncation leaves the log in place");
    // The snapshot itself committed before the truncate site fired.
    let (_restored, last_seq) = snapshot::restore_with_seq(&dir, 2).unwrap();
    assert_eq!(last_seq, 30);
    drop(live);

    let rec = wal::recover(&dir, 2).unwrap();
    assert_eq!(rec.replayed, 0, "records ≤ last_seq must be skipped");
    assert_equivalent(&rec.gus, &twin, &ds, "truncate-window");
}

/// A failure at the snapshot commit rename leaves the *previous*
/// checkpoint authoritative (the WAL still holds everything), and the
/// spent rule lets a retry checkpoint go through.
#[test]
fn failed_checkpoint_rename_keeps_previous_checkpoint() {
    let ds = SyntheticConfig::arxiv_like(150, 0xf45).generate();
    let dir = tmpdir("rename-window");
    let (live, twin) = booted(&ds, &dir, 100, FsyncPolicy::Never);
    arm(&live, "checkpoint_rename:err@nth=1");

    for p in &ds.points[100..120] {
        live.insert(p.clone()).unwrap();
        twin.insert(p.clone()).unwrap();
    }
    let err = live.checkpoint().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "{msg}");
    assert!(msg.contains(snapshot::SNAPSHOT_META), "{msg}");
    // Commit never happened: the metadata still points at the bootstrap
    // snapshot and the untruncated WAL replays the full delta.
    let (_restored, last_seq) = snapshot::restore_with_seq(&dir, 2).unwrap();
    assert_eq!(last_seq, 0, "previous checkpoint must stay authoritative");
    assert!(wal_len(&dir) > 0);

    // Retry: the rule is spent, the checkpoint commits and truncates.
    assert_eq!(live.checkpoint().unwrap(), 20);
    assert_eq!(live.wal_pending(), 0);
    let (_restored, last_seq) = snapshot::restore_with_seq(&dir, 2).unwrap();
    assert_eq!(last_seq, 20);
    drop(live);

    let rec = wal::recover(&dir, 2).unwrap();
    assert_equivalent(&rec.gus, &twin, &ds, "rename-window");
}

/// Every fired injection is visible in the `"faults"` stats section the
/// `stats` RPC serves — the drill's proof that the plan executed.
#[test]
fn fired_injections_show_up_in_stats() {
    let ds = SyntheticConfig::arxiv_like(110, 0xf46).generate();
    let dir = tmpdir("stats");
    let (live, _twin) = booted(&ds, &dir, 100, FsyncPolicy::Never);
    arm(&live, "wal_append:enospc@nth=1");

    let before = dynamic_gus::metrics::faults().to_json();
    let enospc0 = before.get("injected").get("enospc").as_u64().unwrap();
    live.insert(ds.points[100].clone()).unwrap_err();

    // Gauges are process-wide (like the plan they mirror), so assert
    // deltas, not absolutes: parallel tests may fire their own faults.
    let after = live.stats_json();
    let faults = after.get("faults");
    assert!(
        faults.get("injected").get("enospc").as_u64().unwrap() >= enospc0 + 1,
        "stats must count the fired enospc: {faults:?}"
    );
    assert!(faults.get("backoff_retries").as_u64().is_some());
    assert!(faults.get("circuit_open_windows").as_u64().is_some());
}

/// A TCP echo server on an ephemeral port (the chaosproxy's upstream).
fn spawn_echo() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { continue };
            std::thread::spawn(move || {
                let mut buf = [0u8; 1024];
                loop {
                    match s.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if s.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
            });
        }
    });
    addr
}

/// An armed chaosproxy with an empty schedule is a faithful relay.
#[test]
fn chaosproxy_passthrough_relays_verbatim() {
    let upstream = spawn_echo();
    let proxy = proxy::start("127.0.0.1:0", &upstream, Schedule::passthrough()).unwrap();
    proxy.arm();

    let mut conn = TcpStream::connect(proxy.addr()).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for msg in [&b"ping"[..], &b"pong-pong"[..]] {
        conn.write_all(msg).unwrap();
        let mut back = vec![0u8; msg.len()];
        conn.read_exact(&mut back).unwrap();
        assert_eq!(&back, msg, "echo through passthrough proxy diverged");
    }
}

/// A partition window from t=0 looks like a dead host: connections are
/// accepted and dropped, so the client sees EOF/reset, never an answer.
#[test]
fn chaosproxy_partition_cuts_connections() {
    let upstream = spawn_echo();
    let schedule = Schedule {
        windows: vec![Window { start_ms: 0, end_ms: 600_000, fault: NetFault::Partition }],
    };
    let proxy = proxy::start("127.0.0.1:0", &upstream, schedule).unwrap();
    proxy.arm();

    let mut conn = TcpStream::connect(proxy.addr()).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = conn.write_all(b"hello?");
    let mut buf = [0u8; 8];
    assert!(
        matches!(conn.read(&mut buf), Ok(0) | Err(_)),
        "partitioned proxy must never deliver bytes"
    );
}

/// A truncate window tears the stream mid-frame: the receiver gets a
/// strict prefix of what was sent, then the wire dies.
#[test]
fn chaosproxy_truncate_tears_mid_frame() {
    let upstream = spawn_echo();
    let schedule = Schedule {
        windows: vec![Window { start_ms: 0, end_ms: 600_000, fault: NetFault::Truncate }],
    };
    let proxy = proxy::start("127.0.0.1:0", &upstream, schedule).unwrap();
    proxy.arm();

    let mut conn = TcpStream::connect(proxy.addr()).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    conn.write_all(b"0123456789abcdef").unwrap();
    let mut got = Vec::new();
    let _ = conn.read_to_end(&mut got);
    assert!(got.len() < 16, "truncate window must tear the frame, got {} bytes", got.len());
}

/// The acceptance criterion: the drill's per-link schedule derivation
/// (`mix2(seed, link)`, partition guaranteed on the leader link) replays
/// bit-for-bit from the seed — same seed, same windows, same digests;
/// different seeds diverge.
#[test]
fn chaos_drill_schedules_replay_bit_for_bit() {
    let span_ms = 10_000;
    let links = |seed: u64| -> Vec<Schedule> {
        (0..3u64).map(|i| Schedule::generate(mix2(seed, i), span_ms, i == 0)).collect()
    };
    for seed in [0xc405u64, 7, 0xdead_beef] {
        let a = links(seed);
        let b = links(seed);
        assert_eq!(a, b, "seed {seed:#x}: schedules must replay bit-for-bit");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.digest(), y.digest());
            assert_eq!(x.describe(), y.describe());
        }
        assert!(
            a[0].windows.iter().any(|w| w.fault == NetFault::Partition),
            "seed {seed:#x}: leader link must carry a partition window"
        );
    }
    assert_ne!(
        links(1)[0].digest(),
        links(2)[0].digest(),
        "distinct seeds must produce distinct leader schedules"
    );
}
