//! Socket-level crash/recovery acceptance: open-loop loadgen traffic
//! against a real served socket, the server killed at a random point
//! mid-load, recovery from the WAL — then prove that
//!
//! 1. every *acknowledged* mutation survived (the durability contract:
//!    ack ⇒ logged ⇒ recovered),
//! 2. the recovered state is an *applied prefix* of each connection's
//!    submission order (unacked in-flight ops may or may not have landed,
//!    but never out of order, and never beyond what was submitted),
//! 3. a twin service that replays exactly that prefix answers a fixed
//!    query workload **byte-identically** to the recovered service.
//!
//! The kill is `ServerHandle::shutdown` at a random instant: the accept
//! loop dies, connection sockets drop, and the generator sees resets
//! mid-flight — producing a genuine unacked tail. (File-level torn-tail
//! and `kill -9` process-death crashes are covered by `wal_test.rs` and
//! `gus loadgen --crash-at`; this test targets the socket/ledger layer.)

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dynamic_gus::config::ScorerKind;
use dynamic_gus::coordinator::{wal, DynamicGus};
use dynamic_gus::loadgen::runner::{run_load, LoadOptions, LoadOutcome};
use dynamic_gus::loadgen::scenario::CorpusSpec;
use dynamic_gus::loadgen::{verify, Mix};
use dynamic_gus::prop_assert;
use dynamic_gus::server::{serve, ServerConfig, ServerHandle};
use dynamic_gus::testing::proptest_cases;
use dynamic_gus::util::rng::Rng;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("gus-crash-int").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

struct CrashRig {
    corpus: CorpusSpec,
    ds: dynamic_gus::data::Dataset,
    dir: PathBuf,
}

impl CrashRig {
    fn new(rng: &mut Rng, tag: &str) -> CrashRig {
        let n = 250 + rng.below_usize(100);
        let seed = rng.below(1 << 32);
        let corpus = CorpusSpec::new("arxiv_like", n, seed, 10);
        let ds = corpus.generate().unwrap();
        let dir = tmpdir(&format!("{tag}-{seed:x}-{n}"));
        CrashRig { corpus, ds, dir }
    }

    /// Bootstrap the corpus (Native scorer, fixed thread count so the
    /// twin is built identically).
    fn bootstrap(&self) -> DynamicGus {
        let mut cfg = self.corpus.gus_config();
        cfg.scorer = ScorerKind::Native;
        cfg.n_shards = 2;
        DynamicGus::bootstrap(self.ds.schema.clone(), cfg, &self.ds.points, 2).unwrap()
    }

    /// Boot a WAL-backed server on a loopback port.
    fn serve_live(&self) -> (ServerHandle, Arc<DynamicGus>) {
        let live = self.bootstrap();
        wal::init_fresh(&live, &self.dir).unwrap();
        let live = Arc::new(live);
        let handle =
            serve(Arc::clone(&live), "127.0.0.1:0", ServerConfig::from_gus(live.config()))
                .unwrap();
        (handle, live)
    }

    /// Drive the open-loop generator while a killer thread shuts the
    /// server down `kill_after` into the run. Returns once both the
    /// generator and the shutdown (including the queue drain — so no WAL
    /// appends race recovery) have finished.
    fn load_and_kill(
        &self,
        opts: &LoadOptions,
        handle: ServerHandle,
        kill_after: Duration,
    ) -> LoadOutcome {
        let addr = handle.addr.to_string();
        let sampler = self.corpus.sampler().unwrap();
        std::thread::scope(|s| {
            let killer = s.spawn(move || {
                std::thread::sleep(kill_after);
                handle.shutdown();
            });
            let outcome = run_load(&addr, opts, &sampler).unwrap();
            killer.join().unwrap();
            outcome
        })
    }
}

fn crash_opts(rng: &mut Rng, connections: usize) -> LoadOptions {
    LoadOptions {
        rate: 150.0 + rng.below(150) as f64,
        duration: Duration::from_millis(600),
        mix: Mix::parse("insert=35,delete=10,query=50,query_batch=5").unwrap(),
        connections,
        k: 10,
        batch: 8,
        deadline_ms: None,
        seed: rng.below(1 << 32),
        record_points: true,
        classes: false,
    }
}

/// Durability across a random-point kill, multi-connection: every acked
/// mutation survives recovery, and each connection's recovered state is
/// an applied prefix of its submission order.
#[test]
fn prop_socket_crash_preserves_acked_mutations() {
    proptest_cases(3, |rng: &mut Rng| {
        let rig = CrashRig::new(rng, "acked");
        let (handle, _live) = rig.serve_live();
        let opts = crash_opts(rng, 2);
        let kill_after = Duration::from_millis(30 + rng.below(450));
        let outcome = rig.load_and_kill(&opts, handle, kill_after);

        let acked: usize =
            outcome.ledgers.iter().flat_map(|l| &l.records).filter(|r| r.acked).count();
        let rec = wal::recover(&rig.dir, 2).unwrap();

        let expected = verify::determinate_final_state(&outcome.ledgers);
        let violations = verify::check_survival_inproc(&rec.gus, &expected);
        prop_assert!(
            violations.is_empty(),
            "acked mutations lost after crash at {kill_after:?} ({acked} acked): {violations:?}"
        );
        for (i, ledger) in outcome.ledgers.iter().enumerate() {
            let m = verify::find_applied_prefix(ledger, |id| rec.gus.contains(id));
            prop_assert!(
                m.is_some(),
                "conn {i}: no applied prefix of {} records explains the recovered state",
                ledger.records.len()
            );
        }
    });
}

/// Byte-identical twin equivalence, single connection (one total
/// mutation order, so the twin can replay it exactly): recover, find the
/// applied prefix, replay it into an uninterrupted twin, and require
/// identical answers on a fixed query workload — corpus probes and the
/// run's own surviving inserts.
#[test]
fn prop_crash_twin_answers_byte_identically() {
    proptest_cases(3, |rng: &mut Rng| {
        let rig = CrashRig::new(rng, "twin");
        let (handle, _live) = rig.serve_live();
        let opts = crash_opts(rng, 1);
        let kill_after = Duration::from_millis(30 + rng.below(450));
        let outcome = rig.load_and_kill(&opts, handle, kill_after);

        let rec = wal::recover(&rig.dir, 2).unwrap();
        let ledger = &outcome.ledgers[0];
        let m = verify::find_applied_prefix(ledger, |id| rec.gus.contains(id))
            .expect("no applied prefix explains the recovered state");
        let last_acked = ledger.records.iter().rposition(|r| r.acked).map_or(0, |i| i + 1);
        prop_assert!(
            m >= last_acked,
            "applied prefix {m} fails to cover the acked prefix {last_acked}"
        );

        // The uninterrupted twin: same bootstrap, then exactly the
        // applied prefix of the generator's mutation stream.
        let twin = rig.bootstrap();
        verify::replay_prefix(&twin, ledger, m).unwrap();

        assert_eq!(rec.gus.len(), twin.len(), "corpus size diverged");
        for qi in (0..rig.ds.points.len()).step_by(13) {
            assert_eq!(
                rec.gus.query(&rig.ds.points[qi], 10).unwrap(),
                twin.query(&rig.ds.points[qi], 10).unwrap(),
                "query {qi} diverged after crash/recovery"
            );
        }
        // Probe the run's own surviving inserts too (the points the
        // crash actually put at risk), by id on both sides.
        for r in ledger.records.iter().take(m) {
            if rec.gus.contains(r.id) {
                assert_eq!(
                    rec.gus.query_by_id(r.id, 10).unwrap(),
                    twin.query_by_id(r.id, 10).unwrap(),
                    "query_by_id {} diverged after crash/recovery",
                    r.id
                );
            }
        }
    });
}
