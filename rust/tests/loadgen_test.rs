//! Open-loop load-harness integration: the runner against a real served
//! socket, and the three built-in scenarios at smoke scale.
//!
//! Smoke gates are deliberately correctness-only (no error responses, no
//! unanswered requests, every determinate mutation's effect present,
//! staleness finite) — latency SLOs are checked at full scale by
//! `gus loadgen`, where the hardware is known.

use std::sync::Arc;
use std::time::Duration;

use dynamic_gus::config::ScorerKind;
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::loadgen::runner::{run_load, LoadOptions};
use dynamic_gus::loadgen::scenario::{builtin, Scenario};
use dynamic_gus::loadgen::{verify, Mix};
use dynamic_gus::server::{serve, ServerConfig};

/// Boot a scenario's corpus in-process with the Native scorer (hermetic:
/// no XLA artifacts in the test environment).
fn boot(sc: &Scenario) -> (dynamic_gus::server::ServerHandle, Arc<DynamicGus>) {
    let ds = sc.corpus.generate().unwrap();
    let mut cfg = sc.corpus.gus_config();
    cfg.scorer = ScorerKind::Native;
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap());
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::from_gus(gus.config())).unwrap();
    (handle, gus)
}

/// The smoke contract every scenario must meet on any hardware.
fn assert_smoke_clean(sc: &Scenario) {
    let (handle, gus) = boot(sc);
    let opts = LoadOptions::from_scenario(sc);
    let sampler = sc.corpus.sampler().unwrap();
    let outcome = run_load(&handle.addr.to_string(), &opts, &sampler).unwrap();
    let r = &outcome.report;

    assert!(r.sent > 0, "{}: generator sent nothing", sc.name);
    assert!(
        r.errors.is_empty(),
        "{}: error responses under smoke load: {:?}",
        sc.name,
        r.errors
    );
    assert_eq!(r.transport_lost, 0, "{}: requests never answered", sc.name);
    assert_eq!(r.ok, r.sent, "{}: ok ({}) != sent ({})", sc.name, r.ok, r.sent);

    // Every determinate mutation's effect is present in the live service.
    let expected = verify::determinate_final_state(&outcome.ledgers);
    let violations = verify::check_survival_inproc(&gus, &expected);
    assert!(
        violations.is_empty(),
        "{}: acked mutations missing: {violations:?}",
        sc.name
    );
    // No crash happened, so *every* mutation was acked (determinate).
    let mutations: usize = outcome.ledgers.iter().map(|l| l.records.len()).sum();
    assert!(
        sc.mix.has_mutations() == (mutations > 0),
        "{}: mutation ledger does not reflect the mix",
        sc.name
    );

    // Staleness: recorded for every acked mutation, finite, and visible
    // in the report.
    if sc.mix.has_mutations() {
        assert_eq!(r.staleness_count as usize, mutations, "{}: staleness count", sc.name);
        assert!(
            r.staleness_p99_ms.is_finite() && r.staleness_p99_ms >= 0.0,
            "{}: staleness p99 {} not finite",
            sc.name,
            r.staleness_p99_ms
        );
    }
    handle.shutdown();
}

#[test]
fn scenario_smoke_android_security() {
    assert_smoke_clean(&builtin("android_security").unwrap().smoke());
}

#[test]
fn scenario_smoke_recsys_stream() {
    assert_smoke_clean(&builtin("recsys_stream").unwrap().smoke());
}

#[test]
fn scenario_smoke_dynamic_clustering() {
    assert_smoke_clean(&builtin("dynamic_clustering").unwrap().smoke());
}

/// The runner itself, decoupled from the scenario layer: a mixed
/// workload where inserts/deletes are verified against the service and
/// per-kind accounting adds up.
#[test]
fn runner_accounts_for_every_request() {
    let mut sc = builtin("dynamic_clustering").unwrap().smoke();
    sc.corpus.n = 600;
    sc.rate = 150.0;
    sc.duration_s = 0.5;
    sc.connections = 2;
    sc.mix = Mix::parse("insert=30,delete=10,query=50,query_batch=10").unwrap();
    let (handle, gus) = boot(&sc);

    let mut opts = LoadOptions::from_scenario(&sc);
    opts.record_points = true;
    opts.duration = Duration::from_millis(500);
    let sampler = sc.corpus.sampler().unwrap();
    let outcome = run_load(&handle.addr.to_string(), &opts, &sampler).unwrap();
    let r = &outcome.report;

    assert!(r.errors.is_empty(), "errors: {:?}", r.errors);
    assert_eq!(r.transport_lost, 0);
    // Per-kind tallies sum to the totals, and latency was recorded for
    // every acked request.
    assert_eq!(r.per_kind.iter().map(|k| k.sent).sum::<u64>(), r.sent);
    assert_eq!(r.per_kind.iter().map(|k| k.ok).sum::<u64>(), r.ok);
    assert_eq!(r.latency.count, r.ok);
    assert_eq!(outcome.ledgers.len(), 2);

    // record_points captured every insert, so a twin could replay.
    for ledger in &outcome.ledgers {
        for rec in &ledger.records {
            assert!(rec.acked, "no crash, so every mutation acked");
            if rec.kind == dynamic_gus::loadgen::runner::MutKind::Insert {
                let idx = rec.point.expect("insert with record_points carries its point");
                assert_eq!(ledger.points[idx].id, rec.id);
            }
        }
    }
    let expected = verify::determinate_final_state(&outcome.ledgers);
    assert!(verify::check_survival_inproc(&gus, &expected).is_empty());

    // The same ledgers also verify over the wire (the external-server
    // path `gus loadgen --addr` uses).
    let mut client = dynamic_gus::client::GusClient::connect(&handle.addr.to_string()).unwrap();
    let rpc_violations = verify::check_survival_rpc(&mut client, &expected).unwrap();
    assert!(rpc_violations.is_empty(), "RPC probe disagreed: {rpc_violations:?}");

    handle.shutdown();
}

/// Deterministic replay: the same seed offers the same workload — same
/// arrival count, same per-kind counts, same mutation-kind sequence,
/// same inserted ids — even though wall-clock timing differs run to run.
/// (Delete *targets* are excluded by design: which acked insert a delete
/// picks depends on server ack timing.)
#[test]
fn same_seed_replays_the_same_workload() {
    let mut sc = builtin("recsys_stream").unwrap().smoke();
    sc.corpus.n = 400;
    sc.rate = 120.0;
    sc.duration_s = 0.4;
    sc.connections = 2;
    sc.mix = Mix::parse("insert=30,delete=10,query=55,query_batch=5").unwrap();
    let sampler = sc.corpus.sampler().unwrap();
    let opts = LoadOptions::from_scenario(&sc);

    // Per connection: the mutation-kind sequence, with insert ids pinned.
    let offered = |outcome: &dynamic_gus::loadgen::LoadOutcome| -> Vec<Vec<(bool, u64)>> {
        use dynamic_gus::loadgen::runner::MutKind;
        outcome
            .ledgers
            .iter()
            .map(|l| {
                l.records
                    .iter()
                    .map(|r| match r.kind {
                        MutKind::Insert => (true, r.id),
                        MutKind::Delete => (false, 0),
                    })
                    .collect()
            })
            .collect()
    };
    let (handle_a, _gus_a) = boot(&sc);
    let a = run_load(&handle_a.addr.to_string(), &opts, &sampler).unwrap();
    handle_a.shutdown();
    let (handle_b, _gus_b) = boot(&sc);
    let b = run_load(&handle_b.addr.to_string(), &opts, &sampler).unwrap();
    handle_b.shutdown();

    assert_eq!(a.report.sent, b.report.sent, "same schedule, same arrivals");
    for (ka, kb) in a.report.per_kind.iter().zip(&b.report.per_kind) {
        assert_eq!(ka.sent, kb.sent, "kind {} diverged across replays", ka.kind);
    }
    assert_eq!(offered(&a), offered(&b), "offered mutation stream diverged across replays");
}
