//! Integration: AOT artifacts × PJRT runtime × native oracle parity.
//!
//! Requires `make artifacts` (skips with a visible message otherwise).
//! This is the load-bearing cross-language test: it proves the python
//! JAX/Pallas graph and the Rust featurizer/scorer implement the same
//! mathematical function, so the XLA path can serve what the model was
//! trained for.

use dynamic_gus::features::{FeatureValue, Point, Schema};
use dynamic_gus::runtime::artifacts_dir;
use dynamic_gus::scorer::{
    MlpWeights, NativeScorer, PairFeaturizer, PairScorer, XlaScorer, HIDDEN,
};
use dynamic_gus::util::rng::Rng;

fn have_artifacts(schema: &str) -> bool {
    XlaScorer::artifacts_available(&artifacts_dir(), schema)
}

fn random_points(schema: &Schema, n: usize, seed: u64) -> Vec<Point> {
    let mut rng = Rng::seeded(seed);
    let d = schema.primary_dense_dim();
    (0..n as u64)
        .map(|id| {
            let features = schema
                .channels
                .iter()
                .map(|c| match c.kind {
                    dynamic_gus::features::FeatureKind::Dense => {
                        FeatureValue::Dense(rng.normal_vec_f32(d))
                    }
                    dynamic_gus::features::FeatureKind::Scalar => {
                        FeatureValue::Scalar(1995.0 + rng.below(29) as f32)
                    }
                    dynamic_gus::features::FeatureKind::Tokens => FeatureValue::Tokens(
                        (0..rng.below_usize(12)).map(|_| rng.below(500)).collect(),
                    ),
                })
                .collect();
            Point::new(id, features)
        })
        .collect()
}

fn parity_for(schema: Schema, seed: u64) {
    if !have_artifacts(&schema.name) {
        eprintln!(
            "SKIP runtime_parity({}): artifacts missing — run `make artifacts`",
            schema.name
        );
        return;
    }
    let featurizer = PairFeaturizer::new(&schema);
    // Use the *trained* weights so the test also validates the weights file.
    let weights =
        MlpWeights::load(&XlaScorer::weights_path(&artifacts_dir(), &schema.name)).unwrap();
    let native = NativeScorer::new(featurizer.clone(), weights.clone());
    let xla = XlaScorer::with_weights(featurizer, &artifacts_dir(), weights).unwrap();

    let pts = random_points(&schema, 80, seed);
    let q = &pts[0];
    // Sweep batch sizes across variant boundaries incl. padding + chunking.
    for n in [1usize, 2, 31, 32, 33, 79] {
        let cands: Vec<&Point> = pts[..n].iter().collect();
        let a = native.score_batch(q, &cands);
        let b = xla.score_batch(q, &cands);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() < 1e-5,
                "{}: n={n} cand {i}: native {x} vs xla {y}",
                schema.name
            );
        }
    }
}

#[test]
fn arxiv_like_parity() {
    parity_for(Schema::arxiv_like(128), 11);
}

#[test]
fn products_like_parity() {
    parity_for(Schema::products_like(100), 12);
}

#[test]
fn random_weights_parity_arxiv() {
    // Independent of training: random weights through both paths.
    let schema = Schema::arxiv_like(128);
    if !have_artifacts(&schema.name) {
        eprintln!("SKIP random_weights_parity: artifacts missing");
        return;
    }
    let featurizer = PairFeaturizer::new(&schema);
    let weights = MlpWeights::random(featurizer.input_dim(), HIDDEN, 999);
    let native = NativeScorer::new(featurizer.clone(), weights.clone());
    let xla = XlaScorer::with_weights(featurizer, &artifacts_dir(), weights).unwrap();
    let pts = random_points(&schema, 40, 13);
    let cands: Vec<&Point> = pts[1..].iter().collect();
    let a = native.score_batch(&pts[0], &cands);
    let b = xla.score_batch(&pts[0], &cands);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5, "native {x} vs xla {y}");
    }
}

#[test]
fn scores_are_probabilities() {
    let schema = Schema::products_like(100);
    if !have_artifacts(&schema.name) {
        eprintln!("SKIP scores_are_probabilities: artifacts missing");
        return;
    }
    let featurizer = PairFeaturizer::new(&schema);
    let xla = XlaScorer::load(featurizer, &artifacts_dir()).unwrap();
    let pts = random_points(&schema, 20, 14);
    let cands: Vec<&Point> = pts[1..].iter().collect();
    for s in xla.score_batch(&pts[0], &cands) {
        assert!((0.0..=1.0).contains(&s), "score {s} out of range");
    }
}
