//! Protocol-layer conformance: golden wire fixtures, encode↔decode
//! round-trip properties, and malformed-input behavior.
//!
//! The golden fixtures in `tests/fixtures/` pin the wire format byte for
//! byte — an accidental change to field names, key order, or number
//! formatting fails here loudly instead of silently breaking clients,
//! WAL replay, and cross-version compatibility.

use dynamic_gus::admission::Class;
use dynamic_gus::coordinator::ScoredNeighbor;
use dynamic_gus::features::{FeatureValue, Point};
use dynamic_gus::protocol::{
    decode_request, Envelope, ErrorCode, Incoming, Request, Response, MAX_K,
};
use dynamic_gus::util::json::Json;
use dynamic_gus::util::rng::Rng;

const REQUEST_FIXTURES: &str = include_str!("fixtures/protocol_v1_requests.txt");
const RESPONSE_FIXTURES: &str = include_str!("fixtures/protocol_v1_responses.txt");

fn fixture_point(id: u64) -> Point {
    Point::new(
        id,
        vec![FeatureValue::Dense(vec![0.5, -1.5]), FeatureValue::Scalar(2021.0)],
    )
}

/// The typed values corresponding, line for line, to
/// `fixtures/protocol_v1_requests.txt`.
fn request_fixture_values() -> Vec<Incoming> {
    vec![
        Incoming::Legacy(Request::Insert { point: fixture_point(1) }),
        Incoming::Legacy(Request::Delete { id: 42 }),
        Incoming::Legacy(Request::Query { point: fixture_point(1), k: Some(5) }),
        Incoming::Legacy(Request::QueryId { id: 7, k: None }),
        Incoming::Legacy(Request::InsertBatch {
            points: vec![fixture_point(1), fixture_point(2)],
        }),
        Incoming::Legacy(Request::DeleteBatch { ids: vec![1, 2, 3] }),
        Incoming::Legacy(Request::QueryBatch { points: vec![fixture_point(9)], k: Some(2) }),
        Incoming::Legacy(Request::Checkpoint),
        Incoming::Legacy(Request::Stats),
        Incoming::Legacy(Request::RefreshTables),
        Incoming::V1(Envelope {
            id: 7,
            deadline_ms: Some(50),
            class: None,
            request: Request::QueryId { id: 3, k: Some(5) },
        }),
        Incoming::V1(Envelope {
            id: 9,
            deadline_ms: None,
            class: None,
            request: Request::Insert { point: fixture_point(1) },
        }),
    ]
}

/// The typed values corresponding, line for line, to
/// `fixtures/protocol_v1_responses.txt` (`None` id = legacy shape).
fn response_fixture_values() -> Vec<(Option<u64>, Response)> {
    let n = |id, score: f32, dot: f32| ScoredNeighbor { id, score, dot };
    vec![
        (None, Response::Existed { existed: false }),
        (None, Response::ExistedBatch { existed: vec![true, false] }),
        (
            None,
            Response::Neighbors {
                neighbors: vec![n(4, 0.5, 3.0), n(9, 0.25, -0.5)],
                degraded: None,
            },
        ),
        (
            None,
            Response::Results { results: vec![vec![n(2, 0.5, 1.0)], vec![]], degraded: None },
        ),
        (None, Response::Checkpoint { seq: 1041 }),
        (
            None,
            Response::Stats { stats: Json::obj(vec![("points", Json::num(10.0))]) },
        ),
        (None, Response::error(ErrorCode::NotFound, "unknown point 3")),
        (Some(7), Response::Existed { existed: true }),
        (
            Some(9),
            Response::error(ErrorCode::DeadlineExceeded, "deadline of 50ms expired before execution"),
        ),
        (None, Response::error(ErrorCode::Overloaded, "run queue full; retry (server saturated)")),
    ]
}

fn encode_incoming(inc: &Incoming) -> String {
    match inc {
        Incoming::Legacy(r) => r.to_wire().dump(),
        Incoming::V1(e) => e.to_wire().dump(),
    }
}

#[test]
fn golden_request_fixtures_are_byte_stable() {
    let lines: Vec<&str> = REQUEST_FIXTURES.lines().filter(|l| !l.is_empty()).collect();
    let values = request_fixture_values();
    assert_eq!(lines.len(), values.len(), "fixture/value count mismatch");
    for (line, value) in lines.iter().zip(&values) {
        // Encoding is byte-identical to the checked-in fixture.
        assert_eq!(&encode_incoming(value), line, "encode drifted for {line}");
        // The fixture decodes back to the same typed value.
        let decoded = decode_request(line).unwrap_or_else(|e| panic!("{line}: {e:?}"));
        assert_eq!(&decoded, value, "decode drifted for {line}");
    }
}

#[test]
fn golden_response_fixtures_are_byte_stable() {
    let lines: Vec<&str> = RESPONSE_FIXTURES.lines().filter(|l| !l.is_empty()).collect();
    let values = response_fixture_values();
    assert_eq!(lines.len(), values.len(), "fixture/value count mismatch");
    for (line, (id, value)) in lines.iter().zip(&values) {
        assert_eq!(&value.to_wire(*id).dump(), line, "encode drifted for {line}");
        let parsed = Json::parse(line).unwrap();
        let (rid, decoded) = Response::from_wire(&parsed).unwrap();
        assert_eq!(rid, *id, "{line}");
        assert_eq!(&decoded, value, "decode drifted for {line}");
    }
}

// ---------- round-trip properties ----------

/// Eighth-grid floats survive the f32 → JSON → f32 round trip exactly.
fn grid_f32(rng: &mut Rng) -> f32 {
    (rng.below(2001) as f32 - 1000.0) / 8.0
}

fn random_point(rng: &mut Rng) -> Point {
    let nf = 1 + rng.below(3) as usize;
    let features = (0..nf)
        .map(|_| match rng.below(3) {
            0 => FeatureValue::Dense((0..rng.below(5)).map(|_| grid_f32(rng)).collect()),
            1 => FeatureValue::Tokens((0..rng.below(5)).map(|_| rng.below(1 << 60)).collect()),
            _ => FeatureValue::Scalar(grid_f32(rng)),
        })
        .collect();
    // Ids above 2^53 exercise the string-encoded u64 wire path.
    Point::new(rng.below(1 << 60), features)
}

fn random_k(rng: &mut Rng) -> Option<usize> {
    match rng.below(3) {
        0 => None,
        _ => Some(1 + rng.below(MAX_K as u64 - 1) as usize),
    }
}

fn random_request(rng: &mut Rng) -> Request {
    match rng.below(10) {
        0 => Request::Insert { point: random_point(rng) },
        1 => Request::Delete { id: rng.below(1 << 60) },
        2 => Request::Query { point: random_point(rng), k: random_k(rng) },
        3 => Request::QueryId { id: rng.below(1 << 60), k: random_k(rng) },
        4 => Request::InsertBatch {
            points: (0..rng.below(4)).map(|_| random_point(rng)).collect(),
        },
        5 => Request::DeleteBatch {
            ids: (0..rng.below(6)).map(|_| rng.below(1 << 60)).collect(),
        },
        6 => Request::QueryBatch {
            points: (0..rng.below(4)).map(|_| random_point(rng)).collect(),
            k: random_k(rng),
        },
        7 => Request::Checkpoint,
        8 => Request::Stats,
        _ => Request::RefreshTables,
    }
}

fn random_neighbors(rng: &mut Rng) -> Vec<ScoredNeighbor> {
    (0..rng.below(5))
        .map(|_| ScoredNeighbor {
            id: rng.below(1 << 60),
            score: grid_f32(rng),
            dot: grid_f32(rng),
        })
        .collect()
}

/// Quarter-grid budget fractions (exactly representable, so the
/// dump → parse round trip is lossless, like `grid_f32` for scores).
fn random_degraded(rng: &mut Rng) -> Option<f64> {
    match rng.below(4) {
        0 => Some(0.25 * (1 + rng.below(3)) as f64),
        _ => None,
    }
}

fn random_response(rng: &mut Rng) -> Response {
    let codes = [
        ErrorCode::BadRequest,
        ErrorCode::NotFound,
        ErrorCode::Unavailable,
        ErrorCode::DeadlineExceeded,
        ErrorCode::Overloaded,
    ];
    match rng.below(7) {
        0 => Response::Existed { existed: rng.below(2) == 0 },
        1 => Response::ExistedBatch {
            existed: (0..rng.below(6)).map(|_| rng.below(2) == 0).collect(),
        },
        2 => Response::Neighbors {
            neighbors: random_neighbors(rng),
            degraded: random_degraded(rng),
        },
        3 => Response::Results {
            results: (0..rng.below(4)).map(|_| random_neighbors(rng)).collect(),
            degraded: random_degraded(rng),
        },
        4 => Response::Checkpoint { seq: rng.below(1 << 60) },
        5 => Response::Stats {
            stats: Json::obj(vec![
                ("points", Json::num(rng.below(100_000) as f64)),
                ("label", Json::str(format!("s{}", rng.below(10)))),
            ]),
        },
        _ => Response::error(
            codes[rng.below(codes.len() as u64) as usize],
            format!("message {}", rng.below(100)),
        ),
    }
}

#[test]
fn prop_every_request_variant_round_trips() {
    let mut rng = Rng::seeded(0x7031);
    for i in 0..500 {
        let req = random_request(&mut rng);
        let wire = req.to_wire();
        let back = Request::from_wire(&wire)
            .unwrap_or_else(|e| panic!("iter {i}: {e} for {}", wire.dump()));
        assert_eq!(back, req, "iter {i}: {}", wire.dump());
        // Dump → parse → decode is the full socket path.
        let reparsed = Json::parse(&wire.dump()).unwrap();
        assert_eq!(Request::from_wire(&reparsed).unwrap(), req, "iter {i}");
    }
}

#[test]
fn prop_every_envelope_round_trips() {
    let mut rng = Rng::seeded(0x7032);
    for i in 0..300 {
        let env = Envelope {
            id: rng.below(1 << 60),
            deadline_ms: if rng.below(2) == 0 { None } else { Some(rng.below(100_000)) },
            class: match rng.below(4) {
                0 => Some(Class::Interactive),
                1 => Some(Class::Batch),
                2 => Some(Class::Replication),
                _ => None,
            },
            request: random_request(&mut rng),
        };
        match decode_request(&env.to_wire().dump()) {
            Ok(Incoming::V1(back)) => assert_eq!(back, env, "iter {i}"),
            other => panic!("iter {i}: {other:?}"),
        }
    }
}

#[test]
fn prop_every_response_variant_round_trips() {
    let mut rng = Rng::seeded(0x7033);
    for i in 0..500 {
        let resp = random_response(&mut rng);
        let id = if rng.below(2) == 0 { None } else { Some(rng.below(1 << 60)) };
        let wire = resp.to_wire(id).dump();
        let parsed = Json::parse(&wire).unwrap();
        let (rid, back) = Response::from_wire(&parsed)
            .unwrap_or_else(|e| panic!("iter {i}: {e} for {wire}"));
        assert_eq!(rid, id, "iter {i}: {wire}");
        assert_eq!(back, resp, "iter {i}: {wire}");
    }
}

// ---------- malformed inputs ----------

#[test]
fn malformed_requests_are_typed_errors() {
    // (line, is_v1_shaped, message fragment)
    let cases: &[(&str, bool, &str)] = &[
        // Truncated / not JSON.
        ("{\"v\":1", false, "bad json"),
        ("", false, "bad json"),
        ("[1,2,3]", false, "request must be a JSON object"),
        ("\"insert\"", false, "request must be a JSON object"),
        // Legacy shape errors.
        (r#"{"op":"teleport"}"#, false, "unknown op"),
        (r#"{"op":"insert"}"#, false, "missing/bad 'point'"),
        (r#"{"op":"insert","point":{"id":1}}"#, false, "missing/bad 'point'"),
        (r#"{"op":"delete","id":"abc"}"#, false, "missing/bad 'id'"),
        (r#"{"op":"delete_batch","ids":[1,null]}"#, false, "missing/bad 'ids'"),
        (r#"{"op":"query_batch","points":{}}"#, false, "missing/bad 'points'"),
        // k bounds (the regression the redesign fixes).
        (r#"{"op":"query_id","id":1,"k":0}"#, false, "'k' must be >= 1"),
        (r#"{"op":"query","point":{"features":[],"id":1},"k":0}"#, false, "'k' must be >= 1"),
        (r#"{"op":"query_id","id":1,"k":70000}"#, false, "exceeds maximum"),
        (r#"{"op":"query_id","id":1,"k":true}"#, false, "non-negative integer"),
        // Envelope header errors.
        (r#"{"v":2,"id":1,"req":{"op":"stats"}}"#, true, "unsupported protocol version 2"),
        (r#"{"v":"one","id":1,"req":{"op":"stats"}}"#, true, "'v' must be an integer"),
        (r#"{"v":1,"req":{"op":"stats"}}"#, true, "missing 'id'"),
        (r#"{"v":1,"id":true,"req":{"op":"stats"}}"#, true, "missing 'id'"),
        (r#"{"v":1,"id":3}"#, true, "missing 'req'"),
        (r#"{"v":1,"id":3,"req":17}"#, true, "request must be a JSON object"),
        (r#"{"v":1,"id":3,"deadline_ms":-1,"req":{"op":"stats"}}"#, true, "deadline_ms"),
        (r#"{"v":1,"id":3,"deadline_ms":1.5,"req":{"op":"stats"}}"#, true, "deadline_ms"),
        (r#"{"v":1,"id":3,"req":{"op":"warp"}}"#, true, "unknown op"),
    ];
    for (line, v1, fragment) in cases {
        let err = decode_request(line).expect_err(line);
        assert_eq!(err.v1, *v1, "{line}");
        assert_eq!(err.error.code, ErrorCode::BadRequest, "{line}");
        assert!(
            err.error.message.contains(fragment),
            "{line}: got '{}', wanted '{fragment}'",
            err.error.message
        );
    }
}

#[test]
fn envelope_errors_echo_the_correlation_id_when_readable() {
    let err = decode_request(r#"{"v":1,"id":77,"req":{"op":"warp"}}"#).unwrap_err();
    assert_eq!(err.id, Some(77));
    let err = decode_request(r#"{"v":2,"id":78,"req":{"op":"stats"}}"#).unwrap_err();
    assert_eq!(err.id, Some(78));
    // Unreadable header: no id to echo.
    let err = decode_request(r#"{"v":1,"req":{"op":"stats"}}"#).unwrap_err();
    assert_eq!(err.id, None);
}

#[test]
fn truncated_lines_never_panic() {
    for line in REQUEST_FIXTURES.lines().chain(RESPONSE_FIXTURES.lines()) {
        for cut in 0..line.len() {
            // Any prefix must produce a Result, never a panic.
            let _ = decode_request(&line[..cut]);
            if let Ok(j) = Json::parse(&line[..cut]) {
                let _ = Response::from_wire(&j);
            }
        }
    }
}

#[test]
fn malformed_responses_are_errors() {
    for line in [
        r#"{"neighbors":[{"score":1}],"ok":true}"#, // neighbor missing id
        r#"{"ok":true}"#,                           // no recognizable payload
        r#"{"existed":[1,2],"ok":true}"#,           // wrong-typed entries
        r#"{"ok":"yes"}"#,                          // wrong-typed ok
        "[]",                                       // not an object
    ] {
        let j = Json::parse(line).unwrap();
        assert!(Response::from_wire(&j).is_err(), "{line}");
    }
}
