//! Coordinator integration: dynamic semantics under realistic traces,
//! concurrency, and failure injection.

use std::sync::Arc;

use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::data::trace::{Op, TraceConfig};
use dynamic_gus::features::{FeatureValue, Point};
use dynamic_gus::testing::proptest_cases;
use dynamic_gus::util::rng::Rng;

fn boot(n: usize, seed: u64) -> (DynamicGus, dynamic_gus::data::Dataset) {
    let ds = SyntheticConfig::arxiv_like(n, seed).generate();
    let cfg = GusConfig {
        scorer: ScorerKind::Native,
        filter_p: 10.0,
        ..GusConfig::default()
    };
    let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap();
    (gus, ds)
}

/// Replay a full mixed trace; service-level invariants hold throughout.
#[test]
fn mixed_trace_replay_consistent() {
    let ds = SyntheticConfig::arxiv_like(800, 0x71).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let trace = TraceConfig {
        initial_fraction: 0.7,
        n_ops: 2_000,
        insert_prob: 0.15,
        update_prob: 0.1,
        delete_prob: 0.05,
        query_k: 10,
        seed: 3,
    }
    .build(&ds);
    let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &trace.initial, 2).unwrap();
    let mut live: std::collections::BTreeSet<u64> =
        trace.initial.iter().map(|p| p.id).collect();
    for op in &trace.ops {
        match op {
            Op::Insert(p) | Op::Update(p) => {
                gus.insert(p.clone()).unwrap();
                live.insert(p.id);
            }
            Op::Delete(id) => {
                gus.delete(*id).unwrap();
                live.remove(id);
            }
            Op::Query { point, k } => {
                let res = gus.query(point, *k).unwrap();
                assert!(res.len() <= *k);
                for nb in &res {
                    assert!(live.contains(&nb.id), "dead neighbor {}", nb.id);
                    assert_ne!(nb.id, point.id, "self-neighbor");
                    assert!((0.0..=1.0).contains(&nb.score));
                }
            }
        }
        assert_eq!(gus.len(), live.len(), "index drift");
    }
}

/// A delete immediately hides the point; a re-insert immediately restores
/// it (sequential consistency from one client's view).
#[test]
fn delete_insert_visibility_cycle() {
    let (gus, ds) = boot(300, 0x72);
    let victim = ds.points[7].clone();
    for _ in 0..10 {
        gus.delete(victim.id).unwrap();
        let res = gus.query(&ds.points[8], 50).unwrap();
        assert!(res.iter().all(|n| n.id != victim.id));
        gus.insert(victim.clone()).unwrap();
    }
    assert_eq!(gus.len(), 300);
}

/// Concurrent clients: mutations and queries from many threads never
/// produce malformed results.
#[test]
fn concurrent_clients_no_dangling_results() {
    let (gus, ds) = boot(500, 0x73);
    let gus = Arc::new(gus);
    let ds = Arc::new(ds);
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let gus = Arc::clone(&gus);
        let ds = Arc::clone(&ds);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seeded(t);
            for i in 0..300 {
                match i % 3 {
                    0 => {
                        // churn: delete + re-insert a random point
                        let idx = rng.below_usize(ds.points.len());
                        let p = ds.points[idx].clone();
                        gus.delete(p.id).ok();
                        gus.insert(p).unwrap();
                    }
                    _ => {
                        let idx = rng.below_usize(ds.points.len());
                        if let Ok(res) = gus.query(&ds.points[idx], 10) {
                            for nb in res {
                                assert!(nb.score.is_finite());
                                assert!((0.0..=1.0).contains(&nb.score));
                            }
                        }
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(gus.len(), 500);
}

/// Failure injection: malformed points are rejected atomically — the
/// service state is untouched by failed mutations.
#[test]
fn rejected_mutations_leave_no_trace() {
    let (gus, _ds) = boot(200, 0x74);
    let before = gus.len();
    let bad_points = vec![
        Point::new(9001, vec![]),
        Point::new(9002, vec![FeatureValue::Scalar(1.0)]),
        Point::new(
            9003,
            vec![
                FeatureValue::Dense(vec![1.0; 3]), // wrong dim
                FeatureValue::Scalar(2020.0),
            ],
        ),
        Point::new(
            9004,
            vec![
                FeatureValue::Dense(vec![f32::NAN; 128]),
                FeatureValue::Scalar(2020.0),
            ],
        ),
    ];
    for p in bad_points {
        assert!(gus.insert(p.clone()).is_err(), "{p:?} accepted");
        assert!(!gus.contains(p.id));
    }
    assert_eq!(gus.len(), before);
}

/// Property: after any random op sequence, query results are sorted by
/// score and contain only live points.
#[test]
fn prop_random_ops_preserve_invariants() {
    let ds = SyntheticConfig::arxiv_like(150, 0x75).generate();
    proptest_cases(8, |rng| {
        let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
        let split = 100;
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points[..split], 1).unwrap();
        let mut live: std::collections::BTreeSet<u64> =
            ds.points[..split].iter().map(|p| p.id).collect();
        for _ in 0..60 {
            match rng.below(4) {
                0 => {
                    let idx = rng.below_usize(ds.points.len());
                    gus.insert(ds.points[idx].clone()).unwrap();
                    live.insert(ds.points[idx].id);
                }
                1 => {
                    if let Some(&id) = live.iter().next() {
                        gus.delete(id).unwrap();
                        live.remove(&id);
                    }
                }
                _ => {
                    let idx = rng.below_usize(ds.points.len());
                    let res = gus.query(&ds.points[idx], 5).unwrap();
                    for w in res.windows(2) {
                        assert!(w[0].score >= w[1].score, "unsorted");
                    }
                    for nb in &res {
                        assert!(live.contains(&nb.id));
                    }
                }
            }
        }
        assert_eq!(gus.len(), live.len());
    });
}

/// Sharded deployment answers exactly like the single-shard one.
#[test]
fn sharded_equals_sequential() {
    let ds = SyntheticConfig::arxiv_like(400, 0x76).generate();
    let mk = |shards: usize| {
        let cfg = GusConfig {
            scorer: ScorerKind::Native,
            n_shards: shards,
            ..GusConfig::default()
        };
        DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap()
    };
    let g1 = mk(1);
    let g4 = mk(4);
    for qi in (0..ds.points.len()).step_by(37) {
        let a = g1.query(&ds.points[qi], 10).unwrap();
        let b = g4.query(&ds.points[qi], 10).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert!((x.score - y.score).abs() < 1e-6);
        }
    }
}

/// Staleness SLO: with synchronous apply, p99 staleness is far inside the
/// paper's "few seconds" bound.
#[test]
fn staleness_slo_within_bound() {
    let (gus, ds) = boot(300, 0x77);
    for i in 0..100 {
        let mut p = ds.points[i].clone();
        p.id = 10_000 + i as u64;
        gus.insert(p).unwrap();
    }
    assert!(gus
        .metrics
        .staleness
        .within_slo(std::time::Duration::from_secs(5)));
}
