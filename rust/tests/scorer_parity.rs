//! Packed-kernel parity suite: the tile kernel vs the scalar oracle.
//!
//! The packed scoring path (`PairScorer::score_into`, lane-parallel tiles
//! over `PackedWeights`) must match the scalar reference
//! (`NativeScorer::score_batch_scalar`) within 1e-5 on every schema shape —
//! and bit-exactly at tile width 1, where the accumulation order is
//! unchanged by construction. Also covers: `score_batch` ≡ `score_into`,
//! parallel-split scoring ≡ serial, scratch reuse across schemas, and the
//! NaN-score regression in the coordinator's result sort.

use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::features::{ChannelSchema, FeatureKind, FeatureValue, Point, Schema};
use dynamic_gus::scorer::{
    score_into_parallel, MlpWeights, NativeScorer, PairFeaturizer, PairScorer, ScorerScratch,
    ScratchPool, HIDDEN,
};
use dynamic_gus::testing::{gen_usize, proptest};
use dynamic_gus::util::rng::Rng;

/// A random schema: dense primary channel plus 0..=3 extra channels of
/// random kinds, in random positions relative to the primary.
fn random_schema(rng: &mut Rng) -> Schema {
    let d = gen_usize(rng, 1, 48);
    let n_extras = gen_usize(rng, 0, 4);
    let mut channels = Vec::new();
    // The primary dense channel is the first *dense* channel; placing
    // scalar/token channels before it exercises non-zero primary indices.
    let primary_at = gen_usize(rng, 0, n_extras + 1);
    let extra_kind = |rng: &mut Rng, i: usize| {
        let kind = match rng.below(3) {
            0 => FeatureKind::Tokens,
            1 => FeatureKind::Scalar,
            _ => FeatureKind::Dense,
        };
        ChannelSchema {
            name: format!("x{i}"),
            kind,
            dim: if kind == FeatureKind::Dense { gen_usize(rng, 1, 6) } else { 1 },
        }
    };
    for i in 0..n_extras + 1 {
        if i == primary_at {
            channels.push(ChannelSchema {
                name: "emb".into(),
                kind: FeatureKind::Dense,
                dim: d,
            });
        } else {
            let mut c = extra_kind(rng, i);
            // A dense channel before the primary would *become* the
            // primary; keep pre-primary extras non-dense.
            if i < primary_at && c.kind == FeatureKind::Dense {
                c.kind = FeatureKind::Scalar;
                c.dim = 1;
            }
            channels.push(c);
        }
    }
    Schema { name: "rand".into(), channels }
}

fn random_point(rng: &mut Rng, schema: &Schema, id: u64) -> Point {
    let features = schema
        .channels
        .iter()
        .map(|c| match c.kind {
            FeatureKind::Dense => FeatureValue::Dense(rng.normal_vec_f32(c.dim)),
            FeatureKind::Scalar => FeatureValue::Scalar(rng.below(4000) as f32 / 2.0),
            FeatureKind::Tokens => {
                // Duplicates on purpose: set semantics must hold.
                let n = rng.below_usize(8);
                FeatureValue::Tokens((0..n).map(|_| rng.below(12)).collect())
            }
        })
        .collect();
    Point::new(id, features)
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol,
            "{ctx}: pair {i}: packed {g} vs scalar {w}"
        );
    }
}

fn parity_over_schema(schema: &Schema, seed: u64) {
    let f = PairFeaturizer::new(schema);
    let w = MlpWeights::random(f.input_dim(), HIDDEN, seed);
    let scorer = NativeScorer::new(f, w);
    let mut rng = Rng::seeded(seed ^ 0xabcd);
    let pts: Vec<Point> = (0..21).map(|i| random_point(&mut rng, schema, i)).collect();
    let q = &pts[0];
    let mut scratch = ScorerScratch::default();
    // Batch sizes straddling every tile boundary, including empty.
    for n in [0usize, 1, 2, 7, 8, 9, 15, 16, 17, 20] {
        let cands: Vec<&Point> = pts[..n].iter().collect();
        let want = scorer.score_batch_scalar(q, &cands);
        let mut got = Vec::new();
        scorer.score_into(q, &cands, &mut scratch, &mut got);
        assert_close(&got, &want, 1e-5, &format!("{} n={n}", schema.name));
        // Tile width 1: bit-exact, same accumulation order.
        let mut got1 = Vec::new();
        scorer.score_into_tiled::<1>(q, &cands, &mut scratch, &mut got1);
        assert_eq!(got1, want, "{} n={n}: width-1 not bit-exact", schema.name);
    }
}

#[test]
fn golden_parity_arxiv_like() {
    parity_over_schema(&Schema::arxiv_like(8), 71);
    parity_over_schema(&Schema::arxiv_like(128), 72);
}

#[test]
fn golden_parity_products_like() {
    // Tokens-only extras.
    parity_over_schema(&Schema::products_like(16), 73);
}

#[test]
fn golden_parity_zero_extras() {
    // A single dense channel: ke = 0, φ = [prod | diff].
    let schema = Schema {
        name: "dense_only".into(),
        channels: vec![ChannelSchema {
            name: "emb".into(),
            kind: FeatureKind::Dense,
            dim: 5,
        }],
    };
    parity_over_schema(&schema, 74);
}

#[test]
fn golden_parity_tokens_only_extras() {
    // Two token channels (4 extras), no scalar/dense extras.
    let schema = Schema {
        name: "tokens_heavy".into(),
        channels: vec![
            ChannelSchema { name: "emb".into(), kind: FeatureKind::Dense, dim: 6 },
            ChannelSchema { name: "t1".into(), kind: FeatureKind::Tokens, dim: 0 },
            ChannelSchema { name: "t2".into(), kind: FeatureKind::Tokens, dim: 0 },
        ],
    };
    parity_over_schema(&schema, 75);
}

#[test]
fn prop_packed_matches_scalar_on_random_schemas() {
    proptest(|rng| {
        let schema = random_schema(rng);
        let f = PairFeaturizer::new(&schema);
        let hidden = gen_usize(rng, 1, 13);
        let w = MlpWeights::random(f.input_dim(), hidden, rng.below(1 << 40));
        let scorer = NativeScorer::new(f, w);
        let n = gen_usize(rng, 1, 24);
        let pts: Vec<Point> =
            (0..n as u64 + 1).map(|i| random_point(rng, &schema, i)).collect();
        let q = &pts[n];
        let cands: Vec<&Point> = pts[..n].iter().collect();
        let want = scorer.score_batch_scalar(q, &cands);
        let mut scratch = ScorerScratch::default();
        let mut got = Vec::new();
        scorer.score_into(q, &cands, &mut scratch, &mut got);
        assert_close(&got, &want, 1e-5, "random schema");
        let mut got1 = Vec::new();
        scorer.score_into_tiled::<1>(q, &cands, &mut scratch, &mut got1);
        assert_eq!(got1, want, "width-1 not bit-exact on random schema");
    });
}

#[test]
fn prop_score_batch_equals_score_into() {
    proptest(|rng| {
        let schema = random_schema(rng);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(f.input_dim(), HIDDEN, rng.below(1 << 40));
        let scorer = NativeScorer::new(f, w);
        let n = gen_usize(rng, 0, 30);
        let pts: Vec<Point> =
            (0..n as u64 + 1).map(|i| random_point(rng, &schema, i)).collect();
        let q = &pts[n];
        let cands: Vec<&Point> = pts[..n].iter().collect();
        // The compatibility wrapper and the scratch-reusing entry point
        // must agree bitwise (same kernel, fresh vs pooled scratch).
        let batch = scorer.score_batch(q, &cands);
        let mut scratch = ScorerScratch::default();
        let mut into = Vec::new();
        scorer.score_into(q, &cands, &mut scratch, &mut into);
        assert_eq!(batch, into);
        // And `score_into` appends without clobbering what's in `out`.
        let mut appended = vec![-1.0f32];
        scorer.score_into(q, &cands, &mut scratch, &mut appended);
        assert_eq!(appended[0], -1.0);
        assert_eq!(&appended[1..], batch.as_slice());
    });
}

#[test]
fn parallel_split_equals_serial() {
    let schema = Schema::arxiv_like(24);
    let f = PairFeaturizer::new(&schema);
    let w = MlpWeights::random(f.input_dim(), HIDDEN, 99);
    let scorer = NativeScorer::new(f, w);
    let mut rng = Rng::seeded(17);
    // Large enough to cross SCORE_PAR_MIN and split into several chunks.
    let pts: Vec<Point> = (0..1501).map(|i| random_point(&mut rng, &schema, i)).collect();
    let q = &pts[0];
    let cands: Vec<&Point> = pts[1..].iter().collect();
    let mut scratch = ScorerScratch::default();
    let mut serial = Vec::new();
    scorer.score_into(q, &cands, &mut scratch, &mut serial);
    let pool = ScratchPool::new();
    for threads in [1usize, 2, 4, 16] {
        let mut par = Vec::new();
        score_into_parallel(&scorer, q, &cands, &pool, threads, &mut par);
        assert_eq!(par, serial, "threads={threads} changed scores");
    }
}

#[test]
fn scratch_survives_schema_changes() {
    // One scratch used against scorers of different schemas must relayout
    // its query prep in place and stay correct.
    let mut scratch = ScorerScratch::default();
    let mut rng = Rng::seeded(23);
    for schema in [
        Schema::products_like(4),
        Schema::arxiv_like(8),
        Schema::products_like(3),
    ] {
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(f.input_dim(), HIDDEN, 5);
        let scorer = NativeScorer::new(f, w);
        let pts: Vec<Point> = (0..10).map(|i| random_point(&mut rng, &schema, i)).collect();
        let cands: Vec<&Point> = pts[1..].iter().collect();
        let want = scorer.score_batch_scalar(&pts[0], &cands);
        let mut got = Vec::new();
        scorer.score_into(&pts[0], &cands, &mut scratch, &mut got);
        assert_eq!(got, want, "schema {}", schema.name);
    }
}

/// Weights engineered so scoring produces NaN on large inputs. ReLU
/// (`f32::max`) launders a mid-network NaN to 0, so the NaN must appear at
/// the final logit: every hidden unit saturates to +inf (product weights
/// all +1 against an overflowing product block) and the alternating-sign
/// output layer sums inf − inf = NaN.
fn nan_prone_scorer(schema: &Schema) -> NativeScorer {
    let f = PairFeaturizer::new(schema);
    let d = f.dense_dim();
    let (input_dim, hidden) = (f.input_dim(), 4);
    let mut w1 = vec![0.0f32; input_dim * hidden];
    for j in 0..d {
        // Product block rows only; |diff| and extras rows stay 0 (their φ
        // values are finite here, so 0-weights stay exact zeros).
        for k in 0..hidden {
            w1[j * hidden + k] = 1.0;
        }
    }
    let weights = MlpWeights {
        input_dim,
        hidden,
        w1,
        b1: vec![0.0; hidden],
        w2: vec![0.1; hidden * hidden],
        b2: vec![0.0; hidden],
        w3: vec![1.0, -1.0, 1.0, -1.0],
        b3: 0.0,
    };
    NativeScorer::new(f, weights)
}

#[test]
fn nan_scores_sort_without_panicking() {
    // Regression: `score_neighbors` used `partial_cmp(..).unwrap()`, which
    // panicked the Neighborhood RPC whenever a score came out NaN. Huge
    // (but finite, so schema-valid) feature values overflow the product
    // block to inf and the cancelling weights produce inf−inf = NaN.
    let schema = Schema::arxiv_like(2);
    let scorer = Box::new(nan_prone_scorer(&schema));
    let mut points = Vec::new();
    for i in 0..8u64 {
        // Identical huge embeddings: all land in the same LSH buckets, so
        // every query retrieves them; 1e30 * 1e30 overflows f32.
        points.push(Point::new(
            i,
            vec![
                FeatureValue::Dense(vec![1e30, 1e30]),
                FeatureValue::Scalar(2020.0),
            ],
        ));
    }
    let config = GusConfig {
        scorer: ScorerKind::Native,
        filter_p: 0.0,
        ..GusConfig::default()
    };
    let gus = DynamicGus::bootstrap_with_scorer(schema, config, &points, 2, scorer).unwrap();
    let a = gus.query(&points[0], 5).expect("query must not panic on NaN scores");
    assert!(!a.is_empty(), "huge twins should be retrieved");
    assert!(
        a.iter().any(|n| n.score.is_nan()),
        "test vector no longer produces NaN — tighten it: {a:?}"
    );
    // Deterministic order on repeat (total_cmp is a total order).
    let b = gus.query(&points[0], 5).unwrap();
    let ids = |v: &[dynamic_gus::coordinator::ScoredNeighbor]| {
        v.iter().map(|n| n.id).collect::<Vec<_>>()
    };
    assert_eq!(ids(&a), ids(&b));
}
