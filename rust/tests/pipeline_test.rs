//! Whole-pipeline integration: dataset → preprocess → embed → index →
//! retrieve → score, including the XLA path when artifacts exist, plus the
//! offline/dynamic equivalence the paper asserts in §5.1.

use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::eval::offline::{self, GusOfflineParams};
use dynamic_gus::graph::WeightHistogram;
use dynamic_gus::runtime::artifacts_dir;
use dynamic_gus::scorer::XlaScorer;

/// §5.1: "the offline GUS and dynamic GUS provide identical results" —
/// querying every point through the live coordinator must reproduce the
/// offline harness's histogram exactly (same retrieval, same scorer).
#[test]
fn offline_equals_dynamic() {
    let ds = SyntheticConfig::arxiv_like(600, 0x91).generate();
    let nn = 10;

    let offline_out = offline::gus_offline(
        &ds,
        GusOfflineParams { nn, idf_s: 0, filter_p: 10.0 },
        2,
    );

    let cfg = GusConfig {
        scann_nn: nn,
        idf_s: 0,
        filter_p: 10.0,
        scorer: ScorerKind::Native,
        lsh_seed: offline::EVAL_LSH_SEED, // same buckets as the offline run
        ..GusConfig::default()
    };
    let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap();
    let mut hist = WeightHistogram::default_bins();
    let mut edges = 0u64;
    for p in &ds.points {
        for nb in gus.query(p, nn).unwrap() {
            hist.add(nb.score);
            edges += 1;
        }
    }
    assert_eq!(edges, offline_out.directed_edges, "edge count differs");
    assert_eq!(
        hist.percentile_curve(&dynamic_gus::graph::standard_percentiles()),
        offline_out
            .histogram
            .percentile_curve(&dynamic_gus::graph::standard_percentiles()),
        "histograms differ"
    );
}

/// The dynamic system built incrementally (point by point) ends in the same
/// state as one bootstrapped from the full corpus (same tables).
#[test]
fn incremental_equals_bulk() {
    let ds = SyntheticConfig::arxiv_like(400, 0x92).generate();
    let cfg = GusConfig {
        scorer: ScorerKind::Native,
        filter_p: 0.0, // tables derived from initial corpus only — disable
        idf_s: 0,      // to make bulk/incremental strictly comparable
        ..GusConfig::default()
    };
    let bulk = DynamicGus::bootstrap(ds.schema.clone(), cfg.clone(), &ds.points, 2).unwrap();
    let incr = DynamicGus::bootstrap(ds.schema.clone(), cfg, &[], 2).unwrap();
    for p in &ds.points {
        incr.insert(p.clone()).unwrap();
    }
    assert_eq!(bulk.len(), incr.len());
    for qi in (0..ds.points.len()).step_by(29) {
        let a = bulk.query(&ds.points[qi], 10).unwrap();
        let b = incr.query(&ds.points[qi], 10).unwrap();
        assert_eq!(a, b, "query {qi} differs");
    }
}

/// XLA-scored coordinator matches the native-scored one end-to-end
/// (requires `make artifacts`; skips otherwise).
#[test]
fn xla_and_native_coordinators_agree() {
    let ds = SyntheticConfig::arxiv_like(300, 0x93).generate();
    if !XlaScorer::artifacts_available(&artifacts_dir(), &ds.schema.name) {
        eprintln!("SKIP xla_and_native_coordinators_agree: run `make artifacts`");
        return;
    }
    let mk = |kind| {
        let cfg = GusConfig { scorer: kind, ..GusConfig::default() };
        DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap()
    };
    let native = mk(ScorerKind::Native);
    let xla = mk(ScorerKind::Xla);
    for qi in (0..ds.points.len()).step_by(17) {
        let a = native.query(&ds.points[qi], 10).unwrap();
        let b = xla.query(&ds.points[qi], 10).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id, "neighbor sets differ at {qi}");
            assert!(
                (x.score - y.score).abs() < 1e-4,
                "scores differ: {} vs {}",
                x.score,
                y.score
            );
        }
    }
}

/// Dataset persistence round-trips through the full pipeline.
#[test]
fn saved_dataset_serves_identically() {
    let ds = SyntheticConfig::products_like(300, 0x94).generate();
    let dir = std::env::temp_dir().join("gus-pipeline-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ds.jsonl");
    dynamic_gus::data::loader::save(&ds, &path).unwrap();
    let ds2 = dynamic_gus::data::loader::load(&path).unwrap();

    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let a = DynamicGus::bootstrap(ds.schema.clone(), cfg.clone(), &ds.points, 2).unwrap();
    let b = DynamicGus::bootstrap(ds2.schema.clone(), cfg, &ds2.points, 2).unwrap();
    for qi in (0..ds.points.len()).step_by(31) {
        assert_eq!(
            a.query(&ds.points[qi], 5).unwrap(),
            b.query(&ds2.points[qi], 5).unwrap()
        );
    }
}
