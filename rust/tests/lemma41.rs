//! Lemma 4.1 end-to-end: "For any point p, the neighborhood of p is exactly
//! the same in Grale and Dynamic GUS if we retrieve all the points with
//! negative distance to p in ScaNN."
//!
//! The paper validates this experimentally in Fig. 3; here it is an
//! integration test over both datasets, plus the generalization the paper
//! notes after the lemma: it holds for *any* strictly-positive embedding
//! weights, i.e. with IDF enabled too.

use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::embed::{BucketStats, EmbeddingGenerator, IdfTable};
use dynamic_gus::eval::offline;
use dynamic_gus::index::{QueryParams, QueryScratch, SparseAnn};
use dynamic_gus::lsh::Bucketer;
use dynamic_gus::util::hash::FxHashSet;

#[test]
fn fig3_identity_arxiv_like() {
    let ds = SyntheticConfig::arxiv_like(1200, 0xf3).generate();
    let (series, identical) = offline::fig3(&ds, 4);
    assert!(identical, "Lemma 4.1 violated on arxiv_like");
    assert!(series[0].total_edges > 0);
}

#[test]
fn fig3_identity_products_like() {
    let ds = SyntheticConfig::products_like(1000, 0xf4).generate();
    let (series, identical) = offline::fig3(&ds, 4);
    assert!(identical, "Lemma 4.1 violated on products_like");
    assert!(series[0].total_edges > 0);
}

/// Pairwise form of the lemma, with IDF weights: shared bucket ⇔ negative
/// distance, point by point against a brute-force bucket comparison.
#[test]
fn lemma_holds_with_idf_weights() {
    let ds = SyntheticConfig::products_like(300, 0xf5).generate();
    let bucketer = Bucketer::with_defaults(&ds.schema, 0x11);
    let mut stats = BucketStats::new();
    let all_buckets: Vec<Vec<u64>> =
        ds.points.iter().map(|p| bucketer.buckets(p)).collect();
    for b in &all_buckets {
        stats.add_buckets(b);
    }
    let idf = IdfTable::from_stats(&stats, 50); // bounded table, default weight
    let generator = EmbeddingGenerator::new(bucketer, Some(idf), None);

    let mut index = SparseAnn::new();
    for p in &ds.points {
        index.upsert(p.id, generator.embed(p));
    }
    let mut scratch = QueryScratch::default();
    for (i, p) in ds.points.iter().enumerate().take(60) {
        let emb = generator.embed(p);
        let got: FxHashSet<u64> = index
            .threshold(
                &emb,
                -f32::MIN_POSITIVE,
                QueryParams { exclude: Some(p.id), max_postings: 0 },
                &mut scratch,
            )
            .into_iter()
            .map(|n| n.id)
            .collect();
        // Brute force: share >= 1 bucket.
        let want: FxHashSet<u64> = ds
            .points
            .iter()
            .enumerate()
            .filter(|&(j, _)| j != i)
            .filter(|&(j, _)| {
                all_buckets[i]
                    .iter()
                    .any(|b| all_buckets[j].binary_search(b).is_ok())
            })
            .map(|(_, q)| q.id)
            .collect();
        assert_eq!(got, want, "point {i}: neighborhood mismatch");
    }
}

/// The lemma intentionally stops applying under Filter-P: filtered buckets
/// no longer connect points. Check the direction of the containment.
#[test]
fn filtering_only_removes_neighbors() {
    let ds = SyntheticConfig::products_like(300, 0xf6).generate();
    let unfiltered = offline::gus_offline(
        &ds,
        offline::GusOfflineParams { nn: 0, idf_s: 0, filter_p: 0.0 },
        2,
    );
    let filtered = offline::gus_offline(
        &ds,
        offline::GusOfflineParams { nn: 0, idf_s: 0, filter_p: 20.0 },
        2,
    );
    assert!(filtered.directed_edges <= unfiltered.directed_edges);
}
