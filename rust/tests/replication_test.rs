//! Multi-node replication integration: leader → follower WAL shipping
//! over real TCP, catch-up equivalence, promotion, retention-driven
//! re-bootstrap, and the semi-sync ack gate.
//!
//! The core contract (ISSUE 8's acceptance criterion): a follower that
//! subscribes, disconnects at arbitrary points, restarts with a torn
//! local WAL tail, and reconnects converges to a state **byte-identical**
//! to the leader's — same query answers, same WAL bytes — because the
//! replication stream is the leader's own log and follower apply is the
//! crash-recovery replay path.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use dynamic_gus::client::GusClient;
use dynamic_gus::config::{FsyncPolicy, GusConfig, ScorerKind};
use dynamic_gus::coordinator::{wal, DynamicGus};
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::data::Dataset;
use dynamic_gus::features::Point;
use dynamic_gus::protocol::{ErrorCode, Request, Response};
use dynamic_gus::replication::{start_follower, FollowerOpts, NodeReplication, ACK_TIMEOUT};
use dynamic_gus::server::{serve, Replication, ServerConfig, ServerHandle};
use dynamic_gus::testing::proptest_cases;
use dynamic_gus::util::rng::Rng;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("gus-repl-int").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn repl_cfg(wal_retain: u64) -> GusConfig {
    GusConfig {
        scorer: ScorerKind::Native,
        filter_p: 10.0,
        n_shards: 2,
        // Process crashes lose nothing at any fsync policy; Never keeps
        // the tests fast.
        fsync: FsyncPolicy::Never,
        wal_retain,
        ..GusConfig::default()
    }
}

/// Bootstrap a durable leader over `ds.points[..boot]` and serve it with
/// replication enabled.
fn boot_leader(
    ds: &Dataset,
    boot: usize,
    dir: &Path,
    ack_replicas: usize,
    ack_timeout: Duration,
    wal_retain: u64,
) -> (ServerHandle, Arc<DynamicGus>, Arc<NodeReplication>) {
    let gus =
        DynamicGus::bootstrap(ds.schema.clone(), repl_cfg(wal_retain), &ds.points[..boot], 2)
            .unwrap();
    wal::init_fresh(&gus, dir).unwrap();
    let gus = Arc::new(gus);
    let rep = NodeReplication::leader(Arc::clone(&gus), ack_replicas, ack_timeout);
    let config = ServerConfig {
        replication: Some(Arc::clone(&rep) as Arc<dyn Replication>),
        ..ServerConfig::default()
    };
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", config).unwrap();
    (handle, gus, rep)
}

fn boot_follower(leader_addr: &str, dir: &Path) -> (Arc<DynamicGus>, Arc<NodeReplication>) {
    start_follower(FollowerOpts {
        leader: leader_addr.to_string(),
        peers: Vec::new(),
        wal_dir: dir.to_path_buf(),
        threads: 2,
        ack_replicas: 0,
        ack_timeout: ACK_TIMEOUT,
    })
    .unwrap()
}

/// Wait until the follower's durable seq reaches the leader's. Appending
/// and applying happen under the follower's WAL writer lock (the same
/// lock `wal_seq` takes), so reaching the seq implies the apply landed.
fn wait_caught_up(leader: &DynamicGus, follower: &DynamicGus, tag: &str) {
    let target = leader.wal_seq();
    for _ in 0..1500 {
        if follower.wal_seq() >= target {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!(
        "{tag}: follower stuck at seq {} (leader at {target})",
        follower.wal_seq()
    );
}

/// Assert two nodes answer a fixed query workload identically.
fn assert_converged(follower: &DynamicGus, leader: &DynamicGus, ds: &Dataset, tag: &str) {
    assert_eq!(follower.len(), leader.len(), "{tag}: corpus size");
    for qi in (0..ds.points.len()).step_by(13) {
        assert_eq!(
            follower.query(&ds.points[qi], 10).unwrap(),
            leader.query(&ds.points[qi], 10).unwrap(),
            "{tag}: query {qi} diverged"
        );
    }
    let probes: Vec<Point> = ds.points.iter().step_by(29).cloned().collect();
    assert_eq!(
        follower.query_batch(&probes, 10).unwrap(),
        leader.query_batch(&probes, 10).unwrap(),
        "{tag}: query_batch diverged"
    );
}

/// Stop a follower the way a clean shutdown would: promotion stops the
/// follow loop; waiting for our Arc to be the last drops the WAL writer
/// before a restart reopens the directory. Only usable when nothing else
/// (e.g. a server) shares the service Arc.
fn stop_follower(gus: Arc<DynamicGus>, rep: Arc<NodeReplication>) {
    rep.promote().unwrap();
    drop(rep);
    for _ in 0..500 {
        if Arc::strong_count(&gus) == 1 {
            drop(gus);
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("follow loop did not release the service after promotion");
}

// ---------- basic shipping + read-only serving ----------

#[test]
fn follower_replicates_and_serves_reads() {
    let ds = SyntheticConfig::arxiv_like(300, 0xe1).generate();
    let ldir = tmpdir("basic-leader");
    let fdir = tmpdir("basic-follower");
    let (l_handle, leader, _l_rep) = boot_leader(&ds, 240, &ldir, 0, ACK_TIMEOUT, 0);
    let leader_addr = l_handle.addr.to_string();
    let (follower, f_rep) = boot_follower(&leader_addr, &fdir);
    let f_config = ServerConfig {
        replication: Some(Arc::clone(&f_rep) as Arc<dyn Replication>),
        ..ServerConfig::default()
    };
    let f_handle = serve(Arc::clone(&follower), "127.0.0.1:0", f_config).unwrap();

    // Mutations through the leader's RPC surface: single inserts,
    // deletes, and a batch.
    let mut client = GusClient::connect(&leader_addr).unwrap();
    for p in &ds.points[240..270] {
        client.insert(p).unwrap();
    }
    for p in &ds.points[..10] {
        assert!(client.delete(p.id).unwrap());
    }
    client.insert_batch(&ds.points[270..300]).unwrap();

    wait_caught_up(&leader, &follower, "basic");
    assert_converged(&follower, &leader, &ds, "basic");

    // The follower serves reads over its own RPC surface...
    let mut f_client = GusClient::connect(&f_handle.addr.to_string()).unwrap();
    let via_rpc = f_client.query_id(ds.points[20].id, 5).unwrap();
    assert_eq!(via_rpc, leader.query_by_id(ds.points[20].id, 5).unwrap());

    // ...but refuses mutations with the leader's address in the hint.
    let id = f_client
        .submit(Request::Insert { point: ds.points[240].clone() })
        .unwrap();
    match f_client.wait_response(id).unwrap() {
        Response::Error { code: ErrorCode::NotLeader, message, .. } => {
            assert!(
                message.contains(&format!("leader={leader_addr}")),
                "NOT_LEADER hint missing leader address: {message}"
            );
        }
        other => panic!("follower accepted a mutation: {other:?}"),
    }

    // Health gauges over the wire: the section the router's failover
    // logic reads.
    let stats = f_client.stats().unwrap();
    let repl = stats.get("replication");
    assert_eq!(repl.get("role").as_str(), Some("follower"));
    assert_eq!(repl.get("leader").as_str(), Some(leader_addr.as_str()));
    assert_eq!(repl.get("wal_last_seq").as_u64(), Some(leader.wal_seq()));
    assert_eq!(repl.get("replication_lag_records").as_u64(), Some(0));
    let l_stats = client.stats().unwrap();
    let l_repl = l_stats.get("replication");
    assert_eq!(l_repl.get("role").as_str(), Some("leader"));
    assert_eq!(l_repl.get("subscribers").as_u64(), Some(1));
    assert!(l_repl.get("records_shipped").as_u64().unwrap() >= leader.wal_seq());

    // Stop the follow loop before tearing the servers down.
    f_rep.promote().unwrap();
    f_handle.shutdown();
    l_handle.shutdown();
}

// ---------- failover: promotion turns a follower into a leader ----------

#[test]
fn promote_turns_follower_into_leader() {
    let ds = SyntheticConfig::arxiv_like(260, 0xe2).generate();
    let ldir = tmpdir("promote-leader");
    let fdir = tmpdir("promote-follower");
    let (l_handle, leader, _l_rep) = boot_leader(&ds, 200, &ldir, 0, ACK_TIMEOUT, 0);
    let leader_addr = l_handle.addr.to_string();
    let (follower, f_rep) = boot_follower(&leader_addr, &fdir);
    let f_config = ServerConfig {
        replication: Some(Arc::clone(&f_rep) as Arc<dyn Replication>),
        ..ServerConfig::default()
    };
    let f_handle = serve(Arc::clone(&follower), "127.0.0.1:0", f_config).unwrap();

    let mut client = GusClient::connect(&leader_addr).unwrap();
    for p in &ds.points[200..230] {
        client.insert(p).unwrap();
    }
    wait_caught_up(&leader, &follower, "promote");
    let durable = leader.wal_seq();

    // "Kill" the leader (stop accepting connections), then promote the
    // follower through its own RPC surface — the manual failover path.
    drop(client);
    l_handle.shutdown();
    let mut f_client = GusClient::connect(&f_handle.addr.to_string()).unwrap();
    let seq = f_client.promote().unwrap();
    assert_eq!(seq, durable, "promotion must report the durable seq");

    // The promoted node now accepts mutations and reports leader role.
    for p in &ds.points[230..240] {
        assert!(!f_client.insert(p).unwrap());
    }
    assert!(f_client.delete(ds.points[0].id).unwrap());
    let stats = f_client.stats().unwrap();
    let repl = stats.get("replication");
    assert_eq!(repl.get("role").as_str(), Some("leader"));
    assert_eq!(repl.get("leader").as_str(), None);
    assert_eq!(follower.wal_seq(), durable + 11);
    assert!(follower.contains(ds.points[235].id));

    f_handle.shutdown();
}

// ---------- semi-sync ack gate ----------

#[test]
fn ack_gate_requires_a_live_follower() {
    let ds = SyntheticConfig::arxiv_like(160, 0xe3).generate();
    let ldir = tmpdir("acks-leader");
    let fdir = tmpdir("acks-follower");
    // --ack-replicas 1: every mutation ack waits for one follower. The
    // short --ack-timeout-ms keeps the dead-follower half of the test
    // from sitting out the 5 s default gate window.
    let ack_timeout = Duration::from_millis(600);
    let (l_handle, leader, _l_rep) = boot_leader(&ds, 120, &ldir, 1, ack_timeout, 0);
    let leader_addr = l_handle.addr.to_string();
    let (follower, f_rep) = boot_follower(&leader_addr, &fdir);

    // The leader registers the subscription on its own connection
    // thread; wait for it rather than racing the handshake.
    for _ in 0..500 {
        if leader.metrics.replication.subscribers() == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(leader.metrics.replication.subscribers(), 1);
    let mut client = GusClient::connect(&leader_addr).unwrap();
    for p in &ds.points[120..135] {
        // Succeeds only because the follower acks within the gate window.
        assert!(!client.insert(p).unwrap());
    }
    wait_caught_up(&leader, &follower, "acks");
    assert_converged(&follower, &leader, &ds, "acks");

    // With the only follower gone, the gate must time out and surface
    // UNAVAILABLE — the mutation is applied but unacknowledged.
    stop_follower(follower, f_rep);
    for _ in 0..500 {
        if leader.metrics.replication.subscribers() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(leader.metrics.replication.subscribers(), 0);
    let before = leader.wal_seq();
    let timeouts_before = leader.metrics.replication.to_json(0).get("ack_timeouts").as_u64();
    let start = std::time::Instant::now();
    let err = client.insert(&ds.points[150]).unwrap_err().to_string();
    assert!(err.contains("UNAVAILABLE"), "gate timeout must be UNAVAILABLE: {err}");
    assert!(
        start.elapsed() < Duration::from_secs(4),
        "gate held the ack past the configured 600 ms timeout: {:?}",
        start.elapsed()
    );
    assert_eq!(leader.wal_seq(), before + 1, "gated mutation is still applied + logged");
    assert_eq!(
        leader.metrics.replication.to_json(0).get("ack_timeouts").as_u64(),
        timeouts_before.map(|n| n + 1),
        "the timed-out gated ack must be counted in replication stats"
    );

    l_handle.shutdown();
}

// ---------- WAL retention: bounded tail vs snapshot re-bootstrap ----------

#[test]
fn retention_bounds_catchup_and_forces_rebootstrap() {
    let ds = SyntheticConfig::arxiv_like(200, 0xe4).generate();
    let ldir = tmpdir("retain-leader");
    let fdir = tmpdir("retain-follower");
    // Keep only the last 8 records past each checkpoint.
    let (l_handle, leader, _l_rep) = boot_leader(&ds, 120, &ldir, 0, ACK_TIMEOUT, 8);
    let leader_addr = l_handle.addr.to_string();

    let (follower, f_rep) = boot_follower(&leader_addr, &fdir);
    for p in &ds.points[120..130] {
        leader.insert(p.clone()).unwrap(); // seq 1..=10
    }
    wait_caught_up(&leader, &follower, "retain-phase0");
    stop_follower(follower, f_rep);
    let pre = std::fs::read(fdir.join(wal::WAL_FILE)).unwrap();
    assert!(!pre.is_empty());

    // Phase A: the follower lags by less than the retained tail, so a
    // restart resumes streaming from its own log — no re-bootstrap.
    for p in &ds.points[130..134] {
        leader.insert(p.clone()).unwrap(); // seq 11..=14
    }
    let seq = leader.checkpoint().unwrap();
    assert_eq!(seq, 14);
    let (follower, f_rep) = boot_follower(&leader_addr, &fdir);
    wait_caught_up(&leader, &follower, "retain-tail");
    assert_converged(&follower, &leader, &ds, "retain-tail");
    let post = std::fs::read(fdir.join(wal::WAL_FILE)).unwrap();
    assert!(
        post.len() > pre.len() && post.starts_with(&pre),
        "tail resume must append to the existing follower log, not re-bootstrap"
    );
    stop_follower(follower, f_rep);

    // Phase B: the leader checkpoints past the follower's seq by more
    // than the retained tail; the restart must wipe and re-bootstrap
    // from the snapshot.
    for p in &ds.points[134..154] {
        leader.insert(p.clone()).unwrap(); // seq 15..=34
    }
    let seq = leader.checkpoint().unwrap();
    assert_eq!(seq, 34);
    let (follower, f_rep) = boot_follower(&leader_addr, &fdir);
    wait_caught_up(&leader, &follower, "retain-snapshot");
    assert_converged(&follower, &leader, &ds, "retain-snapshot");
    assert_eq!(
        std::fs::metadata(fdir.join(wal::WAL_FILE)).unwrap().len(),
        0,
        "snapshot re-bootstrap covers everything; the new follower log starts empty"
    );

    // The re-bootstrapped follower streams live again.
    for p in &ds.points[154..158] {
        leader.insert(p.clone()).unwrap();
    }
    wait_caught_up(&leader, &follower, "retain-live");
    assert_converged(&follower, &leader, &ds, "retain-live");

    stop_follower(follower, f_rep);
    l_handle.shutdown();
}

// ---------- property: convergence across disconnects + torn tails ----------

/// One random mutation against a shared synthetic pool.
enum Op {
    Insert(Point),
    Delete(u64),
    Refresh,
}

fn gen_ops(rng: &mut Rng, ds: &Dataset, boot: usize, n: usize, fresh: &mut usize) -> Vec<Op> {
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        let roll = rng.below(10);
        let op = if roll < 5 && *fresh < ds.points.len() {
            let p = ds.points[*fresh].clone();
            *fresh += 1;
            Op::Insert(p)
        } else if roll < 7 {
            // Update: move an existing id onto another point's features.
            let mut p = ds.points[rng.below_usize(ds.points.len())].clone();
            p.id = ds.points[rng.below_usize(boot)].id;
            Op::Insert(p)
        } else if roll < 9 {
            // May be a no-op delete; still WAL-logged either way.
            Op::Delete(ds.points[rng.below_usize(ds.points.len())].id)
        } else {
            Op::Refresh
        };
        ops.push(op);
    }
    ops
}

fn apply_ops(gus: &DynamicGus, ops: &[Op]) {
    for op in ops {
        match op {
            Op::Insert(p) => {
                gus.insert(p.clone()).unwrap();
            }
            Op::Delete(id) => {
                gus.delete(*id).unwrap();
            }
            Op::Refresh => gus.refresh_tables(2).unwrap(),
        }
    }
}

/// Leader and follower logs must match byte-for-byte: the stream ships
/// the leader's frames verbatim and the follower appends them raw.
fn assert_same_wal(ldir: &Path, fdir: &Path, tag: &str) {
    let l = std::fs::read(ldir.join(wal::WAL_FILE)).unwrap();
    let f = std::fs::read(fdir.join(wal::WAL_FILE)).unwrap();
    assert!(
        l == f,
        "{tag}: follower WAL ({} bytes) is not byte-identical to the leader's ({} bytes)",
        f.len(),
        l.len()
    );
}

/// Random op streams × random disconnect points × torn local tails: the
/// follower must always converge to the leader, byte-identically.
#[test]
fn follower_converges_across_random_disconnects() {
    proptest_cases(3, |rng| {
        let case = rng.next_u64();
        let ds = SyntheticConfig::arxiv_like(240, 0x9000 + case % 101).generate();
        let boot = 120;
        let ldir = tmpdir(&format!("prop-leader-{case:016x}"));
        let fdir = tmpdir(&format!("prop-follower-{case:016x}"));
        let mut fresh = boot;

        let (l_handle, leader, _l_rep) = boot_leader(&ds, boot, &ldir, 0, ACK_TIMEOUT, 0);
        let leader_addr = l_handle.addr.to_string();

        // Random prefix before the follower ever connects: shipped via
        // snapshot bootstrap (the forced checkpoint), not frames.
        let prefix = gen_ops(rng, &ds, boot, rng.below_usize(12), &mut fresh);
        apply_ops(&leader, &prefix);
        let (follower, f_rep) = boot_follower(&leader_addr, &fdir);

        // Random mid-stream batch shipped as live frames.
        let mid = gen_ops(rng, &ds, boot, 1 + rng.below_usize(10), &mut fresh);
        apply_ops(&leader, &mid);
        wait_caught_up(&leader, &follower, "prop-mid");
        assert_converged(&follower, &leader, &ds, "prop-mid");
        assert_same_wal(&ldir, &fdir, "prop-mid");

        // Disconnect at a random point, then restart with a torn tail:
        // cut 1..=20 bytes off the follower's log — always inside the
        // last frame (header alone is 20 bytes, payloads are larger).
        stop_follower(follower, f_rep);
        let wal_path = fdir.join(wal::WAL_FILE);
        let len = std::fs::metadata(&wal_path).unwrap().len();
        let cut = rng.below(20) + 1;
        if len > cut {
            let f = std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap();
            f.set_len(len - cut).unwrap();
        }

        // The leader moves on while the follower is down.
        let tail = gen_ops(rng, &ds, boot, 1 + rng.below_usize(10), &mut fresh);
        apply_ops(&leader, &tail);

        // Restart: recovery truncates the torn record, the subscription
        // resumes at the durable seq, and the lost record is re-shipped.
        let (follower, f_rep) = boot_follower(&leader_addr, &fdir);
        wait_caught_up(&leader, &follower, "prop-restart");
        assert_converged(&follower, &leader, &ds, "prop-restart");
        assert_same_wal(&ldir, &fdir, "prop-restart");

        stop_follower(follower, f_rep);
        l_handle.shutdown();
    });
}
