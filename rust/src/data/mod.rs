//! Datasets and workload traces.
//!
//! The paper evaluates on **ogbn-arxiv** (169,343 papers: 128-d text
//! embedding + publication year) and **ogbn-products** (2,449,029 products:
//! 100-d bag-of-words/PCA embedding + co-purchase list). This environment
//! is offline, so [`synthetic`] generates clustered multimodal datasets
//! with the same schemas and the statistical properties the evaluation
//! depends on (latent similarity structure; heavy-tailed bucket
//! popularity); [`loader`] reads real OGB-format exports if the user drops
//! them under `data/ogb/` (see DESIGN.md substitution table).
//!
//! [`trace`] turns a dataset into the dynamic workload of §5.2: an initial
//! corpus plus a stream of insert/update/delete/query operations.

pub mod loader;
pub mod synthetic;
pub mod trace;

use crate::features::{Point, Schema};

pub use synthetic::{SyntheticConfig, SyntheticDataset};
pub use trace::{Op, Trace, TraceConfig};

/// A concrete dataset: schema + points (+ optional latent cluster labels,
/// available for synthetic data and used by training and examples).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub schema: Schema,
    pub points: Vec<Point>,
    /// Latent cluster id per point (parallel to `points`); empty if unknown.
    pub cluster_of: Vec<u32>,
}

impl Dataset {
    /// Ground-truth "similar" relation for training/eval: same cluster.
    pub fn same_cluster(&self, i: usize, j: usize) -> Option<bool> {
        if self.cluster_of.is_empty() {
            None
        } else {
            Some(self.cluster_of[i] == self.cluster_of[j])
        }
    }
}
