//! Dynamic workload traces (§5.2).
//!
//! A trace is an initial corpus plus an operation stream. The paper's
//! dynamic experiment loads the dataset, then queries the neighborhood of
//! 10,000 sampled points sequentially; it also measures insertion latency.
//! [`TraceConfig`] generalizes this into a mixed mutation/query stream for
//! the end-to-end examples (e.g. the Android-security scenario streams
//! inserts of new apps and queries their neighborhoods immediately).

use super::Dataset;
use crate::features::{Point, PointId};
use crate::util::rng::Rng;

/// One operation in a dynamic workload.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Insert (or re-insert) a point.
    Insert(Point),
    /// Update an existing point with new features.
    Update(Point),
    /// Delete a point by id.
    Delete(PointId),
    /// Query the neighborhood of a point (by full features — the point may
    /// be new or known) with `k` neighbors.
    Query { point: Point, k: usize },
}

/// A dynamic workload: preloaded corpus + operation stream.
pub struct Trace {
    /// Points loaded before the stream starts (§4.3 initial corpus).
    pub initial: Vec<Point>,
    pub ops: Vec<Op>,
}

/// Stream composition knobs.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Fraction of the dataset preloaded as the initial corpus.
    pub initial_fraction: f64,
    /// Number of stream operations.
    pub n_ops: usize,
    /// Mix (must sum to ≤ 1.0; remainder = queries).
    pub insert_prob: f64,
    pub update_prob: f64,
    pub delete_prob: f64,
    /// `k` for queries (the paper's ScaNN-NN).
    pub query_k: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            initial_fraction: 0.8,
            n_ops: 10_000,
            insert_prob: 0.1,
            update_prob: 0.05,
            delete_prob: 0.02,
            query_k: 10,
            seed: 0x7472_6163_65,
        }
    }
}

impl TraceConfig {
    /// The paper's §5.2 query-only experiment: full corpus preloaded, then
    /// `n_queries` sequential neighborhood queries of sampled known points.
    pub fn query_only(n_queries: usize, k: usize, seed: u64) -> TraceConfig {
        TraceConfig {
            initial_fraction: 1.0,
            n_ops: n_queries,
            insert_prob: 0.0,
            update_prob: 0.0,
            delete_prob: 0.0,
            query_k: k,
            seed,
        }
    }

    /// Build a trace from a dataset.
    ///
    /// Inserts introduce the held-out points; updates re-feature random live
    /// points (jittering the dense embedding); deletes remove random live
    /// points; queries sample random live points.
    pub fn build(&self, ds: &Dataset) -> Trace {
        assert!(self.insert_prob + self.update_prob + self.delete_prob <= 1.0 + 1e-9);
        let mut rng = Rng::seeded(self.seed);
        let n = ds.points.len();
        let n_initial = ((n as f64) * self.initial_fraction).round() as usize;
        let n_initial = n_initial.min(n);
        let initial: Vec<Point> = ds.points[..n_initial].to_vec();

        let mut live: Vec<usize> = (0..n_initial).collect();
        let mut pending: Vec<usize> = (n_initial..n).collect();
        let mut ops = Vec::with_capacity(self.n_ops);
        for _ in 0..self.n_ops {
            let r = rng.f64();
            if r < self.insert_prob && !pending.is_empty() {
                let i = pending.swap_remove(rng.below_usize(pending.len()));
                ops.push(Op::Insert(ds.points[i].clone()));
                live.push(i);
            } else if r < self.insert_prob + self.update_prob && !live.is_empty() {
                let &i = rng.choose(&live);
                let mut p = ds.points[i].clone();
                jitter_dense(&mut p, &mut rng, 0.05);
                ops.push(Op::Update(p));
            } else if r < self.insert_prob + self.update_prob + self.delete_prob
                && live.len() > 1
            {
                let pos = rng.below_usize(live.len());
                let i = live.swap_remove(pos);
                ops.push(Op::Delete(ds.points[i].id));
            } else if !live.is_empty() {
                let &i = rng.choose(&live);
                ops.push(Op::Query { point: ds.points[i].clone(), k: self.query_k });
            }
        }
        Trace { initial, ops }
    }
}

/// Add N(0, σ) noise to the first dense channel and re-normalize.
fn jitter_dense(p: &mut Point, rng: &mut Rng, sigma: f64) {
    for f in &mut p.features {
        if let crate::features::FeatureValue::Dense(v) = f {
            for x in v.iter_mut() {
                *x += (sigma * rng.normal()) as f32;
            }
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
            for x in v.iter_mut() {
                *x /= norm;
            }
            break;
        }
    }
}

impl Op {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match self {
            Op::Insert(p) => Json::obj(vec![("op", Json::str("insert")), ("point", p.to_json())]),
            Op::Update(p) => Json::obj(vec![("op", Json::str("update")), ("point", p.to_json())]),
            Op::Delete(id) => Json::obj(vec![("op", Json::str("delete")), ("id", Json::u64(*id))]),
            Op::Query { point, k } => Json::obj(vec![
                ("op", Json::str("query")),
                ("point", point.to_json()),
                ("k", Json::num(*k as f64)),
            ]),
        }
    }

    pub fn from_json(j: &crate::util::json::Json) -> Option<Op> {
        match j.get("op").as_str()? {
            "insert" => Some(Op::Insert(Point::from_json(j.get("point"))?)),
            "update" => Some(Op::Update(Point::from_json(j.get("point"))?)),
            "delete" => Some(Op::Delete(j.get("id").as_u64()?)),
            "query" => Some(Op::Query {
                point: Point::from_json(j.get("point"))?,
                k: j.get("k").as_usize()?,
            }),
            _ => None,
        }
    }
}

impl Trace {
    /// Save as JSONL: first line `{"initial": N}`, then N initial points,
    /// then one op per line (shareable, replayable workloads).
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use std::io::Write;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        let header = crate::util::json::Json::obj(vec![(
            "initial",
            crate::util::json::Json::num(self.initial.len() as f64),
        )]);
        writeln!(w, "{}", header.dump())?;
        for p in &self.initial {
            writeln!(w, "{}", p.to_json().dump())?;
        }
        for op in &self.ops {
            writeln!(w, "{}", op.to_json().dump())?;
        }
        Ok(())
    }

    /// Load a trace saved by [`Trace::save`].
    pub fn load(path: &std::path::Path) -> anyhow::Result<Trace> {
        use std::io::BufRead;
        let f = std::fs::File::open(path)?;
        let mut lines = std::io::BufReader::new(f).lines();
        let header = crate::util::json::Json::parse(
            &lines.next().ok_or_else(|| anyhow::anyhow!("empty trace"))??,
        )
        .map_err(|e| anyhow::anyhow!("trace header: {e}"))?;
        let n_initial = header
            .get("initial")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("trace header missing 'initial'"))?;
        let mut initial = Vec::with_capacity(n_initial);
        let mut ops = Vec::new();
        for (i, line) in lines.enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let j = crate::util::json::Json::parse(&line)
                .map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 2))?;
            if i < n_initial {
                initial.push(
                    Point::from_json(&j)
                        .ok_or_else(|| anyhow::anyhow!("trace line {}: bad point", i + 2))?,
                );
            } else {
                ops.push(
                    Op::from_json(&j)
                        .ok_or_else(|| anyhow::anyhow!("trace line {}: bad op", i + 2))?,
                );
            }
        }
        anyhow::ensure!(initial.len() == n_initial, "trace truncated");
        Ok(Trace { initial, ops })
    }

    /// Count of each op kind: (inserts, updates, deletes, queries).
    pub fn op_mix(&self) -> (usize, usize, usize, usize) {
        let mut mix = (0, 0, 0, 0);
        for op in &self.ops {
            match op {
                Op::Insert(_) => mix.0 += 1,
                Op::Update(_) => mix.1 += 1,
                Op::Delete(_) => mix.2 += 1,
                Op::Query { .. } => mix.3 += 1,
            }
        }
        mix
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;

    fn ds() -> Dataset {
        SyntheticConfig::arxiv_like(300, 9).generate()
    }

    #[test]
    fn query_only_trace() {
        let d = ds();
        let t = TraceConfig::query_only(100, 10, 1).build(&d);
        assert_eq!(t.initial.len(), 300);
        assert_eq!(t.ops.len(), 100);
        let (i, u, del, q) = t.op_mix();
        assert_eq!((i, u, del), (0, 0, 0));
        assert_eq!(q, 100);
        for op in &t.ops {
            match op {
                Op::Query { k, .. } => assert_eq!(*k, 10),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn mixed_trace_respects_probabilities_roughly() {
        let d = ds();
        let cfg = TraceConfig {
            initial_fraction: 0.5,
            n_ops: 2000,
            insert_prob: 0.2,
            update_prob: 0.1,
            delete_prob: 0.05,
            query_k: 5,
            seed: 3,
        };
        let t = cfg.build(&d);
        assert_eq!(t.initial.len(), 150);
        let (i, u, del, q) = t.op_mix();
        assert!(i > 50, "inserts {i}"); // capped by 150 held-out points
        assert!(u > 100, "updates {u}");
        assert!(del > 40, "deletes {del}");
        assert!(q > 1000, "queries {q}");
    }

    #[test]
    fn inserts_only_introduce_held_out_points() {
        let d = ds();
        let cfg = TraceConfig {
            initial_fraction: 0.9,
            n_ops: 500,
            insert_prob: 0.5,
            update_prob: 0.0,
            delete_prob: 0.0,
            query_k: 5,
            seed: 4,
        };
        let t = cfg.build(&d);
        let initial_ids: std::collections::HashSet<u64> =
            t.initial.iter().map(|p| p.id).collect();
        let mut seen = std::collections::HashSet::new();
        for op in &t.ops {
            if let Op::Insert(p) = op {
                assert!(!initial_ids.contains(&p.id), "re-inserting initial point");
                assert!(seen.insert(p.id), "duplicate insert");
            }
        }
    }

    #[test]
    fn deterministic() {
        let d = ds();
        let t1 = TraceConfig::default().build(&d);
        let t2 = TraceConfig::default().build(&d);
        assert_eq!(t1.ops.len(), t2.ops.len());
        assert_eq!(t1.op_mix(), t2.op_mix());
    }

    #[test]
    fn save_load_roundtrip() {
        let d = ds();
        let t = TraceConfig {
            n_ops: 200,
            insert_prob: 0.2,
            update_prob: 0.1,
            delete_prob: 0.05,
            ..TraceConfig::default()
        }
        .build(&d);
        let path = std::env::temp_dir().join("gus-trace-tests/t.jsonl");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.initial, t.initial);
        assert_eq!(back.ops, t.ops);
    }

    #[test]
    fn load_rejects_truncated() {
        let d = ds();
        let t = TraceConfig::query_only(10, 5, 1).build(&d);
        let path = std::env::temp_dir().join("gus-trace-tests/trunc.jsonl");
        t.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let keep: Vec<&str> = text.lines().take(50).collect();
        std::fs::write(&path, keep.join("\n")).unwrap();
        assert!(Trace::load(&path).is_err());
    }

    #[test]
    fn updates_stay_normalized() {
        let d = ds();
        let cfg = TraceConfig {
            update_prob: 1.0,
            insert_prob: 0.0,
            delete_prob: 0.0,
            n_ops: 50,
            ..TraceConfig::default()
        };
        let t = cfg.build(&d);
        for op in &t.ops {
            if let Op::Update(p) = op {
                let n: f32 = p.dense(0).iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!((n - 1.0).abs() < 1e-4);
            }
        }
    }
}
