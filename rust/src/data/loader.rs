//! Dataset persistence: JSONL save/load (and the OGB drop-in path).
//!
//! Format: one JSON object per line. First line is a header
//! `{"schema": "arxiv_like"|"products_like", "dense_dim": d}`; every other
//! line is a point `{"id": .., "features": [..], "cluster": ..?}` in the
//! [`crate::features::Point::to_json`] encoding. Real OGB exports converted
//! to this format (e.g. via a small offline script) load through the same
//! path — see DESIGN.md's substitution table.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::Dataset;
use crate::features::{Point, Schema};
use crate::util::json::Json;

/// Save a dataset as JSONL.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let header = Json::obj(vec![
        ("schema", Json::str(ds.schema.name.clone())),
        ("dense_dim", Json::num(ds.schema.primary_dense_dim() as f64)),
    ]);
    writeln!(w, "{}", header.dump())?;
    for (i, p) in ds.points.iter().enumerate() {
        let mut j = p.to_json();
        if let (Json::Obj(m), Some(&c)) = (&mut j, ds.cluster_of.get(i)) {
            m.insert("cluster".to_string(), Json::num(c as f64));
        }
        writeln!(w, "{}", j.dump())?;
    }
    Ok(())
}

/// Load a dataset from JSONL.
pub fn load(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut lines = reader.lines();
    let header_line = lines
        .next()
        .ok_or_else(|| anyhow!("{}: empty file", path.display()))??;
    let header =
        Json::parse(&header_line).map_err(|e| anyhow!("{}: header: {e}", path.display()))?;
    let schema_name = header
        .get("schema")
        .as_str()
        .ok_or_else(|| anyhow!("header missing 'schema'"))?;
    let dense_dim = header
        .get("dense_dim")
        .as_usize()
        .ok_or_else(|| anyhow!("header missing 'dense_dim'"))?;
    let schema = match schema_name {
        "arxiv_like" => Schema::arxiv_like(dense_dim),
        "products_like" => Schema::products_like(dense_dim),
        other => bail!("unknown schema '{other}'"),
    };

    let mut points = Vec::new();
    let mut cluster_of = Vec::new();
    let mut any_cluster = false;
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| anyhow!("{} line {}: {e}", path.display(), lineno + 2))?;
        let p = Point::from_json(&j)
            .ok_or_else(|| anyhow!("{} line {}: bad point", path.display(), lineno + 2))?;
        schema
            .validate(&p)
            .map_err(|e| anyhow!("{} line {}: {e}", path.display(), lineno + 2))?;
        if let Some(c) = j.get("cluster").as_u64() {
            cluster_of.push(c as u32);
            any_cluster = true;
        } else {
            cluster_of.push(u32::MAX);
        }
        points.push(p);
    }
    Ok(Dataset {
        schema,
        points,
        cluster_of: if any_cluster { cluster_of } else { Vec::new() },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("gus-loader-tests");
        let _ = std::fs::create_dir_all(&dir);
        dir.join(name)
    }

    #[test]
    fn roundtrip_arxiv() {
        let ds = SyntheticConfig::arxiv_like(50, 1).generate();
        let path = tmpfile("arxiv.jsonl");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.points, ds.points);
        assert_eq!(back.cluster_of, ds.cluster_of);
        assert_eq!(back.schema, ds.schema);
    }

    #[test]
    fn roundtrip_products() {
        let ds = SyntheticConfig::products_like(40, 2).generate();
        let path = tmpfile("products.jsonl");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.points, ds.points);
        assert_eq!(back.schema.name, "products_like");
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(load(Path::new("/nonexistent/ds.jsonl")).is_err());
    }

    #[test]
    fn load_rejects_schema_violation() {
        let path = tmpfile("bad.jsonl");
        std::fs::write(
            &path,
            concat!(
                "{\"schema\":\"arxiv_like\",\"dense_dim\":4}\n",
                "{\"id\":0,\"features\":[{\"dense\":[1,2]},{\"scalar\":2020}]}\n"
            ),
        )
        .unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err}").contains("line 2"), "{err}");
    }

    #[test]
    fn load_rejects_bad_json() {
        let path = tmpfile("badjson.jsonl");
        std::fs::write(
            &path,
            "{\"schema\":\"arxiv_like\",\"dense_dim\":2}\nnot json\n",
        )
        .unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn cluster_labels_optional() {
        let path = tmpfile("nocluster.jsonl");
        std::fs::write(
            &path,
            concat!(
                "{\"schema\":\"arxiv_like\",\"dense_dim\":2}\n",
                "{\"id\":0,\"features\":[{\"dense\":[1,0]},{\"scalar\":2020}]}\n"
            ),
        )
        .unwrap();
        let ds = load(&path).unwrap();
        assert_eq!(ds.points.len(), 1);
        assert!(ds.cluster_of.is_empty());
    }
}
