//! Synthetic clustered multimodal datasets.
//!
//! Generative model (kept deliberately simple and **mirrored in
//! `python/compile/datagen.py`** so the offline-trained model sees the same
//! distribution family the Rust side serves — the parameters below are the
//! cross-language contract):
//!
//! - `n_clusters` clusters; cluster sizes ∝ lognormal(σ=1) (heavy-tailed,
//!   like real topic/product categories);
//! - clusters are **hierarchical**: `n_clusters/5` parent topics with
//!   centers `~ N(0, I_d)`; each cluster center = parent + 0.6·N(0, I).
//!   Same-parent clusters are therefore moderately similar — this is what
//!   gives the similarity model a *graded* score distribution (like the
//!   paper's curves) instead of a trivially separable 0/1 one;
//! - point embedding `x = μ_c + σ·N(0, I)` then L2-normalized (OGB text
//!   embeddings are average word vectors — roughly unit-norm directions);
//! - **arxiv_like**: publication year = cluster base year (uniform in
//!   [1995, 2023]) + N(0, 3), clamped to the range;
//! - **products_like**: co-purchase tokens = `n_tok ~ U[3, 12]` samples from
//!   the cluster's pool of 40 tokens, **plus** `2 + U[0, 6]` samples from a
//!   global Zipf(1.1) pool of 2,000 "popular" tokens shared across all
//!   clusters (best-sellers co-purchased with everything — the "the"/"a"
//!   analogue). Every point carries junk tokens, and the junk pool is a few
//!   percent of the distinct-bucket universe, so `Filter-P` has exactly the
//!   role the paper gives it: banning the junk mega-buckets that otherwise
//!   pollute candidate retrieval.

use super::Dataset;
use crate::features::{FeatureValue, Point, Schema};
use crate::util::rng::Rng;

/// Parameters of the generator.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    /// `"arxiv_like"` or `"products_like"`.
    pub kind: SyntheticDataset,
    pub n_points: usize,
    pub n_clusters: usize,
    pub dense_dim: usize,
    /// Embedding noise σ around the cluster center (before normalization).
    pub noise: f64,
    pub seed: u64,
}

/// The two dataset shapes of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyntheticDataset {
    ArxivLike,
    ProductsLike,
}

impl SyntheticConfig {
    /// ogbn-arxiv stand-in (paper scale: 169,343; default laptop scale).
    pub fn arxiv_like(n_points: usize, seed: u64) -> SyntheticConfig {
        SyntheticConfig {
            kind: SyntheticDataset::ArxivLike,
            n_points,
            n_clusters: (n_points / 200).max(4),
            dense_dim: 128,
            noise: 0.55,
            seed,
        }
    }

    /// ogbn-products stand-in (paper scale: 2,449,029; default laptop scale).
    pub fn products_like(n_points: usize, seed: u64) -> SyntheticConfig {
        SyntheticConfig {
            kind: SyntheticDataset::ProductsLike,
            n_points,
            n_clusters: (n_points / 150).max(4),
            dense_dim: 100,
            noise: 0.5,
            seed,
        }
    }

    pub fn schema(&self) -> Schema {
        match self.kind {
            SyntheticDataset::ArxivLike => Schema::arxiv_like(self.dense_dim),
            SyntheticDataset::ProductsLike => Schema::products_like(self.dense_dim),
        }
    }

    /// The cluster-level parameters of the generative model, drawn from
    /// `self.seed` in the exact order [`SyntheticConfig::generate`] draws
    /// them — so a [`PointSampler`] built from the same config samples
    /// from the *same* latent clusters as the materialized corpus.
    fn cluster_model(&self, rng: &mut Rng) -> ClusterModel {
        let d = self.dense_dim;
        let k = self.n_clusters.max(1);
        let weights: Vec<f64> = (0..k).map(|_| rng.lognormal(0.0, 1.0)).collect();
        let n_parents = (k / 5).max(1);
        let parents: Vec<Vec<f32>> = (0..n_parents).map(|_| rng.normal_vec_f32(d)).collect();
        let centers: Vec<Vec<f32>> = (0..k)
            .map(|c| {
                parents[c % n_parents]
                    .iter()
                    .map(|&x| x + 0.6 * rng.normal() as f32)
                    .collect()
            })
            .collect();
        let base_years: Vec<f32> = (0..k).map(|_| 1995.0 + rng.below(29) as f32).collect();
        let token_pools: Vec<Vec<u64>> = (0..k)
            .map(|c| (0..40u64).map(|t| 1_000_000 + c as u64 * 1000 + t).collect())
            .collect();
        ClusterModel { weights, centers, base_years, token_pools }
    }

    /// A streaming per-point generator over the same latent cluster model
    /// as [`SyntheticConfig::generate`]. Holds only the cluster
    /// parameters (O(clusters × dim) memory), so the load generator can
    /// draw fresh inserts and query points against a ≥10M-point corpus
    /// without ever materializing the corpus on the client side.
    pub fn sampler(&self) -> PointSampler {
        let mut rng = Rng::seeded(self.seed);
        let model = self.cluster_model(&mut rng);
        let wsum: f64 = model.weights.iter().sum();
        let mut acc = 0.0;
        let cdf: Vec<f64> = model
            .weights
            .iter()
            .map(|w| {
                acc += w / wsum;
                acc
            })
            .collect();
        PointSampler { kind: self.kind, noise: self.noise, model, cdf }
    }

    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        let mut rng = Rng::seeded(self.seed);
        let schema = self.schema();
        let k = self.n_clusters.max(1);
        let model = self.cluster_model(&mut rng);

        // Cluster sizes: lognormal weights normalized to n_points.
        let weights = &model.weights;
        let wsum: f64 = weights.iter().sum();
        let mut sizes: Vec<usize> = weights
            .iter()
            .map(|w| ((w / wsum) * self.n_points as f64).floor() as usize)
            .collect();
        // Distribute the rounding remainder.
        let mut total: usize = sizes.iter().sum();
        let mut ci = 0;
        while total < self.n_points {
            sizes[ci % k] += 1;
            total += 1;
            ci += 1;
        }

        let mut points = Vec::with_capacity(self.n_points);
        let mut cluster_of = Vec::with_capacity(self.n_points);
        let mut next_id = 0u64;
        for (c, &size) in sizes.iter().enumerate() {
            for _ in 0..size {
                points.push(emit_point(self.kind, self.noise, &model, c, next_id, &mut rng));
                cluster_of.push(c as u32);
                next_id += 1;
            }
        }

        // Shuffle so ids do not correlate with clusters (stream realism),
        // keeping (point, cluster) pairs aligned.
        let mut perm: Vec<usize> = (0..points.len()).collect();
        rng.shuffle(&mut perm);
        let points_shuffled: Vec<Point> = perm.iter().map(|&i| points[i].clone()).collect();
        let clusters_shuffled: Vec<u32> = perm.iter().map(|&i| cluster_of[i]).collect();
        // Re-assign ids in order so external ids are dense 0..n.
        let points_final: Vec<Point> = points_shuffled
            .into_iter()
            .enumerate()
            .map(|(i, mut p)| {
                p.id = i as u64;
                p
            })
            .collect();

        Dataset {
            schema,
            points: points_final,
            cluster_of: clusters_shuffled,
        }
    }
}

// Global popular tokens: ids 1..=2000, sampled by Zipf rank.
const GLOBAL_POOL: u64 = 2000;
const ZIPF_S: f64 = 1.1;

/// The per-cluster parameters both [`SyntheticConfig::generate`] and
/// [`PointSampler`] draw points from.
struct ClusterModel {
    weights: Vec<f64>,
    centers: Vec<Vec<f32>>,
    base_years: Vec<f32>,
    token_pools: Vec<Vec<u64>>,
}

/// Draw one point of cluster `c`. Consumes `rng` in a fixed order, so
/// `generate()`'s output for a given seed is stable across refactors.
fn emit_point(
    kind: SyntheticDataset,
    noise: f64,
    model: &ClusterModel,
    c: usize,
    id: u64,
    rng: &mut Rng,
) -> Point {
    let mut x: Vec<f32> = model.centers[c]
        .iter()
        .map(|&m| m + (noise * rng.normal()) as f32)
        .collect();
    let norm = x.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
    for v in &mut x {
        *v /= norm;
    }
    let features = match kind {
        SyntheticDataset::ArxivLike => {
            let year =
                (model.base_years[c] + (3.0 * rng.normal()) as f32).clamp(1995.0, 2023.0);
            vec![FeatureValue::Dense(x), FeatureValue::Scalar(year)]
        }
        SyntheticDataset::ProductsLike => {
            let pool = &model.token_pools[c];
            let n_tok = 3 + rng.below_usize(10);
            let mut toks: Vec<u64> = rng
                .sample_indices(pool.len(), n_tok.min(40))
                .into_iter()
                .map(|i| pool[i])
                .collect();
            let n_pop = 2 + rng.below_usize(7);
            for _ in 0..n_pop {
                toks.push(1 + rng.zipf(GLOBAL_POOL, ZIPF_S));
            }
            toks.sort_unstable();
            toks.dedup();
            vec![FeatureValue::Dense(x), FeatureValue::Tokens(toks)]
        }
    };
    Point::new(id, features)
}

/// Streaming point generator over a [`SyntheticConfig`]'s cluster model
/// (see [`SyntheticConfig::sampler`]). `Sync`: callers bring their own
/// [`Rng`], so one sampler can feed many load-generator workers.
pub struct PointSampler {
    kind: SyntheticDataset,
    noise: f64,
    model: ClusterModel,
    /// Cumulative cluster-pick distribution (∝ the corpus's lognormal
    /// cluster sizes, so streamed points land in clusters at the same
    /// rate the materialized corpus populates them).
    cdf: Vec<f64>,
}

impl PointSampler {
    pub fn n_clusters(&self) -> usize {
        self.model.centers.len()
    }

    /// Sample one fresh point with the caller-chosen id.
    pub fn sample(&self, id: u64, rng: &mut Rng) -> Point {
        let u = rng.f64();
        let c = self.cdf.partition_point(|&acc| acc < u).min(self.cdf.len() - 1);
        self.sample_cluster(c, id, rng)
    }

    /// Sample one fresh point from a specific cluster.
    pub fn sample_cluster(&self, c: usize, id: u64, rng: &mut Rng) -> Point {
        emit_point(self.kind, self.noise, &self.model, c, id, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_size_and_schema() {
        let ds = SyntheticConfig::arxiv_like(500, 1).generate();
        assert_eq!(ds.points.len(), 500);
        assert_eq!(ds.cluster_of.len(), 500);
        assert_eq!(ds.schema.name, "arxiv_like");
        for p in &ds.points {
            ds.schema.validate(p).unwrap();
        }
        // Dense ids 0..n.
        for (i, p) in ds.points.iter().enumerate() {
            assert_eq!(p.id, i as u64);
        }
    }

    #[test]
    fn products_have_tokens_with_popular_overlap() {
        let ds = SyntheticConfig::products_like(800, 2).generate();
        assert_eq!(ds.schema.name, "products_like");
        let mut popular_count = 0usize;
        for p in &ds.points {
            let toks = p.tokens(1);
            assert!(!toks.is_empty());
            popular_count += toks.iter().filter(|&&t| t <= 2000).count();
        }
        // Zipf pool tokens must actually occur (they drive Filter-P).
        assert!(popular_count > 200, "too few popular tokens: {popular_count}");
    }

    #[test]
    fn deterministic() {
        let a = SyntheticConfig::arxiv_like(100, 7).generate();
        let b = SyntheticConfig::arxiv_like(100, 7).generate();
        assert_eq!(a.points, b.points);
        assert_eq!(a.cluster_of, b.cluster_of);
        let c = SyntheticConfig::arxiv_like(100, 8).generate();
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn embeddings_unit_norm() {
        let ds = SyntheticConfig::arxiv_like(50, 3).generate();
        for p in &ds.points {
            let n: f32 = p.dense(0).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4, "norm {n}");
        }
    }

    #[test]
    fn same_cluster_points_are_closer() {
        let ds = SyntheticConfig::arxiv_like(400, 4).generate();
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let (mut intra, mut inter, mut ni, mut nx) = (0.0f64, 0.0f64, 0, 0);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let d = dot(ds.points[i].dense(0), ds.points[j].dense(0)) as f64;
                if ds.same_cluster(i, j).unwrap() {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nx += 1;
                }
            }
        }
        if ni > 0 && nx > 0 {
            assert!(
                intra / ni as f64 > inter / nx as f64 + 0.2,
                "clusters not separated: intra={} inter={}",
                intra / ni as f64,
                inter / nx as f64
            );
        }
    }

    #[test]
    fn sampler_points_match_schema_and_are_deterministic() {
        for cfg in [
            SyntheticConfig::arxiv_like(2_000, 11),
            SyntheticConfig::products_like(2_000, 11),
        ] {
            let schema = cfg.schema();
            let sampler = cfg.sampler();
            let mut rng = Rng::seeded(99);
            for i in 0..50u64 {
                let p = sampler.sample(1_000_000 + i, &mut rng);
                assert_eq!(p.id, 1_000_000 + i);
                schema.validate(&p).unwrap();
                let n: f32 = p.dense(0).iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!((n - 1.0).abs() < 1e-4, "norm {n}");
            }
            // Same rng stream → same points (replayable load schedules).
            let mut a = Rng::seeded(5);
            let mut b = Rng::seeded(5);
            assert_eq!(sampler.sample(7, &mut a), sampler.sample(7, &mut b));
        }
    }

    #[test]
    fn sampler_shares_the_corpus_cluster_model() {
        // A streamed point must be substantially closer to its own
        // cluster's corpus points than to the rest — i.e. the sampler
        // really drew from the same latent centers `generate()` used.
        let cfg = SyntheticConfig::arxiv_like(1_000, 21);
        let ds = cfg.generate();
        let sampler = cfg.sampler();
        let mut rng = Rng::seeded(3);
        let dot = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x * y) as f64).sum()
        };
        let (mut intra, mut inter, mut ni, mut nx) = (0.0f64, 0.0f64, 0u32, 0u32);
        for _ in 0..30 {
            let c = rng.below_usize(sampler.n_clusters());
            let p = sampler.sample_cluster(c, u64::MAX, &mut rng);
            for (q, &qc) in ds.points.iter().zip(&ds.cluster_of).take(300) {
                let d = dot(p.dense(0), q.dense(0));
                if qc as usize == c {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nx += 1;
                }
            }
        }
        assert!(ni > 0 && nx > 0, "no same-cluster corpus points sampled");
        assert!(
            intra / ni as f64 > inter / nx as f64 + 0.2,
            "sampler decoupled from corpus clusters: intra={} inter={}",
            intra / ni as f64,
            inter / nx as f64
        );
    }

    #[test]
    fn cluster_sizes_heavy_tailed() {
        let ds = SyntheticConfig::products_like(2000, 5).generate();
        let k = ds.cluster_of.iter().max().unwrap() + 1;
        let mut sizes = vec![0usize; k as usize + 1];
        for &c in &ds.cluster_of {
            sizes[c as usize] += 1;
        }
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().filter(|&&s| s > 0).min().unwrap();
        assert!(max > min * 2, "sizes not skewed: max={max} min={min}");
    }
}
