//! Offline preprocessing (§4.3).
//!
//! Before the dynamic service starts, the initial corpus is scanned once to
//! (1) collect bucket statistics, (2) derive the bounded IDF table and the
//! popular-bucket filter, and (3) warm the index. The same scan is re-run
//! periodically ("periodic reloading") so the tables stay approximately
//! consistent with the evolving dataset; the model itself is retrained by
//! `python/compile/train.py` and hot-swapped through the weights JSON.

use crate::config::GusConfig;
use crate::embed::{BucketStats, EmbeddingGenerator, IdfTable, PopularFilter};
use crate::features::Point;
use crate::lsh::Bucketer;
use crate::util::threadpool::parallel_map;

/// Result of an offline preprocessing pass.
pub struct Preprocessed {
    pub stats: BucketStats,
    pub idf: Option<IdfTable>,
    pub filter: Option<PopularFilter>,
}

/// Scan `corpus` once and derive the §4.2 tables per `config`.
pub fn preprocess(
    bucketer: &Bucketer,
    corpus: &[Point],
    config: &GusConfig,
    threads: usize,
) -> Preprocessed {
    // Parallel bucket computation, merged into one stats object.
    let threads = threads.max(1);
    let chunk = corpus.len().div_ceil(threads).max(1);
    let ranges: Vec<std::ops::Range<usize>> = (0..threads)
        .map(|t| (t * chunk).min(corpus.len())..((t + 1) * chunk).min(corpus.len()))
        .filter(|r| !r.is_empty())
        .collect();
    let partials: Vec<BucketStats> = parallel_map(ranges.len(), threads, |ri| {
        let mut stats = BucketStats::new();
        let mut buf = Vec::new();
        for i in ranges[ri].clone() {
            bucketer.buckets_into(&corpus[i], &mut buf);
            stats.add_buckets(&buf);
        }
        stats
    });
    let mut stats = BucketStats::new();
    for p in &partials {
        stats.merge(p);
    }
    let idf = (config.idf_s > 0).then(|| IdfTable::from_stats(&stats, config.idf_s));
    let filter =
        (config.filter_p > 0.0).then(|| PopularFilter::from_stats(&stats, config.filter_p));
    Preprocessed { stats, idf, filter }
}

/// Build a ready-to-serve [`EmbeddingGenerator`] from a preprocessing pass.
pub fn build_generator(
    bucketer: Bucketer,
    pre: &Preprocessed,
) -> EmbeddingGenerator {
    EmbeddingGenerator::new(bucketer, pre.idf.clone(), pre.filter.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;

    fn corpus() -> (Bucketer, Vec<Point>) {
        let ds = SyntheticConfig::products_like(400, 3).generate();
        let bucketer = Bucketer::with_defaults(&ds.schema, 42);
        (bucketer, ds.points)
    }

    #[test]
    fn derives_tables_per_config() {
        let (b, pts) = corpus();
        let cfg = GusConfig { idf_s: 100, filter_p: 5.0, ..GusConfig::default() };
        let pre = preprocess(&b, &pts, &cfg, 4);
        assert_eq!(pre.stats.num_points(), 400);
        assert!(pre.stats.num_buckets() > 0);
        let idf = pre.idf.as_ref().unwrap();
        assert!(idf.len() <= 100);
        let filter = pre.filter.as_ref().unwrap();
        assert_eq!(
            filter.len(),
            (pre.stats.num_buckets() as f64 * 0.05).floor() as usize
        );
    }

    #[test]
    fn disabled_tables_are_none() {
        let (b, pts) = corpus();
        let cfg = GusConfig { idf_s: 0, filter_p: 0.0, ..GusConfig::default() };
        let pre = preprocess(&b, &pts, &cfg, 2);
        assert!(pre.idf.is_none());
        assert!(pre.filter.is_none());
    }

    #[test]
    fn parallel_matches_sequential() {
        let (b, pts) = corpus();
        let cfg = GusConfig { idf_s: 50, filter_p: 10.0, ..GusConfig::default() };
        let p1 = preprocess(&b, &pts, &cfg, 1);
        let p8 = preprocess(&b, &pts, &cfg, 8);
        assert_eq!(p1.stats.num_points(), p8.stats.num_points());
        assert_eq!(p1.stats.num_buckets(), p8.stats.num_buckets());
        // Same filter decisions.
        for (bucket, _) in p1.stats.iter() {
            assert_eq!(
                p1.filter.as_ref().unwrap().is_banned(bucket),
                p8.filter.as_ref().unwrap().is_banned(bucket)
            );
        }
    }

    #[test]
    fn generator_applies_tables() {
        let (b, pts) = corpus();
        let cfg = GusConfig { idf_s: 1000, filter_p: 20.0, ..GusConfig::default() };
        let pre = preprocess(&b, &pts, &cfg, 2);
        let banned_before: usize = pts
            .iter()
            .map(|p| {
                b.buckets(p)
                    .iter()
                    .filter(|&&bk| pre.filter.as_ref().unwrap().is_banned(bk))
                    .count()
            })
            .sum();
        assert!(banned_before > 0, "popular tokens should produce bans");
        let bucketer2 = Bucketer::with_defaults(
            &SyntheticConfig::products_like(400, 3).generate().schema,
            42,
        );
        let g = build_generator(bucketer2, &pre);
        // Embeddings exclude banned dims.
        for p in pts.iter().take(50) {
            let v = g.embed(p);
            for d in v.dims() {
                assert!(!pre.filter.as_ref().unwrap().is_banned(*d));
            }
        }
    }
}
