//! Offline quality experiments: Figs. 3–8.
//!
//! "Offline GUS" runs the exact request-path pipeline (embed → retrieve →
//! score) over a static corpus; the paper notes it produces results
//! identical to the dynamic system (§5.1), which holds here trivially —
//! it *is* the same code.
//!
//! Edge counting follows the paper: directed edges (each point's retrieved
//! neighbor list counts; a scored pair contributes to both endpoints in
//! Grale's no-Top-K mode).

use crate::config::{GusConfig, ScorerKind};
use crate::coordinator::DynamicGus;
use crate::data::Dataset;
use crate::grale::{GraleBuilder, GraleConfig};
use crate::graph::WeightHistogram;
use crate::index::{DimOrder, QueryParams, QueryScratch, SparseAnn};
use crate::lsh::Bucketer;
use crate::preprocess;
use crate::scorer::PairScorer;
use crate::util::threadpool::parallel_map;

use super::report::Series;

/// LSH seed shared by Grale and GUS in every experiment (Lemma 4.1 requires
/// both to see the same buckets).
pub const EVAL_LSH_SEED: u64 = 0xe7a1;

/// Offline GUS parameters (the paper's knobs).
#[derive(Debug, Clone, Copy)]
pub struct GusOfflineParams {
    /// ScaNN-NN; 0 = threshold retrieval of ALL negative-distance points
    /// (Fig. 3's setting).
    pub nn: usize,
    /// IDF-S (0 = disabled).
    pub idf_s: usize,
    /// Filter-P percent.
    pub filter_p: f64,
}

impl GusOfflineParams {
    pub fn label(&self) -> String {
        let nn = if self.nn == 0 {
            "all".to_string()
        } else {
            self.nn.to_string()
        };
        format!(
            "GUS NN={} IDF-S={} Filter-P={}",
            nn, self.idf_s, self.filter_p
        )
    }
}

/// Result of one offline GUS run.
pub struct GusOfflineOutput {
    pub histogram: WeightHistogram,
    pub directed_edges: u64,
}

/// Run offline GUS over a dataset: embed all points, index them, query
/// each point, score retrieved candidates.
pub fn gus_offline(
    ds: &Dataset,
    params: GusOfflineParams,
    threads: usize,
) -> GusOfflineOutput {
    let bucketer = Bucketer::with_defaults(&ds.schema, EVAL_LSH_SEED);
    let cfg = GusConfig {
        idf_s: params.idf_s,
        filter_p: params.filter_p,
        ..GusConfig::default()
    };
    let pre = preprocess::preprocess(&bucketer, &ds.points, &cfg, threads);
    let generator = preprocess::build_generator(bucketer, &pre);

    // Embed + index (ids are dense 0..n so candidate features are O(1)).
    let n = ds.points.len();
    let embeddings: Vec<crate::sparse::SparseVec> =
        parallel_map(n, threads, |i| generator.embed(&ds.points[i]));
    let mut index = SparseAnn::new();
    for (i, e) in embeddings.into_iter().enumerate() {
        index.upsert(ds.points[i].id, e);
    }

    let scorer = DynamicGus::make_scorer(&ds.schema, ScorerKind::Native)
        .expect("native scorer");

    // Parallel query pass with per-thread scratch + histogram.
    let threads = threads.max(1);
    let chunk = n.div_ceil(threads).max(1);
    let ranges: Vec<std::ops::Range<usize>> = (0..threads)
        .map(|t| (t * chunk).min(n)..((t + 1) * chunk).min(n))
        .filter(|r| !r.is_empty())
        .collect();
    let index_ref = &index;
    let scorer_ref: &dyn PairScorer = &*scorer;
    let generator_ref = &generator;
    let partials: Vec<(WeightHistogram, u64)> = parallel_map(ranges.len(), threads, |ri| {
        let mut hist = WeightHistogram::default_bins();
        let mut edges = 0u64;
        let mut scratch = QueryScratch::default();
        for qi in ranges[ri].clone() {
            let q = &ds.points[qi];
            let emb = generator_ref.embed(q);
            let qp = QueryParams { exclude: Some(q.id), max_postings: 0 };
            let neighbors = if params.nn == 0 {
                index_ref.threshold(&emb, -f32::MIN_POSITIVE, qp, &mut scratch)
            } else {
                index_ref.top_k(&emb, params.nn, qp, &mut scratch)
            };
            if neighbors.is_empty() {
                continue;
            }
            let cands: Vec<&crate::features::Point> = neighbors
                .iter()
                .map(|nb| &ds.points[nb.id as usize])
                .collect();
            let scores = scorer_ref.score_batch(q, &cands);
            for s in scores {
                hist.add(s);
                edges += 1;
            }
        }
        (hist, edges)
    });
    let mut histogram = WeightHistogram::default_bins();
    let mut directed_edges = 0u64;
    for (h, e) in &partials {
        histogram.merge(h);
        directed_edges += e;
    }
    GusOfflineOutput { histogram, directed_edges }
}

/// Run the Grale baseline with the shared eval bucketer.
pub fn grale_run(
    ds: &Dataset,
    bucket_split: Option<usize>,
    top_k: Option<usize>,
    threads: usize,
) -> crate::grale::GraleOutput {
    let bucketer = Bucketer::with_defaults(&ds.schema, EVAL_LSH_SEED);
    let scorer = DynamicGus::make_scorer(&ds.schema, ScorerKind::Native)
        .expect("native scorer");
    let cfg = GraleConfig {
        bucket_split_size: bucket_split,
        top_k,
        threads,
        materialize_graph: false,
        ..GraleConfig::default()
    };
    GraleBuilder::new(&bucketer, &*scorer, cfg).build(&ds.points)
}

/// Grale label helper.
pub fn grale_label(bucket_split: Option<usize>, top_k: Option<usize>) -> String {
    let mut s = "Grale".to_string();
    if let Some(b) = bucket_split {
        s.push_str(&format!(" Bucket-S={b}"));
    }
    if let Some(k) = top_k {
        s.push_str(&format!(" Top-K={k}"));
    }
    s
}

// ---------------------------------------------------------------- figures

/// Fig. 3: Grale (no split) vs GUS (all negative distance) — identical by
/// Lemma 4.1. Returns (series, identical?).
pub fn fig3(ds: &Dataset, threads: usize) -> (Vec<Series>, bool) {
    let grale = grale_run(ds, None, None, threads);
    let gus = gus_offline(
        ds,
        GusOfflineParams { nn: 0, idf_s: 0, filter_p: 0.0 },
        threads,
    );
    let identical = grale.directed_edges == gus.directed_edges
        && grale.histogram.percentile_curve(&crate::graph::standard_percentiles())
            == gus.histogram.percentile_curve(&crate::graph::standard_percentiles());
    let series = vec![
        Series::from_histogram(grale_label(None, None), &grale.histogram),
        Series::from_histogram("GUS all-negative-distance", &gus.histogram),
    ];
    (series, identical)
}

/// Fig. 4 grid: one subplot per `nn`, curves over IDF-S × Filter-P.
pub fn fig4_grid(ds: &Dataset, nn: usize, idf_sizes: &[usize], threads: usize) -> Vec<Series> {
    let mut series = Vec::new();
    for &filter_p in &[0.0, 10.0] {
        for &idf_s in idf_sizes {
            let p = GusOfflineParams { nn, idf_s, filter_p };
            let out = gus_offline(ds, p, threads);
            series.push(Series::from_histogram(p.label(), &out.histogram));
        }
    }
    series
}

/// Bucket-S scaled to dataset size: the paper uses Bucket-S=1000 on
/// 169k–2.4M-point datasets; to preserve the Bucket-S/|P| ratio (i.e. make
/// the random splitting bite comparably) we scale it down linearly with
/// the corpus, flooring at 16.
pub fn scaled_bucket_s(n_points: usize) -> usize {
    (n_points / 170).max(16)
}

/// Fig. 5 / Fig. 8: Grale Top-K + (scaled) Bucket-S=1000 vs GUS NN=K with
/// the best-performing parameters (IDF-S=0, Filter-P=10).
pub fn fig_topk(ds: &Dataset, k: usize, threads: usize) -> Vec<Series> {
    let bs = scaled_bucket_s(ds.points.len());
    let grale = grale_run(ds, Some(bs), Some(k), threads);
    let gus = gus_offline(
        ds,
        GusOfflineParams { nn: k, idf_s: 0, filter_p: 10.0 },
        threads,
    );
    vec![
        Series::from_histogram(grale_label(Some(bs), Some(k)), &grale.histogram),
        Series::from_histogram(
            GusOfflineParams { nn: k, idf_s: 0, filter_p: 10.0 }.label(),
            &gus.histogram,
        ),
    ]
}

/// Fig. 6: Grale (scaled) Bucket-S=1000 vs GUS at NN ∈ nns with best params.
pub fn fig6(ds: &Dataset, nns: &[usize], threads: usize) -> Vec<Series> {
    let mut series = Vec::new();
    let bs = scaled_bucket_s(ds.points.len());
    let grale = grale_run(ds, Some(bs), None, threads);
    series.push(Series::from_histogram(grale_label(Some(bs), None), &grale.histogram));
    for &nn in nns {
        let p = GusOfflineParams { nn, idf_s: 0, filter_p: 10.0 };
        let out = gus_offline(ds, p, threads);
        series.push(Series::from_histogram(p.label(), &out.histogram));
    }
    series
}

/// Fig. 7: Grale alone for Bucket-S ∈ sizes.
pub fn fig7(ds: &Dataset, sizes: &[usize], threads: usize) -> Vec<Series> {
    sizes
        .iter()
        .map(|&s| {
            let out = grale_run(ds, Some(s), None, threads);
            Series::from_histogram(grale_label(Some(s), None), &out.histogram)
        })
        .collect()
}

/// Ablation: ScaNN-style approximation dial. Sweeps the posting-scan
/// budget and reports quality (mean retrieved-edge weight) + mean scan cost
/// — the recall/latency trade the paper's exact-at-our-scale substitute
/// otherwise hides. Returns rows (max_postings, mean_weight, directed_edges).
/// `(index, embeddings)` come from [`ablation_setup`], shared with
/// [`ablation_dim_order`] so the expensive embed+index phase runs once.
pub fn ablation_max_postings(
    index: &SparseAnn,
    embeddings: &[crate::sparse::SparseVec],
    ds: &Dataset,
    nn: usize,
    budgets: &[usize],
    threads: usize,
) -> Vec<(usize, f64, u64)> {
    let n = ds.points.len();
    let scorer = DynamicGus::make_scorer(&ds.schema, ScorerKind::Native)
        .expect("native scorer");
    let scorer_ref: &dyn PairScorer = &*scorer;
    let index_ref = index;
    budgets
        .iter()
        .map(|&budget| {
            let partials: Vec<(f64, u64)> = parallel_map(threads, threads, |t| {
                let mut scratch = QueryScratch::default();
                let (mut sum, mut cnt) = (0.0f64, 0u64);
                let mut qi = t;
                while qi < n {
                    let q = &ds.points[qi];
                    let neighbors = index_ref.top_k(
                        &embeddings[qi],
                        nn,
                        QueryParams { exclude: Some(q.id), max_postings: budget },
                        &mut scratch,
                    );
                    if !neighbors.is_empty() {
                        let cands: Vec<&crate::features::Point> = neighbors
                            .iter()
                            .map(|nb| &ds.points[nb.id as usize])
                            .collect();
                        for s in scorer_ref.score_batch(q, &cands) {
                            sum += s as f64;
                            cnt += 1;
                        }
                    }
                    qi += threads;
                }
                (sum, cnt)
            });
            let sum: f64 = partials.iter().map(|p| p.0).sum();
            let cnt: u64 = partials.iter().map(|p| p.1).sum();
            (budget, if cnt == 0 { 0.0 } else { sum / cnt as f64 }, cnt)
        })
        .collect()
}

/// Embed + index a dataset with the best-performing offline params
/// (Filter-P=10) — the shared setup for the posting-budget ablations
/// (build once, pass to both sweeps).
pub fn ablation_setup(
    ds: &Dataset,
    threads: usize,
) -> (SparseAnn, Vec<crate::sparse::SparseVec>) {
    let bucketer = Bucketer::with_defaults(&ds.schema, EVAL_LSH_SEED);
    let cfg = GusConfig { filter_p: 10.0, ..GusConfig::default() };
    let pre = preprocess::preprocess(&bucketer, &ds.points, &cfg, threads);
    let generator = preprocess::build_generator(bucketer, &pre);
    let n = ds.points.len();
    let embeddings: Vec<crate::sparse::SparseVec> =
        parallel_map(n, threads, |i| generator.embed(&ds.points[i]));
    let mut index = SparseAnn::new();
    for (i, e) in embeddings.iter().enumerate() {
        index.upsert(ds.points[i].id, e.clone());
    }
    (index, embeddings)
}

/// One row of [`ablation_dim_order`].
#[derive(Debug, Clone, Copy)]
pub struct DimOrderRow {
    pub budget: usize,
    /// Recall@nn vs the exact scan, dims visited shortest-list-first.
    pub recall_selectivity: f64,
    /// Recall@nn vs the exact scan, dims visited in query (dim-id) order —
    /// the seed scan's order, kept as the baseline.
    pub recall_query_order: f64,
    /// Mean valid postings scored per query (selectivity order).
    pub scanned_selectivity: f64,
    /// Mean valid postings scored per query (query order).
    pub scanned_query_order: f64,
}

/// Ablation for the budgeted scan's dim ordering: at each posting budget,
/// recall@nn against the exact scan for [`DimOrder::Selectivity`] vs
/// [`DimOrder::QueryOrder`], plus mean postings actually scored per query
/// (from the index's scan counter) — recall **per scanned posting** is
/// the figure of merit. Unbudgeted (`budget == 0`) rows are sanity
/// anchors: both orders are exact there by construction.
/// `(index, embeddings)` come from [`ablation_setup`].
pub fn ablation_dim_order(
    index: &SparseAnn,
    embeddings: &[crate::sparse::SparseVec],
    ds: &Dataset,
    nn: usize,
    budgets: &[usize],
    threads: usize,
) -> Vec<DimOrderRow> {
    let n = ds.points.len();
    let index_ref = index;
    let exact = topk_ids_pass(index_ref, embeddings, ds, nn, 0, DimOrder::Selectivity, threads);
    budgets
        .iter()
        .map(|&budget| {
            let run = |order: DimOrder| {
                let before = index_ref.stats().postings_scanned;
                let got = topk_ids_pass(index_ref, embeddings, ds, nn, budget, order, threads);
                let scanned =
                    (index_ref.stats().postings_scanned - before) as f64 / n.max(1) as f64;
                (recall_vs(&exact, &got), scanned)
            };
            let (recall_selectivity, scanned_selectivity) = run(DimOrder::Selectivity);
            let (recall_query_order, scanned_query_order) = run(DimOrder::QueryOrder);
            DimOrderRow {
                budget,
                recall_selectivity,
                recall_query_order,
                scanned_selectivity,
                scanned_query_order,
            }
        })
        .collect()
}

/// Retrieve the top-`nn` neighbor ids of every point under one
/// (budget, order) configuration; per-thread stride loop with a reused
/// scratch, results indexed by query position.
fn topk_ids_pass(
    index: &SparseAnn,
    embeddings: &[crate::sparse::SparseVec],
    ds: &Dataset,
    nn: usize,
    budget: usize,
    order: DimOrder,
    threads: usize,
) -> Vec<Vec<u64>> {
    let n = embeddings.len();
    let threads = threads.max(1);
    let per_thread: Vec<Vec<(usize, Vec<u64>)>> = parallel_map(threads, threads, |t| {
        let mut scratch = QueryScratch::default();
        let mut out = Vec::new();
        let mut qi = t;
        while qi < n {
            let params = QueryParams {
                exclude: Some(ds.points[qi].id),
                max_postings: budget,
            };
            let ids: Vec<u64> = index
                .top_k_ordered(&embeddings[qi], nn, params, order, &mut scratch)
                .iter()
                .map(|nb| nb.id)
                .collect();
            out.push((qi, ids));
            qi += threads;
        }
        out
    });
    let mut all = vec![Vec::new(); n];
    for (qi, ids) in per_thread.into_iter().flatten() {
        all[qi] = ids;
    }
    all
}

/// Mean per-query recall of `got` against `exact`, over queries whose
/// exact neighborhood is non-empty.
fn recall_vs(exact: &[Vec<u64>], got: &[Vec<u64>]) -> f64 {
    let (mut sum, mut cnt) = (0.0f64, 0usize);
    for (e, g) in exact.iter().zip(got) {
        if e.is_empty() {
            continue;
        }
        let hits = g.iter().filter(|id| e.contains(id)).count();
        sum += hits as f64 / e.len() as f64;
        cnt += 1;
    }
    if cnt == 0 {
        0.0
    } else {
        sum / cnt as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;

    fn small_ds() -> Dataset {
        SyntheticConfig::arxiv_like(400, 77).generate()
    }

    #[test]
    fn lemma_4_1_fig3_identical() {
        // The paper's first experiment: Grale (no split) and GUS (threshold)
        // must produce IDENTICAL edge sets.
        let ds = small_ds();
        let (series, identical) = fig3(&ds, 4);
        assert!(identical, "Lemma 4.1 violated: {series:?}");
        assert_eq!(series[0].total_edges, series[1].total_edges);
        assert!(series[0].total_edges > 0);
    }

    #[test]
    fn gus_nn_bounds_edges() {
        let ds = small_ds();
        let out10 = gus_offline(
            &ds,
            GusOfflineParams { nn: 10, idf_s: 0, filter_p: 0.0 },
            2,
        );
        assert!(out10.directed_edges <= (ds.points.len() * 10) as u64);
        let out_all = gus_offline(
            &ds,
            GusOfflineParams { nn: 0, idf_s: 0, filter_p: 0.0 },
            2,
        );
        assert!(out_all.directed_edges >= out10.directed_edges);
    }

    #[test]
    fn filtering_reduces_edges_in_threshold_mode() {
        // Banning popular buckets can only shrink the candidate sets.
        let ds = SyntheticConfig::products_like(400, 78).generate();
        let all = gus_offline(
            &ds,
            GusOfflineParams { nn: 0, idf_s: 0, filter_p: 0.0 },
            2,
        );
        let filtered = gus_offline(
            &ds,
            GusOfflineParams { nn: 0, idf_s: 0, filter_p: 10.0 },
            2,
        );
        assert!(filtered.directed_edges < all.directed_edges);
    }

    #[test]
    fn gus_quality_comparable_to_grale_at_equal_k() {
        // Fig. 5's claim at this dataset shape: "Grale and GUS have high
        // and comparable edge weights" — the paper itself reports GUS
        // slightly LOWER on ogbn-arxiv at Top-K. Assert comparability (no
        // collapse), plus the efficiency side of the claim: GUS reaches
        // that quality while scoring only n·NN pairs, whereas Grale's
        // cost is its full scoring-pair set regardless of Top-K.
        let ds = small_ds();
        let series = fig_topk(&ds, 10, 4);
        let (grale, gus) = (&series[0], &series[1]);
        let area = |s: &Series| -> f64 {
            s.curve.iter().map(|&(_, w)| w).sum::<f64>() / s.curve.len() as f64
        };
        assert!(
            area(gus) >= area(grale) * 0.7,
            "GUS quality collapsed: gus={} grale={}",
            area(gus),
            area(grale)
        );
        // Efficiency: Grale scored far more pairs than GUS retrieved.
        let grale_full = grale_run(&ds, Some(scaled_bucket_s(ds.points.len())), Some(10), 4);
        assert!(
            grale_full.scored_pairs > gus.total_edges,
            "Grale cost {} should exceed GUS retrievals {}",
            grale_full.scored_pairs,
            gus.total_edges
        );
    }

    #[test]
    fn dim_order_ablation_exact_anchor_and_budget_bounds() {
        let ds = small_ds();
        let (index, embeddings) = ablation_setup(&ds, 2);
        let rows = ablation_dim_order(&index, &embeddings, &ds, 10, &[0, 500], 2);
        assert_eq!(rows.len(), 2);
        let r0 = &rows[0];
        assert_eq!(r0.budget, 0);
        // Unbudgeted, both orders ARE the exact scan: recall exactly 1,
        // identical scan volume.
        assert_eq!(r0.recall_selectivity, 1.0);
        assert_eq!(r0.recall_query_order, 1.0);
        assert_eq!(r0.scanned_selectivity, r0.scanned_query_order);
        assert!(r0.scanned_selectivity > 0.0);
        let r1 = &rows[1];
        for recall in [r1.recall_selectivity, r1.recall_query_order] {
            assert!((0.0..=1.0).contains(&recall), "recall out of range: {recall}");
        }
        // The budget caps the mean scored postings per query.
        assert!(r1.scanned_selectivity <= 500.0);
        assert!(r1.scanned_query_order <= 500.0);
        assert!(r1.scanned_selectivity <= r0.scanned_selectivity);
    }

    #[test]
    fn deterministic_runs() {
        let ds = small_ds();
        let p = GusOfflineParams { nn: 10, idf_s: 100, filter_p: 5.0 };
        let a = gus_offline(&ds, p, 1);
        let b = gus_offline(&ds, p, 4);
        assert_eq!(a.directed_edges, b.directed_edges);
        assert_eq!(
            a.histogram.percentile_curve(&[50.0]),
            b.histogram.percentile_curve(&[50.0])
        );
    }
}
