//! Experiment harness: regenerates every figure/table of the paper's §5.
//!
//! - [`offline`]: the quality experiments (Figs. 3–8) — edge-weight
//!   percentile curves + total edge counts for Grale and offline GUS under
//!   the paper's parameter grids. (The paper notes offline and dynamic GUS
//!   produce identical results, §5.1 — ours are literally the same code
//!   path: embed → retrieve → score.)
//! - [`dynamic`]: the serving experiments (Figs. 9–10 + insertion
//!   latencies, §5.2) — per-configuration latency distributions, CPU time
//!   per query, and peak memory, measured on a live [`DynamicGus`]
//!   instance.
//! - [`report`]: CSV/markdown/ASCII-plot output under `results/`.
//!
//! See DESIGN.md's experiment index for the exact figure ↔ module ↔
//! command mapping.

pub mod dynamic;
pub mod offline;
pub mod report;

use crate::data::synthetic::SyntheticConfig;
use crate::data::Dataset;

/// Default laptop-scale sizes standing in for the paper's full datasets
/// (ogbn-arxiv 169,343 / ogbn-products 2,449,029). Both are overridable
/// from the CLI; the generators scale linearly.
pub const DEFAULT_ARXIV_N: usize = 20_000;
pub const DEFAULT_PRODUCTS_N: usize = 30_000;

/// Deterministic dataset seeds (figures must be reproducible).
pub const ARXIV_SEED: u64 = 0xa1;
pub const PRODUCTS_SEED: u64 = 0xb2;

/// Resolve a dataset by name at a given scale.
pub fn load_dataset(name: &str, n: usize) -> Dataset {
    match name {
        "arxiv_like" => SyntheticConfig::arxiv_like(n, ARXIV_SEED).generate(),
        "products_like" => SyntheticConfig::products_like(n, PRODUCTS_SEED).generate(),
        other => panic!("unknown dataset '{other}' (arxiv_like|products_like)"),
    }
}

/// The two datasets of the paper's evaluation.
pub fn dataset_names() -> [&'static str; 2] {
    ["arxiv_like", "products_like"]
}

/// Default scale per dataset.
pub fn default_n(name: &str) -> usize {
    match name {
        "arxiv_like" => DEFAULT_ARXIV_N,
        _ => DEFAULT_PRODUCTS_N,
    }
}
