//! Experiment output: CSV files, markdown tables, ASCII percentile plots.
//!
//! Everything lands under `results/` with deterministic names so
//! EXPERIMENTS.md can reference them and reruns diff cleanly.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::graph::{standard_percentiles, WeightHistogram};

/// Root of experiment outputs.
pub fn results_dir() -> PathBuf {
    std::env::var("GUS_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// One labeled curve for a figure: percentile → edge weight.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub total_edges: u64,
    pub curve: Vec<(f64, f64)>,
}

impl Series {
    pub fn from_histogram(label: impl Into<String>, h: &WeightHistogram) -> Series {
        Series {
            label: label.into(),
            total_edges: h.total(),
            curve: h.percentile_curve(&standard_percentiles()),
        }
    }
}

/// Write a figure's series as CSV: `percentile,<label1>,<label2>,...` plus a
/// `#total_edges` comment row per series.
pub fn write_csv(name: &str, series: &[Series]) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    for s in series {
        writeln!(f, "# {}: total_edges={}", s.label, s.total_edges)?;
    }
    write!(f, "percentile")?;
    for s in series {
        write!(f, ",{}", s.label.replace(',', ";"))?;
    }
    writeln!(f)?;
    if let Some(first) = series.first() {
        for (i, &(p, _)) in first.curve.iter().enumerate() {
            write!(f, "{p}")?;
            for s in series {
                write!(f, ",{:.6}", s.curve[i].1)?;
            }
            writeln!(f)?;
        }
    }
    Ok(path)
}

/// Render an ASCII plot of the percentile curves (stdout-friendly stand-in
/// for the paper's figures).
pub fn ascii_plot(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("=== {title} ===\n"));
    let mut grid = vec![vec![b' '; width]; height];
    let marks: &[u8] = b"*o+x#@%&";
    for (si, s) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(p, w) in &s.curve {
            let x = ((p / 100.0) * (width - 1) as f64).round() as usize;
            let y = ((1.0 - w.clamp(0.0, 1.0)) * (height - 1) as f64).round() as usize;
            grid[y.min(height - 1)][x.min(width - 1)] = mark;
        }
    }
    for (i, row) in grid.iter().enumerate() {
        let yval = 1.0 - i as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:5.2} |"));
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str("      +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str("       0%");
    out.push_str(&" ".repeat(width.saturating_sub(12)));
    out.push_str("100%\n");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "  {} {}  (edges: {})\n",
            marks[si % marks.len()] as char,
            s.label,
            s.total_edges
        ));
    }
    out
}

/// Append a markdown section to `results/SUMMARY.md`.
pub fn append_summary(section: &str) -> Result<()> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("SUMMARY.md");
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "{section}\n")?;
    Ok(())
}

/// Write a generic markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&format!("| {} |\n", headers.join(" | ")));
    out.push_str(&format!(
        "|{}\n",
        headers.iter().map(|_| "---|").collect::<String>()
    ));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    out
}

/// Write arbitrary text to `results/<name>`.
pub fn write_text(name: &str, text: &str) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    std::fs::write(&path, text)?;
    Ok(path)
}

/// Write CSV rows (header + data) to `results/<name>.csv`.
pub fn write_rows_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(path)
}

/// Check a path exists relative to results (test helper).
pub fn exists(name: &str) -> bool {
    results_dir().join(name).exists()
}

#[allow(unused)]
fn _assert_path_is_path(_: &Path) {}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(weights: &[f32]) -> WeightHistogram {
        let mut h = WeightHistogram::new(128);
        for &w in weights {
            h.add(w);
        }
        h
    }

    #[test]
    fn csv_roundtrip_shape() {
        std::env::set_var("GUS_RESULTS_DIR", std::env::temp_dir().join("gus-results-test"));
        let s1 = Series::from_histogram("a", &hist(&[0.1, 0.5, 0.9]));
        let s2 = Series::from_histogram("b", &hist(&[0.2, 0.8]));
        let path = write_csv("unittest_fig", &[s1, s2]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("total_edges=3"));
        assert!(text.contains("total_edges=2"));
        assert!(text.lines().any(|l| l.starts_with("percentile,a,b")));
        // 21 standard percentiles + headers + 2 comments.
        assert_eq!(text.lines().count(), 2 + 1 + 21);
        std::env::remove_var("GUS_RESULTS_DIR");
    }

    #[test]
    fn ascii_plot_contains_labels() {
        let s = Series::from_histogram("curve-x", &hist(&[0.3, 0.6]));
        let plot = ascii_plot("t", &[s], 40, 10);
        assert!(plot.contains("curve-x"));
        assert!(plot.contains("edges: 2"));
        assert!(plot.lines().count() > 10);
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        assert_eq!(t.lines().count(), 4);
        assert!(t.contains("| 1 | 2 |"));
    }
}
