//! Dynamic (serving) experiments: Figs. 9–10 + §5.2 insertion latencies.
//!
//! Mirrors the paper's §5.2 protocol: preload the corpus into a live
//! [`DynamicGus`], then (a) insert a held-out slice of points one by one
//! (insertion wall-clock), and (b) query the neighborhoods of `n_queries`
//! sampled points **sequentially, one by one** on a single worker. Reports
//! wall-clock latency percentiles, average CPU time per query, and peak
//! RSS.
//!
//! One process measures one configuration — the `experiments` binary
//! re-execs itself per grid cell so peak-RSS (`VmHWM`) is per-config, like
//! the paper's one-experiment-at-a-time methodology.

use anyhow::Result;

use crate::config::{GusConfig, ScorerKind};
use crate::coordinator::DynamicGus;
use crate::data::Dataset;
use crate::metrics::{self, LatencyHistogram};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One dynamic-experiment configuration (a row of Fig. 10's tables).
#[derive(Debug, Clone)]
pub struct DynamicParams {
    pub scann_nn: usize,
    pub idf_s: usize,
    pub filter_p: f64,
    pub n_queries: usize,
    /// Points inserted dynamically (after bootstrap) to measure insertion.
    pub n_inserts: usize,
    pub scorer: ScorerKind,
    pub seed: u64,
}

impl Default for DynamicParams {
    fn default() -> Self {
        DynamicParams {
            scann_nn: 10,
            idf_s: 0,
            filter_p: 0.0,
            n_queries: 10_000,
            n_inserts: 1_000,
            scorer: ScorerKind::Auto,
            seed: 0xd1a,
        }
    }
}

/// Measured outcome of one dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicOutput {
    pub query_ms: LatencySummaryMs,
    pub insert_ms: LatencySummaryMs,
    pub avg_cpu_ms_per_query: f64,
    pub peak_rss_mib: f64,
    pub n_points: usize,
}

/// Millisecond latency summary.
#[derive(Debug, Clone)]
pub struct LatencySummaryMs {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencySummaryMs {
    fn from_hist(h: &LatencyHistogram) -> LatencySummaryMs {
        let s = h.summary();
        LatencySummaryMs {
            count: s.count,
            mean: s.mean_ns / 1e6,
            p50: s.p50_ns as f64 / 1e6,
            p90: s.p90_ns as f64 / 1e6,
            p95: s.p95_ns as f64 / 1e6,
            p99: s.p99_ns as f64 / 1e6,
            max: s.max_ns as f64 / 1e6,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_ms", Json::num(self.mean)),
            ("p50_ms", Json::num(self.p50)),
            ("p90_ms", Json::num(self.p90)),
            ("p95_ms", Json::num(self.p95)),
            ("p99_ms", Json::num(self.p99)),
            ("max_ms", Json::num(self.max)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<LatencySummaryMs> {
        Some(LatencySummaryMs {
            count: j.get("count").as_u64()?,
            mean: j.get("mean_ms").as_f64()?,
            p50: j.get("p50_ms").as_f64()?,
            p90: j.get("p90_ms").as_f64()?,
            p95: j.get("p95_ms").as_f64()?,
            p99: j.get("p99_ms").as_f64()?,
            max: j.get("max_ms").as_f64()?,
        })
    }
}

/// Run one dynamic experiment on a dataset.
pub fn run_dynamic(ds: &Dataset, params: &DynamicParams) -> Result<DynamicOutput> {
    let n = ds.points.len();
    let n_inserts = params.n_inserts.min(n / 10);
    let preload = &ds.points[..n - n_inserts];
    let holdout = &ds.points[n - n_inserts..];

    let config = GusConfig {
        scann_nn: params.scann_nn,
        idf_s: params.idf_s,
        filter_p: params.filter_p,
        n_shards: 1, // the paper's sequential single-core setting
        scorer: params.scorer,
        ..GusConfig::default()
    };
    let gus = DynamicGus::bootstrap(ds.schema.clone(), config, preload, 1)?;

    // --- insertion latencies (§5.2 last paragraph) ---
    for p in holdout {
        gus.insert(p.clone())?;
    }

    // --- sequential query pass (Figs. 9–10) ---
    let mut rng = Rng::seeded(params.seed);
    let cpu_before = metrics::process_cpu_time();
    for _ in 0..params.n_queries {
        let qi = rng.below_usize(n);
        let _ = gus.query(&ds.points[qi], params.scann_nn)?;
    }
    let cpu_after = metrics::process_cpu_time();
    let queries = gus.metrics.query_latency.count().max(1);

    Ok(DynamicOutput {
        query_ms: LatencySummaryMs::from_hist(&gus.metrics.query_latency),
        insert_ms: LatencySummaryMs::from_hist(&gus.metrics.mutation_latency),
        avg_cpu_ms_per_query: (cpu_after - cpu_before).as_secs_f64() * 1e3 / queries as f64,
        peak_rss_mib: metrics::peak_rss_bytes() as f64 / (1024.0 * 1024.0),
        n_points: gus.len(),
    })
}

impl DynamicOutput {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", self.query_ms.to_json()),
            ("insert", self.insert_ms.to_json()),
            ("avg_cpu_ms_per_query", Json::num(self.avg_cpu_ms_per_query)),
            ("peak_rss_mib", Json::num(self.peak_rss_mib)),
            ("n_points", Json::num(self.n_points as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<DynamicOutput> {
        Some(DynamicOutput {
            query_ms: LatencySummaryMs::from_json(j.get("query"))?,
            insert_ms: LatencySummaryMs::from_json(j.get("insert"))?,
            avg_cpu_ms_per_query: j.get("avg_cpu_ms_per_query").as_f64()?,
            peak_rss_mib: j.get("peak_rss_mib").as_f64()?,
            n_points: j.get("n_points").as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;

    #[test]
    fn dynamic_run_produces_sane_numbers() {
        let ds = SyntheticConfig::arxiv_like(600, 55).generate();
        let params = DynamicParams {
            n_queries: 200,
            n_inserts: 50,
            scorer: ScorerKind::Native,
            ..DynamicParams::default()
        };
        let out = run_dynamic(&ds, &params).unwrap();
        assert_eq!(out.n_points, 600);
        assert_eq!(out.query_ms.count, 200);
        assert_eq!(out.insert_ms.count, 50);
        assert!(out.query_ms.p50 > 0.0);
        assert!(out.query_ms.p50 <= out.query_ms.p99);
        assert!(out.insert_ms.p50 < out.query_ms.max + 1000.0);
        assert!(out.avg_cpu_ms_per_query >= 0.0);
        assert!(out.peak_rss_mib > 1.0);
        // JSON roundtrip (subprocess protocol).
        let j = out.to_json().dump();
        let back = DynamicOutput::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.query_ms.count, 200);
    }
}
