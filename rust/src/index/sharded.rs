//! Sharded, thread-safe wrapper around [`SparseAnn`].
//!
//! The paper's dynamic experiments are single-core by design (§5.2,
//! "for interpretability and stability"), but the system "can be run in a
//! parallel and distributed setting" — this wrapper is that setting's
//! single-machine form: N shards, each an independently RwLock'd
//! [`SparseAnn`]; points are routed by id hash, queries fan out to all
//! shards and merge.

use std::sync::RwLock;

use super::{Neighbor, QueryParams, QueryScratch, SparseAnn};
use crate::features::PointId;
use crate::sparse::SparseVec;
use crate::util::hash::mix64;

/// Sharded dynamic sparse ANN index.
pub struct ShardedIndex {
    shards: Vec<RwLock<SparseAnn>>,
}

impl ShardedIndex {
    /// `n_shards` must be ≥ 1; 1 shard reproduces the paper's sequential
    /// setting exactly.
    pub fn new(n_shards: usize) -> ShardedIndex {
        assert!(n_shards >= 1);
        ShardedIndex {
            shards: (0..n_shards).map(|_| RwLock::new(SparseAnn::new())).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard_of(&self, id: PointId) -> usize {
        (mix64(id) % self.shards.len() as u64) as usize
    }

    /// Upsert a point; returns true if it existed.
    pub fn upsert(&self, id: PointId, vec: SparseVec) -> bool {
        self.shards[self.shard_of(id)].write().unwrap().upsert(id, vec)
    }

    /// Remove a point; returns true if it existed.
    pub fn remove(&self, id: PointId) -> bool {
        self.shards[self.shard_of(id)].write().unwrap().remove(id)
    }

    pub fn contains(&self, id: PointId) -> bool {
        self.shards[self.shard_of(id)].read().unwrap().contains(id)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Top-k across all shards (per-shard top-k then merge; exact because
    /// per-shard retrieval is exact).
    pub fn top_k(&self, query: &SparseVec, k: usize, params: QueryParams) -> Vec<Neighbor> {
        let mut all = Vec::with_capacity(k * self.shards.len().min(4));
        let mut scratch = QueryScratch::default();
        for shard in &self.shards {
            let res = shard.read().unwrap().top_k(query, k, params, &mut scratch);
            all.extend(res);
        }
        all.sort_unstable_by(|a, b| b.dot.partial_cmp(&a.dot).unwrap().then(a.id.cmp(&b.id)));
        all.truncate(k);
        all
    }

    /// Threshold query across all shards.
    pub fn threshold(&self, query: &SparseVec, tau: f32, params: QueryParams) -> Vec<Neighbor> {
        let mut all = Vec::new();
        let mut scratch = QueryScratch::default();
        for shard in &self.shards {
            all.extend(shard.read().unwrap().threshold(query, tau, params, &mut scratch));
        }
        all.sort_unstable_by(|a, b| b.dot.partial_cmp(&a.dot).unwrap().then(a.id.cmp(&b.id)));
        all
    }

    /// Aggregate stats over shards.
    pub fn stats(&self) -> super::IndexStats {
        let mut agg = super::IndexStats {
            live_points: 0,
            live_postings: 0,
            dead_postings: 0,
            distinct_dims: 0,
            slot_capacity: 0,
            approx_bytes: 0,
        };
        for s in &self.shards {
            let st = s.read().unwrap().stats();
            agg.live_points += st.live_points;
            agg.live_postings += st.live_postings;
            agg.dead_postings += st.dead_postings;
            agg.distinct_dims += st.distinct_dims; // upper bound (dims span shards)
            agg.slot_capacity += st.slot_capacity;
            agg.approx_bytes += st.approx_bytes;
        }
        agg
    }

    /// Compact all shards.
    pub fn compact_all(&self) {
        for s in &self.shards {
            s.write().unwrap().compact_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::proptest;

    fn sv(pairs: &[(u64, f32)]) -> SparseVec {
        SparseVec::from_pairs(pairs.to_vec())
    }

    #[test]
    fn routes_and_merges() {
        let ix = ShardedIndex::new(4);
        for i in 0..100u64 {
            ix.upsert(i, sv(&[(7, 1.0 + i as f32)]));
        }
        assert_eq!(ix.len(), 100);
        let r = ix.top_k(&sv(&[(7, 1.0)]), 5, QueryParams::default());
        assert_eq!(r.len(), 5);
        assert_eq!(r[0].id, 99); // global best regardless of shard
        assert!(ix.contains(50));
        ix.remove(99);
        let r = ix.top_k(&sv(&[(7, 1.0)]), 1, QueryParams::default());
        assert_eq!(r[0].id, 98);
    }

    #[test]
    fn single_shard_equivalence() {
        // Sharded results must equal a 1-shard index for any op sequence.
        proptest(|rng| {
            let multi = ShardedIndex::new(1 + rng.below_usize(5));
            let single = ShardedIndex::new(1);
            for _ in 0..60 {
                let id = rng.below(30);
                if rng.chance(0.7) {
                    let n = 1 + rng.below_usize(5);
                    let v = SparseVec::from_pairs(
                        (0..n).map(|_| (rng.below(15), 0.1 + rng.f32())).collect(),
                    );
                    multi.upsert(id, v.clone());
                    single.upsert(id, v);
                } else {
                    multi.remove(id);
                    single.remove(id);
                }
            }
            assert_eq!(multi.len(), single.len());
            let q = SparseVec::from_pairs(vec![
                (rng.below(15), 1.0),
                (rng.below(15), 0.5),
            ]);
            let a = multi.top_k(&q, 7, QueryParams::default());
            let b = single.top_k(&q, 7, QueryParams::default());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert!((x.dot - y.dot).abs() < 1e-5);
            }
            let at = multi.threshold(&q, -0.2, QueryParams::default());
            let bt = single.threshold(&q, -0.2, QueryParams::default());
            assert_eq!(
                at.iter().map(|n| n.id).collect::<Vec<_>>(),
                bt.iter().map(|n| n.id).collect::<Vec<_>>()
            );
        });
    }

    #[test]
    fn concurrent_mutations_and_queries() {
        use std::sync::Arc;
        let ix = Arc::new(ShardedIndex::new(4));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ix = Arc::clone(&ix);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let id = t * 1000 + i;
                    ix.upsert(id, sv(&[(i % 50, 1.0)]));
                    if i % 3 == 0 {
                        ix.remove(id);
                    }
                    if i % 7 == 0 {
                        let _ = ix.top_k(&sv(&[(i % 50, 1.0)]), 5, QueryParams::default());
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 500 per thread, every 3rd removed → ceil(2/3 * 500)*4 total-ish.
        let expect: usize = 4 * (500 - 167);
        assert_eq!(ix.len(), expect);
    }
}
