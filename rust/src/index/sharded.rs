//! Sharded, thread-safe wrapper around [`SparseAnn`] — the concurrent
//! serving engine.
//!
//! The paper's dynamic experiments are single-core by design (§5.2,
//! "for interpretability and stability"), but the system "can be run in a
//! parallel and distributed setting" — this wrapper is that setting's
//! single-machine form: N shards, each an independently RwLock'd
//! [`SparseAnn`]; points are routed by id hash, queries fan out to all
//! shards and merge.
//!
//! # Threading model
//!
//! - **Shard locks.** Each shard is a `RwLock<SparseAnn>`: any number of
//!   concurrent readers (queries) per shard, one writer (mutation) at a
//!   time, and no lock is ever held across shards — so mutations on one
//!   shard never block queries on another.
//! - **Query fan-out.** [`top_k`](ShardedIndex::top_k) and
//!   [`threshold`](ShardedIndex::threshold) scan shards on up to
//!   `query_threads` scoped worker threads
//!   ([`crate::util::threadpool::parallel_map`]); per-shard results are
//!   collected in shard order and merged with the deterministic
//!   (dot desc, id asc) order, so results are identical for any thread
//!   count — `with_threads(n, 1)` reproduces the paper's sequential
//!   setting exactly. The scoped workers are spawned per call (the
//!   borrow-friendly `thread::scope` mechanism; `ThreadPool` jobs need
//!   `'static`), which costs tens of microseconds per fan-out — worth it
//!   for large multi-shard scans, and amortized to one spawn set per
//!   *batch* by [`query_batch`](ShardedIndex::query_batch), which is the
//!   intended high-throughput path. A persistent scoped worker pool is
//!   the natural next optimization.
//! - **Scratch pool.** Workers draw [`QueryScratch`] buffers from a
//!   free-list pool instead of allocating per call, so the scoring hot
//!   path (accumulators, touched lists, heaps) allocates nothing in
//!   steady state; the pool grows to the peak number of concurrent
//!   workers. (Scratches are safely shared across shards because
//!   touched-slot tracking is epoch-tagged — see [`QueryScratch`].)
//! - **Posting budget.** A nonzero [`QueryParams::max_postings`] is a
//!   *global* budget: it is split across shards as `ceil(budget / N)`, so
//!   the effective scan volume does not scale with the shard count (it
//!   previously did, which also meant 1-shard equivalence tests never
//!   exercised the budget). Total postings scanned is at most
//!   `budget + N - 1` due to the per-shard rounding.
//!
//! # Batch APIs
//!
//! - [`upsert_batch`](ShardedIndex::upsert_batch) /
//!   [`remove_batch`](ShardedIndex::remove_batch) group mutations by
//!   destination shard, take each shard's write lock **once**, and apply
//!   the groups on the worker threads. Mutations to the same id land in
//!   the same group and apply in input order, so batch semantics match the
//!   equivalent sequence of single calls.
//! - [`query_batch`](ShardedIndex::query_batch) parallelizes *across
//!   queries* (each worker scans shards sequentially with a pooled
//!   scratch), which keeps every per-query computation identical to the
//!   single-query path — results are byte-identical to calling
//!   [`top_k`](ShardedIndex::top_k) per query, in order.

use std::sync::{Mutex, RwLock};

use super::{Neighbor, QueryParams, QueryScratch, SparseAnn};
use crate::features::PointId;
use crate::sparse::SparseVec;
use crate::util::hash::mix64;
use crate::util::pool::Pool;
use crate::util::threadpool::parallel_map;

/// Sharded dynamic sparse ANN index with a parallel serving path.
pub struct ShardedIndex {
    shards: Vec<RwLock<SparseAnn>>,
    /// Free-list of [`QueryScratch`] buffers shared by query workers.
    scratch: Pool<QueryScratch>,
    query_threads: usize,
}

impl ShardedIndex {
    /// `n_shards` must be ≥ 1. Queries scan shards on the calling thread
    /// (the paper's sequential setting); use [`with_threads`] for the
    /// parallel serving path.
    ///
    /// [`with_threads`]: ShardedIndex::with_threads
    pub fn new(n_shards: usize) -> ShardedIndex {
        Self::with_threads(n_shards, 1)
    }

    /// `n_shards` shards served by up to `query_threads` worker threads
    /// (both clamped to ≥ 1). Thread count affects only latency, never
    /// results.
    pub fn with_threads(n_shards: usize, query_threads: usize) -> ShardedIndex {
        assert!(n_shards >= 1);
        ShardedIndex {
            shards: (0..n_shards).map(|_| RwLock::new(SparseAnn::new())).collect(),
            scratch: Pool::new(),
            query_threads: query_threads.max(1),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Worker threads used by the query fan-out and batch APIs.
    pub fn query_threads(&self) -> usize {
        self.query_threads
    }

    #[inline]
    fn shard_of(&self, id: PointId) -> usize {
        (mix64(id) % self.shards.len() as u64) as usize
    }

    /// Per-shard query params: a nonzero global posting budget is divided
    /// across shards (ceil) so total scanning stays ≈ the requested budget
    /// regardless of shard count.
    fn shard_params(&self, params: QueryParams) -> QueryParams {
        if params.max_postings == 0 || self.shards.len() == 1 {
            params
        } else {
            QueryParams {
                max_postings: params.max_postings.div_ceil(self.shards.len()),
                ..params
            }
        }
    }

    /// Upsert a point; returns true if it existed.
    pub fn upsert(&self, id: PointId, vec: SparseVec) -> bool {
        self.shards[self.shard_of(id)].write().unwrap().upsert(id, vec)
    }

    /// Remove a point; returns true if it existed.
    pub fn remove(&self, id: PointId) -> bool {
        self.shards[self.shard_of(id)].write().unwrap().remove(id)
    }

    pub fn contains(&self, id: PointId) -> bool {
        self.shards[self.shard_of(id)].read().unwrap().contains(id)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Upsert a batch of points. Items are grouped by destination shard so
    /// each shard's write lock is taken once; groups apply in parallel on
    /// the worker threads. Returns, per input position, whether the point
    /// already existed. Duplicate ids within one batch apply in input
    /// order (they share a shard group), matching sequential semantics.
    pub fn upsert_batch(&self, items: Vec<(PointId, SparseVec)>) -> Vec<bool> {
        // One batch entry routed to a shard: (input position, id, vector).
        type Group = Vec<(usize, PointId, SparseVec)>;
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let n_shards = self.shards.len();
        let mut grouped: Vec<Group> = (0..n_shards).map(|_| Vec::new()).collect();
        for (pos, (id, vec)) in items.into_iter().enumerate() {
            grouped[self.shard_of(id)].push((pos, id, vec));
        }
        // Mutex-wrapped so each worker can move its group out by value.
        let grouped: Vec<Mutex<Group>> = grouped.into_iter().map(Mutex::new).collect();
        let per_shard: Vec<Vec<(usize, bool)>> =
            parallel_map(n_shards, self.query_threads, |s| {
                let group = std::mem::take(&mut *grouped[s].lock().unwrap());
                if group.is_empty() {
                    return Vec::new();
                }
                let mut shard = self.shards[s].write().unwrap();
                group
                    .into_iter()
                    .map(|(pos, id, vec)| (pos, shard.upsert(id, vec)))
                    .collect()
            });
        let mut existed = vec![false; n];
        for (pos, e) in per_shard.into_iter().flatten() {
            existed[pos] = e;
        }
        existed
    }

    /// Remove a batch of points; one write-lock acquisition per shard, as
    /// in [`upsert_batch`](ShardedIndex::upsert_batch). Returns, per input
    /// position, whether the point was present.
    pub fn remove_batch(&self, ids: &[PointId]) -> Vec<bool> {
        let n = ids.len();
        if n == 0 {
            return Vec::new();
        }
        let n_shards = self.shards.len();
        let mut grouped: Vec<Vec<(usize, PointId)>> = (0..n_shards).map(|_| Vec::new()).collect();
        for (pos, &id) in ids.iter().enumerate() {
            grouped[self.shard_of(id)].push((pos, id));
        }
        let per_shard: Vec<Vec<(usize, bool)>> =
            parallel_map(n_shards, self.query_threads, |s| {
                let group = &grouped[s];
                if group.is_empty() {
                    return Vec::new();
                }
                let mut shard = self.shards[s].write().unwrap();
                group.iter().map(|&(pos, id)| (pos, shard.remove(id))).collect()
            });
        let mut existed = vec![false; n];
        for (pos, e) in per_shard.into_iter().flatten() {
            existed[pos] = e;
        }
        existed
    }

    /// Top-k across all shards: the per-shard top-k runs on the worker
    /// threads (exact because per-shard retrieval is exact), then results
    /// merge with the deterministic (dot desc, id asc) order.
    pub fn top_k(&self, query: &SparseVec, k: usize, params: QueryParams) -> Vec<Neighbor> {
        let sp = self.shard_params(params);
        let per_shard = parallel_map(self.shards.len(), self.query_threads, |s| {
            let mut scratch = self.scratch.take();
            let res = self.shards[s].read().unwrap().top_k(query, k, sp, &mut scratch);
            self.scratch.put(scratch);
            res
        });
        let mut all = Self::merge(per_shard);
        all.truncate(k);
        all
    }

    /// Threshold query across all shards (parallel fan-out + merge, as in
    /// [`top_k`](ShardedIndex::top_k)).
    pub fn threshold(&self, query: &SparseVec, tau: f32, params: QueryParams) -> Vec<Neighbor> {
        let sp = self.shard_params(params);
        let per_shard = parallel_map(self.shards.len(), self.query_threads, |s| {
            let mut scratch = self.scratch.take();
            let res = self.shards[s].read().unwrap().threshold(query, tau, sp, &mut scratch);
            self.scratch.put(scratch);
            res
        });
        Self::merge(per_shard)
    }

    /// Top-k for a batch of `(query, params)` pairs, parallelized across
    /// queries: each worker scans shards sequentially with a pooled
    /// scratch, so entry `i` is byte-identical to
    /// `self.top_k(&queries[i].0, k, queries[i].1)`.
    pub fn query_batch(
        &self,
        queries: &[(SparseVec, QueryParams)],
        k: usize,
    ) -> Vec<Vec<Neighbor>> {
        parallel_map(queries.len(), self.query_threads, |i| {
            let (query, params) = &queries[i];
            let sp = self.shard_params(*params);
            let mut scratch = self.scratch.take();
            let mut per_shard = Vec::with_capacity(self.shards.len());
            for shard in &self.shards {
                per_shard.push(shard.read().unwrap().top_k(query, k, sp, &mut scratch));
            }
            self.scratch.put(scratch);
            let mut all = Self::merge(per_shard);
            all.truncate(k);
            all
        })
    }

    /// Merge per-shard results into the global (dot desc, id asc) order.
    fn merge(per_shard: Vec<Vec<Neighbor>>) -> Vec<Neighbor> {
        merge_ranked(per_shard, |a, b| b.dot.total_cmp(&a.dot).then(a.id.cmp(&b.id)))
    }

    /// Aggregate stats over shards. O(shards): each per-shard snapshot is
    /// O(1) now that `SparseAnn` maintains its byte estimate incrementally.
    pub fn stats(&self) -> super::IndexStats {
        let mut agg = super::IndexStats {
            live_points: 0,
            live_postings: 0,
            dead_postings: 0,
            distinct_dims: 0,
            slot_capacity: 0,
            approx_bytes: 0,
            postings_scanned: 0,
        };
        for s in &self.shards {
            let st = s.read().unwrap().stats();
            agg.live_points += st.live_points;
            agg.live_postings += st.live_postings;
            agg.dead_postings += st.dead_postings;
            agg.distinct_dims += st.distinct_dims; // upper bound (dims span shards)
            agg.slot_capacity += st.slot_capacity;
            agg.approx_bytes += st.approx_bytes;
            agg.postings_scanned += st.postings_scanned;
        }
        agg
    }

    /// Compact all shards.
    pub fn compact_all(&self) {
        for s in &self.shards {
            s.write().unwrap().compact_all();
        }
    }
}

/// Merge independently ranked result lists into one globally ranked list
/// under `cmp` (descending relevance first). Used by the per-shard
/// fan-out merge above.
pub fn merge_ranked<T>(
    lists: Vec<Vec<T>>,
    cmp: impl FnMut(&T, &T) -> std::cmp::Ordering,
) -> Vec<T> {
    let mut all: Vec<T> = lists.into_iter().flatten().collect();
    all.sort_unstable_by(cmp);
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::proptest;
    use crate::util::rng::Rng;

    fn sv(pairs: &[(u64, f32)]) -> SparseVec {
        SparseVec::from_pairs(pairs.to_vec())
    }

    fn random_vec(rng: &mut Rng) -> SparseVec {
        let n = 1 + rng.below_usize(5);
        SparseVec::from_pairs((0..n).map(|_| (rng.below(15), 0.1 + rng.f32())).collect())
    }

    #[test]
    fn routes_and_merges() {
        let ix = ShardedIndex::new(4);
        for i in 0..100u64 {
            ix.upsert(i, sv(&[(7, 1.0 + i as f32)]));
        }
        assert_eq!(ix.len(), 100);
        let r = ix.top_k(&sv(&[(7, 1.0)]), 5, QueryParams::default());
        assert_eq!(r.len(), 5);
        assert_eq!(r[0].id, 99); // global best regardless of shard
        assert!(ix.contains(50));
        ix.remove(99);
        let r = ix.top_k(&sv(&[(7, 1.0)]), 1, QueryParams::default());
        assert_eq!(r[0].id, 98);
    }

    #[test]
    fn single_shard_equivalence() {
        // Sharded results must equal a 1-shard index for any op sequence —
        // on any worker-thread count, including with a non-binding posting
        // budget (a binding budget is approximation, exercised separately).
        proptest(|rng| {
            let multi = ShardedIndex::with_threads(1 + rng.below_usize(5), 1 + rng.below_usize(4));
            let single = ShardedIndex::new(1);
            for _ in 0..60 {
                let id = rng.below(30);
                if rng.chance(0.7) {
                    let v = random_vec(rng);
                    multi.upsert(id, v.clone());
                    single.upsert(id, v);
                } else {
                    multi.remove(id);
                    single.remove(id);
                }
            }
            assert_eq!(multi.len(), single.len());
            let q = SparseVec::from_pairs(vec![
                (rng.below(15), 1.0),
                (rng.below(15), 0.5),
            ]);
            // A budget of live_postings × n_shards cannot bind on any shard
            // after the ceil split, so results must stay exact.
            let budget = if rng.chance(0.5) {
                0
            } else {
                (single.stats().live_postings.max(1)) * multi.n_shards()
            };
            let params = QueryParams { exclude: None, max_postings: budget };
            let a = multi.top_k(&q, 7, params);
            let b = single.top_k(&q, 7, params);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert!((x.dot - y.dot).abs() < 1e-5);
            }
            let at = multi.threshold(&q, -0.2, params);
            let bt = single.threshold(&q, -0.2, params);
            assert_eq!(
                at.iter().map(|n| n.id).collect::<Vec<_>>(),
                bt.iter().map(|n| n.id).collect::<Vec<_>>()
            );
        });
    }

    /// The posting budget is global: splitting it across shards keeps the
    /// result volume ≈ budget instead of scaling with the shard count.
    #[test]
    fn max_postings_budget_splits_across_shards() {
        let multi = ShardedIndex::with_threads(4, 4);
        let single = ShardedIndex::new(1);
        for i in 0..100u64 {
            let v = sv(&[(7, 1.0)]);
            multi.upsert(i, v.clone());
            single.upsert(i, v);
        }
        let params = QueryParams { exclude: None, max_postings: 20 };
        let q = sv(&[(7, 1.0)]);
        let rs = single.top_k(&q, 100, params);
        assert_eq!(rs.len(), 20, "1-shard budget baseline");
        let rm = multi.top_k(&q, 100, params);
        // Per-shard budget is ceil(20/4) = 5 ⇒ at most 20 results in total
        // (the old per-shard semantics returned 4 × 20 = 80).
        assert!(
            rm.len() <= 20,
            "budget scaled with shard count: {} results",
            rm.len()
        );
        assert!(rm.len() >= 5, "budget collapsed: {} results", rm.len());
        let rt = multi.threshold(&q, 10.0, params);
        assert!(rt.len() <= 20, "threshold budget scaled: {}", rt.len());
    }

    /// Parallel `query_batch` must be byte-identical to the sequential
    /// single-query path, for any shard count, thread count, exclusion and
    /// posting budget.
    #[test]
    fn prop_query_batch_equals_sequential() {
        proptest(|rng| {
            let ix = ShardedIndex::with_threads(1 + rng.below_usize(5), 1 + rng.below_usize(4));
            for _ in 0..50 {
                let id = rng.below(30);
                if rng.chance(0.75) {
                    ix.upsert(id, random_vec(rng));
                } else {
                    ix.remove(id);
                }
            }
            let k = 1 + rng.below_usize(8);
            let queries: Vec<(SparseVec, QueryParams)> = (0..1 + rng.below_usize(8))
                .map(|_| {
                    let params = QueryParams {
                        exclude: if rng.chance(0.3) { Some(rng.below(30)) } else { None },
                        max_postings: if rng.chance(0.3) { 1 + rng.below_usize(40) } else { 0 },
                    };
                    (random_vec(rng), params)
                })
                .collect();
            let batch = ix.query_batch(&queries, k);
            assert_eq!(batch.len(), queries.len());
            for (i, (q, params)) in queries.iter().enumerate() {
                let single = ix.top_k(q, k, *params);
                assert_eq!(batch[i].len(), single.len(), "query {i}");
                for (x, y) in batch[i].iter().zip(&single) {
                    assert_eq!(x.id, y.id, "query {i}");
                    assert_eq!(
                        x.dot.to_bits(),
                        y.dot.to_bits(),
                        "query {i}: batch dot {} != single dot {}",
                        x.dot,
                        y.dot
                    );
                }
            }
        });
    }

    /// Batch mutations must be equivalent to the same mutations applied
    /// one at a time — including duplicate ids within a batch, which must
    /// apply in input order.
    #[test]
    fn prop_batch_mutations_equal_sequential() {
        proptest(|rng| {
            let batched = ShardedIndex::with_threads(1 + rng.below_usize(4), 4);
            let sequential = ShardedIndex::new(1 + rng.below_usize(4));
            for _round in 0..3 {
                let upserts: Vec<(u64, SparseVec)> = (0..5 + rng.below_usize(20))
                    .map(|_| (rng.below(20), random_vec(rng)))
                    .collect();
                let want: Vec<bool> = upserts
                    .iter()
                    .map(|(id, v)| sequential.upsert(*id, v.clone()))
                    .collect();
                let got = batched.upsert_batch(upserts);
                assert_eq!(got, want, "upsert existed-flags diverged");

                let removals: Vec<u64> = (0..rng.below_usize(10)).map(|_| rng.below(20)).collect();
                let want: Vec<bool> = removals.iter().map(|&id| sequential.remove(id)).collect();
                let got = batched.remove_batch(&removals);
                assert_eq!(got, want, "remove existed-flags diverged");
            }
            assert_eq!(batched.len(), sequential.len());
            let q = random_vec(rng);
            let a = batched.top_k(&q, 10, QueryParams::default());
            let b = sequential.top_k(&q, 10, QueryParams::default());
            assert_eq!(
                a.iter().map(|n| n.id).collect::<Vec<_>>(),
                b.iter().map(|n| n.id).collect::<Vec<_>>()
            );
        });
    }

    /// Unbudgeted, the shards collectively score exactly the valid
    /// postings a 1-shard index would — sharding moves postings around
    /// but the scan volume (and the `postings_scanned` stat) is
    /// identical. Tombstones must not count.
    #[test]
    fn postings_scanned_stat_matches_single_shard() {
        let multi = ShardedIndex::with_threads(4, 2);
        let single = ShardedIndex::new(1);
        for i in 0..60u64 {
            let v = sv(&[(i % 5, 1.0), (7, 0.5)]);
            multi.upsert(i, v.clone());
            single.upsert(i, v);
        }
        for i in 0..20u64 {
            multi.remove(i);
            single.remove(i);
        }
        assert_eq!(multi.stats().postings_scanned, 0);
        let q = sv(&[(2, 1.0), (7, 1.0)]);
        let a = multi.top_k(&q, 10, QueryParams::default());
        let b = single.top_k(&q, 10, QueryParams::default());
        assert_eq!(
            a.iter().map(|n| n.id).collect::<Vec<_>>(),
            b.iter().map(|n| n.id).collect::<Vec<_>>()
        );
        let (ms, ss) = (multi.stats(), single.stats());
        assert!(ms.postings_scanned > 0);
        assert_eq!(ms.postings_scanned, ss.postings_scanned);
        // A binding global budget caps the total scan volume across shards.
        let budget = 8usize;
        let _ = multi.top_k(&q, 10, QueryParams { exclude: None, max_postings: budget });
        let scanned = multi.stats().postings_scanned - ms.postings_scanned;
        assert!(
            scanned as usize <= budget + multi.n_shards() - 1,
            "budgeted fan-out scanned {scanned} > {budget} + rounding"
        );
    }

    #[test]
    fn empty_batches_are_noops() {
        let ix = ShardedIndex::with_threads(3, 2);
        assert!(ix.upsert_batch(Vec::new()).is_empty());
        assert!(ix.remove_batch(&[]).is_empty());
        assert!(ix.query_batch(&[], 5).is_empty());
        assert_eq!(ix.len(), 0);
    }

    #[test]
    fn concurrent_mutations_and_queries() {
        use std::sync::Arc;
        let ix = Arc::new(ShardedIndex::with_threads(4, 2));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ix = Arc::clone(&ix);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let id = t * 1000 + i;
                    ix.upsert(id, sv(&[(i % 50, 1.0)]));
                    if i % 3 == 0 {
                        ix.remove(id);
                    }
                    if i % 7 == 0 {
                        let _ = ix.top_k(&sv(&[(i % 50, 1.0)]), 5, QueryParams::default());
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 500 per thread, every 3rd removed → ceil(2/3 * 500)*4 total-ish.
        let expect: usize = 4 * (500 - 167);
        assert_eq!(ix.len(), expect);
    }

    /// Stress: batch mutations racing batch queries and single-op threads.
    /// Each thread owns a disjoint id range, so the final count is exact.
    #[test]
    fn concurrent_batch_mutations_and_queries() {
        use std::sync::Arc;
        let ix = Arc::new(ShardedIndex::with_threads(4, 2));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ix = Arc::clone(&ix);
            handles.push(std::thread::spawn(move || {
                for chunk in 0..10u64 {
                    let base = t * 10_000 + chunk * 50;
                    let batch: Vec<(u64, SparseVec)> = (0..50)
                        .map(|i| (base + i, sv(&[((base + i) % 50, 1.0)])))
                        .collect();
                    let existed = ix.upsert_batch(batch);
                    assert!(existed.iter().all(|&e| !e), "fresh ids reported existing");
                    // Remove every other id of the chunk via the batch path.
                    let removals: Vec<u64> =
                        (0..50).filter(|i| i % 2 == 0).map(|i| base + i).collect();
                    let removed = ix.remove_batch(&removals);
                    assert!(removed.iter().all(|&e| e), "own ids must be present");
                    // Query batch racing other threads' mutations: results
                    // must stay well-formed (sorted, positive dots).
                    let queries: Vec<(SparseVec, QueryParams)> = (0..4)
                        .map(|i| (sv(&[(i % 50, 1.0)]), QueryParams::default()))
                        .collect();
                    for res in ix.query_batch(&queries, 8) {
                        assert!(res.len() <= 8);
                        for w in res.windows(2) {
                            assert!(
                                w[0].dot > w[1].dot || (w[0].dot == w[1].dot && w[0].id < w[1].id),
                                "unordered merge: {w:?}"
                            );
                        }
                        assert!(res.iter().all(|n| n.dot > 0.0));
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Per thread: 10 chunks × 50 inserts, 25 of each chunk removed.
        assert_eq!(ix.len(), 4 * 10 * 25);
    }

    #[test]
    fn nan_dot_does_not_panic() {
        // Regression for the NaN-poisoned sort class: stored weights are
        // all finite (so `SparseVec`'s debug_assert passes), but the dot
        // accumulator overflows to `inf + (-inf) = NaN` — the same shape
        // as the shipped relu-NaN scorer bug. The query path must not
        // panic and must still return the finite-dot points.
        let ix = ShardedIndex::new(2);
        ix.upsert(1, sv(&[(1, f32::MAX), (2, -f32::MAX)]));
        ix.upsert(2, sv(&[(1, 1.0)]));
        ix.upsert(3, sv(&[(2, 2.0)]));
        let q = sv(&[(1, f32::MAX), (2, f32::MAX)]);
        let r = ix.top_k(&q, 3, QueryParams::default());
        assert!(r.iter().any(|n| n.id == 2));
        assert!(r.iter().any(|n| n.id == 3));
        // Threshold path shares the comparator; exercise it too.
        let t = ix.threshold(&q, 0.0, QueryParams::default());
        assert!(t.iter().any(|n| n.id == 2));
    }
}
