//! Dynamic sparse ANN index — the ScaNN substitute.
//!
//! The paper uses ScaNN's (Google-internal) dynamic sparse-vector mode with
//! distance `Dist(p,q) = -M(p)·M(q)` and two retrieval primitives (§2):
//! top-k nearest and all-points-below-a-distance-threshold. This module
//! provides the same contract with an inverted (posting-list) index:
//!
//! - **exact** for sparse dot products (every candidate sharing ≥1 dimension
//!   is scored, everything else has dot = 0), which makes Lemma 4.1
//!   experiments deterministic;
//! - **dynamic**: insert / update / delete at sub-millisecond cost via
//!   generation-tagged slots and tombstoned postings with incremental
//!   compaction — no global rebuilds, matching the paper's freshness
//!   requirement (mutations visible to queries immediately);
//! - optional posting-budget approximation ([`QueryParams::max_postings`])
//!   to emulate ScaNN's accuracy/latency knob for ablations.
//!
//! # Memory layout (the scan hot path)
//!
//! Every retrieval bottoms out in [`SparseAnn::scan_postings`], which walks
//! posting lists and accumulates partial dots. The layout is
//! struct-of-arrays so that loop stays cache-resident:
//!
//! - **Postings** are contiguous 12-byte `(slot, generation, weight)`
//!   entries scanned linearly.
//! - **Liveness** lives in a dense `Vec<u32>` generation array, with the
//!   alive/dead bit folded into the generation's low bit (even = alive,
//!   odd = dead; bumped on every transition). Validating a posting is one
//!   4-byte compare against that hot array — the scan never dereferences
//!   the ~64-byte cold `Slot` (id + stored embedding), which previously
//!   cost a likely cache miss per posting.
//! - **Budget** ([`QueryParams::max_postings`]) is enforced by pre-slicing
//!   each list to the remaining budget instead of branching per posting;
//!   under a binding budget, query dims are visited shortest-list-first
//!   ([`DimOrder::Selectivity`]) so the budget is spent on the most
//!   selective dims (best recall per scanned posting — see
//!   `eval::offline::ablation_dim_order`). Unbudgeted scans visit dims in
//!   query order and are bit-identical to the pre-SoA scan.
//!
//! [`sharded::ShardedIndex`] wraps the core in N independently-locked
//! shards for concurrent serving.

pub mod sharded;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::features::PointId;
use crate::sparse::SparseVec;
use crate::util::hash::FxHashMap;

/// A retrieved neighbor: external id + dot product (`dist = -dot`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: PointId,
    pub dot: f32,
}

impl Neighbor {
    /// The paper's distance.
    #[inline]
    pub fn dist(&self) -> f32 {
        -self.dot
    }
}

/// Query-time knobs.
#[derive(Debug, Clone, Copy)]
pub struct QueryParams {
    /// Exclude this id from results (a point is never its own neighbor).
    pub exclude: Option<PointId>,
    /// Approximation budget: stop scoring after this many postings
    /// (0 = unlimited = exact). Emulates ScaNN's recall/latency dial.
    pub max_postings: usize,
}

impl Default for QueryParams {
    fn default() -> Self {
        QueryParams { exclude: None, max_postings: 0 }
    }
}

/// Order in which a budgeted scan visits the query's dimensions.
///
/// Only consulted when [`QueryParams::max_postings`] is nonzero: an
/// unbudgeted scan visits every posting either way (in query-dim order, so
/// results are bit-identical regardless of this knob). Under a binding
/// budget the order decides which postings the budget is spent on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DimOrder {
    /// Shortest posting lists first: the budget goes to the most selective
    /// dims, which buys measurably more recall per scanned posting (see
    /// `eval::offline::ablation_dim_order`). The serving default.
    #[default]
    Selectivity,
    /// Ascending dim id (the query's storage order) — the original scan
    /// order, kept as the ablation baseline.
    QueryOrder,
}

/// One inverted-list entry: 12 contiguous bytes scanned linearly by the
/// hot loop. Validation compares `generation` against the dense
/// `SparseAnn::generations` array — never against `Slot`.
#[derive(Debug, Clone, Copy)]
#[repr(C)]
struct Posting {
    slot: u32,
    generation: u32,
    weight: f32,
}

// The scan kernel's working set is `entries.len() * 12` bytes per list;
// keep the entry exactly 12 bytes (no padding).
const _: () = assert!(std::mem::size_of::<Posting>() == 12);

#[derive(Debug, Default)]
struct PostingList {
    entries: Vec<Posting>,
    dead: u32,
}

/// Cold per-point storage: external id + stored embedding. Deliberately
/// holds no liveness/generation state — that lives in the dense
/// `SparseAnn::generations` array so posting validation never touches
/// this struct (one `Slot` is ~64 bytes; a dereference per posting was a
/// likely cache miss each).
#[derive(Debug)]
struct Slot {
    id: PointId,
    vec: SparseVec,
}

/// Reusable query scratch space: dense accumulator over slots plus the
/// touched list. Reusing it across queries removes all per-query allocation
/// from the hot path (see EXPERIMENTS.md §Perf).
///
/// Touched-slot membership is tracked with an epoch-tagged `visited` array
/// (`visited[slot] == epoch` ⇔ slot touched by the current query), not by
/// testing `acc[slot] == 0.0`: with signed embedding weights a partial dot
/// sum can cancel back to exactly `0.0` mid-accumulation, and the old
/// zero-test pushed such slots into `touched` twice. Epoch tagging also
/// makes a scratch safely reusable across different index instances (the
/// sharded fan-out pools scratches across shards) — stale accumulator
/// values are lazily reset on first touch of each new query.
#[derive(Default)]
pub struct QueryScratch {
    acc: Vec<f32>,
    visited: Vec<u32>,
    epoch: u32,
    touched: Vec<u32>,
    heap: Vec<(f32, PointId)>,
    /// Budgeted-scan plan: `(list length, query-dim position)` per
    /// non-empty query dim, sorted shortest-first under
    /// [`DimOrder::Selectivity`]. Pooled here so planning allocates
    /// nothing in steady state.
    plan: Vec<(u32, u32)>,
}

/// Single-shard dynamic sparse ANN index.
pub struct SparseAnn {
    slots: Vec<Slot>,
    /// Per-slot generation with liveness folded into the low bit (even =
    /// alive, odd = dead; bumped on every transition, so a slot's
    /// generation is even exactly while it holds a live point). Postings
    /// record the (even) generation at insert time; a posting is valid
    /// iff `generations[p.slot] == p.generation` — one 4-byte compare
    /// against this dense, hot array. Kept out of `Slot` on purpose: the
    /// scan must not touch cold per-point state.
    generations: Vec<u32>,
    free: Vec<u32>,
    id_to_slot: FxHashMap<PointId, u32>,
    postings: FxHashMap<u64, PostingList>,
    live_points: usize,
    live_postings: usize,
    dead_postings: usize,
    /// Heap bytes held by stored embeddings, maintained incrementally on
    /// upsert/remove so [`SparseAnn::stats`] is O(1).
    vec_heap_bytes: usize,
    /// Total valid postings scored by queries since construction
    /// (observability counter; relaxed — queries run under a shared read
    /// lock).
    postings_scanned: AtomicU64,
    /// Compact a posting list when dead entries exceed this fraction.
    compact_threshold: f32,
}

impl Default for SparseAnn {
    fn default() -> Self {
        Self::new()
    }
}

impl SparseAnn {
    pub fn new() -> SparseAnn {
        Self::with_compact_threshold(0.5)
    }

    /// An index that compacts a posting list once more than
    /// `compact_threshold` of its entries are tombstones. The default is
    /// 0.5; benches raise it to hold a target tombstone density steady.
    pub fn with_compact_threshold(compact_threshold: f32) -> SparseAnn {
        assert!(compact_threshold > 0.0, "threshold must be positive");
        SparseAnn {
            slots: Vec::new(),
            generations: Vec::new(),
            free: Vec::new(),
            id_to_slot: FxHashMap::default(),
            postings: FxHashMap::default(),
            live_points: 0,
            live_postings: 0,
            dead_postings: 0,
            vec_heap_bytes: 0,
            postings_scanned: AtomicU64::new(0),
            compact_threshold,
        }
    }

    /// Number of live points.
    pub fn len(&self) -> usize {
        self.live_points
    }

    pub fn is_empty(&self) -> bool {
        self.live_points == 0
    }

    /// Whether `id` is currently present.
    pub fn contains(&self, id: PointId) -> bool {
        self.id_to_slot.contains_key(&id)
    }

    /// The stored embedding for `id`, if present.
    pub fn get(&self, id: PointId) -> Option<&SparseVec> {
        self.id_to_slot.get(&id).map(|&s| &self.slots[s as usize].vec)
    }

    /// Insert or update (upsert) a point's embedding. Returns `true` if the
    /// point was already present (update).
    pub fn upsert(&mut self, id: PointId, vec: SparseVec) -> bool {
        let existed = self.remove(id);
        let slot = match self.free.pop() {
            Some(s) => {
                let g = &mut self.generations[s as usize];
                debug_assert_eq!(*g & 1, 1, "free slot must be dead");
                *g = g.wrapping_add(1); // odd (dead) → even (alive)
                self.slots[s as usize].id = id;
                s
            }
            None => {
                let s = self.slots.len() as u32;
                self.slots.push(Slot { id, vec: SparseVec::empty() });
                self.generations.push(0);
                s
            }
        };
        let generation = self.generations[slot as usize];
        // Insert postings from the still-owned `vec` (its slices bound
        // once), then move it into the slot — no per-nonzero re-indexing
        // of `self.slots` to appease the borrow checker.
        let nnz = vec.nnz();
        self.postings.reserve(nnz);
        for (&dim, &weight) in vec.dims().iter().zip(vec.weights()) {
            self.postings
                .entry(dim)
                .or_default()
                .entries
                .push(Posting { slot, generation, weight });
        }
        self.vec_heap_bytes += vec.heap_bytes();
        self.slots[slot as usize].vec = vec;
        self.live_postings += nnz;
        self.id_to_slot.insert(id, slot);
        self.live_points += 1;
        existed
    }

    /// Delete a point. Returns `true` if it was present. O(1): postings
    /// become tombstones invalidated by the generation check and are
    /// reclaimed lazily by per-list compaction.
    pub fn remove(&mut self, id: PointId) -> bool {
        let Some(slot) = self.id_to_slot.remove(&id) else {
            return false;
        };
        let s = slot as usize;
        debug_assert_eq!(self.generations[s] & 1, 0, "mapped slot must be live");
        self.generations[s] = self.generations[s].wrapping_add(1); // alive → dead
        // Take the embedding out of the slot: its heap memory is released
        // now instead of lingering until slot reuse, and owning it lets us
        // iterate dims while mutating `postings` — no cloned dim vector.
        let vec = std::mem::take(&mut self.slots[s].vec);
        let nnz = vec.nnz();
        self.live_points -= 1;
        self.live_postings -= nnz;
        self.dead_postings += nnz;
        self.vec_heap_bytes -= vec.heap_bytes();
        // Account the dead entries on their lists so compaction can trigger.
        for &d in vec.dims() {
            if let Some(list) = self.postings.get_mut(&d) {
                list.dead += 1;
                if list.dead as f32 > list.entries.len() as f32 * self.compact_threshold {
                    Self::compact_list(&self.generations, list, &mut self.dead_postings);
                    if list.entries.is_empty() {
                        self.postings.remove(&d);
                    }
                }
            }
        }
        self.free.push(slot);
        true
    }

    fn compact_list(generations: &[u32], list: &mut PostingList, dead_total: &mut usize) {
        let before = list.entries.len();
        // Valid ⇔ the slot's current generation equals the posting's
        // (postings are recorded with an even generation, so a dead slot —
        // odd generation — can never match).
        list.entries.retain(|p| generations[p.slot as usize] == p.generation);
        let removed = before - list.entries.len();
        *dead_total = dead_total.saturating_sub(removed);
        list.dead = 0;
    }

    /// Force-compact every posting list (periodic maintenance).
    pub fn compact_all(&mut self) {
        let generations = std::mem::take(&mut self.generations);
        self.postings.retain(|_, list| {
            Self::compact_list(&generations, list, &mut self.dead_postings);
            !list.entries.is_empty()
        });
        self.generations = generations;
        self.dead_postings = 0;
    }

    /// The scan kernel: score all points sharing ≥ 1 dimension with
    /// `query` into the scratch accumulator. Returns the number of
    /// **valid** (live) postings scored — tombstones skipped by the
    /// generation check never count against the budget, exactly as in the
    /// original per-posting check.
    ///
    /// Layout discipline (see module docs): posting validation is one
    /// 4-byte compare against the dense generation array (never a `Slot`
    /// dereference), and the `max_postings` budget is enforced by
    /// pre-slicing each list to the remaining budget instead of branching
    /// per posting — a chunk is re-sliced only when tombstones inside it
    /// left budget unspent, so budget semantics are unchanged.
    ///
    /// With a nonzero budget, `order` decides which dims the budget is
    /// spent on (see [`DimOrder`]); unbudgeted scans visit dims in query
    /// order and are bit-identical for both orders. Public so benches and
    /// ablations can isolate the kernel from candidate selection.
    pub fn scan_postings(
        &self,
        query: &SparseVec,
        params: QueryParams,
        order: DimOrder,
        scratch: &mut QueryScratch,
    ) -> usize {
        if scratch.acc.len() < self.slots.len() {
            scratch.acc.resize(self.slots.len(), 0.0);
            scratch.visited.resize(self.slots.len(), 0);
        }
        scratch.epoch = scratch.epoch.wrapping_add(1);
        if scratch.epoch == 0 {
            // Epoch counter wrapped: stale tags could alias the new epoch.
            scratch.visited.fill(0);
            scratch.epoch = 1;
        }
        let epoch = scratch.epoch;
        scratch.touched.clear();
        let gens: &[u32] = &self.generations;
        let budget = params.max_postings;
        let mut scored = 0usize;
        if budget == 0 {
            for (dim, qw) in query.iter() {
                if let Some(list) = self.postings.get(&dim) {
                    scored += scan_chunk(gens, &list.entries, qw, epoch, scratch);
                }
            }
        } else {
            let dims = query.dims();
            let weights = query.weights();
            // Plan the scan: (list length, query-dim position) per dim
            // with a non-empty list. Taken out of the scratch so the plan
            // buffer can be iterated while the scratch is mutated.
            let mut plan = std::mem::take(&mut scratch.plan);
            plan.clear();
            for (i, dim) in dims.iter().enumerate() {
                if let Some(list) = self.postings.get(dim) {
                    let len = list.entries.len().min(u32::MAX as usize) as u32;
                    plan.push((len, i as u32));
                }
            }
            if order == DimOrder::Selectivity {
                // Shortest (most selective) lists first; ties break by
                // query-dim position for determinism.
                plan.sort_unstable();
            }
            'dims: for &(_, i) in &plan {
                let i = i as usize;
                let entries: &[Posting] = &self.postings[&dims[i]].entries;
                let qw = weights[i];
                let mut offset = 0usize;
                while offset < entries.len() {
                    let remaining = budget - scored;
                    if remaining == 0 {
                        break 'dims;
                    }
                    let take = remaining.min(entries.len() - offset);
                    scored += scan_chunk(gens, &entries[offset..offset + take], qw, epoch, scratch);
                    offset += take;
                }
            }
            scratch.plan = plan;
        }
        self.postings_scanned.fetch_add(scored as u64, Ordering::Relaxed);
        scored
    }

    /// Top-k nearest (highest dot / lowest dist). Deterministic: ties in dot
    /// are broken by ascending id. Only points with `dot > 0` are returned —
    /// with the strictly-positive embeddings of §4.1 these are exactly the
    /// points sharing ≥ 1 bucket (Lemma 4.1); everything else is at the
    /// maximal distance 0 and is not a neighbor.
    pub fn top_k(
        &self,
        query: &SparseVec,
        k: usize,
        params: QueryParams,
        scratch: &mut QueryScratch,
    ) -> Vec<Neighbor> {
        self.top_k_ordered(query, k, params, DimOrder::Selectivity, scratch)
    }

    /// [`top_k`](SparseAnn::top_k) with an explicit budgeted-scan dim
    /// order (ablations; the order only matters under a binding
    /// `max_postings` budget).
    pub fn top_k_ordered(
        &self,
        query: &SparseVec,
        k: usize,
        params: QueryParams,
        order: DimOrder,
        scratch: &mut QueryScratch,
    ) -> Vec<Neighbor> {
        if k == 0 || self.live_points == 0 {
            return Vec::new();
        }
        self.scan_postings(query, params, order, scratch);
        // Select top-k by (dot desc, id asc) with a bounded min-heap
        // materialized as a sorted insertion buffer (k is small: 10–1000).
        let heap = &mut scratch.heap;
        heap.clear();
        for &slot in &scratch.touched {
            let dot = scratch.acc[slot as usize];
            if dot <= 0.0 {
                continue;
            }
            let id = self.slots[slot as usize].id;
            if params.exclude == Some(id) {
                continue;
            }
            if heap.len() < k {
                heap.push((dot, id));
                if heap.len() == k {
                    // Build min-heap ordering lazily: sort once full.
                    heap.sort_unstable_by(cmp_heap);
                }
            } else {
                // heap[0] is the current worst (smallest dot, largest id).
                if cmp_candidate(dot, id, heap[0]) {
                    heap[0] = (dot, id);
                    sift_down(heap);
                }
            }
        }
        if heap.len() < k {
            heap.sort_unstable_by(cmp_heap);
        }
        let mut out: Vec<Neighbor> =
            heap.iter().map(|&(dot, id)| Neighbor { id, dot }).collect();
        out.sort_unstable_by(|a, b| b.dot.total_cmp(&a.dot).then(a.id.cmp(&b.id)));
        out
    }

    /// All points with `Dist ≤ tau` i.e. `dot ≥ -tau`. With `tau` slightly
    /// below 0 this is the paper's "all points with negative distance"
    /// (Lemma 4.1). Results sorted by (dot desc, id asc).
    pub fn threshold(
        &self,
        query: &SparseVec,
        tau: f32,
        params: QueryParams,
        scratch: &mut QueryScratch,
    ) -> Vec<Neighbor> {
        self.threshold_ordered(query, tau, params, DimOrder::Selectivity, scratch)
    }

    /// [`threshold`](SparseAnn::threshold) with an explicit budgeted-scan
    /// dim order (see [`top_k_ordered`](SparseAnn::top_k_ordered)).
    pub fn threshold_ordered(
        &self,
        query: &SparseVec,
        tau: f32,
        params: QueryParams,
        order: DimOrder,
        scratch: &mut QueryScratch,
    ) -> Vec<Neighbor> {
        self.scan_postings(query, params, order, scratch);
        let min_dot = -tau;
        let mut out = Vec::new();
        for &slot in &scratch.touched {
            let dot = scratch.acc[slot as usize];
            // `dot > 0` is implied for touched slots with positive weights,
            // but embeddings may in principle carry any weights: check.
            if dot >= min_dot && dot != 0.0 {
                let id = self.slots[slot as usize].id;
                if params.exclude != Some(id) {
                    out.push(Neighbor { id, dot });
                }
            }
        }
        out.sort_unstable_by(|a, b| b.dot.total_cmp(&a.dot).then(a.id.cmp(&b.id)));
        out
    }

    /// Index statistics (Fig. 10 memory accounting + ops). O(1): every
    /// component is a counter maintained incrementally by the mutation
    /// path — the `stats` RPC no longer walks every slot and posting list
    /// per request.
    pub fn stats(&self) -> IndexStats {
        IndexStats {
            live_points: self.live_points,
            live_postings: self.live_postings,
            dead_postings: self.dead_postings,
            distinct_dims: self.postings.len(),
            slot_capacity: self.slots.len(),
            approx_bytes: self.approx_bytes(),
            postings_scanned: self.postings_scanned.load(Ordering::Relaxed),
        }
    }

    /// O(1) byte estimate from the incremental counters. Posting storage
    /// is estimated from entry counts (live + dead ≡ total entries across
    /// lists) rather than the exact `Vec` capacities the old walk summed —
    /// an under-estimate of at most the growth slack, acceptable for an
    /// `approx_bytes` figure that used to cost a full index walk.
    fn approx_bytes(&self) -> usize {
        let entries = self.live_postings + self.dead_postings;
        entries * std::mem::size_of::<Posting>()
            + self.postings.len() * 48
            + self.vec_heap_bytes
            + self.slots.len() * std::mem::size_of::<Slot>()
            + self.generations.len() * std::mem::size_of::<u32>()
            + self.id_to_slot.len() * 24
    }

    /// Iterate live `(id, embedding)` pairs (offline experiments).
    pub fn iter_live(&self) -> impl Iterator<Item = (PointId, &SparseVec)> + '_ {
        self.slots
            .iter()
            .zip(&self.generations)
            .filter(|&(_, &g)| g & 1 == 0)
            .map(|(s, _)| (s.id, &s.vec))
    }
}

/// The tight inner loop: score one contiguous run of 12-byte postings.
/// Per posting it reads 4 bytes of the dense generation array and the
/// posting itself — no `Slot` dereference, no budget branch (the caller
/// pre-slices `entries` to the remaining budget).
#[inline]
fn scan_chunk(
    gens: &[u32],
    entries: &[Posting],
    qw: f32,
    epoch: u32,
    scratch: &mut QueryScratch,
) -> usize {
    let mut scored = 0usize;
    for p in entries {
        let s = p.slot as usize;
        if gens[s] != p.generation {
            continue;
        }
        scored += 1;
        if scratch.visited[s] != epoch {
            scratch.visited[s] = epoch;
            scratch.acc[s] = 0.0;
            scratch.touched.push(p.slot);
        }
        scratch.acc[s] += qw * p.weight;
    }
    scored
}

/// Heap ordering: worst candidate first = (dot asc, id desc).
#[inline]
fn cmp_heap(a: &(f32, PointId), b: &(f32, PointId)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then(b.1.cmp(&a.1))
}

/// Does candidate (dot, id) beat the heap's worst `w`?
#[inline]
fn cmp_candidate(dot: f32, id: PointId, w: (f32, PointId)) -> bool {
    dot > w.0 || (dot == w.0 && id < w.1)
}

fn sift_down(heap: &mut [(f32, PointId)]) {
    let n = heap.len();
    let mut i = 0;
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut worst = i;
        if l < n && cmp_heap(&heap[l], &heap[worst]).is_lt() {
            worst = l;
        }
        if r < n && cmp_heap(&heap[r], &heap[worst]).is_lt() {
            worst = r;
        }
        if worst == i {
            break;
        }
        heap.swap(i, worst);
        i = worst;
    }
}

/// Snapshot of index size/health.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexStats {
    pub live_points: usize,
    pub live_postings: usize,
    pub dead_postings: usize,
    pub distinct_dims: usize,
    pub slot_capacity: usize,
    pub approx_bytes: usize,
    /// Valid postings scored by queries since construction (monotonic
    /// counter — recall-per-posting observability, not a size).
    pub postings_scanned: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::proptest;
    use crate::util::rng::Rng;

    fn sv(pairs: &[(u64, f32)]) -> SparseVec {
        SparseVec::from_pairs(pairs.to_vec())
    }

    fn topk(ix: &SparseAnn, q: &SparseVec, k: usize) -> Vec<Neighbor> {
        ix.top_k(q, k, QueryParams::default(), &mut QueryScratch::default())
    }

    #[test]
    fn insert_query_basic() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(10, 1.0), (20, 1.0)]));
        ix.upsert(2, sv(&[(20, 1.0), (30, 1.0)]));
        ix.upsert(3, sv(&[(40, 1.0)]));
        let r = topk(&ix, &sv(&[(10, 1.0), (20, 1.0)]), 10);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].id, 1);
        assert_eq!(r[0].dot, 2.0);
        assert_eq!(r[1].id, 2);
        assert_eq!(r[1].dot, 1.0);
        assert_eq!(r[0].dist(), -2.0);
    }

    #[test]
    fn no_shared_dim_not_returned() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(10, 1.0)]));
        let r = topk(&ix, &sv(&[(99, 1.0)]), 10);
        assert!(r.is_empty());
    }

    #[test]
    fn k_limits_results() {
        let mut ix = SparseAnn::new();
        for i in 0..100u64 {
            ix.upsert(i, sv(&[(7, 1.0 + i as f32)]));
        }
        let r = topk(&ix, &sv(&[(7, 1.0)]), 5);
        assert_eq!(r.len(), 5);
        // Highest weights win.
        assert_eq!(r[0].id, 99);
        assert_eq!(r[4].id, 95);
    }

    #[test]
    fn exclude_self() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        ix.upsert(2, sv(&[(5, 1.0)]));
        let r = ix.top_k(
            &sv(&[(5, 1.0)]),
            10,
            QueryParams { exclude: Some(1), max_postings: 0 },
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 2);
    }

    #[test]
    fn delete_removes_from_results() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        ix.upsert(2, sv(&[(5, 2.0)]));
        assert!(ix.remove(2));
        assert!(!ix.remove(2));
        let r = topk(&ix, &sv(&[(5, 1.0)]), 10);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 1);
        assert_eq!(ix.len(), 1);
        assert!(!ix.contains(2));
    }

    #[test]
    fn update_replaces_embedding() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        let existed = ix.upsert(1, sv(&[(9, 1.0)]));
        assert!(existed);
        assert_eq!(ix.len(), 1);
        assert!(topk(&ix, &sv(&[(5, 1.0)]), 10).is_empty());
        let r = topk(&ix, &sv(&[(9, 1.0)]), 10);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn slot_reuse_does_not_resurrect() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        ix.remove(1);
        // Slot of point 1 is reused by point 2 with a different dim.
        ix.upsert(2, sv(&[(6, 1.0)]));
        // A stale posting for dim 5 must not surface point 2.
        let r = topk(&ix, &sv(&[(5, 1.0)]), 10);
        assert!(r.is_empty(), "stale posting resurrected: {r:?}");
    }

    #[test]
    fn threshold_query_negative_distance() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0), (6, 1.0)]));
        ix.upsert(2, sv(&[(6, 1.0)]));
        ix.upsert(3, sv(&[(7, 1.0)]));
        // All with Dist < 0 ⇔ dot > 0: tau just below zero.
        let r = ix.threshold(
            &sv(&[(5, 1.0), (6, 1.0)]),
            -f32::MIN_POSITIVE,
            QueryParams::default(),
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].id, 1);
    }

    #[test]
    fn threshold_tau_cuts() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 3.0)]));
        ix.upsert(2, sv(&[(5, 1.0)]));
        // dot(q,1)=3, dot(q,2)=1. Dist: -3 and -1. tau=-2 keeps only dist≤-2.
        let r = ix.threshold(
            &sv(&[(5, 1.0)]),
            -2.0,
            QueryParams::default(),
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 1);
    }

    #[test]
    fn compaction_reclaims() {
        let mut ix = SparseAnn::new();
        for i in 0..100u64 {
            ix.upsert(i, sv(&[(7, 1.0)]));
        }
        for i in 0..90u64 {
            ix.remove(i);
        }
        // Per-list compaction should have fired (dead > 50%).
        let st = ix.stats();
        assert_eq!(st.live_points, 10);
        assert!(
            st.dead_postings < 60,
            "compaction did not run: {st:?}"
        );
        ix.compact_all();
        assert_eq!(ix.stats().dead_postings, 0);
        let r = topk(&ix, &sv(&[(7, 1.0)]), 100);
        assert_eq!(r.len(), 10);
    }

    #[test]
    fn stats_track_sizes() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(1, 1.0), (2, 1.0)]));
        ix.upsert(2, sv(&[(2, 1.0)]));
        let st = ix.stats();
        assert_eq!(st.live_points, 2);
        assert_eq!(st.live_postings, 3);
        assert_eq!(st.distinct_dims, 2);
        assert!(st.approx_bytes > 0);
    }

    #[test]
    fn max_postings_budget_approximates() {
        let mut ix = SparseAnn::new();
        for i in 0..50u64 {
            ix.upsert(i, sv(&[(7, 1.0)]));
        }
        let r = ix.top_k(
            &sv(&[(7, 1.0)]),
            50,
            QueryParams { exclude: None, max_postings: 10 },
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 10, "budget should cap scanning");
    }

    #[test]
    fn deterministic_tie_break_by_id() {
        let mut ix = SparseAnn::new();
        for &id in &[42u64, 7, 19, 3, 88] {
            ix.upsert(id, sv(&[(5, 1.0)]));
        }
        let r = topk(&ix, &sv(&[(5, 1.0)]), 3);
        let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![3, 7, 19]);
    }

    #[test]
    fn empty_query_returns_nothing() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        assert!(topk(&ix, &SparseVec::empty(), 10).is_empty());
        assert!(topk(&ix, &sv(&[(5, 1.0)]), 0).is_empty());
    }

    /// Regression for the `accumulate` touched-list bug: with signed
    /// weights, point 1's partial sum goes 1.0 → 0.0 → 2.0 over the query's
    /// (sorted) dims, so the old `acc == 0.0` membership test pushed its
    /// slot into `touched` twice. Epoch tagging must yield each id exactly
    /// once, with the fully-accumulated dot.
    #[test]
    fn threshold_no_duplicates_with_signed_weights() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0), (6, -1.0), (7, 2.0)]));
        ix.upsert(2, sv(&[(7, 1.0)]));
        let q = sv(&[(5, 1.0), (6, 1.0), (7, 1.0)]);
        let r = ix.threshold(&q, 10.0, QueryParams::default(), &mut QueryScratch::default());
        let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![1, 2], "duplicate or wrong ids: {r:?}");
        assert_eq!(r[0].dot, 2.0);
        assert_eq!(r[1].dot, 1.0);

        let r = ix.top_k(&q, 10, QueryParams::default(), &mut QueryScratch::default());
        let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![1, 2], "top_k duplicated: {r:?}");
    }

    /// A slot whose dot cancels to exactly 0.0 overall is not a neighbor,
    /// and a reused scratch must not leak state between queries.
    #[test]
    fn signed_weights_cancel_to_zero_excluded() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0), (6, -1.0)]));
        ix.upsert(2, sv(&[(5, 0.5)]));
        let mut scratch = QueryScratch::default();
        let q = sv(&[(5, 1.0), (6, 1.0)]);
        for _ in 0..3 {
            // dot(q, 1) = 0.0 exactly → excluded; dot(q, 2) = 0.5.
            let r = ix.threshold(&q, 10.0, QueryParams::default(), &mut scratch);
            let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
            assert_eq!(ids, vec![2], "{r:?}");
            let r = ix.top_k(&q, 10, QueryParams::default(), &mut scratch);
            assert_eq!(r.len(), 1);
            assert_eq!(r[0].id, 2);
        }
    }

    /// Property with signed weights: threshold never returns duplicate ids
    /// and always matches the brute-force oracle.
    #[test]
    fn prop_signed_weights_no_duplicates() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live: std::collections::BTreeMap<u64, SparseVec> = Default::default();
            for _ in 0..40 {
                let id = rng.below(20);
                let n = 1 + rng.below_usize(6);
                // Half-integral signed weights make exact mid-accumulation
                // cancellation likely.
                let v = SparseVec::from_pairs(
                    (0..n)
                        .map(|_| (rng.below(12), (rng.below(9) as f32 - 4.0) * 0.5))
                        .collect(),
                );
                ix.upsert(id, v.clone());
                live.insert(id, v);
            }
            let q = SparseVec::from_pairs(
                (0..3).map(|_| (rng.below(12), (rng.below(9) as f32 - 4.0) * 0.5)).collect(),
            );
            let tau = 2.0 - rng.f32() * 6.0;
            let got = ix.threshold(&q, tau, QueryParams::default(), &mut QueryScratch::default());
            let got_ids: Vec<u64> = got.iter().map(|n| n.id).collect();
            let mut dedup = got_ids.clone();
            dedup.dedup();
            assert_eq!(got_ids, dedup, "duplicate neighbors: {got:?}");
            let want_ids: std::collections::BTreeSet<u64> = live
                .iter()
                .filter(|(_, v)| {
                    let d = q.dot(v);
                    -d <= tau && d != 0.0
                })
                .map(|(&id, _)| id)
                .collect();
            let got_set: std::collections::BTreeSet<u64> = got_ids.iter().copied().collect();
            assert_eq!(got_set, want_ids);
        });
    }

    /// Property: top-k always matches a brute-force scan over live points.
    #[test]
    fn prop_topk_matches_bruteforce() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live: std::collections::BTreeMap<u64, SparseVec> = Default::default();
            let n_ops = 60 + rng.below_usize(60);
            for _ in 0..n_ops {
                let id = rng.below(30);
                match rng.below(10) {
                    0..=6 => {
                        let v = random_vec(rng);
                        ix.upsert(id, v.clone());
                        live.insert(id, v);
                    }
                    _ => {
                        ix.remove(id);
                        live.remove(&id);
                    }
                }
            }
            assert_eq!(ix.len(), live.len());
            let q = random_vec(rng);
            let k = 1 + rng.below_usize(8);
            let got = ix.top_k(&q, k, QueryParams::default(), &mut QueryScratch::default());
            // Brute force oracle.
            let mut want: Vec<Neighbor> = live
                .iter()
                .map(|(&id, v)| Neighbor { id, dot: q.dot(v) })
                .filter(|n| n.dot > 0.0)
                .collect();
            want.sort_by(|a, b| b.dot.total_cmp(&a.dot).then(a.id.cmp(&b.id)));
            want.truncate(k);
            assert_eq!(got.len(), want.len(), "count mismatch");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.id, w.id, "got {got:?} want {want:?}");
                assert!((g.dot - w.dot).abs() < 1e-4);
            }
        });
    }

    /// Property: threshold query equals brute-force filter.
    #[test]
    fn prop_threshold_matches_bruteforce() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live: std::collections::BTreeMap<u64, SparseVec> = Default::default();
            for _ in 0..40 {
                let id = rng.below(25);
                let v = random_vec(rng);
                ix.upsert(id, v.clone());
                live.insert(id, v);
            }
            let q = random_vec(rng);
            let tau = -0.5 - rng.f32() * 2.0; // Dist ≤ tau < 0
            let got = ix.threshold(&q, tau, QueryParams::default(), &mut QueryScratch::default());
            let want: Vec<u64> = live
                .iter()
                .filter(|(_, v)| -q.dot(v) <= tau && q.dot(v) != 0.0)
                .map(|(&id, _)| id)
                .collect();
            let got_ids: std::collections::BTreeSet<u64> =
                got.iter().map(|n| n.id).collect();
            let want_ids: std::collections::BTreeSet<u64> = want.into_iter().collect();
            assert_eq!(got_ids, want_ids);
        });
    }

    fn random_vec(rng: &mut Rng) -> SparseVec {
        let n = 1 + rng.below_usize(8);
        SparseVec::from_pairs(
            (0..n).map(|_| (rng.below(20), 0.1 + rng.f32())).collect(),
        )
    }

    /// Signed half-integral weights: exact mid-accumulation cancellation
    /// is likely, which is the hard case for bitwise comparisons.
    fn signed_vec(rng: &mut Rng) -> SparseVec {
        let n = 1 + rng.below_usize(6);
        SparseVec::from_pairs(
            (0..n)
                .map(|_| (rng.below(16), (rng.below(9) as f32 - 4.0) * 0.5))
                .collect(),
        )
    }

    /// Random op stream (upserts, removes, occasional full compaction)
    /// applied to both the index and a brute-force oracle map.
    fn churn(
        rng: &mut Rng,
        ix: &mut SparseAnn,
        live: &mut std::collections::BTreeMap<u64, SparseVec>,
        ops: usize,
        mk: fn(&mut Rng) -> SparseVec,
    ) {
        for _ in 0..ops {
            let id = rng.below(30);
            match rng.below(12) {
                0..=7 => {
                    let v = mk(rng);
                    ix.upsert(id, v.clone());
                    live.insert(id, v);
                }
                8..=10 => {
                    ix.remove(id);
                    live.remove(&id);
                }
                _ => ix.compact_all(),
            }
        }
    }

    /// Internal accounting invariants the incremental O(1) stats rely on.
    fn check_accounting(ix: &SparseAnn) {
        let entries: usize = ix.postings.values().map(|l| l.entries.len()).sum();
        assert_eq!(
            entries,
            ix.live_postings + ix.dead_postings,
            "entry count drifted from live+dead counters"
        );
        let heap: usize = ix.slots.iter().map(|s| s.vec.heap_bytes()).sum();
        assert_eq!(heap, ix.vec_heap_bytes, "incremental heap-bytes drifted");
        for (&id, &s) in &ix.id_to_slot {
            assert_eq!(
                ix.generations[s as usize] & 1,
                0,
                "live slot {s} (id {id}) has a dead (odd) generation"
            );
        }
        for &s in &ix.free {
            assert_eq!(
                ix.generations[s as usize] & 1,
                1,
                "free slot {s} has a live (even) generation"
            );
        }
        for list in ix.postings.values() {
            for p in &list.entries {
                assert_eq!(p.generation & 1, 0, "posting recorded with odd generation");
            }
        }
    }

    /// Property: the SoA scan's unbudgeted results are bit-identical to
    /// the seed scan. `SparseVec::dot` merges shared dims in ascending
    /// dim order — the exact accumulation order of the original
    /// per-posting scan — so comparing result dots to the oracle's bits
    /// proves the refactor changed the layout, not the arithmetic.
    #[test]
    fn prop_unbudgeted_scan_bitwise_matches_seed_oracle() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live = std::collections::BTreeMap::new();
            churn(rng, &mut ix, &mut live, 70, signed_vec);
            let q = signed_vec(rng);
            let mut scratch = QueryScratch::default();
            let got = ix.threshold(&q, f32::MAX, QueryParams::default(), &mut scratch);
            let want_ids: std::collections::BTreeSet<u64> = live
                .iter()
                .filter(|(_, v)| q.dot(v) != 0.0)
                .map(|(&id, _)| id)
                .collect();
            let got_ids: std::collections::BTreeSet<u64> =
                got.iter().map(|n| n.id).collect();
            assert_eq!(got_ids, want_ids);
            for n in &got {
                assert_eq!(
                    n.dot.to_bits(),
                    q.dot(&live[&n.id]).to_bits(),
                    "dot bits diverged from the seed accumulation order for id {}",
                    n.id
                );
            }
            let top = ix.top_k(&q, 8, QueryParams::default(), &mut scratch);
            for n in &top {
                assert_eq!(n.dot.to_bits(), q.dot(&live[&n.id]).to_bits());
            }
            check_accounting(&ix);
        });
    }

    /// Property: a budgeted scan never scores (or returns) more postings
    /// than the budget, for either dim order, across interleaved
    /// upsert/remove/compaction; and a non-binding budget reproduces the
    /// exact result set — bit-identically in `QueryOrder` (same visit
    /// order as unbudgeted, so the chunked slicing must not change the
    /// arithmetic).
    #[test]
    fn prop_budgeted_scan_respects_budget_any_order() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live = std::collections::BTreeMap::new();
            churn(rng, &mut ix, &mut live, 60, random_vec);
            let q = random_vec(rng);
            let mut scratch = QueryScratch::default();
            let exact = ix.top_k(&q, 1000, QueryParams::default(), &mut scratch);
            let st = ix.stats();
            let total_entries = (st.live_postings + st.dead_postings).max(1);
            for order in [DimOrder::Selectivity, DimOrder::QueryOrder] {
                let budget = 1 + rng.below_usize(30);
                let params = QueryParams { exclude: None, max_postings: budget };
                let scanned = ix.scan_postings(&q, params, order, &mut scratch);
                assert!(scanned <= budget, "scored {scanned} > budget {budget}");
                let r = ix.top_k_ordered(&q, 1000, params, order, &mut scratch);
                assert!(r.len() <= budget, "{} results > budget {budget}", r.len());
                for n in &r {
                    assert!(live.contains_key(&n.id), "budgeted scan surfaced dead id");
                }
                // A budget ≥ total entries cannot bind: exact results.
                let nb = QueryParams { exclude: None, max_postings: total_entries };
                let r2 = ix.top_k_ordered(&q, 1000, nb, order, &mut scratch);
                assert_eq!(r2.len(), exact.len(), "non-binding budget changed results");
                for (x, y) in r2.iter().zip(&exact) {
                    assert_eq!(x.id, y.id);
                    if order == DimOrder::QueryOrder {
                        assert_eq!(x.dot.to_bits(), y.dot.to_bits());
                    } else {
                        assert!((x.dot - y.dot).abs() < 1e-4);
                    }
                }
            }
        });
    }

    /// A binding budget spent shortest-list-first finds the high-value
    /// neighbors hiding behind a short list; the seed's dim-id order
    /// burns the whole budget on the long, low-value list in front of it.
    #[test]
    fn selectivity_order_spends_budget_on_short_lists_first() {
        let mut ix = SparseAnn::new();
        // Long list on the smaller dim id: 100 weak matches.
        for i in 0..100u64 {
            ix.upsert(i, sv(&[(1, 0.1)]));
        }
        // Short list on the larger dim id: the 5 true nearest.
        for i in 100..105u64 {
            ix.upsert(i, sv(&[(2, 5.0)]));
        }
        let q = sv(&[(1, 1.0), (2, 1.0)]);
        let params = QueryParams { exclude: None, max_postings: 5 };
        let mut scratch = QueryScratch::default();
        let sel = ix.top_k_ordered(&q, 5, params, DimOrder::Selectivity, &mut scratch);
        assert_eq!(sel.len(), 5);
        assert!(
            sel.iter().all(|n| n.id >= 100 && n.dot == 5.0),
            "selectivity order missed the short list: {sel:?}"
        );
        let qo = ix.top_k_ordered(&q, 5, params, DimOrder::QueryOrder, &mut scratch);
        assert!(
            qo.iter().all(|n| n.id < 100 && n.dot < 1.0),
            "query order unexpectedly escaped the long list: {qo:?}"
        );
        // Unbudgeted, the orders are bit-identical.
        let none = QueryParams::default();
        let a = ix.top_k_ordered(&q, 10, none, DimOrder::Selectivity, &mut scratch);
        let b = ix.top_k_ordered(&q, 10, none, DimOrder::QueryOrder, &mut scratch);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.id, x.dot.to_bits()), (y.id, y.dot.to_bits()));
        }
    }

    /// The scan counter counts valid postings only — tombstones skipped
    /// by the generation check don't inflate it.
    #[test]
    fn postings_scanned_counts_valid_postings_only() {
        let mut ix = SparseAnn::with_compact_threshold(0.99);
        for i in 0..10u64 {
            ix.upsert(i, sv(&[(7, 1.0)]));
        }
        for i in 0..4u64 {
            ix.remove(i);
        }
        let st = ix.stats();
        assert_eq!(st.dead_postings, 4, "compaction fired unexpectedly");
        let before = st.postings_scanned;
        let mut scratch = QueryScratch::default();
        let r = ix.top_k(&sv(&[(7, 1.0)]), 100, QueryParams::default(), &mut scratch);
        assert_eq!(r.len(), 6);
        assert_eq!(ix.stats().postings_scanned - before, 6);
    }

    /// Generation parity across repeated slot reuse: stale postings from
    /// two lives ago must stay dead, and accounting must hold throughout.
    #[test]
    fn repeated_slot_reuse_keeps_generations_sound() {
        let mut ix = SparseAnn::new();
        for cycle in 0..5u64 {
            ix.upsert(1, sv(&[(10 + cycle, 1.0)]));
            check_accounting(&ix);
            // Only the current life's dim surfaces the point.
            for d in 10..10 + cycle {
                let r = ix.top_k(
                    &sv(&[(d, 1.0)]),
                    10,
                    QueryParams::default(),
                    &mut QueryScratch::default(),
                );
                assert!(r.is_empty(), "stale dim {d} resurrected on cycle {cycle}: {r:?}");
            }
            ix.remove(1);
            check_accounting(&ix);
        }
        assert!(ix.is_empty());
    }

    /// Property: the incremental byte/entry accounting never drifts from
    /// a full recount under interleaved upsert/remove/compaction.
    #[test]
    fn prop_incremental_accounting_matches_recount() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live = std::collections::BTreeMap::new();
            churn(rng, &mut ix, &mut live, 80, random_vec);
            check_accounting(&ix);
            assert_eq!(ix.len(), live.len());
            assert!(ix.stats().approx_bytes > 0 || live.is_empty());
            let live_from_iter: usize = ix.iter_live().count();
            assert_eq!(live_from_iter, live.len(), "iter_live diverged");
        });
    }
}
