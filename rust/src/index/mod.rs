//! Dynamic sparse ANN index — the ScaNN substitute.
//!
//! The paper uses ScaNN's (Google-internal) dynamic sparse-vector mode with
//! distance `Dist(p,q) = -M(p)·M(q)` and two retrieval primitives (§2):
//! top-k nearest and all-points-below-a-distance-threshold. This module
//! provides the same contract with an inverted (posting-list) index:
//!
//! - **exact** for sparse dot products (every candidate sharing ≥1 dimension
//!   is scored, everything else has dot = 0), which makes Lemma 4.1
//!   experiments deterministic;
//! - **dynamic**: insert / update / delete at sub-millisecond cost via
//!   generation-tagged slots and tombstoned postings with incremental
//!   compaction — no global rebuilds, matching the paper's freshness
//!   requirement (mutations visible to queries immediately);
//! - optional posting-budget approximation ([`QueryParams::max_postings`])
//!   to emulate ScaNN's accuracy/latency knob for ablations.
//!
//! [`sharded::ShardedIndex`] wraps the core in N independently-locked
//! shards for concurrent serving.

pub mod sharded;

use crate::features::PointId;
use crate::sparse::SparseVec;
use crate::util::hash::FxHashMap;

/// A retrieved neighbor: external id + dot product (`dist = -dot`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: PointId,
    pub dot: f32,
}

impl Neighbor {
    /// The paper's distance.
    #[inline]
    pub fn dist(&self) -> f32 {
        -self.dot
    }
}

/// Query-time knobs.
#[derive(Debug, Clone, Copy)]
pub struct QueryParams {
    /// Exclude this id from results (a point is never its own neighbor).
    pub exclude: Option<PointId>,
    /// Approximation budget: stop scoring after this many postings
    /// (0 = unlimited = exact). Emulates ScaNN's recall/latency dial.
    pub max_postings: usize,
}

impl Default for QueryParams {
    fn default() -> Self {
        QueryParams { exclude: None, max_postings: 0 }
    }
}

#[derive(Debug, Clone, Copy)]
struct Posting {
    slot: u32,
    generation: u32,
    weight: f32,
}

#[derive(Debug, Default)]
struct PostingList {
    entries: Vec<Posting>,
    dead: u32,
}

#[derive(Debug)]
struct Slot {
    id: PointId,
    generation: u32,
    alive: bool,
    vec: SparseVec,
}

/// Reusable query scratch space: dense accumulator over slots plus the
/// touched list. Reusing it across queries removes all per-query allocation
/// from the hot path (see EXPERIMENTS.md §Perf).
///
/// Touched-slot membership is tracked with an epoch-tagged `visited` array
/// (`visited[slot] == epoch` ⇔ slot touched by the current query), not by
/// testing `acc[slot] == 0.0`: with signed embedding weights a partial dot
/// sum can cancel back to exactly `0.0` mid-accumulation, and the old
/// zero-test pushed such slots into `touched` twice. Epoch tagging also
/// makes a scratch safely reusable across different index instances (the
/// sharded fan-out pools scratches across shards) — stale accumulator
/// values are lazily reset on first touch of each new query.
#[derive(Default)]
pub struct QueryScratch {
    acc: Vec<f32>,
    visited: Vec<u32>,
    epoch: u32,
    touched: Vec<u32>,
    heap: Vec<(f32, PointId)>,
}

/// Single-shard dynamic sparse ANN index.
pub struct SparseAnn {
    slots: Vec<Slot>,
    free: Vec<u32>,
    id_to_slot: FxHashMap<PointId, u32>,
    postings: FxHashMap<u64, PostingList>,
    live_points: usize,
    live_postings: usize,
    dead_postings: usize,
    /// Compact a posting list when dead entries exceed this fraction.
    compact_threshold: f32,
}

impl Default for SparseAnn {
    fn default() -> Self {
        Self::new()
    }
}

impl SparseAnn {
    pub fn new() -> SparseAnn {
        SparseAnn {
            slots: Vec::new(),
            free: Vec::new(),
            id_to_slot: FxHashMap::default(),
            postings: FxHashMap::default(),
            live_points: 0,
            live_postings: 0,
            dead_postings: 0,
            compact_threshold: 0.5,
        }
    }

    /// Number of live points.
    pub fn len(&self) -> usize {
        self.live_points
    }

    pub fn is_empty(&self) -> bool {
        self.live_points == 0
    }

    /// Whether `id` is currently present.
    pub fn contains(&self, id: PointId) -> bool {
        self.id_to_slot.contains_key(&id)
    }

    /// The stored embedding for `id`, if present.
    pub fn get(&self, id: PointId) -> Option<&SparseVec> {
        self.id_to_slot.get(&id).map(|&s| &self.slots[s as usize].vec)
    }

    /// Insert or update (upsert) a point's embedding. Returns `true` if the
    /// point was already present (update).
    pub fn upsert(&mut self, id: PointId, vec: SparseVec) -> bool {
        let existed = self.remove(id);
        let slot = match self.free.pop() {
            Some(s) => {
                let sl = &mut self.slots[s as usize];
                sl.id = id;
                sl.generation = sl.generation.wrapping_add(1);
                sl.alive = true;
                sl.vec = vec;
                s
            }
            None => {
                let s = self.slots.len() as u32;
                self.slots.push(Slot {
                    id,
                    generation: 0,
                    alive: true,
                    vec,
                });
                s
            }
        };
        let generation = self.slots[slot as usize].generation;
        // The borrow checker: read dims/weights through a clone-free split.
        let nnz = self.slots[slot as usize].vec.nnz();
        for i in 0..nnz {
            let (dim, w) = {
                let v = &self.slots[slot as usize].vec;
                (v.dims()[i], v.weights()[i])
            };
            self.postings.entry(dim).or_default().entries.push(Posting {
                slot,
                generation,
                weight: w,
            });
        }
        self.live_postings += nnz;
        self.id_to_slot.insert(id, slot);
        self.live_points += 1;
        existed
    }

    /// Delete a point. Returns `true` if it was present. O(1): postings
    /// become tombstones invalidated by the generation check and are
    /// reclaimed lazily by per-list compaction.
    pub fn remove(&mut self, id: PointId) -> bool {
        let Some(slot) = self.id_to_slot.remove(&id) else {
            return false;
        };
        let sl = &mut self.slots[slot as usize];
        sl.alive = false;
        let nnz = sl.vec.nnz();
        self.live_points -= 1;
        self.live_postings -= nnz;
        self.dead_postings += nnz;
        // Account the dead entries on their lists so compaction can trigger.
        let dims: Vec<u64> = sl.vec.dims().to_vec();
        for d in dims {
            if let Some(list) = self.postings.get_mut(&d) {
                list.dead += 1;
                if list.dead as f32 > list.entries.len() as f32 * self.compact_threshold {
                    Self::compact_list(&self.slots, list, &mut self.dead_postings);
                    if list.entries.is_empty() {
                        self.postings.remove(&d);
                    }
                }
            }
        }
        self.free.push(slot);
        true
    }

    fn compact_list(slots: &[Slot], list: &mut PostingList, dead_total: &mut usize) {
        let before = list.entries.len();
        list.entries.retain(|p| {
            let sl = &slots[p.slot as usize];
            sl.alive && sl.generation == p.generation
        });
        let removed = before - list.entries.len();
        *dead_total = dead_total.saturating_sub(removed);
        list.dead = 0;
    }

    /// Force-compact every posting list (periodic maintenance).
    pub fn compact_all(&mut self) {
        let slots = std::mem::take(&mut self.slots);
        self.postings.retain(|_, list| {
            Self::compact_list(&slots, list, &mut self.dead_postings);
            !list.entries.is_empty()
        });
        self.slots = slots;
        self.dead_postings = 0;
    }

    /// Score all points sharing ≥ 1 dimension with `query` into the scratch
    /// accumulator; returns number of postings scanned.
    fn accumulate(
        &self,
        query: &SparseVec,
        params: &QueryParams,
        scratch: &mut QueryScratch,
    ) -> usize {
        if scratch.acc.len() < self.slots.len() {
            scratch.acc.resize(self.slots.len(), 0.0);
            scratch.visited.resize(self.slots.len(), 0);
        }
        scratch.epoch = scratch.epoch.wrapping_add(1);
        if scratch.epoch == 0 {
            // Epoch counter wrapped: stale tags could alias the new epoch.
            scratch.visited.fill(0);
            scratch.epoch = 1;
        }
        let epoch = scratch.epoch;
        scratch.touched.clear();
        let mut scanned = 0usize;
        'outer: for (dim, qw) in query.iter() {
            let Some(list) = self.postings.get(&dim) else {
                continue;
            };
            for p in &list.entries {
                let sl = &self.slots[p.slot as usize];
                if !sl.alive || sl.generation != p.generation {
                    continue;
                }
                scanned += 1;
                let s = p.slot as usize;
                if scratch.visited[s] != epoch {
                    scratch.visited[s] = epoch;
                    scratch.acc[s] = 0.0;
                    scratch.touched.push(p.slot);
                }
                scratch.acc[s] += qw * p.weight;
                if params.max_postings != 0 && scanned >= params.max_postings {
                    break 'outer;
                }
            }
        }
        scanned
    }

    /// Top-k nearest (highest dot / lowest dist). Deterministic: ties in dot
    /// are broken by ascending id. Only points with `dot > 0` are returned —
    /// with the strictly-positive embeddings of §4.1 these are exactly the
    /// points sharing ≥ 1 bucket (Lemma 4.1); everything else is at the
    /// maximal distance 0 and is not a neighbor.
    pub fn top_k(
        &self,
        query: &SparseVec,
        k: usize,
        params: QueryParams,
        scratch: &mut QueryScratch,
    ) -> Vec<Neighbor> {
        if k == 0 || self.live_points == 0 {
            return Vec::new();
        }
        self.accumulate(query, &params, scratch);
        // Select top-k by (dot desc, id asc) with a bounded min-heap
        // materialized as a sorted insertion buffer (k is small: 10–1000).
        let heap = &mut scratch.heap;
        heap.clear();
        for &slot in &scratch.touched {
            let dot = scratch.acc[slot as usize];
            if dot <= 0.0 {
                continue;
            }
            let id = self.slots[slot as usize].id;
            if params.exclude == Some(id) {
                continue;
            }
            if heap.len() < k {
                heap.push((dot, id));
                if heap.len() == k {
                    // Build min-heap ordering lazily: sort once full.
                    heap.sort_unstable_by(cmp_heap);
                }
            } else {
                // heap[0] is the current worst (smallest dot, largest id).
                if cmp_candidate(dot, id, heap[0]) {
                    heap[0] = (dot, id);
                    sift_down(heap);
                }
            }
        }
        if heap.len() < k {
            heap.sort_unstable_by(cmp_heap);
        }
        let mut out: Vec<Neighbor> =
            heap.iter().map(|&(dot, id)| Neighbor { id, dot }).collect();
        out.sort_unstable_by(|a, b| {
            b.dot
                .partial_cmp(&a.dot)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        out
    }

    /// All points with `Dist ≤ tau` i.e. `dot ≥ -tau`. With `tau` slightly
    /// below 0 this is the paper's "all points with negative distance"
    /// (Lemma 4.1). Results sorted by (dot desc, id asc).
    pub fn threshold(
        &self,
        query: &SparseVec,
        tau: f32,
        params: QueryParams,
        scratch: &mut QueryScratch,
    ) -> Vec<Neighbor> {
        self.accumulate(query, &params, scratch);
        let min_dot = -tau;
        let mut out = Vec::new();
        for &slot in &scratch.touched {
            let dot = scratch.acc[slot as usize];
            // `dot > 0` is implied for touched slots with positive weights,
            // but embeddings may in principle carry any weights: check.
            if dot >= min_dot && dot != 0.0 {
                let id = self.slots[slot as usize].id;
                if params.exclude != Some(id) {
                    out.push(Neighbor { id, dot });
                }
            }
        }
        out.sort_unstable_by(|a, b| b.dot.partial_cmp(&a.dot).unwrap().then(a.id.cmp(&b.id)));
        out
    }

    /// Index statistics (Fig. 10 memory accounting + ops).
    pub fn stats(&self) -> IndexStats {
        IndexStats {
            live_points: self.live_points,
            live_postings: self.live_postings,
            dead_postings: self.dead_postings,
            distinct_dims: self.postings.len(),
            slot_capacity: self.slots.len(),
            approx_bytes: self.approx_bytes(),
        }
    }

    fn approx_bytes(&self) -> usize {
        let posting_bytes: usize = self
            .postings
            .values()
            .map(|l| l.entries.capacity() * std::mem::size_of::<Posting>() + 48)
            .sum();
        let slot_bytes: usize = self
            .slots
            .iter()
            .map(|s| s.vec.heap_bytes() + std::mem::size_of::<Slot>())
            .sum();
        posting_bytes + slot_bytes + self.id_to_slot.len() * 24
    }

    /// Iterate live `(id, embedding)` pairs (offline experiments).
    pub fn iter_live(&self) -> impl Iterator<Item = (PointId, &SparseVec)> + '_ {
        self.slots
            .iter()
            .filter(|s| s.alive)
            .map(|s| (s.id, &s.vec))
    }
}

/// Heap ordering: worst candidate first = (dot asc, id desc).
#[inline]
fn cmp_heap(a: &(f32, PointId), b: &(f32, PointId)) -> std::cmp::Ordering {
    a.0.partial_cmp(&b.0).unwrap().then(b.1.cmp(&a.1))
}

/// Does candidate (dot, id) beat the heap's worst `w`?
#[inline]
fn cmp_candidate(dot: f32, id: PointId, w: (f32, PointId)) -> bool {
    dot > w.0 || (dot == w.0 && id < w.1)
}

fn sift_down(heap: &mut [(f32, PointId)]) {
    let n = heap.len();
    let mut i = 0;
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut worst = i;
        if l < n && cmp_heap(&heap[l], &heap[worst]).is_lt() {
            worst = l;
        }
        if r < n && cmp_heap(&heap[r], &heap[worst]).is_lt() {
            worst = r;
        }
        if worst == i {
            break;
        }
        heap.swap(i, worst);
        i = worst;
    }
}

/// Snapshot of index size/health.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexStats {
    pub live_points: usize,
    pub live_postings: usize,
    pub dead_postings: usize,
    pub distinct_dims: usize,
    pub slot_capacity: usize,
    pub approx_bytes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::proptest;
    use crate::util::rng::Rng;

    fn sv(pairs: &[(u64, f32)]) -> SparseVec {
        SparseVec::from_pairs(pairs.to_vec())
    }

    fn topk(ix: &SparseAnn, q: &SparseVec, k: usize) -> Vec<Neighbor> {
        ix.top_k(q, k, QueryParams::default(), &mut QueryScratch::default())
    }

    #[test]
    fn insert_query_basic() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(10, 1.0), (20, 1.0)]));
        ix.upsert(2, sv(&[(20, 1.0), (30, 1.0)]));
        ix.upsert(3, sv(&[(40, 1.0)]));
        let r = topk(&ix, &sv(&[(10, 1.0), (20, 1.0)]), 10);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].id, 1);
        assert_eq!(r[0].dot, 2.0);
        assert_eq!(r[1].id, 2);
        assert_eq!(r[1].dot, 1.0);
        assert_eq!(r[0].dist(), -2.0);
    }

    #[test]
    fn no_shared_dim_not_returned() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(10, 1.0)]));
        let r = topk(&ix, &sv(&[(99, 1.0)]), 10);
        assert!(r.is_empty());
    }

    #[test]
    fn k_limits_results() {
        let mut ix = SparseAnn::new();
        for i in 0..100u64 {
            ix.upsert(i, sv(&[(7, 1.0 + i as f32)]));
        }
        let r = topk(&ix, &sv(&[(7, 1.0)]), 5);
        assert_eq!(r.len(), 5);
        // Highest weights win.
        assert_eq!(r[0].id, 99);
        assert_eq!(r[4].id, 95);
    }

    #[test]
    fn exclude_self() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        ix.upsert(2, sv(&[(5, 1.0)]));
        let r = ix.top_k(
            &sv(&[(5, 1.0)]),
            10,
            QueryParams { exclude: Some(1), max_postings: 0 },
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 2);
    }

    #[test]
    fn delete_removes_from_results() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        ix.upsert(2, sv(&[(5, 2.0)]));
        assert!(ix.remove(2));
        assert!(!ix.remove(2));
        let r = topk(&ix, &sv(&[(5, 1.0)]), 10);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 1);
        assert_eq!(ix.len(), 1);
        assert!(!ix.contains(2));
    }

    #[test]
    fn update_replaces_embedding() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        let existed = ix.upsert(1, sv(&[(9, 1.0)]));
        assert!(existed);
        assert_eq!(ix.len(), 1);
        assert!(topk(&ix, &sv(&[(5, 1.0)]), 10).is_empty());
        let r = topk(&ix, &sv(&[(9, 1.0)]), 10);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn slot_reuse_does_not_resurrect() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        ix.remove(1);
        // Slot of point 1 is reused by point 2 with a different dim.
        ix.upsert(2, sv(&[(6, 1.0)]));
        // A stale posting for dim 5 must not surface point 2.
        let r = topk(&ix, &sv(&[(5, 1.0)]), 10);
        assert!(r.is_empty(), "stale posting resurrected: {r:?}");
    }

    #[test]
    fn threshold_query_negative_distance() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0), (6, 1.0)]));
        ix.upsert(2, sv(&[(6, 1.0)]));
        ix.upsert(3, sv(&[(7, 1.0)]));
        // All with Dist < 0 ⇔ dot > 0: tau just below zero.
        let r = ix.threshold(
            &sv(&[(5, 1.0), (6, 1.0)]),
            -f32::MIN_POSITIVE,
            QueryParams::default(),
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].id, 1);
    }

    #[test]
    fn threshold_tau_cuts() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 3.0)]));
        ix.upsert(2, sv(&[(5, 1.0)]));
        // dot(q,1)=3, dot(q,2)=1. Dist: -3 and -1. tau=-2 keeps only dist≤-2.
        let r = ix.threshold(
            &sv(&[(5, 1.0)]),
            -2.0,
            QueryParams::default(),
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 1);
    }

    #[test]
    fn compaction_reclaims() {
        let mut ix = SparseAnn::new();
        for i in 0..100u64 {
            ix.upsert(i, sv(&[(7, 1.0)]));
        }
        for i in 0..90u64 {
            ix.remove(i);
        }
        // Per-list compaction should have fired (dead > 50%).
        let st = ix.stats();
        assert_eq!(st.live_points, 10);
        assert!(
            st.dead_postings < 60,
            "compaction did not run: {st:?}"
        );
        ix.compact_all();
        assert_eq!(ix.stats().dead_postings, 0);
        let r = topk(&ix, &sv(&[(7, 1.0)]), 100);
        assert_eq!(r.len(), 10);
    }

    #[test]
    fn stats_track_sizes() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(1, 1.0), (2, 1.0)]));
        ix.upsert(2, sv(&[(2, 1.0)]));
        let st = ix.stats();
        assert_eq!(st.live_points, 2);
        assert_eq!(st.live_postings, 3);
        assert_eq!(st.distinct_dims, 2);
        assert!(st.approx_bytes > 0);
    }

    #[test]
    fn max_postings_budget_approximates() {
        let mut ix = SparseAnn::new();
        for i in 0..50u64 {
            ix.upsert(i, sv(&[(7, 1.0)]));
        }
        let r = ix.top_k(
            &sv(&[(7, 1.0)]),
            50,
            QueryParams { exclude: None, max_postings: 10 },
            &mut QueryScratch::default(),
        );
        assert_eq!(r.len(), 10, "budget should cap scanning");
    }

    #[test]
    fn deterministic_tie_break_by_id() {
        let mut ix = SparseAnn::new();
        for &id in &[42u64, 7, 19, 3, 88] {
            ix.upsert(id, sv(&[(5, 1.0)]));
        }
        let r = topk(&ix, &sv(&[(5, 1.0)]), 3);
        let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![3, 7, 19]);
    }

    #[test]
    fn empty_query_returns_nothing() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0)]));
        assert!(topk(&ix, &SparseVec::empty(), 10).is_empty());
        assert!(topk(&ix, &sv(&[(5, 1.0)]), 0).is_empty());
    }

    /// Regression for the `accumulate` touched-list bug: with signed
    /// weights, point 1's partial sum goes 1.0 → 0.0 → 2.0 over the query's
    /// (sorted) dims, so the old `acc == 0.0` membership test pushed its
    /// slot into `touched` twice. Epoch tagging must yield each id exactly
    /// once, with the fully-accumulated dot.
    #[test]
    fn threshold_no_duplicates_with_signed_weights() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0), (6, -1.0), (7, 2.0)]));
        ix.upsert(2, sv(&[(7, 1.0)]));
        let q = sv(&[(5, 1.0), (6, 1.0), (7, 1.0)]);
        let r = ix.threshold(&q, 10.0, QueryParams::default(), &mut QueryScratch::default());
        let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![1, 2], "duplicate or wrong ids: {r:?}");
        assert_eq!(r[0].dot, 2.0);
        assert_eq!(r[1].dot, 1.0);

        let r = ix.top_k(&q, 10, QueryParams::default(), &mut QueryScratch::default());
        let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![1, 2], "top_k duplicated: {r:?}");
    }

    /// A slot whose dot cancels to exactly 0.0 overall is not a neighbor,
    /// and a reused scratch must not leak state between queries.
    #[test]
    fn signed_weights_cancel_to_zero_excluded() {
        let mut ix = SparseAnn::new();
        ix.upsert(1, sv(&[(5, 1.0), (6, -1.0)]));
        ix.upsert(2, sv(&[(5, 0.5)]));
        let mut scratch = QueryScratch::default();
        let q = sv(&[(5, 1.0), (6, 1.0)]);
        for _ in 0..3 {
            // dot(q, 1) = 0.0 exactly → excluded; dot(q, 2) = 0.5.
            let r = ix.threshold(&q, 10.0, QueryParams::default(), &mut scratch);
            let ids: Vec<u64> = r.iter().map(|n| n.id).collect();
            assert_eq!(ids, vec![2], "{r:?}");
            let r = ix.top_k(&q, 10, QueryParams::default(), &mut scratch);
            assert_eq!(r.len(), 1);
            assert_eq!(r[0].id, 2);
        }
    }

    /// Property with signed weights: threshold never returns duplicate ids
    /// and always matches the brute-force oracle.
    #[test]
    fn prop_signed_weights_no_duplicates() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live: std::collections::BTreeMap<u64, SparseVec> = Default::default();
            for _ in 0..40 {
                let id = rng.below(20);
                let n = 1 + rng.below_usize(6);
                // Half-integral signed weights make exact mid-accumulation
                // cancellation likely.
                let v = SparseVec::from_pairs(
                    (0..n)
                        .map(|_| (rng.below(12), (rng.below(9) as f32 - 4.0) * 0.5))
                        .collect(),
                );
                ix.upsert(id, v.clone());
                live.insert(id, v);
            }
            let q = SparseVec::from_pairs(
                (0..3).map(|_| (rng.below(12), (rng.below(9) as f32 - 4.0) * 0.5)).collect(),
            );
            let tau = 2.0 - rng.f32() * 6.0;
            let got = ix.threshold(&q, tau, QueryParams::default(), &mut QueryScratch::default());
            let got_ids: Vec<u64> = got.iter().map(|n| n.id).collect();
            let mut dedup = got_ids.clone();
            dedup.dedup();
            assert_eq!(got_ids, dedup, "duplicate neighbors: {got:?}");
            let want_ids: std::collections::BTreeSet<u64> = live
                .iter()
                .filter(|(_, v)| {
                    let d = q.dot(v);
                    -d <= tau && d != 0.0
                })
                .map(|(&id, _)| id)
                .collect();
            let got_set: std::collections::BTreeSet<u64> = got_ids.iter().copied().collect();
            assert_eq!(got_set, want_ids);
        });
    }

    /// Property: top-k always matches a brute-force scan over live points.
    #[test]
    fn prop_topk_matches_bruteforce() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live: std::collections::BTreeMap<u64, SparseVec> = Default::default();
            let n_ops = 60 + rng.below_usize(60);
            for _ in 0..n_ops {
                let id = rng.below(30);
                match rng.below(10) {
                    0..=6 => {
                        let v = random_vec(rng);
                        ix.upsert(id, v.clone());
                        live.insert(id, v);
                    }
                    _ => {
                        ix.remove(id);
                        live.remove(&id);
                    }
                }
            }
            assert_eq!(ix.len(), live.len());
            let q = random_vec(rng);
            let k = 1 + rng.below_usize(8);
            let got = ix.top_k(&q, k, QueryParams::default(), &mut QueryScratch::default());
            // Brute force oracle.
            let mut want: Vec<Neighbor> = live
                .iter()
                .map(|(&id, v)| Neighbor { id, dot: q.dot(v) })
                .filter(|n| n.dot > 0.0)
                .collect();
            want.sort_by(|a, b| b.dot.partial_cmp(&a.dot).unwrap().then(a.id.cmp(&b.id)));
            want.truncate(k);
            assert_eq!(got.len(), want.len(), "count mismatch");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.id, w.id, "got {got:?} want {want:?}");
                assert!((g.dot - w.dot).abs() < 1e-4);
            }
        });
    }

    /// Property: threshold query equals brute-force filter.
    #[test]
    fn prop_threshold_matches_bruteforce() {
        proptest(|rng| {
            let mut ix = SparseAnn::new();
            let mut live: std::collections::BTreeMap<u64, SparseVec> = Default::default();
            for _ in 0..40 {
                let id = rng.below(25);
                let v = random_vec(rng);
                ix.upsert(id, v.clone());
                live.insert(id, v);
            }
            let q = random_vec(rng);
            let tau = -0.5 - rng.f32() * 2.0; // Dist ≤ tau < 0
            let got = ix.threshold(&q, tau, QueryParams::default(), &mut QueryScratch::default());
            let want: Vec<u64> = live
                .iter()
                .filter(|(_, v)| -q.dot(v) <= tau && q.dot(v) != 0.0)
                .map(|(&id, _)| id)
                .collect();
            let got_ids: std::collections::BTreeSet<u64> =
                got.iter().map(|n| n.id).collect();
            let want_ids: std::collections::BTreeSet<u64> = want.into_iter().collect();
            assert_eq!(got_ids, want_ids);
        });
    }

    fn random_vec(rng: &mut Rng) -> SparseVec {
        let n = 1 + rng.below_usize(8);
        SparseVec::from_pairs(
            (0..n).map(|_| (rng.below(20), 0.1 + rng.f32())).collect(),
        )
    }
}
