//! The clock-free pressure controller.
//!
//! Pressure is computed from two signals the server already has:
//!
//! - **Sojourn time**: how long the job a worker just popped sat in the
//!   run queue (the controlled-delay idea: queue *delay*, not queue
//!   *length*, is what clients feel). Smoothed with an EWMA.
//! - **Instantaneous queue depth** relative to capacity, sampled at
//!   admission time so pressure reacts within one request even between
//!   pops.
//!
//! `pressure = max(sojourn_ewma / target, 2 × depth / capacity)` — a
//! dimensionless overload factor where 1.0 means "the queue delay has
//! reached its target" (or the queue is half full). Tiers:
//!
//! | tier       | pressure   | action                                        |
//! |------------|------------|-----------------------------------------------|
//! | `Normal`   | `< 0.5`    | admit everything at full budget               |
//! | `ShedBatch`| `0.5 – 1`  | shed `batch`                                  |
//! | `Degrade`  | `1 – 2`    | shed `batch`+`replication`; scale interactive |
//! |            |            | budget by `1/pressure`, mark `degraded`       |
//! | `Critical` | `≥ 2`      | also skip scoring refinement; shed even       |
//! |            |            | interactive once `1/pressure` falls below the |
//! |            |            | configured quality floor                      |
//!
//! Recovery is built into [`Controller::decide`]: sojourn samples
//! normally arrive only when a worker pops a job, so a controller that
//! sheds *everything* (classed-only traffic after a severe surge) would
//! otherwise never see another sample and stay latched above target
//! forever. A shed decided against an empty queue therefore folds in a
//! zero sojourn sample — the empty queue is the observation — so
//! sustained shedding itself decays pressure back below the tiers.
//!
//! The struct is pure: no `Instant`, no `SystemTime`, no hash-order
//! iteration (the `replay-determinism` lint enforces this). Callers
//! measure time and feed samples; the controller only does arithmetic,
//! so a recorded `(sojourn, depth, capacity)` stream replays the same
//! decisions bit-for-bit.

/// Request priority class carried in the v1 envelope (`class` key).
/// Ordering is shedding priority: `Batch` sheds first, `Interactive`
/// last. Requests without a class are treated as `Interactive` for
/// shedding (legacy clients keep working) but are never served degraded
/// — degradation is opt-in by classing the request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    /// Latency-sensitive foreground traffic; shed last, degraded first.
    Interactive,
    /// Replication/maintenance traffic; middle priority.
    Replication,
    /// Bulk/background traffic; shed first, never degraded (a batch
    /// caller wants full-quality answers or none).
    Batch,
}

impl Class {
    pub fn parse(s: &str) -> Option<Class> {
        match s {
            "interactive" => Some(Class::Interactive),
            "replication" => Some(Class::Replication),
            "batch" => Some(Class::Batch),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Class::Interactive => "interactive",
            Class::Replication => "replication",
            Class::Batch => "batch",
        }
    }

    /// Stable index for per-class accounting arrays.
    pub fn index(self) -> usize {
        match self {
            Class::Interactive => 0,
            Class::Replication => 1,
            Class::Batch => 2,
        }
    }

    /// All classes, ordered by [`Class::index`].
    pub const ALL: [Class; 3] = [Class::Interactive, Class::Replication, Class::Batch];
}

/// Controller knobs (from [`crate::config::GusConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Run-queue sojourn target in milliseconds; pressure 1.0 when the
    /// sojourn EWMA reaches it. 0 disables admission control entirely
    /// (the queue-full backstop still sheds).
    pub target_sojourn_ms: u64,
    /// Quality floor: the smallest budget fraction worth serving. When
    /// the degraded fraction `1/pressure` falls below this, interactive
    /// requests are shed instead of answered badly.
    pub min_budget_frac: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { target_sojourn_ms: 50, min_budget_frac: 0.25 }
    }
}

/// Pressure tier (see the module table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Normal,
    ShedBatch,
    Degrade,
    Critical,
}

impl Tier {
    pub fn as_str(self) -> &'static str {
        match self {
            Tier::Normal => "normal",
            Tier::ShedBatch => "shed_batch",
            Tier::Degrade => "degrade",
            Tier::Critical => "critical",
        }
    }
}

/// What to do with one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Execute. `budget_frac < 1.0` means the query runs with a scaled
    /// posting budget and must be marked degraded; `skip_refine` means
    /// the scoring-refinement phase is skipped too (critical tier).
    Admit { budget_frac: f64, skip_refine: bool },
    /// Refuse with `OVERLOADED`; the client should wait `retry_after_ms`
    /// before retrying.
    Shed { retry_after_ms: u64 },
}

impl Decision {
    pub fn is_shed(&self) -> bool {
        matches!(self, Decision::Shed { .. })
    }
}

/// EWMA weight for sojourn samples: new = old + ALPHA * (sample - old).
/// 0.2 averages over roughly the last ten pops — fast enough to track a
/// surge front, smooth enough that one slow job doesn't flip tiers.
const ALPHA: f64 = 0.2;

/// The pressure controller. One per server; the server samples sojourn
/// at every queue pop and consults [`Controller::decide`] at every
/// admission.
#[derive(Debug)]
pub struct Controller {
    cfg: AdmissionConfig,
    sojourn_ewma_ms: f64,
    /// Sojourn samples observed (diagnostics; also lets the first sample
    /// seed the EWMA exactly instead of decaying up from zero).
    samples: u64,
    /// Depth/capacity from the most recent decide() (diagnostics only).
    last_depth_frac: f64,
}

impl Controller {
    pub fn new(cfg: AdmissionConfig) -> Controller {
        Controller { cfg, sojourn_ewma_ms: 0.0, samples: 0, last_depth_frac: 0.0 }
    }

    pub fn config(&self) -> AdmissionConfig {
        self.cfg
    }

    /// Feed one sojourn sample: the job a worker just popped waited
    /// `sojourn_ms` in the run queue.
    pub fn observe_sojourn(&mut self, sojourn_ms: u64) {
        let s = sojourn_ms as f64;
        if self.samples == 0 {
            self.sojourn_ewma_ms = s;
        } else {
            self.sojourn_ewma_ms += ALPHA * (s - self.sojourn_ewma_ms);
        }
        self.samples = self.samples.saturating_add(1);
    }

    /// The smoothed queue delay.
    pub fn sojourn_ewma_ms(&self) -> f64 {
        self.sojourn_ewma_ms
    }

    /// Dimensionless overload factor for the given instantaneous queue
    /// state; 1.0 = at target. Disabled (target 0) always reports 0.
    pub fn pressure(&self, depth: usize, capacity: usize) -> f64 {
        if self.cfg.target_sojourn_ms == 0 {
            return 0.0;
        }
        let sojourn = self.sojourn_ewma_ms / self.cfg.target_sojourn_ms as f64;
        // A queue half full counts as pressure 1.0: depth leads sojourn
        // (jobs at the back haven't been popped yet), so reacting at
        // half-full is what keeps the sojourn target from ever being
        // blown through by a fast ramp.
        let depth = 2.0 * depth as f64 / capacity.max(1) as f64;
        sojourn.max(depth)
    }

    /// Tier for a given pressure (see the module table).
    pub fn tier_at(pressure: f64) -> Tier {
        if pressure < 0.5 {
            Tier::Normal
        } else if pressure < 1.0 {
            Tier::ShedBatch
        } else if pressure < 2.0 {
            Tier::Degrade
        } else {
            Tier::Critical
        }
    }

    /// Current tier for the given queue state.
    pub fn tier(&self, depth: usize, capacity: usize) -> Tier {
        Self::tier_at(self.pressure(depth, capacity))
    }

    /// Admission decision for one request. `class` is the envelope's
    /// class (None = unclassed/legacy). Pure: same input stream, same
    /// answers.
    pub fn decide(&mut self, class: Option<Class>, depth: usize, capacity: usize) -> Decision {
        let p = self.pressure(depth, capacity);
        self.last_depth_frac = depth as f64 / capacity.max(1) as f64;
        let tier = Self::tier_at(p);
        let admit_full = Decision::Admit { budget_frac: 1.0, skip_refine: false };
        let decision = match tier {
            Tier::Normal => admit_full,
            Tier::ShedBatch => match class {
                Some(Class::Batch) => self.shed(p),
                _ => admit_full,
            },
            Tier::Degrade | Tier::Critical => match class {
                Some(Class::Batch) | Some(Class::Replication) => self.shed(p),
                // Unclassed requests keep today's semantics: admitted at
                // full budget, never marked degraded. The queue-full
                // backstop is their only shed path.
                None => admit_full,
                Some(Class::Interactive) => {
                    // Serve in inverse proportion to overload: at 2× the
                    // target delay, half the budget. Below the quality
                    // floor the answer would be noise — shed instead.
                    let frac = (1.0 / p).clamp(0.0, 1.0);
                    if frac < self.cfg.min_budget_frac {
                        self.shed(p)
                    } else {
                        Decision::Admit {
                            budget_frac: frac,
                            skip_refine: tier == Tier::Critical,
                        }
                    }
                }
            },
        };
        // Admitted jobs report their real sojourn when a worker pops
        // them; a shed job reports nothing. If every arriving request is
        // classed and shed, no pops ever happen, the queue stays empty,
        // and the EWMA would freeze above target forever — pressure
        // latched by its own response. An empty queue at shed time is
        // itself a sojourn observation ("a job admitted now would wait
        // ~0ms"), so fold in a zero sample: each shed decays the EWMA by
        // `ALPHA` until interactive traffic clears the floor again.
        // Still clock-free and a pure function of the input stream.
        if decision.is_shed() && depth == 0 {
            self.observe_sojourn(0);
        }
        decision
    }

    /// Deterministic retry hint: proportional to how far over target the
    /// queue delay is — the time it plausibly takes the backlog to drain
    /// — clamped to a sane band.
    fn shed(&self, pressure: f64) -> Decision {
        let target = self.cfg.target_sojourn_ms as f64;
        let ms = (target * pressure).clamp(10.0, 5_000.0) as u64;
        Decision::Shed { retry_after_ms: ms }
    }

    /// Snapshot for the `stats` RPC's `"admission"` section.
    pub fn snapshot(&self, depth: usize, capacity: usize) -> ControllerSnapshot {
        let pressure = self.pressure(depth, capacity);
        ControllerSnapshot {
            tier: Self::tier_at(pressure),
            pressure,
            sojourn_ewma_ms: self.sojourn_ewma_ms,
            samples: self.samples,
        }
    }
}

/// Point-in-time controller state (for stats/diagnostics).
#[derive(Debug, Clone, Copy)]
pub struct ControllerSnapshot {
    pub tier: Tier,
    pub pressure: f64,
    pub sojourn_ewma_ms: f64,
    pub samples: u64,
}

impl ControllerSnapshot {
    /// The `"admission"` section of the `stats` RPC.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("tier", Json::str(self.tier.as_str())),
            ("pressure", Json::num(self.pressure)),
            ("sojourn_ewma_ms", Json::num(self.sojourn_ewma_ms)),
            ("samples", Json::u64(self.samples)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(target_ms: u64, floor: f64) -> Controller {
        Controller::new(AdmissionConfig { target_sojourn_ms: target_ms, min_budget_frac: floor })
    }

    fn saturate(c: &mut Controller, sojourn_ms: u64, n: usize) {
        for _ in 0..n {
            c.observe_sojourn(sojourn_ms);
        }
    }

    #[test]
    fn idle_admits_everything_at_full_budget() {
        let mut c = ctl(50, 0.25);
        for class in [None, Some(Class::Interactive), Some(Class::Batch), Some(Class::Replication)]
        {
            assert_eq!(
                c.decide(class, 0, 256),
                Decision::Admit { budget_frac: 1.0, skip_refine: false }
            );
        }
        assert_eq!(c.tier(0, 256), Tier::Normal);
    }

    #[test]
    fn batch_sheds_before_replication_before_interactive() {
        let mut c = ctl(50, 0.25);
        // Sojourn at 60% of target → ShedBatch tier.
        saturate(&mut c, 30, 64);
        assert_eq!(c.tier(0, 256), Tier::ShedBatch);
        assert!(c.decide(Some(Class::Batch), 0, 256).is_shed());
        assert!(!c.decide(Some(Class::Replication), 0, 256).is_shed());
        assert!(!c.decide(Some(Class::Interactive), 0, 256).is_shed());
        assert!(!c.decide(None, 0, 256).is_shed());
        // Sojourn past target → Degrade: replication sheds too.
        saturate(&mut c, 75, 64);
        assert_eq!(c.tier(0, 256), Tier::Degrade);
        assert!(c.decide(Some(Class::Batch), 0, 256).is_shed());
        assert!(c.decide(Some(Class::Replication), 0, 256).is_shed());
        assert!(!c.decide(Some(Class::Interactive), 0, 256).is_shed());
    }

    #[test]
    fn interactive_degrades_monotonically_then_sheds_at_floor() {
        let mut c = ctl(50, 0.25);
        let mut prev_frac = 1.0;
        // Walk the sojourn EWMA up; the admitted fraction must never rise.
        for sojourn in [60, 80, 100, 140, 190] {
            saturate(&mut c, sojourn, 64);
            match c.decide(Some(Class::Interactive), 0, 256) {
                Decision::Admit { budget_frac, .. } => {
                    assert!(
                        budget_frac <= prev_frac + 1e-9,
                        "budget fraction rose under growing pressure: \
                         {budget_frac} > {prev_frac}"
                    );
                    assert!(budget_frac >= 0.25);
                    prev_frac = budget_frac;
                }
                Decision::Shed { .. } => panic!("interactive shed above the floor"),
            }
        }
        // Pressure past 1/floor = 4× → interactive sheds too.
        saturate(&mut c, 250, 64);
        let d = c.decide(Some(Class::Interactive), 0, 256);
        assert!(d.is_shed(), "interactive must shed below the quality floor: {d:?}");
    }

    #[test]
    fn critical_tier_skips_refinement() {
        let mut c = ctl(50, 0.25);
        saturate(&mut c, 150, 64); // pressure 3.0 → Critical, frac 1/3 ≥ floor
        assert_eq!(c.tier(0, 256), Tier::Critical);
        match c.decide(Some(Class::Interactive), 0, 256) {
            Decision::Admit { budget_frac, skip_refine } => {
                assert!(skip_refine, "critical tier must skip refinement");
                assert!((budget_frac - 1.0 / 3.0).abs() < 0.05, "frac {budget_frac}");
            }
            d => panic!("expected degraded admit, got {d:?}"),
        }
    }

    #[test]
    fn depth_alone_raises_pressure_between_pops() {
        let mut c = ctl(50, 0.25);
        // No sojourn samples at all, but the queue is 80% full: depth
        // pressure = 1.6 → Degrade tier immediately.
        assert_eq!(c.tier(205, 256), Tier::Degrade);
        assert!(c.decide(Some(Class::Batch), 205, 256).is_shed());
        // Empty queue, still no samples → Normal.
        assert_eq!(c.tier(0, 256), Tier::Normal);
    }

    #[test]
    fn retry_hint_scales_with_pressure_and_is_clamped() {
        let mut c = ctl(50, 0.25);
        saturate(&mut c, 40, 64); // pressure 0.8
        let Decision::Shed { retry_after_ms: low } = c.decide(Some(Class::Batch), 0, 256) else {
            panic!("batch not shed at 0.8");
        };
        saturate(&mut c, 400, 64); // pressure 8.0
        let Decision::Shed { retry_after_ms: high } = c.decide(Some(Class::Batch), 0, 256) else {
            panic!("batch not shed at 8.0");
        };
        assert!(high > low, "hint did not grow with pressure: {low} → {high}");
        assert!((10..=5_000).contains(&low) && (10..=5_000).contains(&high));
    }

    #[test]
    fn pressure_unlatches_under_classed_only_shedding() {
        // After a severe surge the EWMA sits far above target. With only
        // classed traffic arriving, every request is shed at admission —
        // no job is ever popped, so no real sojourn samples can drain
        // the EWMA. Each shed against the (empty) queue must decay
        // pressure itself, or the controller sheds 100% forever.
        let mut c = ctl(50, 0.25);
        saturate(&mut c, 400, 64); // pressure 8.0 — deep into Critical
        assert!(c.decide(Some(Class::Interactive), 0, 256).is_shed());
        let mut sheds = 0;
        for _ in 0..200 {
            if c.decide(Some(Class::Interactive), 0, 256).is_shed() {
                sheds += 1;
            } else {
                break;
            }
        }
        assert!(
            sheds < 200,
            "pressure latched: 200 consecutive interactive sheds with an empty queue"
        );
        // And recovery is monotone from here: once interactive is
        // admitted again it stays admitted while the queue is empty.
        assert!(!c.decide(Some(Class::Interactive), 0, 256).is_shed());
        assert!(!c.decide(None, 0, 256).is_shed());
    }

    #[test]
    fn disabled_controller_never_sheds() {
        let mut c = ctl(0, 0.25);
        saturate(&mut c, 10_000, 64);
        assert_eq!(c.pressure(256, 256), 0.0);
        assert!(!c.decide(Some(Class::Batch), 256, 256).is_shed());
    }

    #[test]
    fn replayed_sample_stream_reproduces_decisions() {
        // Determinism contract: same samples, same decisions — this is
        // what lets the replay-determinism lint cover the module.
        let run = || {
            let mut c = ctl(50, 0.25);
            let mut out = Vec::new();
            for i in 0..200u64 {
                c.observe_sojourn((i * 7) % 190);
                out.push(c.decide(
                    Some(Class::ALL[(i % 3) as usize]),
                    (i as usize * 13) % 256,
                    256,
                ));
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn class_parses_and_round_trips() {
        for c in Class::ALL {
            assert_eq!(Class::parse(c.as_str()), Some(c));
        }
        assert_eq!(Class::parse("bulk"), None);
        assert_eq!(Class::Interactive.index(), 0);
        assert_eq!(Class::Replication.index(), 1);
        assert_eq!(Class::Batch.index(), 2);
    }
}
