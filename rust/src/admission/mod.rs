//! Adaptive admission control: graceful degradation under overload.
//!
//! The server's original overload response was binary — a bounded run
//! queue that sheds with `OVERLOADED` only once completely full. That
//! keeps the process alive but serves the worst possible latency right up
//! to the cliff: every admitted request first waits behind a full queue.
//! This module replaces the cliff with a gradient:
//!
//! 1. **Priority-aware shedding.** v1 envelopes may carry a `class`
//!    (`interactive | batch | replication`). As pressure rises the
//!    controller sheds lowest-class-first (batch, then replication) with
//!    `OVERLOADED` plus a `retry_after_ms` hint, well before the queue is
//!    full — so admitted interactive requests never queue behind bulk
//!    work.
//! 2. **Degraded-budget serving.** Past the degrade tier, interactive
//!    queries run with a scaled `max_postings` budget (recall traded for
//!    latency — the paper's approximation dial, turned dynamically) and,
//!    at the critical tier, without scoring refinement. Degraded
//!    responses are marked `degraded: true` with the applied fraction;
//!    below the configured quality floor the request is shed instead.
//!
//! The controller itself ([`controller::Controller`]) is a pure function
//! of the samples fed to it — no clocks, no nondeterministic iteration —
//! so gus-lint's `replay-determinism` rule covers it and a recorded
//! sample stream replays bit-for-bit. Callers (the server) measure
//! sojourn time with their own clock and feed milliseconds in.
//!
//! Pressure tiers, the degradation contract, and the client-visible
//! protocol are documented in `docs/ADMISSION.md`.

pub mod controller;

pub use controller::{AdmissionConfig, Class, Controller, Decision, Tier};
