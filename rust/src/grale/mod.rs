//! Offline Grale baseline (§4; Halcrow et al., KDD'20).
//!
//! Grale's three steps: (1) a trained pairwise model, (2) *scoring pairs*
//! found by LSH bucketing, (3) scoring every pair. This module reproduces
//! the graph-building part faithfully enough to serve as the paper's
//! comparison baseline:
//!
//! - bucket table: bucket id → member points,
//! - optional **bucket splitting** (`Bucket-S`): any bucket larger than `m`
//!   is randomly subdivided into sub-buckets of size ≤ m (the paper's
//!   mechanism for bounding the O(bucket²) pair blow-up),
//! - scoring-pair enumeration with per-point dedup (a pair sharing several
//!   buckets is scored once),
//! - scoring through any [`PairScorer`], streamed into a
//!   [`WeightHistogram`] and optionally materialized as a [`Graph`] with
//!   Top-K pruning.
//!
//! Edge counting follows the paper's convention: "the number of edges
//!   returned for a point p is always the number of scoring pairs that
//!   contain p" — i.e. each unordered pair contributes 2 directed edges to
//! the totals reported under the figures ([`GraleOutput::directed_edges`]).

pub mod builder;

pub use builder::{GraleBuilder, GraleConfig, GraleOutput};
