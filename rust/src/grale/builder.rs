//! Grale graph construction: bucket table → splitting → scoring pairs.

use crate::features::Point;
use crate::graph::{Graph, WeightHistogram};
use crate::lsh::Bucketer;
use crate::scorer::PairScorer;
use crate::util::hash::{mix2, FxHashMap};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;

/// Grale configuration (the paper's experiment knobs).
#[derive(Debug, Clone)]
pub struct GraleConfig {
    /// `Bucket-S`: split any bucket larger than this into random
    /// sub-buckets of at most this size. `None` = no splitting (Fig. 3).
    pub bucket_split_size: Option<usize>,
    /// `Top-K` post-processing: keep each point's K heaviest edges.
    /// `None` = keep everything.
    pub top_k: Option<usize>,
    /// Seed for the random bucket subdivision.
    pub seed: u64,
    /// Materialize the graph (needed by downstream examples; costs memory).
    pub materialize_graph: bool,
    /// Worker threads for the scoring pass.
    pub threads: usize,
}

impl Default for GraleConfig {
    fn default() -> Self {
        GraleConfig {
            bucket_split_size: None,
            top_k: None,
            seed: 0x6772_616c_65,
            materialize_graph: false,
            threads: crate::util::threadpool::default_parallelism(),
        }
    }
}

/// Result of a Grale build.
pub struct GraleOutput {
    /// Distribution of edge weights over **directed** edges (the paper's
    /// totals convention: each scored pair contributes one edge per
    /// endpoint; with Top-K, each point's kept list counts).
    pub histogram: WeightHistogram,
    /// Unordered pairs scored by the model.
    pub scored_pairs: u64,
    /// Directed edge count reported under the figures.
    pub directed_edges: u64,
    /// Number of buckets before splitting.
    pub n_buckets: usize,
    /// Number of (sub-)buckets after splitting.
    pub n_split_buckets: usize,
    /// Materialized (undirected, possibly pruned) graph if requested.
    pub graph: Option<Graph>,
}

/// Offline Grale builder.
pub struct GraleBuilder<'a> {
    bucketer: &'a Bucketer,
    scorer: &'a dyn PairScorer,
    config: GraleConfig,
}

impl<'a> GraleBuilder<'a> {
    pub fn new(
        bucketer: &'a Bucketer,
        scorer: &'a dyn PairScorer,
        config: GraleConfig,
    ) -> GraleBuilder<'a> {
        GraleBuilder { bucketer, scorer, config }
    }

    /// Build the graph over `points`.
    pub fn build(&self, points: &[Point]) -> GraleOutput {
        let n = points.len();
        let threads = self.config.threads.max(1);

        // --- 1. bucket every point (parallel; pure local computation) ---
        let point_buckets: Vec<Vec<u64>> =
            parallel_map(n, threads, |i| self.bucketer.buckets(&points[i]));

        // --- 2. bucket table ---
        let mut table: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
        for (i, buckets) in point_buckets.iter().enumerate() {
            for &b in buckets {
                table.entry(b).or_default().push(i as u32);
            }
        }
        let n_buckets = table.len();

        // --- 3. bucket splitting (Bucket-S) ---
        // Deterministic: each bucket's shuffle is seeded by (seed, bucket).
        let mut split_buckets: Vec<Vec<u32>> = Vec::with_capacity(table.len());
        let mut by_id: Vec<(u64, Vec<u32>)> = table.into_iter().collect();
        by_id.sort_unstable_by_key(|&(b, _)| b); // deterministic order
        for (bucket_id, mut members) in by_id {
            match self.config.bucket_split_size {
                Some(m) if members.len() > m => {
                    let mut rng = Rng::seeded(mix2(self.config.seed, bucket_id));
                    rng.shuffle(&mut members);
                    for chunk in members.chunks(m) {
                        split_buckets.push(chunk.to_vec());
                    }
                }
                _ => split_buckets.push(members),
            }
        }
        let n_split_buckets = split_buckets.len();

        // --- 4. per-point membership lists over split buckets ---
        let mut memberships: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (bi, bucket) in split_buckets.iter().enumerate() {
            for &p in bucket {
                memberships[p as usize].push(bi as u32);
            }
        }

        // --- 5. enumerate + score pairs (parallel over points) ---
        // Pair (p, q) with p < q is handled in p's iteration; dedup within
        // p via sort+dedup of its candidate list.
        struct Local {
            hist: WeightHistogram,
            pairs: u64,
            /// Top-K mode: per-node bounded best lists, else raw edges.
            kept: FxHashMap<u32, Vec<(f32, u32)>>,
            edges: Vec<(u32, u32, f32)>,
        }
        let top_k = self.config.top_k;
        let need_edges = self.config.materialize_graph && top_k.is_none();
        let locals: Vec<Local> = {
            let chunk = n.div_ceil(threads);
            let ranges: Vec<std::ops::Range<usize>> = (0..threads)
                .map(|t| (t * chunk).min(n)..((t + 1) * chunk).min(n))
                .filter(|r| !r.is_empty())
                .collect();
            parallel_map(ranges.len(), threads, |ri| {
                let range = ranges[ri].clone();
                let mut local = Local {
                    hist: WeightHistogram::default_bins(),
                    pairs: 0,
                    kept: FxHashMap::default(),
                    edges: Vec::new(),
                };
                // Reused across every point in this worker's range: the
                // scoring pass is allocation-free in steady state.
                let mut cands: Vec<u32> = Vec::new();
                let mut cand_pts: Vec<&Point> = Vec::new();
                let mut scores: Vec<f32> = Vec::new();
                let mut scratch = crate::scorer::ScorerScratch::default();
                for p in range {
                    cands.clear();
                    for &bi in &memberships[p] {
                        for &q in &split_buckets[bi as usize] {
                            if (q as usize) > p {
                                cands.push(q);
                            }
                        }
                    }
                    cands.sort_unstable();
                    cands.dedup();
                    if cands.is_empty() {
                        continue;
                    }
                    cand_pts.clear();
                    cand_pts.extend(cands.iter().map(|&q| &points[q as usize]));
                    scores.clear();
                    self.scorer
                        .score_into(&points[p], &cand_pts, &mut scratch, &mut scores);
                    local.pairs += cands.len() as u64;
                    for (&q, &w) in cands.iter().zip(&scores) {
                        match top_k {
                            None => {
                                // Directed convention: both endpoints see it.
                                local.hist.add(w);
                                local.hist.add(w);
                                if need_edges {
                                    local.edges.push((p as u32, q, w));
                                }
                            }
                            Some(k) => {
                                push_topk(local.kept.entry(p as u32).or_default(), k, w, q);
                                push_topk(local.kept.entry(q).or_default(), k, w, p as u32);
                            }
                        }
                    }
                }
                local
            })
        };

        // --- 6. merge ---
        let mut histogram = WeightHistogram::default_bins();
        let mut scored_pairs = 0u64;
        let mut directed_edges = 0u64;
        let mut graph = self.config.materialize_graph.then(Graph::new);
        match top_k {
            None => {
                for l in &locals {
                    histogram.merge(&l.hist);
                    scored_pairs += l.pairs;
                }
                directed_edges = scored_pairs * 2;
                if let Some(g) = &mut graph {
                    for l in &locals {
                        for &(p, q, w) in &l.edges {
                            g.add_edge(points[p as usize].id, points[q as usize].id, w);
                        }
                    }
                    for p in points {
                        g.add_node(p.id);
                    }
                }
            }
            Some(k) => {
                // Merge per-node kept lists across threads, truncate to k.
                let mut merged: FxHashMap<u32, Vec<(f32, u32)>> = FxHashMap::default();
                for l in locals {
                    scored_pairs += l.pairs;
                    for (node, list) in l.kept {
                        let entry = merged.entry(node).or_default();
                        for (w, other) in list {
                            push_topk(entry, k, w, other);
                        }
                    }
                }
                let mut edge_set: std::collections::BTreeSet<(u32, u32)> = Default::default();
                let mut edge_weight: FxHashMap<(u32, u32), f32> = FxHashMap::default();
                for (node, list) in &merged {
                    for &(w, other) in list {
                        histogram.add(w);
                        directed_edges += 1;
                        if graph.is_some() {
                            let key = (*node.min(&other), *node.max(&other));
                            edge_set.insert(key);
                            edge_weight.insert(key, w);
                        }
                    }
                }
                if let Some(g) = &mut graph {
                    for (a, b) in edge_set {
                        g.add_edge(
                            points[a as usize].id,
                            points[b as usize].id,
                            edge_weight[&(a, b)],
                        );
                    }
                    for p in points {
                        g.add_node(p.id);
                    }
                }
            }
        }

        GraleOutput {
            histogram,
            scored_pairs,
            directed_edges,
            n_buckets,
            n_split_buckets,
            graph,
        }
    }
}

/// Maintain a bounded top-k list (min kept at the end; k is small).
fn push_topk(list: &mut Vec<(f32, u32)>, k: usize, w: f32, other: u32) {
    if list.len() < k {
        list.push((w, other));
        if list.len() == k {
            // total_cmp: a NaN weight must not panic the offline build.
            list.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        }
        return;
    }
    let worst = list.last().copied().unwrap();
    if w > worst.0 || (w == worst.0 && other < worst.1) {
        // Insert in sorted position.
        let pos = list
            .partition_point(|&(lw, lo)| lw > w || (lw == w && lo < other));
        list.pop();
        list.insert(pos, (w, other));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureValue, Schema};
    use crate::scorer::{MlpWeights, NativeScorer, PairFeaturizer, HIDDEN};

    fn setup(n: usize) -> (Bucketer, NativeScorer, Vec<Point>) {
        let schema = Schema::arxiv_like(8);
        let bucketer = Bucketer::with_defaults(&schema, 42);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(f.input_dim(), HIDDEN, 7);
        let scorer = NativeScorer::new(f, w);
        let mut rng = Rng::seeded(1);
        // Two clusters so some pairs share buckets.
        let pts = (0..n)
            .map(|i| {
                let center = if i % 2 == 0 { 1.0 } else { -1.0 };
                let v: Vec<f32> = (0..8)
                    .map(|_| center + 0.1 * rng.normal() as f32)
                    .collect();
                Point::new(
                    i as u64,
                    vec![FeatureValue::Dense(v), FeatureValue::Scalar(2020.0)],
                )
            })
            .collect();
        (bucketer, scorer, pts)
    }

    #[test]
    fn builds_and_counts_consistently() {
        let (b, s, pts) = setup(60);
        let out = GraleBuilder::new(&b, &s, GraleConfig::default()).build(&pts);
        assert!(out.scored_pairs > 0, "clustered points must share buckets");
        assert_eq!(out.directed_edges, out.scored_pairs * 2);
        assert_eq!(out.histogram.total(), out.directed_edges);
        assert!(out.n_buckets > 0);
        assert_eq!(out.n_buckets, out.n_split_buckets); // no splitting
        assert!(out.graph.is_none());
    }

    #[test]
    fn deterministic_given_seed() {
        let (b, s, pts) = setup(50);
        let cfg = GraleConfig {
            bucket_split_size: Some(5),
            ..GraleConfig::default()
        };
        let o1 = GraleBuilder::new(&b, &s, cfg.clone()).build(&pts);
        let o2 = GraleBuilder::new(&b, &s, cfg).build(&pts);
        assert_eq!(o1.scored_pairs, o2.scored_pairs);
        assert_eq!(o1.n_split_buckets, o2.n_split_buckets);
        assert_eq!(
            o1.histogram.percentile_curve(&[10.0, 50.0, 90.0]),
            o2.histogram.percentile_curve(&[10.0, 50.0, 90.0])
        );
    }

    #[test]
    fn splitting_reduces_pairs() {
        let (b, s, pts) = setup(80);
        let full = GraleBuilder::new(&b, &s, GraleConfig::default()).build(&pts);
        let split = GraleBuilder::new(
            &b,
            &s,
            GraleConfig { bucket_split_size: Some(4), ..GraleConfig::default() },
        )
        .build(&pts);
        assert!(split.scored_pairs < full.scored_pairs);
        assert!(split.n_split_buckets > full.n_buckets);
        // Every sub-bucket respects the cap — implied by pair counts, and
        // the scored pairs are a subset of the unsplit ones.
    }

    #[test]
    fn top_k_bounds_directed_edges() {
        let (b, s, pts) = setup(60);
        let k = 3;
        let out = GraleBuilder::new(
            &b,
            &s,
            GraleConfig { top_k: Some(k), ..GraleConfig::default() },
        )
        .build(&pts);
        assert!(out.directed_edges <= (pts.len() * k) as u64);
        assert_eq!(out.histogram.total(), out.directed_edges);
        // Top-k keeps the heaviest edges: its mean weight should not drop.
        let full = GraleBuilder::new(&b, &s, GraleConfig::default()).build(&pts);
        assert!(out.histogram.mean() >= full.histogram.mean() - 1e-9);
    }

    #[test]
    fn materialized_graph_matches_counts() {
        let (b, s, pts) = setup(40);
        let out = GraleBuilder::new(
            &b,
            &s,
            GraleConfig { materialize_graph: true, ..GraleConfig::default() },
        )
        .build(&pts);
        let g = out.graph.as_ref().unwrap();
        assert_eq!(g.n_edges() as u64, out.scored_pairs);
        assert_eq!(g.n_nodes(), pts.len());
    }

    #[test]
    fn single_thread_equals_parallel() {
        let (b, s, pts) = setup(70);
        let cfg1 = GraleConfig { threads: 1, ..GraleConfig::default() };
        let cfg4 = GraleConfig { threads: 4, ..GraleConfig::default() };
        let o1 = GraleBuilder::new(&b, &s, cfg1).build(&pts);
        let o4 = GraleBuilder::new(&b, &s, cfg4).build(&pts);
        assert_eq!(o1.scored_pairs, o4.scored_pairs);
        assert_eq!(o1.histogram.total(), o4.histogram.total());
        assert_eq!(
            o1.histogram.percentile_curve(&[25.0, 75.0]),
            o4.histogram.percentile_curve(&[25.0, 75.0])
        );
    }

    #[test]
    fn push_topk_keeps_best() {
        let mut list = Vec::new();
        for (w, o) in [(0.5, 1u32), (0.9, 2), (0.1, 3), (0.7, 4), (0.95, 5)] {
            push_topk(&mut list, 3, w, o);
        }
        let others: Vec<u32> = list.iter().map(|&(_, o)| o).collect();
        assert_eq!(others, vec![5, 2, 4]);
    }

    #[test]
    fn empty_input() {
        let (b, s, _) = setup(0);
        let out = GraleBuilder::new(&b, &s, GraleConfig::default()).build(&[]);
        assert_eq!(out.scored_pairs, 0);
        assert_eq!(out.histogram.total(), 0);
    }
}
