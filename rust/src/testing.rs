//! Property-testing harness (no `proptest` offline).
//!
//! A deliberately small substitute: a seeded case runner with value
//! generators built on [`crate::util::rng::Rng`]. On failure it reports the
//! case seed so the exact failing input can be replayed by pinning
//! `GUS_PROP_SEED`. No shrinking — generators are kept small enough that raw
//! failing cases are readable.
//!
//! ```ignore
//! proptest(|rng| {
//!     let xs = gen_f32_vec(rng, 0..100, -1.0..1.0);
//!     let v = SparseVec::from_dense(&xs);
//!     prop_assert!((v.dot(&v) - dense_dot(&xs, &xs)).abs() < 1e-4);
//! });
//! ```

use crate::util::rng::Rng;

/// Number of cases per property (overridable via `GUS_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("GUS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Base seed (overridable via `GUS_PROP_SEED` for replay).
pub fn base_seed() -> u64 {
    std::env::var("GUS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x6275_735f_7072_6f70)
}

/// Run `prop` for `default_cases()` seeded cases. The closure gets a
/// per-case RNG; any panic is re-raised with the case seed attached.
pub fn proptest(prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    proptest_cases(default_cases(), prop)
}

/// Run `prop` for exactly `cases` seeded cases.
pub fn proptest_cases(cases: u64, prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::seeded(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed on case {case} (replay with GUS_PROP_SEED={seed} GUS_PROP_CASES=1): {msg}"
            );
        }
    }
}

// ---------- common generators ----------

/// Uniform usize in [lo, hi).
pub fn gen_usize(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    assert!(lo < hi);
    lo + rng.below_usize(hi - lo)
}

/// f32 vector with entries uniform in [lo, hi), length in [min_len, max_len).
pub fn gen_f32_vec(rng: &mut Rng, min_len: usize, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
    let n = gen_usize(rng, min_len, max_len.max(min_len + 1));
    (0..n).map(|_| lo + rng.f32() * (hi - lo)).collect()
}

/// Sorted, deduplicated u64 keys in [0, key_space).
pub fn gen_sorted_keys(rng: &mut Rng, max_len: usize, key_space: u64) -> Vec<u64> {
    let n = rng.below_usize(max_len + 1);
    let mut keys: Vec<u64> = (0..n).map(|_| rng.below(key_space)).collect();
    keys.sort_unstable();
    keys.dedup();
    keys
}

/// Random alphanumeric identifier.
pub fn gen_ident(rng: &mut Rng, max_len: usize) -> String {
    const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789_";
    let n = 1 + rng.below_usize(max_len.max(1));
    (0..n).map(|_| ALPHA[rng.below_usize(ALPHA.len())] as char).collect()
}

/// Assert with context, mirrors `proptest`'s `prop_assert!`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond { panic!($($arg)+); }
    };
    ($cond:expr) => {
        if !$cond { panic!(concat!("assertion failed: ", stringify!($cond))); }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let count = std::sync::atomic::AtomicU64::new(0);
        proptest_cases(10, |_rng| {
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 10);
    }

    #[test]
    fn failure_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            proptest_cases(5, |rng| {
                let x = rng.below(100);
                prop_assert!(x < 1000); // passes
                if rng.below(3) == 99 {
                    unreachable!();
                }
            });
        });
        assert!(r.is_ok());

        let r = std::panic::catch_unwind(|| {
            proptest_cases(3, |_rng| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("should have failed"),
        };
        assert!(msg.contains("GUS_PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn generators_in_bounds() {
        proptest_cases(20, |rng| {
            let v = gen_f32_vec(rng, 0, 50, -2.0, 2.0);
            assert!(v.len() < 50);
            assert!(v.iter().all(|x| (-2.0..2.0).contains(x)));
            let keys = gen_sorted_keys(rng, 30, 1000);
            assert!(keys.windows(2).all(|w| w[0] < w[1]));
            let id = gen_ident(rng, 8);
            assert!(!id.is_empty() && id.len() <= 8);
        });
    }
}
