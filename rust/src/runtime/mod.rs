//! XLA/PJRT runtime: load and execute AOT-compiled artifacts.
//!
//! The build-time python pipeline (`python/compile/aot.py`) lowers the L2
//! JAX scorer graph — whose hot spot is the L1 Pallas kernel — to **HLO
//! text** under `artifacts/`. This module wraps the `xla` crate (PJRT C
//! API) to load those artifacts once at startup, compile them on the CPU
//! PJRT client, and execute them from the Rust request path. Python is
//! never involved at runtime.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Thread-safety: `PjRtClient` is `Rc`-based, so an [`Engine`] is pinned to
//! one thread. [`crate::scorer::xla::XlaScorer`] wraps it in an actor
//! thread with a channel interface for the multi-threaded coordinator.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// A PJRT CPU engine: client + literal/buffer helpers.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Engine { client })
    }

    /// Platform name (e.g. "cpu") — useful for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Compile an in-process `XlaComputation` (tests, tooling).
    pub fn compile(&self, comp: &xla::XlaComputation) -> Result<Executable> {
        let exe = self.client.compile(comp).map_err(|e| anyhow!("compile: {e}"))?;
        Ok(Executable { exe, name: "<in-process>".into() })
    }

    /// Upload an f32 tensor to the device (done once for weights; per-call
    /// for query tensors).
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("buffer_from_host_buffer dims {dims:?}: {e}"))
    }
}

/// A compiled executable (one AOT variant).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with device buffers; expect a single (possibly 1-tuple) f32
    /// output and copy it back to the host.
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        Self::first_output(outs, &self.name)
    }

    /// Execute with host literals (tests / one-shot calls).
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self
            .exe
            .execute(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        Self::first_output(outs, &self.name)
    }

    fn first_output(outs: Vec<Vec<xla::PjRtBuffer>>, name: &str) -> Result<Vec<f32>> {
        let lit = outs
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| anyhow!("{name}: no output buffer"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: to_literal_sync: {e}"))?;
        Self::literal_to_f32(lit).with_context(|| format!("output of {name}"))
    }

    /// Unwrap an (optionally 1-tuple-wrapped) f32 literal.
    fn literal_to_f32(lit: xla::Literal) -> Result<Vec<f32>> {
        // aot.py lowers with return_tuple=True ⇒ a 1-tuple.
        let lit = match lit.shape() {
            Ok(xla::Shape::Tuple(_)) => lit
                .to_tuple1()
                .map_err(|e| anyhow!("unwrapping 1-tuple output: {e}"))?,
            _ => lit,
        };
        lit.to_vec::<f32>().map_err(|e| anyhow!("reading f32 output: {e}"))
    }
}

/// Make an f32 literal with a shape (helper for tests and one-shot runs).
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        debug_assert_eq!(dims[0], data.len());
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape to {dims:?}: {e}"))
}

/// Directory where AOT artifacts live (overridable via `GUS_ARTIFACTS_DIR`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("GUS_ARTIFACTS_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise the PJRT wiring without python: they build tiny
    // computations with XlaBuilder in-process. Like the artifact-dependent
    // integration tests, they skip with a visible message when no PJRT
    // runtime is present (the offline build links the vendor/xla stub, in
    // which `Engine::cpu()` reports the runtime as unavailable).

    fn engine_or_skip(test: &str) -> Option<Engine> {
        match Engine::cpu() {
            Ok(engine) => Some(engine),
            Err(e) => {
                eprintln!("SKIP {test}: XLA/PJRT runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn engine_builds_and_runs_builder_computation() {
        let Some(engine) = engine_or_skip("engine_builds_and_runs_builder_computation") else {
            return;
        };
        assert!(!engine.platform().is_empty());
        let builder = xla::XlaBuilder::new("t");
        let shape = xla::Shape::array::<f32>(vec![4]);
        let p = builder.parameter_s(0, &shape, "p").unwrap();
        let q = builder.parameter_s(1, &shape, "q").unwrap();
        let comp = (p + q).unwrap().build().unwrap();
        let exe = engine.compile(&comp).unwrap();

        // Literal path.
        let a = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
        let b = literal_f32(&[10.0, 20.0, 30.0, 40.0], &[4]).unwrap();
        let out = exe.run_literals(&[a, b]).unwrap();
        assert_eq!(out, vec![11.0, 22.0, 33.0, 44.0]);

        // Buffer path (the production path).
        let ba = engine.buffer_f32(&[1.0, 1.0, 1.0, 1.0], &[4]).unwrap();
        let bb = engine.buffer_f32(&[2.0, 2.0, 2.0, 2.0], &[4]).unwrap();
        let out = exe.run_buffers(&[&ba, &bb]).unwrap();
        assert_eq!(out, vec![3.0; 4]);
    }

    #[test]
    fn matrix_shapes_roundtrip() {
        let Some(engine) = engine_or_skip("matrix_shapes_roundtrip") else {
            return;
        };
        let b = engine
            .buffer_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3])
            .unwrap();
        let shape = b.on_device_shape().unwrap();
        match shape {
            xla::Shape::Array(a) => assert_eq!(a.dims(), &[2, 3]),
            other => panic!("unexpected shape {other:?}"),
        }
    }

    #[test]
    fn buffer_dim_mismatch_errors() {
        let Some(engine) = engine_or_skip("buffer_dim_mismatch_errors") else {
            return;
        };
        assert!(engine.buffer_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn load_missing_artifact_errors() {
        let Some(engine) = engine_or_skip("load_missing_artifact_errors") else {
            return;
        };
        let err = match engine.load_hlo_text(Path::new("/nonexistent/model.hlo.txt")) {
            Ok(_) => panic!("expected error"),
            Err(e) => e,
        };
        assert!(format!("{err}").contains("nonexistent"));
    }
}
