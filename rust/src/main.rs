//! `gus` — the Dynamic GUS launcher.
//!
//! ```text
//! gus serve   --dataset arxiv_like --n 20000 --addr 127.0.0.1:7717
//!             [--scann-nn K] [--idf-s S] [--filter-p P] [--scorer auto]
//!             [--load data.jsonl]
//!             [--wal-dir DIR] [--fsync always|every_n[:N]|never]
//!             [--checkpoint-every M]
//!             [--max-connections C] [--rpc-workers W] [--rpc-queue Q]
//!             # RPC scheduling: W workers (0 = auto) execute enveloped
//!             # v1 requests from a bounded queue of Q; saturation sheds
//!             # with OVERLOADED. See docs/PROTOCOL.md.
//!             # --wal-dir makes the service durable: mutations are
//!             # write-ahead logged, checkpoints land in DIR, and a
//!             # restart with the same --wal-dir recovers everything.
//!             [--replicate] [--ack-replicas R] [--wal-retain N]
//!             [--ack-timeout-ms MS]
//!             # --replicate turns the durable server into a replication
//!             # leader: followers subscribe to its WAL stream. With
//!             # --ack-replicas R, a mutation's ack waits until R
//!             # followers hold it durably (semi-sync) for at most
//!             # --ack-timeout-ms (default 5000) before answering
//!             # UNAVAILABLE. --wal-retain keeps N records past each
//!             # checkpoint so lagging followers can stream instead of
//!             # re-bootstrapping.
//!             [--admission-target-ms MS] [--min-budget-frac F]
//!             # overload admission: MS is the queue-sojourn target the
//!             # pressure controller aims for; F is the floor below
//!             # which interactive requests are shed instead of served
//!             # further degraded. See docs/ADMISSION.md.
//!             [--fault-plan 'wal_append:enospc@seq=1200;fsync:err@nth=3']
//!             # deterministic disk-fault injection (flag or the
//!             # GUS_FAULT_PLAN env var; `follow` accepts it too) — for
//!             # drills and tests only. Grammar in docs/CHAOS.md.
//! gus follow  --leader HOST:PORT --wal-dir DIR [--addr 127.0.0.1:7718]
//!             [--peers HOST:PORT,..] [--ack-replicas R]
//!             [--ack-timeout-ms MS]
//!             # replicating follower: bootstraps from the leader
//!             # (snapshot + WAL tail), serves read-only queries
//!             # (mutations -> NOT_LEADER + leader hint), and can be
//!             # promoted to leader on failover (`gus promote`); the
//!             # ack knobs only matter after a promotion.
//! gus route   --targets HOST:PORT,HOST:PORT,.. [--addr 127.0.0.1:7800]
//!             [--health-interval-ms 500] [--fail-threshold 3]
//!             [--deadline-ms 2000]
//!             # hedged router: forwards mutations to the leader; sends
//!             # each query to the best replica by latency EWMA, fires
//!             # one hedged duplicate to the next-best when the primary
//!             # exceeds its p95 (first answer wins), and ejects
//!             # slow/failing replicas behind per-replica circuit
//!             # breakers; promotes the most-durable follower after
//!             # --fail-threshold leaderless health rounds.
//! gus chaosproxy --upstream HOST:PORT [--listen 127.0.0.1:0]
//!             [--seed S] [--span-ms MS] [--ensure-partition] [--passthrough]
//!             # deterministic TCP fault relay: executes the seeded
//!             # schedule of partitions, one-way blackholes, latency,
//!             # bandwidth caps and mid-frame truncation between cluster
//!             # members. Same seed, same schedule, bit-for-bit; the
//!             # fault timeline arms at startup. See docs/CHAOS.md.
//! gus promote --addr 127.0.0.1:7718   # manually promote a follower
//! gus recover --wal-dir DIR [--addr 127.0.0.1:7717]
//!             # restore checkpoint + WAL, compact, optionally serve
//! gus checkpoint --addr 127.0.0.1:7717   # force a checkpoint via RPC
//! gus query   --addr 127.0.0.1:7717 --id 42 [--k 10]
//! gus insert  --addr 127.0.0.1:7717 --point '{"id":..,"features":[..]}'
//! gus delete  --addr 127.0.0.1:7717 --id 42
//! gus stats   --addr 127.0.0.1:7717
//! gus gen     --dataset products_like --n 5000 --out data.jsonl
//! gus gen-trace --dataset arxiv_like --n 5000 --ops 2000 --out trace.jsonl
//! gus replay  --trace trace.jsonl [--workers 8] [--mode sync|pipeline|batch]
//!             # replay a workload; `batch` drives the insert_batch /
//!             # query_batch RPCs in --batch-size chunks
//! gus preprocess --dataset arxiv_like --n 20000   # table summary (§4.3)
//! gus loadgen [--scenario android_security|recsys_stream|dynamic_clustering|overload_surge]
//!             [--smoke]                 # shrink a scenario to CI scale
//!             [--rate R] [--duration S] [--mix insert=10,delete=2,query=80,query_batch=8]
//!             [--connections C] [--k K] [--batch B] [--deadline-ms D] [--seed S]
//!             [--classes]               # mark queries interactive / mutations batch
//!                                       # so admission control sheds by priority;
//!                                       # --scenario overload_surge runs the full
//!                                       # three-phase overload drill (capacity probe,
//!                                       # 3x surge with priority gates, recovery)
//!             [--dataset arxiv_like --n N --corpus-seed S2]   # ad-hoc corpus
//!             [--addr HOST:PORT]        # drive an external server instead of self-hosting
//!             [--wal-dir DIR]           # durable self-hosted server
//!             [--crash-at T]            # SIGKILL the server T seconds into the load,
//!                                       # recover, prove no acked mutation lost,
//!                                       # then re-check query SLOs (needs --wal-dir)
//!             [--crash-leader-at T]     # multi-node failover drill: boot a leader,
//!                                       # two followers and a router (all real
//!                                       # processes), drive the router, SIGKILL the
//!                                       # leader at T seconds, and prove a follower
//!                                       # was promoted with zero acked-mutation loss
//!                                       # (needs --wal-dir as a scratch base)
//!             [--chaos SEED]            # deterministic network-fault drill: same
//!                                       # four-process topology, but every inter-node
//!                                       # link runs through a chaosproxy executing a
//!                                       # seeded fault schedule. Gates: zero acked
//!                                       # loss, follower WALs stay byte prefixes of
//!                                       # the leader's, the cluster reconverges, and
//!                                       # the same seed replays the same schedule
//!                                       # (needs --wal-dir; see docs/CHAOS.md)
//!             [--gate-latency] [--no-gate] [--bench-out NAME]
//!             # open-loop load harness: Poisson arrivals at R req/s over C
//!             # pipelined v1 connections; never gates sends on completions.
//!             # Reports p50/p99 latency, per-error-code counts, and
//!             # visible staleness into BENCH_index.json (loadgen/NAME).
//!             # Error responses and lost acked mutations always fail the
//!             # run (unless --no-gate); latency/staleness SLOs are
//!             # advisory unless --gate-latency. See docs/LOADGEN.md.
//! ```
//!
//! `serve` also accepts the legacy `--snapshot-dir DIR` (restore-only, no
//! WAL); prefer `--wal-dir`, which loses nothing on a crash.
//!
//! `serve` boots the full stack: dataset (generated or loaded), offline
//! preprocessing, index warm-up, scorer (XLA artifacts if present), then
//! the TCP JSON-lines RPC server. The wire protocol is specified in
//! docs/PROTOCOL.md; the system layout in docs/ARCHITECTURE.md.

use std::sync::Arc;

use dynamic_gus::client::GusClient;
use dynamic_gus::config::GusConfig;
use dynamic_gus::coordinator::{wal, DynamicGus};
use dynamic_gus::data::{loader, synthetic::SyntheticConfig};
use dynamic_gus::features::Point;
use dynamic_gus::server::{serve, ServerConfig};
use dynamic_gus::util::cli::Args;
use dynamic_gus::util::json::Json;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    let code = match run(&cmd, &args) {
        Ok(()) => match args.check_unused() {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("warning: {e}");
                0
            }
        },
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn load_or_generate(args: &Args) -> anyhow::Result<dynamic_gus::data::Dataset> {
    if let Some(path) = args.opt_str("load") {
        return loader::load(std::path::Path::new(&path));
    }
    let name = args.get_str("dataset", "arxiv_like");
    let n = args.get_usize("n", 20_000);
    let seed = args.get_u64("seed", 0xa1);
    Ok(match name.as_str() {
        "arxiv_like" => SyntheticConfig::arxiv_like(n, seed).generate(),
        "products_like" => SyntheticConfig::products_like(n, seed).generate(),
        other => anyhow::bail!("unknown dataset '{other}'"),
    })
}

/// Infer the schema from loaded points (trace files carry no header).
fn infer_schema(points: &[Point]) -> anyhow::Result<dynamic_gus::features::Schema> {
    let p = points
        .first()
        .ok_or_else(|| anyhow::anyhow!("empty trace: cannot infer schema"))?;
    use dynamic_gus::features::{FeatureValue, Schema};
    let dense_dim = p
        .features
        .iter()
        .find_map(|f| match f {
            FeatureValue::Dense(v) => Some(v.len()),
            _ => None,
        })
        .ok_or_else(|| anyhow::anyhow!("points have no dense channel"))?;
    let has_tokens = p
        .features
        .iter()
        .any(|f| matches!(f, FeatureValue::Tokens(_)));
    Ok(if has_tokens {
        Schema::products_like(dense_dim)
    } else {
        Schema::arxiv_like(dense_dim)
    })
}

/// The semi-sync ack-gate timeout (`--ack-timeout-ms`), defaulting to
/// the replication module's [`dynamic_gus::replication::ACK_TIMEOUT`].
fn ack_timeout_arg(args: &Args) -> std::time::Duration {
    std::time::Duration::from_millis(args.get_u64(
        "ack-timeout-ms",
        dynamic_gus::replication::ACK_TIMEOUT.as_millis() as u64,
    ))
}

/// Arm the process-global disk-fault injector (no-op when `spec` is
/// `None`). Must run before any WAL is opened: writers capture the
/// injector once at open. `serve` resolves the spec via
/// [`GusConfig::apply_args`]; `follow` reads the flag/env directly.
fn arm_fault_plan(spec: Option<String>) -> anyhow::Result<()> {
    let Some(spec) = spec else { return Ok(()) };
    let plan = dynamic_gus::fault::FaultPlan::parse(&spec)?;
    dynamic_gus::fault::install_global(dynamic_gus::fault::FaultInjector::new(plan))?;
    eprintln!("[gus] fault plan armed: {spec}");
    Ok(())
}

fn run(cmd: &str, args: &Args) -> anyhow::Result<()> {
    match cmd {
        "serve" => {
            let mut config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            arm_fault_plan(config.fault_plan.clone())?;
            let replicate = args.get_bool("replicate", false);
            let ack_replicas = args.get_usize("ack-replicas", 0);
            if replicate && config.wal_dir.is_none() {
                anyhow::bail!("--replicate requires --wal-dir (the WAL is what gets shipped)");
            }
            if replicate && config.wal_retain == 0 && args.opt_str("wal-retain").is_none() {
                // Zero retention would force a snapshot re-bootstrap on
                // any follower lagging past a single checkpoint.
                eprintln!(
                    "[gus] --replicate without --wal-retain: keeping 65536 WAL records \
                     past checkpoints so lagging followers can stream"
                );
                config.wal_retain = 65_536;
            }
            // RPC scheduling knobs are per-incarnation operational
            // settings: the command line (or its defaults) wins even when
            // the service state is recovered from a snapshot or WAL
            // directory.
            let mut server_cfg = ServerConfig::from_gus(&config);
            if let Some(dir) = args.opt_str("snapshot-dir") {
                if args.opt_str("wal-dir").is_some() {
                    anyhow::bail!(
                        "--snapshot-dir and --wal-dir are mutually exclusive; \
                         --wal-dir supersedes it (recovers snapshots too, losslessly)"
                    );
                }
                let dir = std::path::PathBuf::from(dir);
                if dir.join("snapshot.json").exists() {
                    eprintln!("[gus] restoring from snapshot {}", dir.display());
                    let gus = dynamic_gus::coordinator::snapshot::restore(
                        &dir,
                        dynamic_gus::util::threadpool::default_parallelism(),
                    )?;
                    let addr = args.get_str("addr", "127.0.0.1:7717");
                    let handle = serve(Arc::new(gus), &addr, server_cfg)?;
                    println!("[gus] serving restored snapshot on {}", handle.addr);
                    loop {
                        std::thread::sleep(std::time::Duration::from_secs(3600));
                    }
                }
            }
            let threads = args.get_usize(
                "threads",
                dynamic_gus::util::threadpool::default_parallelism(),
            );
            // Durability knobs as parsed from the CLI, kept aside: on
            // recovery the persisted config is otherwise authoritative,
            // but knobs the operator set explicitly for this incarnation
            // (--fsync, --checkpoint-every) must win.
            let cli_fsync = args.opt_str("fsync").map(|_| config.fsync);
            let cli_checkpoint_every =
                args.opt_str("checkpoint-every").map(|_| config.checkpoint_every);
            let gus = match config.wal_dir.clone() {
                Some(dir) if wal::has_state(std::path::Path::new(&dir)) => {
                    let t0 = std::time::Instant::now();
                    let rec =
                        wal::recover_with(std::path::Path::new(&dir), threads, cli_fsync)?;
                    eprintln!(
                        "[gus] recovered {} points from {dir} ({} from checkpoint, \
                         {} WAL records replayed{}) in {:.1}s",
                        rec.gus.len(),
                        rec.snapshot_points,
                        rec.replayed,
                        if rec.torn_tail { ", torn tail truncated" } else { "" },
                        t0.elapsed().as_secs_f64()
                    );
                    rec.gus
                }
                wal_dir => {
                    let ds = load_or_generate(args)?;
                    eprintln!(
                        "[gus] bootstrapping {} points ({}), config {}",
                        ds.points.len(),
                        ds.schema.name,
                        config.to_json().dump()
                    );
                    let t0 = std::time::Instant::now();
                    let gus =
                        DynamicGus::bootstrap(ds.schema.clone(), config, &ds.points, threads)?;
                    if let Some(dir) = wal_dir {
                        wal::init_fresh(&gus, std::path::Path::new(&dir))?;
                        eprintln!("[gus] durability on: WAL + checkpoints in {dir}");
                    }
                    eprintln!("[gus] ready in {:.1}s", t0.elapsed().as_secs_f64());
                    gus
                }
            };
            let gus = Arc::new(gus);
            if replicate {
                let ack_timeout = ack_timeout_arg(args);
                let rep = dynamic_gus::replication::NodeReplication::leader(
                    Arc::clone(&gus),
                    ack_replicas,
                    ack_timeout,
                );
                server_cfg.replication =
                    Some(rep as Arc<dyn dynamic_gus::server::Replication>);
                eprintln!(
                    "[gus] replication leader (ack_replicas={ack_replicas}, \
                     ack_timeout={}ms)",
                    ack_timeout.as_millis()
                );
            }
            // Background checkpointer: bounds WAL length (and restart
            // cost) without stalling the mutation path on every op.
            let every = cli_checkpoint_every.unwrap_or_else(|| gus.config().checkpoint_every);
            let _checkpointer = (gus.wal().is_some() && every > 0).then(|| {
                wal::Checkpointer::spawn(
                    Arc::clone(&gus),
                    every,
                    std::time::Duration::from_millis(500),
                )
            });
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let handle = serve(Arc::clone(&gus), &addr, server_cfg)?;
            println!("[gus] serving on {}", handle.addr);
            // Serve until killed.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "follow" => {
            let leader = args
                .opt_str("leader")
                .ok_or_else(|| anyhow::anyhow!("follow needs --leader HOST:PORT"))?;
            let dir = args
                .opt_str("wal-dir")
                .ok_or_else(|| anyhow::anyhow!("follow needs --wal-dir DIR"))?;
            let peers: Vec<String> = args
                .opt_str("peers")
                .map(|s| s.split(',').map(|p| p.trim().to_string()).collect())
                .unwrap_or_default();
            let threads = args.get_usize(
                "threads",
                dynamic_gus::util::threadpool::default_parallelism(),
            );
            arm_fault_plan(
                args.opt_str("fault-plan")
                    .or_else(|| std::env::var("GUS_FAULT_PLAN").ok())
                    .filter(|s| !s.trim().is_empty()),
            )?;
            let (gus, rep) = dynamic_gus::replication::start_follower(
                dynamic_gus::replication::FollowerOpts {
                    leader,
                    peers,
                    wal_dir: std::path::PathBuf::from(&dir),
                    threads,
                    ack_replicas: args.get_usize("ack-replicas", 0),
                    ack_timeout: ack_timeout_arg(args),
                },
            )?;
            // A follower checkpoints its own WAL copy, bounding its
            // restart cost the same way a leader bounds its own.
            let every = args
                .opt_str("checkpoint-every")
                .map(|s| s.parse::<u64>())
                .transpose()?
                .unwrap_or_else(|| gus.config().checkpoint_every);
            let _checkpointer = (every > 0).then(|| {
                wal::Checkpointer::spawn(
                    Arc::clone(&gus),
                    every,
                    std::time::Duration::from_millis(500),
                )
            });
            let mut server_cfg = ServerConfig::from_gus(gus.config());
            server_cfg.replication = Some(rep as Arc<dyn dynamic_gus::server::Replication>);
            let addr = args.get_str("addr", "127.0.0.1:7718");
            let handle = serve(Arc::clone(&gus), &addr, server_cfg)?;
            println!("[gus] serving on {}", handle.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "route" => {
            let targets: Vec<String> = args
                .opt_str("targets")
                .ok_or_else(|| anyhow::anyhow!("route needs --targets HOST:PORT,HOST:PORT,.."))?
                .split(',')
                .map(|t| t.trim().to_string())
                .filter(|t| !t.is_empty())
                .collect();
            let opts = dynamic_gus::replication::RouterOpts {
                listen: args.get_str("addr", "127.0.0.1:7800"),
                targets,
                health_interval: std::time::Duration::from_millis(
                    args.get_u64("health-interval-ms", 500),
                ),
                fail_threshold: args.get_u64("fail-threshold", 3) as u32,
                deadline_ms: args.get_u64("deadline-ms", 2_000),
            };
            dynamic_gus::replication::run_router(opts)
        }
        "chaosproxy" => {
            use dynamic_gus::fault::Schedule;
            let upstream = args
                .opt_str("upstream")
                .ok_or_else(|| anyhow::anyhow!("chaosproxy needs --upstream HOST:PORT"))?;
            let listen = args.get_str("listen", "127.0.0.1:0");
            let seed = args.get_u64("seed", 0xc405);
            let span_ms = args.get_u64("span-ms", 10_000);
            let schedule = if args.get_bool("passthrough", false) {
                Schedule::passthrough()
            } else {
                Schedule::generate(seed, span_ms, args.get_bool("ensure-partition", false))
            };
            let digest = schedule.digest();
            let windows = schedule.windows.len();
            eprintln!("[gus] chaosproxy schedule: {}", schedule.describe());
            let proxy = dynamic_gus::fault::proxy::start(&listen, &upstream, schedule)?;
            // One scrapable line, like `serve`'s, so orchestration can
            // learn the bound port; the fault timeline arms right after.
            println!(
                "[gus] chaosproxy on {} -> {upstream} seed={seed} digest={digest:016x} \
                 windows={windows}",
                proxy.addr()
            );
            proxy.arm();
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "promote" => {
            let addr = args.get_str("addr", "127.0.0.1:7718");
            let mut client = GusClient::connect(&addr)?;
            // Promotion legitimately waits out the follower's in-flight
            // stream (bounded server-side); don't give up before it does.
            client.set_read_timeout(Some(std::time::Duration::from_secs(20)))?;
            let seq = client.promote()?;
            println!("ok promoted seq={seq}");
            Ok(())
        }
        "recover" => {
            let dir = args
                .opt_str("wal-dir")
                .ok_or_else(|| anyhow::anyhow!("recover needs --wal-dir DIR"))?;
            let threads = args.get_usize(
                "threads",
                dynamic_gus::util::threadpool::default_parallelism(),
            );
            // Same CLI overrides as `serve` on a recovered service.
            let cli_fsync = args
                .opt_str("fsync")
                .map(|s| dynamic_gus::config::FsyncPolicy::parse(&s))
                .transpose()
                .map_err(|e| anyhow::anyhow!(e))?;
            let cli_checkpoint_every =
                args.opt_str("checkpoint-every").map(|s| s.parse::<u64>()).transpose()?;
            let t0 = std::time::Instant::now();
            let rec = wal::recover_with(std::path::Path::new(&dir), threads, cli_fsync)?;
            println!(
                "recovered {} points from {dir}: {} from checkpoint, {} WAL records \
                 replayed{} ({:.2}s)",
                rec.gus.len(),
                rec.snapshot_points,
                rec.replayed,
                if rec.torn_tail { ", torn tail truncated" } else { "" },
                t0.elapsed().as_secs_f64()
            );
            // Compact: fold the replayed tail into a fresh checkpoint so
            // the next recovery replays nothing.
            let seq = rec.gus.checkpoint()?;
            println!("compacted: checkpoint at seq {seq}, WAL truncated");
            if let Some(addr) = args.opt_str("addr") {
                let gus = Arc::new(rec.gus);
                let every =
                    cli_checkpoint_every.unwrap_or_else(|| gus.config().checkpoint_every);
                let _checkpointer = (every > 0).then(|| {
                    wal::Checkpointer::spawn(
                        Arc::clone(&gus),
                        every,
                        std::time::Duration::from_millis(500),
                    )
                });
                // RPC scheduling knobs: explicit CLI flags win over the
                // recovered incarnation's persisted values, validated the
                // same way as on the `serve` path.
                let mut rpc_cfg = gus.config().clone();
                rpc_cfg.max_connections =
                    args.get_usize("max-connections", rpc_cfg.max_connections);
                rpc_cfg.rpc_workers = args.get_usize("rpc-workers", rpc_cfg.rpc_workers);
                rpc_cfg.rpc_queue = args.get_usize("rpc-queue", rpc_cfg.rpc_queue);
                rpc_cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
                let handle = serve(Arc::clone(&gus), &addr, ServerConfig::from_gus(&rpc_cfg))?;
                println!("[gus] serving on {}", handle.addr);
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(3600));
                }
            }
            Ok(())
        }
        "checkpoint" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let seq = client.checkpoint()?;
            println!("ok checkpoint seq={seq}");
            Ok(())
        }
        "query" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let k = args.get_usize("k", 10);
            let neighbors = if let Some(id) = args.opt_str("id") {
                client.query_id(id.parse()?, k)?
            } else if let Some(pjson) = args.opt_str("point") {
                let p = Point::from_json(&Json::parse(&pjson).map_err(|e| anyhow::anyhow!("{e}"))?)
                    .ok_or_else(|| anyhow::anyhow!("bad point json"))?;
                client.query(&p, k)?
            } else {
                anyhow::bail!("query needs --id or --point");
            };
            for n in neighbors {
                println!("{}\t{:.4}\t{:.3}", n.id, n.score, n.dot);
            }
            Ok(())
        }
        "insert" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let pjson = args
                .opt_str("point")
                .ok_or_else(|| anyhow::anyhow!("insert needs --point"))?;
            let p = Point::from_json(&Json::parse(&pjson).map_err(|e| anyhow::anyhow!("{e}"))?)
                .ok_or_else(|| anyhow::anyhow!("bad point json"))?;
            let existed = client.insert(&p)?;
            println!("ok existed={existed}");
            Ok(())
        }
        "delete" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let id: u64 = args
                .opt_str("id")
                .ok_or_else(|| anyhow::anyhow!("delete needs --id"))?
                .parse()?;
            let existed = client.delete(id)?;
            println!("ok existed={existed}");
            Ok(())
        }
        "stats" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            println!("{}", client.stats()?.dump());
            Ok(())
        }
        "gen-trace" => {
            let ds = load_or_generate(args)?;
            let trace_cfg = dynamic_gus::data::trace::TraceConfig {
                initial_fraction: args.get_f64("initial-fraction", 0.8),
                n_ops: args.get_usize("ops", 2_000),
                insert_prob: args.get_f64("insert-prob", 0.1),
                update_prob: args.get_f64("update-prob", 0.05),
                delete_prob: args.get_f64("delete-prob", 0.02),
                query_k: args.get_usize("k", 10),
                seed: args.get_u64("trace-seed", 0x7472),
            };
            let trace = trace_cfg.build(&ds);
            let out = args.get_str("out", "trace.jsonl");
            trace.save(std::path::Path::new(&out))?;
            let (i, u, d, q) = trace.op_mix();
            println!(
                "wrote {out}: {} initial points; ops: {i} inserts {u} updates {d} deletes {q} queries",
                trace.initial.len()
            );
            Ok(())
        }
        "replay" => {
            use dynamic_gus::coordinator::{IngestPipeline, Mutation};
            use dynamic_gus::data::trace::{Op, Trace};
            let path = args
                .opt_str("trace")
                .ok_or_else(|| anyhow::anyhow!("replay needs --trace FILE"))?;
            let trace = Trace::load(std::path::Path::new(&path))?;
            let schema = infer_schema(&trace.initial)?;
            let config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            let workers = args.get_usize("workers", 1);
            let gus = Arc::new(DynamicGus::bootstrap(
                schema,
                config,
                &trace.initial,
                dynamic_gus::util::threadpool::default_parallelism(),
            )?);
            let mode = args.get_str("mode", if workers <= 1 { "sync" } else { "pipeline" });
            if !["sync", "pipeline", "batch"].contains(&mode.as_str()) {
                anyhow::bail!("unknown --mode '{mode}' (sync|pipeline|batch)");
            }
            let t0 = std::time::Instant::now();
            if mode == "batch" {
                // Drive the batch RPCs: consecutive ops of one kind are
                // grouped into --batch-size chunks. Buffers are flushed
                // before any op of a different kind, so every op observes
                // all earlier mutations (same visibility as sync replay).
                let bs = gus.config().batch_size;
                let mut inserts: Vec<Point> = Vec::new();
                let mut deletes: Vec<u64> = Vec::new();
                let mut queries: Vec<Point> = Vec::new();
                let mut query_k = 0usize;
                for op in &trace.ops {
                    match op {
                        Op::Insert(p) | Op::Update(p) => {
                            if !queries.is_empty() {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                            if !deletes.is_empty() {
                                gus.delete_batch(&std::mem::take(&mut deletes))?;
                            }
                            inserts.push(p.clone());
                            if inserts.len() >= bs {
                                gus.insert_batch(std::mem::take(&mut inserts))?;
                            }
                        }
                        Op::Delete(id) => {
                            if !queries.is_empty() {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                            if !inserts.is_empty() {
                                gus.insert_batch(std::mem::take(&mut inserts))?;
                            }
                            deletes.push(*id);
                            if deletes.len() >= bs {
                                gus.delete_batch(&std::mem::take(&mut deletes))?;
                            }
                        }
                        Op::Query { point, k } => {
                            if !inserts.is_empty() {
                                gus.insert_batch(std::mem::take(&mut inserts))?;
                            }
                            if !deletes.is_empty() {
                                gus.delete_batch(&std::mem::take(&mut deletes))?;
                            }
                            if !queries.is_empty() && *k != query_k {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                            query_k = *k;
                            queries.push(point.clone());
                            if queries.len() >= bs {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                        }
                    }
                }
                if !inserts.is_empty() {
                    gus.insert_batch(inserts)?;
                }
                if !deletes.is_empty() {
                    gus.delete_batch(&deletes)?;
                }
                if !queries.is_empty() {
                    gus.query_batch(&queries, query_k)?;
                }
            } else if mode == "sync" || workers <= 1 {
                for op in &trace.ops {
                    match op {
                        Op::Insert(p) | Op::Update(p) => {
                            gus.insert(p.clone())?;
                        }
                        Op::Delete(id) => {
                            gus.delete(*id)?;
                        }
                        Op::Query { point, k } => {
                            gus.query(point, *k)?;
                        }
                    }
                }
            } else {
                // Mutations through the bulk pipeline; queries inline.
                let pipeline = IngestPipeline::new(Arc::clone(&gus), workers, 1024);
                for op in &trace.ops {
                    match op {
                        Op::Insert(p) | Op::Update(p) => {
                            pipeline.submit(Mutation::Upsert(p.clone()))
                        }
                        Op::Delete(id) => pipeline.submit(Mutation::Delete(*id)),
                        Op::Query { point, k } => {
                            gus.query(point, *k)?;
                        }
                    }
                }
                pipeline.flush();
                pipeline.shutdown();
            }
            let wall = t0.elapsed();
            println!(
                "replayed {} ops in {:.2}s ({:.0} ops/s, mode={mode}, workers={workers})",
                trace.ops.len(),
                wall.as_secs_f64(),
                trace.ops.len() as f64 / wall.as_secs_f64()
            );
            println!("{}", gus.stats_json().dump());
            Ok(())
        }
        "snapshot" => {
            // Save a freshly-bootstrapped service (demo/ops tool); the
            // served process also does this via --snapshot-dir.
            let ds = load_or_generate(args)?;
            let config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            let gus = DynamicGus::bootstrap(
                ds.schema.clone(),
                config,
                &ds.points,
                dynamic_gus::util::threadpool::default_parallelism(),
            )?;
            let dir = args.get_str("snapshot-dir", "snapshot");
            dynamic_gus::coordinator::snapshot::save(&gus, std::path::Path::new(&dir))?;
            println!("snapshot of {} points written to {dir}/", gus.len());
            Ok(())
        }
        "gen" => {
            let ds = load_or_generate(args)?;
            let out = args.get_str("out", "dataset.jsonl");
            loader::save(&ds, std::path::Path::new(&out))?;
            println!("wrote {} points to {out}", ds.points.len());
            Ok(())
        }
        "preprocess" => {
            let ds = load_or_generate(args)?;
            let config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            let bucketer =
                dynamic_gus::lsh::Bucketer::with_defaults(&ds.schema, config.lsh_seed);
            let pre = dynamic_gus::preprocess::preprocess(
                &bucketer,
                &ds.points,
                &config,
                dynamic_gus::util::threadpool::default_parallelism(),
            );
            println!(
                "points={} distinct_buckets={} idf_entries={} banned_buckets={}",
                pre.stats.num_points(),
                pre.stats.num_buckets(),
                pre.idf.as_ref().map(|t| t.len()).unwrap_or(0),
                pre.filter.as_ref().map(|f| f.len()).unwrap_or(0),
            );
            let top: Vec<(u64, u64)> = pre.stats.by_count_desc().into_iter().take(10).collect();
            println!("top-10 bucket cardinalities: {top:?}");
            Ok(())
        }
        "loadgen" => loadgen_cmd(args),
        _ => {
            eprintln!(
                "usage: gus <serve|follow|route|chaosproxy|promote|recover|checkpoint|query|\
                 insert|delete|stats|gen|preprocess|loadgen> [options]\n\
                 see rust/src/main.rs docs and docs/ARCHITECTURE.md for details"
            );
            Ok(())
        }
    }
}

// ---------- gus loadgen ----------

/// One finished load run plus mode-specific verdicts the central gate
/// folds in (crash mode has extra checks a plain run doesn't).
struct LoadRun {
    report: dynamic_gus::loadgen::LoadReport,
    /// Hard failures found by the mode itself (lost acked mutations are
    /// reported via `report.lost_acked_mutations`, this is for the rest).
    extra_failures: Vec<String>,
    /// Latency findings gated only under `--gate-latency`.
    extra_slo: Vec<String>,
    crash_mode: bool,
    /// Error codes this mode expects during its induced failure window
    /// (killing a node legitimately produces them); everything else
    /// still fails the gate.
    exempt_codes: &'static [&'static str],
}

/// Resolve the workload spec: a built-in scenario (optionally shrunk to
/// `--smoke` scale) or an ad-hoc spec from flags, with rate/duration/…
/// flags overriding either.
fn resolve_scenario(
    args: &Args,
    default_scenario: Option<&str>,
) -> anyhow::Result<dynamic_gus::loadgen::Scenario> {
    use dynamic_gus::loadgen::{scenario, Mix, Scenario, SloSpec};
    let mut sc: Scenario = match args
        .opt_str("scenario")
        .or_else(|| default_scenario.map(str::to_string))
    {
        Some(name) => {
            let sc = scenario::builtin(&name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario '{name}' (one of {:?})",
                    scenario::SCENARIO_NAMES
                )
            })?;
            if args.get_bool("smoke", false) {
                sc.smoke()
            } else {
                sc
            }
        }
        None => Scenario {
            name: "adhoc".to_string(),
            corpus: scenario::CorpusSpec::new(
                &args.get_str("dataset", "arxiv_like"),
                args.get_usize("n", 20_000),
                args.get_u64("corpus-seed", 0xa1),
                args.get_usize("k", 10),
            ),
            rate: 500.0,
            duration_s: 10.0,
            connections: 4,
            mix: Mix::default_mixed(),
            batch: 16,
            deadline_ms: None,
            load_seed: 0x10ad,
            classes: false,
            slo: SloSpec {
                p50_ms: args.get_f64("slo-p50-ms", 25.0),
                p99_ms: args.get_f64("slo-p99-ms", 150.0),
                staleness_p99_ms: args.get_f64("slo-staleness-p99-ms", 1_000.0),
            },
        },
    };
    sc.rate = args.get_f64("rate", sc.rate);
    sc.duration_s = args.get_f64("duration", sc.duration_s);
    sc.connections = args.get_usize("connections", sc.connections);
    sc.batch = args.get_usize("batch", sc.batch);
    if let Some(spec) = args.opt_str("mix") {
        sc.mix = dynamic_gus::loadgen::Mix::parse(&spec).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(d) = args.opt_str("deadline-ms") {
        sc.deadline_ms = Some(d.parse()?);
    }
    sc.load_seed = args.get_u64("seed", sc.load_seed);
    sc.classes = args.get_bool("classes", sc.classes);
    Ok(sc)
}

/// Seeds accept decimal or `0x…` hex (drill digests print in hex, so
/// replaying one pasted from a log should just work).
fn parse_seed(s: &str) -> anyhow::Result<u64> {
    let t = s.trim();
    Ok(match t.strip_prefix("0x") {
        Some(hex) => u64::from_str_radix(hex, 16)?,
        None => t.parse()?,
    })
}

fn loadgen_cmd(args: &Args) -> anyhow::Result<()> {
    use dynamic_gus::loadgen::runner::LoadOptions;
    let crash_at = args.opt_str("crash-at").map(|s| s.parse::<f64>()).transpose()?;
    let crash_leader_at =
        args.opt_str("crash-leader-at").map(|s| s.parse::<f64>()).transpose()?;
    let chaos = args.opt_str("chaos").map(|s| parse_seed(&s)).transpose()?;
    anyhow::ensure!(
        [crash_at.is_some(), crash_leader_at.is_some(), chaos.is_some()]
            .iter()
            .filter(|b| **b)
            .count()
            <= 1,
        "--crash-at, --crash-leader-at and --chaos are mutually exclusive"
    );
    // `--chaos` without `--scenario` runs the purpose-built drill
    // workload instead of the ad-hoc default.
    let sc = resolve_scenario(args, chaos.map(|_| "chaos_drill"))?;
    let gate_latency = args.get_bool("gate-latency", false);
    let no_gate = args.get_bool("no-gate", false);
    let bench_name = args.get_str("bench-out", &sc.name);
    let opts = LoadOptions::from_scenario(&sc);
    let sampler = sc.corpus.sampler()?;
    eprintln!("[loadgen] spec: {}", sc.to_json().dump());

    let run = if let Some(seed) = chaos {
        loadgen_chaos(args, &sc, &opts, &sampler, seed)?
    } else if let Some(t) = crash_leader_at {
        loadgen_replicated(args, &sc, &opts, &sampler, t)?
    } else if let Some(t) = crash_at {
        loadgen_crash(args, &sc, &opts, &sampler, t)?
    } else if sc.name == "overload_surge" && args.opt_str("addr").is_none() {
        loadgen_overload(args, &sc, &opts, &sampler)?
    } else if let Some(addr) = args.opt_str("addr") {
        loadgen_external(&addr, &opts, &sampler)?
    } else {
        loadgen_selfhost(args, &sc, &opts, &sampler)?
    };

    let report = &run.report;
    report.print();
    report.dump_bench_index(&bench_name);
    println!("[loadgen] wrote BENCH_index.json entry loadgen/{bench_name}");

    // The hard gates: error responses and lost acked mutations always
    // fail (crash mode exempts transport-level breakage — that's the
    // point of the crash). Latency/staleness SLOs gate only under
    // --gate-latency: they depend on the host, the correctness gates
    // don't.
    let mut failures = run.extra_failures;
    let hard_errors: u64 = report
        .errors
        .iter()
        .filter(|(code, _)| !run.exempt_codes.contains(&code.as_str()))
        .map(|(_, &n)| n)
        .sum();
    if hard_errors > 0 {
        failures.push(format!("{hard_errors} error responses ({:?})", report.errors));
    }
    if !run.crash_mode && report.transport_lost > 0 {
        failures.push(format!("{} requests never answered", report.transport_lost));
    }
    if let Some(lost) = report.lost_acked_mutations {
        if lost > 0 {
            failures.push(format!("{lost} acknowledged mutations lost"));
        }
    }
    let mut slo = report.slo_violations(&sc.slo);
    slo.extend(run.extra_slo);
    if gate_latency {
        failures.extend(slo);
    } else {
        for v in &slo {
            println!("[loadgen] SLO (advisory): {v}");
        }
    }
    if failures.is_empty() {
        println!("[loadgen] PASS");
        return Ok(());
    }
    if no_gate {
        println!("[loadgen] --no-gate: ignoring {} failure(s): {failures:?}", failures.len());
        return Ok(());
    }
    anyhow::bail!("loadgen gate failed: {failures:?}")
}

/// Drive an already-running server. Acked-mutation survival is verified
/// over the wire with `query_id` probes; the corpus flags must match
/// whatever the server was booted with (only the schema actually
/// matters — fresh ids never collide with the corpus).
fn loadgen_external(
    addr: &str,
    opts: &dynamic_gus::loadgen::LoadOptions,
    sampler: &dynamic_gus::data::synthetic::PointSampler,
) -> anyhow::Result<LoadRun> {
    use dynamic_gus::loadgen::{runner, verify};
    let outcome = runner::run_load(addr, opts, sampler)?;
    let mut report = outcome.report;
    let expected = verify::determinate_final_state(&outcome.ledgers);
    let mut client = GusClient::connect(addr)?;
    let violations = verify::check_survival_rpc(&mut client, &expected)?;
    report.lost_acked_mutations = Some(violations.len() as u64);
    runner::attach_server_stats(&mut report, addr);
    Ok(LoadRun {
        report,
        extra_failures: Vec::new(),
        extra_slo: Vec::new(),
        crash_mode: false,
        exempt_codes: &[],
    })
}

/// Boot the scenario's corpus in-process, serve it on a loopback port,
/// and drive it. `--wal-dir` makes the hosted server durable (so the
/// measured mutation path includes the WAL append + fsync policy).
fn loadgen_selfhost(
    args: &Args,
    sc: &dynamic_gus::loadgen::Scenario,
    opts: &dynamic_gus::loadgen::LoadOptions,
    sampler: &dynamic_gus::data::synthetic::PointSampler,
) -> anyhow::Result<LoadRun> {
    use dynamic_gus::loadgen::{runner, verify};
    let ds = sc.corpus.generate()?;
    let threads = dynamic_gus::util::threadpool::default_parallelism();
    eprintln!("[loadgen] bootstrapping {} points ({})", ds.points.len(), ds.schema.name);
    let gus = DynamicGus::bootstrap(ds.schema.clone(), sc.corpus.gus_config(), &ds.points, threads)?;
    if let Some(dir) = args.opt_str("wal-dir") {
        wal::init_fresh(&gus, std::path::Path::new(&dir))?;
        eprintln!("[loadgen] durability on: WAL in {dir}");
    }
    let gus = Arc::new(gus);
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::from_gus(gus.config()))?;
    let addr = handle.addr.to_string();
    let outcome = runner::run_load(&addr, opts, sampler)?;
    let mut report = outcome.report;
    let expected = verify::determinate_final_state(&outcome.ledgers);
    let violations = verify::check_survival_inproc(&gus, &expected);
    report.lost_acked_mutations = Some(violations.len() as u64);
    runner::attach_server_stats(&mut report, &addr);
    handle.shutdown();
    Ok(LoadRun {
        report,
        extra_failures: Vec::new(),
        extra_slo: Vec::new(),
        crash_mode: false,
        exempt_codes: &[],
    })
}

/// Graceful-degradation drill (`gus loadgen --scenario overload_surge`):
/// three phases against one deliberately capacity-constrained in-process
/// server (a single RPC worker and a short run queue, so the drill
/// saturates at honest scale on any host).
///
/// - **Phase A — capacity probe.** Unclassed load at the scenario rate;
///   the measured goodput is the server's capacity for this corpus on
///   this host. Unclassed requests bypass priority shedding, so the
///   probe measures the machine, not the policy.
/// - **Phase B — surge.** Classed load (queries `interactive`,
///   mutations `batch`) offered at 3× the measured capacity. Gates:
///   goodput stays ≥ 70% of capacity (admission sheds cheaply instead
///   of collapsing), batch sheds at a rate ≥ the interactive shed rate
///   (priority order held), zero acked-mutation loss, and — under
///   `--gate-latency` — admitted interactive p99 within the scenario
///   SLO.
/// - **Phase C — recovery.** After a pressure-draining warmup, a
///   query-only run at a fraction of capacity must come back completely
///   clean: no errors, no shed, and *no degraded responses* — proof the
///   controller releases the brakes when the surge ends.
fn loadgen_overload(
    args: &Args,
    sc: &dynamic_gus::loadgen::Scenario,
    opts: &dynamic_gus::loadgen::LoadOptions,
    sampler: &dynamic_gus::data::synthetic::PointSampler,
) -> anyhow::Result<LoadRun> {
    use dynamic_gus::loadgen::{runner, verify, LoadOptions, Mix};
    use dynamic_gus::util::rng::Rng;

    // Bound the surge's request volume: open-loop at 3× capacity can ask
    // for more requests than a CI host can even serialize.
    const SURGE_RATE_CAP: f64 = 30_000.0;

    let ds = sc.corpus.generate()?;
    let threads = dynamic_gus::util::threadpool::default_parallelism();
    let mut cfg = sc.corpus.gus_config();
    cfg.rpc_workers = args.get_usize("rpc-workers", 1);
    cfg.rpc_queue = args.get_usize("rpc-queue", 64);
    cfg.admission_target_ms = args.get_u64("admission-target-ms", cfg.admission_target_ms);
    cfg.min_budget_frac = args.get_f64("min-budget-frac", cfg.min_budget_frac);
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    eprintln!(
        "[loadgen] bootstrapping {} points ({}); constrained to {} worker(s), queue {}",
        ds.points.len(),
        ds.schema.name,
        cfg.rpc_workers,
        cfg.rpc_queue
    );
    let gus = Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, threads)?);
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::from_gus(gus.config()))?;
    let addr = handle.addr.to_string();

    // Phase A: capacity probe.
    let probe_opts = LoadOptions { classes: false, ..opts.clone() };
    let probe = runner::run_load(&addr, &probe_opts, sampler)?;
    let capacity = probe.report.achieved_rate();
    anyhow::ensure!(capacity > 0.0, "capacity probe measured zero goodput");
    eprintln!(
        "[loadgen] phase A: capacity {capacity:.0} req/s goodput (offered {:.0}, {} sheds)",
        opts.rate,
        probe.report.error_total()
    );

    // Phase B: classed surge at 3× measured capacity.
    let surge_rate = (3.0 * capacity).min(SURGE_RATE_CAP);
    let surge_opts = LoadOptions { rate: surge_rate, classes: true, ..opts.clone() };
    let surge = runner::run_load(&addr, &surge_opts, sampler)?;
    let goodput = surge.report.achieved_rate();
    let shed = |class: &str| surge.report.shed_by_class.get(class).copied().unwrap_or(0);
    let (shed_batch, shed_interactive) = (shed("batch"), shed("interactive"));
    eprintln!(
        "[loadgen] phase B: offered {surge_rate:.0} req/s, goodput {goodput:.0} req/s \
         ({:.0}% of capacity); sheds batch={shed_batch} interactive={shed_interactive}; \
         {} degraded responses",
        100.0 * goodput / capacity,
        surge.report.degraded
    );

    let mut extra_failures = Vec::new();
    if goodput < 0.7 * capacity {
        extra_failures.push(format!(
            "goodput collapsed under surge: {goodput:.0} req/s < 70% of the measured \
             {capacity:.0} req/s capacity"
        ));
    }
    // Priority order: the batch class must be shed at least as hard as
    // interactive, normalized by how much of each was offered.
    let sent_of = |kinds: &[&str]| -> u64 {
        surge
            .report
            .per_kind
            .iter()
            .filter(|k| kinds.contains(&k.kind))
            .map(|k| k.sent)
            .sum()
    };
    let batch_sent = sent_of(&["insert", "delete"]);
    let interactive_sent = sent_of(&["query", "query_batch"]);
    if batch_sent > 0 && interactive_sent > 0 {
        let batch_rate = shed_batch as f64 / batch_sent as f64;
        let interactive_rate = shed_interactive as f64 / interactive_sent as f64;
        if interactive_rate > batch_rate {
            extra_failures.push(format!(
                "priority inversion: interactive shed rate {:.3} > batch shed rate {:.3} \
                 (batch must shed first)",
                interactive_rate, batch_rate
            ));
        }
    }
    // Admitted interactive latency vs the scenario SLO (latency gates
    // are advisory unless --gate-latency, like every other mode).
    let mut extra_slo = Vec::new();
    for k in &surge.report.per_kind {
        if ["query", "query_batch"].contains(&k.kind) && k.ok > 0 {
            let p99 = k.latency.p99_ns as f64 / 1e6;
            if p99 > sc.slo.p99_ms {
                extra_slo.push(format!(
                    "surge interactive {} p99 {p99:.2} ms > SLO {:.2} ms",
                    k.kind, sc.slo.p99_ms
                ));
            }
        }
    }
    // Zero acked-mutation loss: a shed mutation was refused, not acked,
    // so the ledger proof holds through the surge unchanged.
    let expected = verify::determinate_final_state(&surge.ledgers);
    let violations = verify::check_survival_inproc(&gus, &expected);
    eprintln!(
        "[loadgen] acked-mutation survival through surge: {} determinate ids, {} violations",
        expected.len(),
        violations.len()
    );

    // Phase C: recovery. The controller unlatches on its own (sheds
    // against an empty queue decay the pressure EWMA), but that takes a
    // handful of requests — a short unclassed warmup (never shed or
    // degraded, each pop feeding a real sojourn sample) drains the
    // surge's memory first, so the gated run measures the recovered
    // steady state rather than the decay transient.
    let mut client = GusClient::connect(&addr)?;
    let mut warm_rng = Rng::seeded(sc.load_seed ^ 0xc001);
    for i in 0..32u64 {
        let p = sampler.sample(runner::FRESH_ID_BASE + (99 << 28) + i, &mut warm_rng);
        let _ = client.query(&p, sc.corpus.k);
    }
    let post_opts = LoadOptions {
        mix: Mix::query_only(),
        rate: (capacity * 0.3).max(50.0),
        duration: std::time::Duration::from_secs_f64(opts.duration.as_secs_f64().min(5.0)),
        record_points: false,
        classes: true,
        ..opts.clone()
    };
    let post = runner::run_load(&addr, &post_opts, sampler)?;
    eprintln!(
        "[loadgen] phase C: {} ok, {} errors, {} degraded, p99 {:.2} ms",
        post.report.ok,
        post.report.error_total(),
        post.report.degraded,
        post.report.latency.p99_ns as f64 / 1e6
    );
    if post.report.error_total() > 0 || post.report.transport_lost > 0 {
        extra_failures.push(format!(
            "post-surge run had {} errors / {} unanswered (the controller must release \
             the brakes once pressure drains)",
            post.report.error_total(),
            post.report.transport_lost
        ));
    }
    if post.report.degraded > 0 {
        extra_failures.push(format!(
            "post-surge run still served {} degraded responses",
            post.report.degraded
        ));
    }

    let mut report = surge.report;
    report.lost_acked_mutations = Some(violations.len() as u64);
    runner::attach_server_stats(&mut report, &addr);
    handle.shutdown();
    // OVERLOADED is the drill's subject, not a failure; deadline misses
    // during the surge window are the deadline system working as
    // specified on requests admission chose to keep.
    Ok(LoadRun {
        report,
        extra_failures,
        extra_slo,
        crash_mode: false,
        exempt_codes: &["OVERLOADED", "DEADLINE_EXCEEDED"],
    })
}

/// A child node process killed on drop, so a failed drill never leaks
/// listeners. `into_inner` hands the child back for deliberate kills.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Spawn a `gus` child and wait for its `[gus] serving on ADDR` line
/// (stdout is line-buffered; bootstrap chatter goes to inherited
/// stderr). A drain thread keeps the pipe from ever filling.
fn spawn_serving(
    mut cmd: std::process::Command,
    what: &str,
) -> anyhow::Result<(ChildGuard, String)> {
    use std::io::BufRead;
    cmd.stdout(std::process::Stdio::piped());
    let mut child = cmd.spawn()?;
    let out = child.stdout.take().expect("child stdout piped");
    let child = ChildGuard(child);
    let mut lines = std::io::BufReader::new(out).lines();
    let mut addr = None;
    for line in lines.by_ref() {
        let line = line?;
        if let Some(rest) = line.strip_prefix("[gus] serving on ") {
            addr = Some(rest.trim().to_string());
            break;
        }
    }
    let Some(addr) = addr else {
        // ChildGuard's drop reaps it.
        anyhow::bail!("{what} child exited before serving");
    };
    std::thread::spawn(move || for _ in lines {});
    Ok((child, addr))
}

/// Multi-node failover drill: a real leader, two followers and a router
/// (all separate processes), load driven through the router, the leader
/// SIGKILLed mid-run. Passes when the router's health monitor promotes
/// a follower and every *acknowledged* mutation is still present on the
/// new leader (the ledger check) — the paper's bar for dynamic serving:
/// failures may refuse requests, never un-happen acknowledged ones.
fn loadgen_replicated(
    args: &Args,
    sc: &dynamic_gus::loadgen::Scenario,
    opts: &dynamic_gus::loadgen::LoadOptions,
    sampler: &dynamic_gus::data::synthetic::PointSampler,
    crash_at: f64,
) -> anyhow::Result<LoadRun> {
    use dynamic_gus::loadgen::{runner, verify, Mix};
    anyhow::ensure!(
        crash_at >= 0.0 && crash_at.is_finite(),
        "--crash-leader-at must be >= 0"
    );
    let base = args.opt_str("wal-dir").ok_or_else(|| {
        anyhow::anyhow!("--crash-leader-at needs --wal-dir DIR (scratch base for the cluster)")
    })?;
    let base = std::path::PathBuf::from(&base);
    for node in ["leader", "follower-1", "follower-2"] {
        anyhow::ensure!(
            !wal::has_state(&base.join(node)),
            "{} already has WAL state; the drill needs a fresh base directory",
            base.join(node).display()
        );
    }
    let exe = std::env::current_exe()?;

    // Leader: durable, replicating, semi-sync (ack-replicas 1) — an
    // acked mutation is durable on at least one follower, which is what
    // makes "zero acked loss across leader death" a theorem rather than
    // a race. Checkpointing stays on its config default, exercising the
    // retained-tail streaming path under load.
    let mut cmd = std::process::Command::new(&exe);
    cmd.arg("serve")
        .arg("--dataset")
        .arg(&sc.corpus.dataset)
        .arg("--n")
        .arg(sc.corpus.n.to_string())
        .arg("--seed")
        .arg(sc.corpus.seed.to_string())
        .arg("--scann-nn")
        .arg(sc.corpus.k.to_string())
        .arg("--filter-p")
        .arg(sc.corpus.filter_p.to_string())
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--wal-dir")
        .arg(base.join("leader"))
        .arg("--fsync")
        .arg("always")
        .arg("--replicate")
        .arg("--ack-replicas")
        .arg("1");
    if let Some(s) = sc.corpus.idf_s {
        cmd.arg("--idf-s").arg(s.to_string());
    }
    let (leader_child, leader_addr) = spawn_serving(cmd, "leader")?;
    eprintln!("[loadgen] leader on {leader_addr}");

    // Followers bootstrap from the leader (snapshot + tail), so they
    // need no corpus flags of their own.
    let mut followers = Vec::new();
    for name in ["follower-1", "follower-2"] {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("follow")
            .arg("--leader")
            .arg(&leader_addr)
            .arg("--wal-dir")
            .arg(base.join(name))
            .arg("--addr")
            .arg("127.0.0.1:0");
        let (child, addr) = spawn_serving(cmd, name)?;
        eprintln!("[loadgen] {name} on {addr}");
        followers.push((child, addr));
    }

    // The router fronts all three; tight health cadence so failover
    // lands well inside the drill window.
    let targets = format!(
        "{leader_addr},{},{}",
        followers[0].1, followers[1].1
    );
    let mut cmd = std::process::Command::new(&exe);
    cmd.arg("route")
        .arg("--targets")
        .arg(&targets)
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--health-interval-ms")
        .arg("200")
        .arg("--fail-threshold")
        .arg("3");
    let (_router_child, router_addr) = spawn_serving(cmd, "router")?;
    eprintln!(
        "[loadgen] router on {router_addr} -> [{targets}]; killing leader at t={crash_at:.1}s"
    );

    // Drive the router; a second thread delivers the SIGKILL.
    let leader_child = std::sync::Mutex::new(leader_child);
    let outcome = std::thread::scope(|s| -> anyhow::Result<_> {
        let killer = s.spawn(|| {
            std::thread::sleep(std::time::Duration::from_secs_f64(crash_at));
            let mut c = leader_child.lock().unwrap();
            let _ = c.0.kill(); // SIGKILL: no flush, no goodbye
            let _ = c.0.wait();
            eprintln!("[loadgen] leader killed");
        });
        let outcome = runner::run_load(&router_addr, opts, sampler)?;
        killer.join().expect("killer thread panicked");
        Ok(outcome)
    })?;

    // Failover must complete: some follower reports itself leader.
    let mut extra_failures = Vec::new();
    let mut promoted: Option<String> = None;
    for _ in 0..150 {
        for (_, addr) in &followers {
            if node_role(addr).as_deref() == Some("leader") {
                promoted = Some(addr.clone());
            }
        }
        if promoted.is_some() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    let violations = match &promoted {
        Some(addr) => {
            eprintln!("[loadgen] failover complete: new leader {addr}");
            // The ledger check, against the new leader directly: every
            // id whose ops were all acked must be in its acked state.
            let expected = verify::determinate_final_state(&outcome.ledgers);
            let mut client = GusClient::connect(addr)?;
            let violations = verify::check_survival_rpc(&mut client, &expected)?;
            eprintln!(
                "[loadgen] acked-mutation survival on new leader: {} determinate ids, \
                 {} violations",
                expected.len(),
                violations.len()
            );
            violations.len() as u64
        }
        None => {
            extra_failures
                .push("no follower was promoted within 30s of the leader dying".to_string());
            0
        }
    };

    // The router must still serve reads (scatter tolerates the dead
    // target; forwards go to the promoted leader).
    let post_opts = dynamic_gus::loadgen::LoadOptions {
        mix: Mix::query_only(),
        duration: std::time::Duration::from_secs_f64(opts.duration.as_secs_f64().min(5.0)),
        record_points: false,
        ..opts.clone()
    };
    let post = runner::run_load(&router_addr, &post_opts, sampler)?;
    eprintln!(
        "[loadgen] post-failover queries via router: {} ok, {} errors, p50 {:.2} ms  \
         p99 {:.2} ms",
        post.report.ok,
        post.report.error_total(),
        post.report.latency.p50_ns as f64 / 1e6,
        post.report.latency.p99_ns as f64 / 1e6
    );
    if post.report.error_total() > 0 || post.report.transport_lost > 0 {
        extra_failures.push(format!(
            "post-failover run had {} errors / {} unanswered",
            post.report.error_total(),
            post.report.transport_lost
        ));
    }
    let extra_slo = post
        .report
        .slo_violations(&sc.slo)
        .into_iter()
        .map(|v| format!("post-failover {v}"))
        .collect();

    let mut report = outcome.report;
    report.lost_acked_mutations = Some(violations);
    // During the failover window the router legitimately answers
    // UNAVAILABLE (no leader), NOT_LEADER (race with a node's own
    // refusal) and DEADLINE_EXCEEDED (probe backlog); the ledger check
    // above is the correctness gate for everything those responses
    // covered.
    Ok(LoadRun {
        report,
        extra_failures,
        extra_slo,
        crash_mode: true,
        exempt_codes: &["TRANSPORT", "UNAVAILABLE", "NOT_LEADER", "DEADLINE_EXCEEDED"],
    })
}

/// One node's `stats` payload over a bounded connection (`None` =
/// unreachable within the timeouts).
fn node_stats(addr: &str) -> Option<Json> {
    let mut c = GusClient::connect_timeout(addr, std::time::Duration::from_secs(1)).ok()?;
    c.set_read_timeout(Some(std::time::Duration::from_secs(2))).ok()?;
    c.stats().ok()
}

/// One node's self-reported replication role (`None` = unreachable).
fn node_role(addr: &str) -> Option<String> {
    node_stats(addr)?.get("replication").get("role").as_str().map(str::to_string)
}

/// One node's durable WAL sequence number (`None` = unreachable).
fn node_wal_seq(addr: &str) -> Option<u64> {
    node_stats(addr)?.get("replication").get("wal_last_seq").as_u64()
}

/// Backoff retries a node has counted (its stats `faults` section);
/// unreachable counts as zero.
fn node_backoff_retries(addr: &str) -> u64 {
    node_stats(addr)
        .and_then(|s| s.get("faults").get("backoff_retries").as_u64())
        .unwrap_or(0)
}

/// Deterministic network-fault drill: the failover drill's four-process
/// topology (leader, two followers, router), but with every inter-node
/// link routed through an in-process chaosproxy executing a fault
/// schedule derived from `--chaos SEED`. Nothing gets killed — the
/// subject is the *network*: partitions, one-way blackholes, added
/// latency, bandwidth caps, and mid-frame truncation of the replication
/// stream. The claim under test is that the cluster degrades to
/// refusals, never to lost acknowledged mutations or diverged WALs.
///
/// Promotion is suppressed (`--fail-threshold` effectively infinite): a
/// partitioned leader is still the leader, and promoting around it would
/// manufacture split-brain — the failover drill covers real leader
/// death; this one covers everything short of it.
///
/// Gates, after the load window (whose last ~fifth is fault-free by
/// construction, giving reconvergence a head start):
/// 1. every follower's durable WAL seq catches up to the leader's;
/// 2. each follower's `wal.log` is a byte prefix of the leader's
///    (checkpoints are disabled on all nodes so the files compare raw);
/// 3. every acknowledged mutation is present on the leader;
/// 4. the faults demonstrably bit: follower backoff retries were counted;
/// 5. a post-fault query-only run through the router is error-free.
fn loadgen_chaos(
    args: &Args,
    sc: &dynamic_gus::loadgen::Scenario,
    opts: &dynamic_gus::loadgen::LoadOptions,
    sampler: &dynamic_gus::data::synthetic::PointSampler,
    seed: u64,
) -> anyhow::Result<LoadRun> {
    use dynamic_gus::fault::{proxy, Schedule};
    use dynamic_gus::loadgen::{runner, verify, ChaosProxyReport, ChaosSummary, Mix};
    use dynamic_gus::util::hash::mix2;

    let base = args.opt_str("wal-dir").ok_or_else(|| {
        anyhow::anyhow!("--chaos needs --wal-dir DIR (scratch base for the cluster)")
    })?;
    let base = std::path::PathBuf::from(&base);
    for node in ["leader", "follower-1", "follower-2"] {
        anyhow::ensure!(
            !wal::has_state(&base.join(node)),
            "{} already has WAL state; the drill needs a fresh base directory",
            base.join(node).display()
        );
    }
    let exe = std::env::current_exe()?;

    // The fault timeline spans the load window; per-link seeds derive
    // from the one drill seed, so a single number replays all three
    // schedules bit-for-bit. The leader link is guaranteed at least one
    // partition so the reconnect/backoff machinery provably runs.
    let span_ms = (sc.duration_s * 1_000.0) as u64;
    let schedules = [
        ("leader", Schedule::generate(mix2(seed, 0), span_ms, true)),
        ("follower-1", Schedule::generate(mix2(seed, 1), span_ms, false)),
        ("follower-2", Schedule::generate(mix2(seed, 2), span_ms, false)),
    ];
    for (label, sched) in &schedules {
        eprintln!(
            "[loadgen] chaos {label}: digest {:016x} [{}]",
            sched.digest(),
            sched.describe()
        );
    }

    // Leader: durable, replicating, semi-sync (an acked mutation is
    // durable on at least one follower). Checkpoints are off so wal.log
    // is never truncated — gate 2 is a literal byte comparison.
    let mut cmd = std::process::Command::new(&exe);
    cmd.arg("serve")
        .arg("--dataset")
        .arg(&sc.corpus.dataset)
        .arg("--n")
        .arg(sc.corpus.n.to_string())
        .arg("--seed")
        .arg(sc.corpus.seed.to_string())
        .arg("--scann-nn")
        .arg(sc.corpus.k.to_string())
        .arg("--filter-p")
        .arg(sc.corpus.filter_p.to_string())
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--wal-dir")
        .arg(base.join("leader"))
        .arg("--fsync")
        .arg("always")
        .arg("--checkpoint-every")
        .arg("0")
        .arg("--replicate")
        .arg("--ack-replicas")
        .arg("1");
    if let Some(s) = sc.corpus.idf_s {
        cmd.arg("--idf-s").arg(s.to_string());
    }
    let (_leader_child, leader_addr) = spawn_serving(cmd, "leader")?;
    eprintln!("[loadgen] leader on {leader_addr}");

    // The leader-link proxy: followers subscribe *through* it, so its
    // partitions cut the replication stream mid-flight and its truncate
    // windows tear WAL frames on the wire. Unarmed = passthrough, so the
    // topology boots cleanly; the timeline starts when load starts.
    let leader_proxy = proxy::start("127.0.0.1:0", &leader_addr, schedules[0].1.clone())?;

    let mut followers = Vec::new();
    for name in ["follower-1", "follower-2"] {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("follow")
            .arg("--leader")
            .arg(leader_proxy.addr())
            .arg("--wal-dir")
            .arg(base.join(name))
            .arg("--checkpoint-every")
            .arg("0")
            .arg("--addr")
            .arg("127.0.0.1:0");
        let (child, addr) = spawn_serving(cmd, name)?;
        eprintln!("[loadgen] {name} on {addr} (leader via {})", leader_proxy.addr());
        followers.push((child, addr));
    }

    // Follower-link proxies sit between the router and each follower, so
    // scatter reads eat their own fault schedules too.
    let f1_proxy = proxy::start("127.0.0.1:0", &followers[0].1, schedules[1].1.clone())?;
    let f2_proxy = proxy::start("127.0.0.1:0", &followers[1].1, schedules[2].1.clone())?;

    let targets =
        format!("{},{},{}", leader_proxy.addr(), f1_proxy.addr(), f2_proxy.addr());
    let mut cmd = std::process::Command::new(&exe);
    cmd.arg("route")
        .arg("--targets")
        .arg(&targets)
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--health-interval-ms")
        .arg("200")
        .arg("--fail-threshold")
        .arg("100000");
    let (_router_child, router_addr) = spawn_serving(cmd, "router")?;
    eprintln!("[loadgen] router on {router_addr} -> [{targets}]; chaos seed {seed:#x}");

    // Arm every fault timeline, then start the load: drill time zero is
    // load time zero, so the printed schedules line up with the run.
    leader_proxy.arm();
    f1_proxy.arm();
    f2_proxy.arm();
    let outcome = runner::run_load(&router_addr, opts, sampler)?;

    let mut extra_failures = Vec::new();

    // Gate 1: reconvergence. Probed directly (not through the proxies) —
    // the drill measures the cluster, not the probe path.
    let t0 = std::time::Instant::now();
    let mut reconverge_ms = None;
    while t0.elapsed() < std::time::Duration::from_secs(30) {
        let leader_seq = node_wal_seq(&leader_addr);
        if leader_seq.is_some()
            && followers.iter().all(|(_, a)| node_wal_seq(a) == leader_seq)
        {
            reconverge_ms = Some(t0.elapsed().as_millis() as u64);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    match reconverge_ms {
        Some(ms) => eprintln!("[loadgen] cluster reconverged {ms} ms after load end"),
        None => extra_failures
            .push("cluster did not reconverge within 30s of load end".to_string()),
    }

    // Gate 2: the prefix property, on the actual bytes. Valid because
    // no node checkpoints (no truncation) and heartbeats are wire-only.
    let leader_wal = std::fs::read(base.join("leader").join(wal::WAL_FILE))?;
    for name in ["follower-1", "follower-2"] {
        let bytes = std::fs::read(base.join(name).join(wal::WAL_FILE))?;
        if bytes.len() > leader_wal.len() || leader_wal[..bytes.len()] != bytes[..] {
            extra_failures.push(format!(
                "{name} wal.log ({} bytes) is not a byte prefix of the leader's ({} bytes)",
                bytes.len(),
                leader_wal.len()
            ));
        } else {
            eprintln!(
                "[loadgen] {name} WAL is a byte prefix of the leader's ({}/{} bytes)",
                bytes.len(),
                leader_wal.len()
            );
        }
    }

    // Gate 3: acked-mutation survival, against the leader directly.
    let expected = verify::determinate_final_state(&outcome.ledgers);
    let mut client = GusClient::connect(&leader_addr)?;
    let violations = verify::check_survival_rpc(&mut client, &expected)?;
    eprintln!(
        "[loadgen] acked-mutation survival on leader: {} determinate ids, {} violations",
        expected.len(),
        violations.len()
    );

    // Gate 4: the faults must have actually bitten. The guaranteed
    // leader-link partition forces at least one follower reconnect, and
    // every reconnect wait is counted by the fault gauges.
    let retries: u64 = followers.iter().map(|(_, a)| node_backoff_retries(a)).sum();
    if retries == 0 {
        extra_failures.push(
            "no backoff retries recorded on any follower — the fault schedule never bit \
             the replication stream"
                .to_string(),
        );
    }

    // Gate 5: with the schedules exhausted the proxies are passthrough
    // again; queries through the router must be error-free.
    let post_opts = dynamic_gus::loadgen::LoadOptions {
        mix: Mix::query_only(),
        duration: std::time::Duration::from_secs_f64(opts.duration.as_secs_f64().min(5.0)),
        record_points: false,
        ..opts.clone()
    };
    let post = runner::run_load(&router_addr, &post_opts, sampler)?;
    eprintln!(
        "[loadgen] post-chaos queries via router: {} ok, {} errors, p50 {:.2} ms  \
         p99 {:.2} ms",
        post.report.ok,
        post.report.error_total(),
        post.report.latency.p50_ns as f64 / 1e6,
        post.report.latency.p99_ns as f64 / 1e6
    );
    if post.report.error_total() > 0 || post.report.transport_lost > 0 {
        extra_failures.push(format!(
            "post-chaos run had {} errors / {} unanswered",
            post.report.error_total(),
            post.report.transport_lost
        ));
    }
    let extra_slo = post
        .report
        .slo_violations(&sc.slo)
        .into_iter()
        .map(|v| format!("post-chaos {v}"))
        .collect();

    let mut report = outcome.report;
    report.lost_acked_mutations = Some(violations.len() as u64);
    runner::attach_server_stats(&mut report, &leader_addr);
    report.chaos = Some(ChaosSummary {
        seed,
        proxies: schedules
            .iter()
            .map(|(label, s)| ChaosProxyReport {
                label: label.to_string(),
                digest: s.digest(),
                by_kind: s.windows_by_kind(),
                schedule: s.describe(),
            })
            .collect(),
        reconverge_ms,
        backoff_retries: retries,
    });
    // During fault windows the router legitimately answers UNAVAILABLE
    // (leader unreachable), NOT_LEADER (stale adoption), DEADLINE_EXCEEDED
    // (latency/blackhole windows) and OVERLOADED (queues absorb the
    // backlog); the ledger check above is the correctness gate for
    // everything those refusals covered.
    Ok(LoadRun {
        report,
        extra_failures,
        extra_slo,
        crash_mode: true,
        exempt_codes: &[
            "TRANSPORT",
            "UNAVAILABLE",
            "NOT_LEADER",
            "DEADLINE_EXCEEDED",
            "OVERLOADED",
        ],
    })
}

/// Crash/recovery injection: spawn a real `gus serve` child (fsync
/// always, durable), SIGKILL it mid-load, recover from its WAL, prove
/// every acknowledged mutation survived and that each connection's
/// recovered state is an applied prefix of its submission order, then
/// re-serve the recovered state and check queries against the same SLO.
fn loadgen_crash(
    args: &Args,
    sc: &dynamic_gus::loadgen::Scenario,
    opts: &dynamic_gus::loadgen::LoadOptions,
    sampler: &dynamic_gus::data::synthetic::PointSampler,
    crash_at: f64,
) -> anyhow::Result<LoadRun> {
    use dynamic_gus::loadgen::{runner, verify, Mix};
    use std::io::BufRead;
    anyhow::ensure!(crash_at >= 0.0 && crash_at.is_finite(), "--crash-at must be >= 0");
    let dir = args.opt_str("wal-dir").ok_or_else(|| {
        anyhow::anyhow!("--crash-at needs --wal-dir DIR (durability is what's under test)")
    })?;
    anyhow::ensure!(
        !wal::has_state(std::path::Path::new(&dir)),
        "--wal-dir {dir} already has WAL state; crash runs need a fresh directory"
    );

    // A real child process, so the kill is a genuine process death (no
    // in-process cleanup can soften it).
    let exe = std::env::current_exe()?;
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("serve")
        .arg("--dataset")
        .arg(&sc.corpus.dataset)
        .arg("--n")
        .arg(sc.corpus.n.to_string())
        .arg("--seed")
        .arg(sc.corpus.seed.to_string())
        .arg("--scann-nn")
        .arg(sc.corpus.k.to_string())
        .arg("--filter-p")
        .arg(sc.corpus.filter_p.to_string())
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--wal-dir")
        .arg(&dir)
        .arg("--fsync")
        .arg("always")
        .arg("--checkpoint-every")
        .arg("0")
        .stdout(std::process::Stdio::piped());
    if let Some(s) = sc.corpus.idf_s {
        cmd.arg("--idf-s").arg(s.to_string());
    }
    let mut child = cmd.spawn()?;
    // Bootstrap progress goes to the child's inherited stderr; stdout
    // carries the one line we need.
    let child_out = child.stdout.take().expect("child stdout piped");
    let mut lines = std::io::BufReader::new(child_out).lines();
    let mut addr = None;
    for line in lines.by_ref() {
        let line = line?;
        if let Some(rest) = line.strip_prefix("[gus] serving on ") {
            addr = Some(rest.trim().to_string());
            break;
        }
    }
    let addr = addr.ok_or_else(|| anyhow::anyhow!("child server exited before serving"))?;
    // Keep draining so the child never blocks on a full stdout pipe.
    std::thread::spawn(move || for _ in lines {});
    eprintln!("[loadgen] child serving on {addr}; killing at t={crash_at:.1}s");

    let child = std::sync::Mutex::new(child);
    let report = std::thread::scope(|s| -> anyhow::Result<_> {
        let killer = s.spawn(|| {
            std::thread::sleep(std::time::Duration::from_secs_f64(crash_at));
            let mut c = child.lock().unwrap();
            let _ = c.kill(); // SIGKILL: no flush, no goodbye
            let _ = c.wait();
        });
        let outcome = runner::run_load(&addr, opts, sampler)?;
        killer.join().expect("killer thread panicked");
        Ok(outcome)
    })?;
    let outcome = report;

    eprintln!("[loadgen] server killed; recovering from {dir}");
    let threads = dynamic_gus::util::threadpool::default_parallelism();
    let t0 = std::time::Instant::now();
    let rec = wal::recover(std::path::Path::new(&dir), threads)?;
    eprintln!(
        "[loadgen] recovered {} points ({} WAL records replayed{}) in {:.2}s",
        rec.gus.len(),
        rec.replayed,
        if rec.torn_tail { ", torn tail truncated" } else { "" },
        t0.elapsed().as_secs_f64()
    );

    let mut extra_failures = Vec::new();
    let expected = verify::determinate_final_state(&outcome.ledgers);
    let violations = verify::check_survival_inproc(&rec.gus, &expected);
    eprintln!(
        "[loadgen] acked-mutation survival: {} determinate ids checked, {} violations",
        expected.len(),
        violations.len()
    );
    for (i, ledger) in outcome.ledgers.iter().enumerate() {
        match verify::find_applied_prefix(ledger, |id| rec.gus.contains(id)) {
            Some(m) => eprintln!(
                "[loadgen] conn {i}: recovered state = applied prefix {m}/{} mutations",
                ledger.records.len()
            ),
            None => extra_failures.push(format!(
                "conn {i}: no applied prefix of the submission order explains the \
                 recovered state"
            )),
        }
    }

    // Re-serve the recovered state; queries must meet the same SLO.
    let gus = Arc::new(rec.gus);
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::from_gus(gus.config()))?;
    let post_opts = dynamic_gus::loadgen::LoadOptions {
        mix: Mix::query_only(),
        duration: std::time::Duration::from_secs_f64(opts.duration.as_secs_f64().min(5.0)),
        record_points: false,
        ..opts.clone()
    };
    let post = runner::run_load(&handle.addr.to_string(), &post_opts, sampler)?;
    eprintln!(
        "[loadgen] post-recovery queries: {} ok, {} errors, p50 {:.2} ms  p99 {:.2} ms",
        post.report.ok,
        post.report.error_total(),
        post.report.latency.p50_ns as f64 / 1e6,
        post.report.latency.p99_ns as f64 / 1e6
    );
    if post.report.error_total() > 0 || post.report.transport_lost > 0 {
        extra_failures.push(format!(
            "post-recovery run had {} errors / {} unanswered",
            post.report.error_total(),
            post.report.transport_lost
        ));
    }
    let extra_slo = post
        .report
        .slo_violations(&sc.slo)
        .into_iter()
        .map(|v| format!("post-recovery {v}"))
        .collect();
    handle.shutdown();

    let mut report = outcome.report;
    report.lost_acked_mutations = Some(violations.len() as u64);
    Ok(LoadRun {
        report,
        extra_failures,
        extra_slo,
        crash_mode: true,
        exempt_codes: &["TRANSPORT"],
    })
}
