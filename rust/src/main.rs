//! `gus` — the Dynamic GUS launcher.
//!
//! ```text
//! gus serve   --dataset arxiv_like --n 20000 --addr 127.0.0.1:7717
//!             [--scann-nn K] [--idf-s S] [--filter-p P] [--scorer auto]
//!             [--load data.jsonl]
//!             [--wal-dir DIR] [--fsync always|every_n[:N]|never]
//!             [--checkpoint-every M]
//!             [--max-connections C] [--rpc-workers W] [--rpc-queue Q]
//!             # RPC scheduling: W workers (0 = auto) execute enveloped
//!             # v1 requests from a bounded queue of Q; saturation sheds
//!             # with OVERLOADED. See docs/PROTOCOL.md.
//!             # --wal-dir makes the service durable: mutations are
//!             # write-ahead logged, checkpoints land in DIR, and a
//!             # restart with the same --wal-dir recovers everything.
//! gus recover --wal-dir DIR [--addr 127.0.0.1:7717]
//!             # restore checkpoint + WAL, compact, optionally serve
//! gus checkpoint --addr 127.0.0.1:7717   # force a checkpoint via RPC
//! gus query   --addr 127.0.0.1:7717 --id 42 [--k 10]
//! gus insert  --addr 127.0.0.1:7717 --point '{"id":..,"features":[..]}'
//! gus delete  --addr 127.0.0.1:7717 --id 42
//! gus stats   --addr 127.0.0.1:7717
//! gus gen     --dataset products_like --n 5000 --out data.jsonl
//! gus gen-trace --dataset arxiv_like --n 5000 --ops 2000 --out trace.jsonl
//! gus replay  --trace trace.jsonl [--workers 8] [--mode sync|pipeline|batch]
//!             # replay a workload; `batch` drives the insert_batch /
//!             # query_batch RPCs in --batch-size chunks
//! gus preprocess --dataset arxiv_like --n 20000   # table summary (§4.3)
//! ```
//!
//! `serve` also accepts the legacy `--snapshot-dir DIR` (restore-only, no
//! WAL); prefer `--wal-dir`, which loses nothing on a crash.
//!
//! `serve` boots the full stack: dataset (generated or loaded), offline
//! preprocessing, index warm-up, scorer (XLA artifacts if present), then
//! the TCP JSON-lines RPC server. The wire protocol is specified in
//! docs/PROTOCOL.md; the system layout in docs/ARCHITECTURE.md.

use std::sync::Arc;

use dynamic_gus::client::GusClient;
use dynamic_gus::config::GusConfig;
use dynamic_gus::coordinator::{wal, DynamicGus};
use dynamic_gus::data::{loader, synthetic::SyntheticConfig};
use dynamic_gus::features::Point;
use dynamic_gus::server::{serve, ServerConfig};
use dynamic_gus::util::cli::Args;
use dynamic_gus::util::json::Json;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    let code = match run(&cmd, &args) {
        Ok(()) => match args.check_unused() {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("warning: {e}");
                0
            }
        },
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn load_or_generate(args: &Args) -> anyhow::Result<dynamic_gus::data::Dataset> {
    if let Some(path) = args.opt_str("load") {
        return loader::load(std::path::Path::new(&path));
    }
    let name = args.get_str("dataset", "arxiv_like");
    let n = args.get_usize("n", 20_000);
    let seed = args.get_u64("seed", 0xa1);
    Ok(match name.as_str() {
        "arxiv_like" => SyntheticConfig::arxiv_like(n, seed).generate(),
        "products_like" => SyntheticConfig::products_like(n, seed).generate(),
        other => anyhow::bail!("unknown dataset '{other}'"),
    })
}

/// Infer the schema from loaded points (trace files carry no header).
fn infer_schema(points: &[Point]) -> anyhow::Result<dynamic_gus::features::Schema> {
    let p = points
        .first()
        .ok_or_else(|| anyhow::anyhow!("empty trace: cannot infer schema"))?;
    use dynamic_gus::features::{FeatureValue, Schema};
    let dense_dim = p
        .features
        .iter()
        .find_map(|f| match f {
            FeatureValue::Dense(v) => Some(v.len()),
            _ => None,
        })
        .ok_or_else(|| anyhow::anyhow!("points have no dense channel"))?;
    let has_tokens = p
        .features
        .iter()
        .any(|f| matches!(f, FeatureValue::Tokens(_)));
    Ok(if has_tokens {
        Schema::products_like(dense_dim)
    } else {
        Schema::arxiv_like(dense_dim)
    })
}

fn run(cmd: &str, args: &Args) -> anyhow::Result<()> {
    match cmd {
        "serve" => {
            let config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            // RPC scheduling knobs are per-incarnation operational
            // settings: the command line (or its defaults) wins even when
            // the service state is recovered from a snapshot or WAL
            // directory.
            let server_cfg = ServerConfig::from_gus(&config);
            if let Some(dir) = args.opt_str("snapshot-dir") {
                if args.opt_str("wal-dir").is_some() {
                    anyhow::bail!(
                        "--snapshot-dir and --wal-dir are mutually exclusive; \
                         --wal-dir supersedes it (recovers snapshots too, losslessly)"
                    );
                }
                let dir = std::path::PathBuf::from(dir);
                if dir.join("snapshot.json").exists() {
                    eprintln!("[gus] restoring from snapshot {}", dir.display());
                    let gus = dynamic_gus::coordinator::snapshot::restore(
                        &dir,
                        dynamic_gus::util::threadpool::default_parallelism(),
                    )?;
                    let addr = args.get_str("addr", "127.0.0.1:7717");
                    let handle = serve(Arc::new(gus), &addr, server_cfg)?;
                    println!("[gus] serving restored snapshot on {}", handle.addr);
                    loop {
                        std::thread::sleep(std::time::Duration::from_secs(3600));
                    }
                }
            }
            let threads = args.get_usize(
                "threads",
                dynamic_gus::util::threadpool::default_parallelism(),
            );
            // Durability knobs as parsed from the CLI, kept aside: on
            // recovery the persisted config is otherwise authoritative,
            // but knobs the operator set explicitly for this incarnation
            // (--fsync, --checkpoint-every) must win.
            let cli_fsync = args.opt_str("fsync").map(|_| config.fsync);
            let cli_checkpoint_every =
                args.opt_str("checkpoint-every").map(|_| config.checkpoint_every);
            let gus = match config.wal_dir.clone() {
                Some(dir) if wal::has_state(std::path::Path::new(&dir)) => {
                    let t0 = std::time::Instant::now();
                    let rec =
                        wal::recover_with(std::path::Path::new(&dir), threads, cli_fsync)?;
                    eprintln!(
                        "[gus] recovered {} points from {dir} ({} from checkpoint, \
                         {} WAL records replayed{}) in {:.1}s",
                        rec.gus.len(),
                        rec.snapshot_points,
                        rec.replayed,
                        if rec.torn_tail { ", torn tail truncated" } else { "" },
                        t0.elapsed().as_secs_f64()
                    );
                    rec.gus
                }
                wal_dir => {
                    let ds = load_or_generate(args)?;
                    eprintln!(
                        "[gus] bootstrapping {} points ({}), config {}",
                        ds.points.len(),
                        ds.schema.name,
                        config.to_json().dump()
                    );
                    let t0 = std::time::Instant::now();
                    let gus =
                        DynamicGus::bootstrap(ds.schema.clone(), config, &ds.points, threads)?;
                    if let Some(dir) = wal_dir {
                        wal::init_fresh(&gus, std::path::Path::new(&dir))?;
                        eprintln!("[gus] durability on: WAL + checkpoints in {dir}");
                    }
                    eprintln!("[gus] ready in {:.1}s", t0.elapsed().as_secs_f64());
                    gus
                }
            };
            let gus = Arc::new(gus);
            // Background checkpointer: bounds WAL length (and restart
            // cost) without stalling the mutation path on every op.
            let every = cli_checkpoint_every.unwrap_or_else(|| gus.config().checkpoint_every);
            let _checkpointer = (gus.wal().is_some() && every > 0).then(|| {
                wal::Checkpointer::spawn(
                    Arc::clone(&gus),
                    every,
                    std::time::Duration::from_millis(500),
                )
            });
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let handle = serve(Arc::clone(&gus), &addr, server_cfg)?;
            println!("[gus] serving on {}", handle.addr);
            // Serve until killed.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "recover" => {
            let dir = args
                .opt_str("wal-dir")
                .ok_or_else(|| anyhow::anyhow!("recover needs --wal-dir DIR"))?;
            let threads = args.get_usize(
                "threads",
                dynamic_gus::util::threadpool::default_parallelism(),
            );
            // Same CLI overrides as `serve` on a recovered service.
            let cli_fsync = args
                .opt_str("fsync")
                .map(|s| dynamic_gus::config::FsyncPolicy::parse(&s))
                .transpose()
                .map_err(|e| anyhow::anyhow!(e))?;
            let cli_checkpoint_every =
                args.opt_str("checkpoint-every").map(|s| s.parse::<u64>()).transpose()?;
            let t0 = std::time::Instant::now();
            let rec = wal::recover_with(std::path::Path::new(&dir), threads, cli_fsync)?;
            println!(
                "recovered {} points from {dir}: {} from checkpoint, {} WAL records \
                 replayed{} ({:.2}s)",
                rec.gus.len(),
                rec.snapshot_points,
                rec.replayed,
                if rec.torn_tail { ", torn tail truncated" } else { "" },
                t0.elapsed().as_secs_f64()
            );
            // Compact: fold the replayed tail into a fresh checkpoint so
            // the next recovery replays nothing.
            let seq = rec.gus.checkpoint()?;
            println!("compacted: checkpoint at seq {seq}, WAL truncated");
            if let Some(addr) = args.opt_str("addr") {
                let gus = Arc::new(rec.gus);
                let every =
                    cli_checkpoint_every.unwrap_or_else(|| gus.config().checkpoint_every);
                let _checkpointer = (every > 0).then(|| {
                    wal::Checkpointer::spawn(
                        Arc::clone(&gus),
                        every,
                        std::time::Duration::from_millis(500),
                    )
                });
                // RPC scheduling knobs: explicit CLI flags win over the
                // recovered incarnation's persisted values, validated the
                // same way as on the `serve` path.
                let mut rpc_cfg = gus.config().clone();
                rpc_cfg.max_connections =
                    args.get_usize("max-connections", rpc_cfg.max_connections);
                rpc_cfg.rpc_workers = args.get_usize("rpc-workers", rpc_cfg.rpc_workers);
                rpc_cfg.rpc_queue = args.get_usize("rpc-queue", rpc_cfg.rpc_queue);
                rpc_cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
                let handle = serve(Arc::clone(&gus), &addr, ServerConfig::from_gus(&rpc_cfg))?;
                println!("[gus] serving on {}", handle.addr);
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(3600));
                }
            }
            Ok(())
        }
        "checkpoint" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let seq = client.checkpoint()?;
            println!("ok checkpoint seq={seq}");
            Ok(())
        }
        "query" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let k = args.get_usize("k", 10);
            let neighbors = if let Some(id) = args.opt_str("id") {
                client.query_id(id.parse()?, k)?
            } else if let Some(pjson) = args.opt_str("point") {
                let p = Point::from_json(&Json::parse(&pjson).map_err(|e| anyhow::anyhow!("{e}"))?)
                    .ok_or_else(|| anyhow::anyhow!("bad point json"))?;
                client.query(&p, k)?
            } else {
                anyhow::bail!("query needs --id or --point");
            };
            for n in neighbors {
                println!("{}\t{:.4}\t{:.3}", n.id, n.score, n.dot);
            }
            Ok(())
        }
        "insert" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let pjson = args
                .opt_str("point")
                .ok_or_else(|| anyhow::anyhow!("insert needs --point"))?;
            let p = Point::from_json(&Json::parse(&pjson).map_err(|e| anyhow::anyhow!("{e}"))?)
                .ok_or_else(|| anyhow::anyhow!("bad point json"))?;
            let existed = client.insert(&p)?;
            println!("ok existed={existed}");
            Ok(())
        }
        "delete" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            let id: u64 = args
                .opt_str("id")
                .ok_or_else(|| anyhow::anyhow!("delete needs --id"))?
                .parse()?;
            let existed = client.delete(id)?;
            println!("ok existed={existed}");
            Ok(())
        }
        "stats" => {
            let addr = args.get_str("addr", "127.0.0.1:7717");
            let mut client = GusClient::connect(&addr)?;
            println!("{}", client.stats()?.dump());
            Ok(())
        }
        "gen-trace" => {
            let ds = load_or_generate(args)?;
            let trace_cfg = dynamic_gus::data::trace::TraceConfig {
                initial_fraction: args.get_f64("initial-fraction", 0.8),
                n_ops: args.get_usize("ops", 2_000),
                insert_prob: args.get_f64("insert-prob", 0.1),
                update_prob: args.get_f64("update-prob", 0.05),
                delete_prob: args.get_f64("delete-prob", 0.02),
                query_k: args.get_usize("k", 10),
                seed: args.get_u64("trace-seed", 0x7472),
            };
            let trace = trace_cfg.build(&ds);
            let out = args.get_str("out", "trace.jsonl");
            trace.save(std::path::Path::new(&out))?;
            let (i, u, d, q) = trace.op_mix();
            println!(
                "wrote {out}: {} initial points; ops: {i} inserts {u} updates {d} deletes {q} queries",
                trace.initial.len()
            );
            Ok(())
        }
        "replay" => {
            use dynamic_gus::coordinator::{IngestPipeline, Mutation};
            use dynamic_gus::data::trace::{Op, Trace};
            let path = args
                .opt_str("trace")
                .ok_or_else(|| anyhow::anyhow!("replay needs --trace FILE"))?;
            let trace = Trace::load(std::path::Path::new(&path))?;
            let schema = infer_schema(&trace.initial)?;
            let config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            let workers = args.get_usize("workers", 1);
            let gus = Arc::new(DynamicGus::bootstrap(
                schema,
                config,
                &trace.initial,
                dynamic_gus::util::threadpool::default_parallelism(),
            )?);
            let mode = args.get_str("mode", if workers <= 1 { "sync" } else { "pipeline" });
            if !["sync", "pipeline", "batch"].contains(&mode.as_str()) {
                anyhow::bail!("unknown --mode '{mode}' (sync|pipeline|batch)");
            }
            let t0 = std::time::Instant::now();
            if mode == "batch" {
                // Drive the batch RPCs: consecutive ops of one kind are
                // grouped into --batch-size chunks. Buffers are flushed
                // before any op of a different kind, so every op observes
                // all earlier mutations (same visibility as sync replay).
                let bs = gus.config().batch_size;
                let mut inserts: Vec<Point> = Vec::new();
                let mut deletes: Vec<u64> = Vec::new();
                let mut queries: Vec<Point> = Vec::new();
                let mut query_k = 0usize;
                for op in &trace.ops {
                    match op {
                        Op::Insert(p) | Op::Update(p) => {
                            if !queries.is_empty() {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                            if !deletes.is_empty() {
                                gus.delete_batch(&std::mem::take(&mut deletes))?;
                            }
                            inserts.push(p.clone());
                            if inserts.len() >= bs {
                                gus.insert_batch(std::mem::take(&mut inserts))?;
                            }
                        }
                        Op::Delete(id) => {
                            if !queries.is_empty() {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                            if !inserts.is_empty() {
                                gus.insert_batch(std::mem::take(&mut inserts))?;
                            }
                            deletes.push(*id);
                            if deletes.len() >= bs {
                                gus.delete_batch(&std::mem::take(&mut deletes))?;
                            }
                        }
                        Op::Query { point, k } => {
                            if !inserts.is_empty() {
                                gus.insert_batch(std::mem::take(&mut inserts))?;
                            }
                            if !deletes.is_empty() {
                                gus.delete_batch(&std::mem::take(&mut deletes))?;
                            }
                            if !queries.is_empty() && *k != query_k {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                            query_k = *k;
                            queries.push(point.clone());
                            if queries.len() >= bs {
                                gus.query_batch(&std::mem::take(&mut queries), query_k)?;
                            }
                        }
                    }
                }
                if !inserts.is_empty() {
                    gus.insert_batch(inserts)?;
                }
                if !deletes.is_empty() {
                    gus.delete_batch(&deletes)?;
                }
                if !queries.is_empty() {
                    gus.query_batch(&queries, query_k)?;
                }
            } else if mode == "sync" || workers <= 1 {
                for op in &trace.ops {
                    match op {
                        Op::Insert(p) | Op::Update(p) => {
                            gus.insert(p.clone())?;
                        }
                        Op::Delete(id) => {
                            gus.delete(*id)?;
                        }
                        Op::Query { point, k } => {
                            gus.query(point, *k)?;
                        }
                    }
                }
            } else {
                // Mutations through the bulk pipeline; queries inline.
                let pipeline = IngestPipeline::new(Arc::clone(&gus), workers, 1024);
                for op in &trace.ops {
                    match op {
                        Op::Insert(p) | Op::Update(p) => {
                            pipeline.submit(Mutation::Upsert(p.clone()))
                        }
                        Op::Delete(id) => pipeline.submit(Mutation::Delete(*id)),
                        Op::Query { point, k } => {
                            gus.query(point, *k)?;
                        }
                    }
                }
                pipeline.flush();
                pipeline.shutdown();
            }
            let wall = t0.elapsed();
            println!(
                "replayed {} ops in {:.2}s ({:.0} ops/s, mode={mode}, workers={workers})",
                trace.ops.len(),
                wall.as_secs_f64(),
                trace.ops.len() as f64 / wall.as_secs_f64()
            );
            println!("{}", gus.stats_json().dump());
            Ok(())
        }
        "snapshot" => {
            // Save a freshly-bootstrapped service (demo/ops tool); the
            // served process also does this via --snapshot-dir.
            let ds = load_or_generate(args)?;
            let config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            let gus = DynamicGus::bootstrap(
                ds.schema.clone(),
                config,
                &ds.points,
                dynamic_gus::util::threadpool::default_parallelism(),
            )?;
            let dir = args.get_str("snapshot-dir", "snapshot");
            dynamic_gus::coordinator::snapshot::save(&gus, std::path::Path::new(&dir))?;
            println!("snapshot of {} points written to {dir}/", gus.len());
            Ok(())
        }
        "gen" => {
            let ds = load_or_generate(args)?;
            let out = args.get_str("out", "dataset.jsonl");
            loader::save(&ds, std::path::Path::new(&out))?;
            println!("wrote {} points to {out}", ds.points.len());
            Ok(())
        }
        "preprocess" => {
            let ds = load_or_generate(args)?;
            let config = GusConfig::default()
                .apply_args(args)
                .map_err(|e| anyhow::anyhow!(e))?;
            let bucketer =
                dynamic_gus::lsh::Bucketer::with_defaults(&ds.schema, config.lsh_seed);
            let pre = dynamic_gus::preprocess::preprocess(
                &bucketer,
                &ds.points,
                &config,
                dynamic_gus::util::threadpool::default_parallelism(),
            );
            println!(
                "points={} distinct_buckets={} idf_entries={} banned_buckets={}",
                pre.stats.num_points(),
                pre.stats.num_buckets(),
                pre.idf.as_ref().map(|t| t.len()).unwrap_or(0),
                pre.filter.as_ref().map(|f| f.len()).unwrap_or(0),
            );
            let top: Vec<(u64, u64)> = pre.stats.by_count_desc().into_iter().take(10).collect();
            println!("top-10 bucket cardinalities: {top:?}");
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: gus <serve|recover|checkpoint|query|insert|delete|stats|gen|preprocess> \
                 [options]\n\
                 see rust/src/main.rs docs and docs/ARCHITECTURE.md for details"
            );
            Ok(())
        }
    }
}
