//! Pipelined TCP client for the Dynamic GUS RPC protocol.
//!
//! Speaks protocol **v1** ([`crate::protocol`]): every request goes out
//! in an envelope with a client-assigned correlation id, so many
//! requests can be in flight on one connection and responses may return
//! out of order — one socket keeps every server core busy.
//!
//! Two API layers:
//!
//! - **Pipelined**: [`GusClient::submit`] writes a request and returns
//!   its id immediately; [`GusClient::wait`] blocks until *that* id's
//!   response arrives (responses for other ids are parked, not lost).
//!   Typed variants ([`GusClient::wait_existed`],
//!   [`GusClient::wait_neighbors`], …) decode the payload.
//! - **Blocking one-shots** ([`GusClient::query`],
//!   [`GusClient::insert`], …): submit + wait in one call — the
//!   pre-envelope API, now wrappers over the pipelined core.
//!
//! ```no_run
//! use dynamic_gus::client::GusClient;
//! use dynamic_gus::protocol::Request;
//! # use dynamic_gus::features::Point;
//! # fn points() -> Vec<Point> { vec![] }
//! let mut c = GusClient::connect("127.0.0.1:7717").unwrap();
//! c.set_deadline_ms(Some(50)); // per-request deadline for what follows
//! // Fill the pipe…
//! let ids: Vec<u64> = points()
//!     .iter()
//!     .map(|p| c.submit(Request::Query { point: p.clone(), k: Some(10) }).unwrap())
//!     .collect();
//! // …then drain it (any order works; responses are matched by id).
//! for id in ids {
//!     let neighbors = c.wait_neighbors(id).unwrap();
//!     println!("{} neighbors", neighbors.len());
//! }
//! ```
//!
//! Mutations submitted on one connection are applied by the server in
//! submission order; queries may overtake mutations. See
//! `docs/PROTOCOL.md` for the ordering and error-code contract.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Result};

use crate::admission::Class;
use crate::coordinator::ScoredNeighbor;
use crate::features::Point;
use crate::protocol::{self, wire, ErrorCode, Request, Response};
use crate::util::json::Json;

/// Fallback sleep when an `OVERLOADED` response carries no retry hint.
const DEFAULT_RETRY_HINT_MS: u64 = 50;
/// Cap on the server's `retry_after_ms` hint — a confused (or hostile)
/// server must not park a client for seconds per attempt.
const RETRY_HINT_CAP_MS: u64 = 2_000;

/// A connected client.
pub struct GusClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Next correlation id (monotonically increasing per connection).
    next_id: u64,
    /// Responses that arrived while waiting for a different id.
    parked: HashMap<u64, Response>,
    /// Deadline attached to subsequently submitted requests.
    deadline_ms: Option<u64>,
    /// Priority class attached to subsequently submitted requests.
    class: Option<Class>,
}

impl GusClient {
    pub fn connect(addr: &str) -> Result<GusClient> {
        let stream = TcpStream::connect(addr)?;
        Self::from_stream(stream)
    }

    /// [`GusClient::connect`] with a bounded connection attempt — the
    /// replication router and health monitor use this so a dead node
    /// costs `timeout`, not the OS connect default.
    pub fn connect_timeout(addr: &str, timeout: std::time::Duration) -> Result<GusClient> {
        use std::net::ToSocketAddrs;
        let sa = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("{addr} resolved to no address"))?;
        let stream = TcpStream::connect_timeout(&sa, timeout)?;
        Self::from_stream(stream)
    }

    fn from_stream(stream: TcpStream) -> Result<GusClient> {
        stream.set_nodelay(true).ok();
        Ok(GusClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            next_id: 1,
            parked: HashMap::new(),
            deadline_ms: None,
            class: None,
        })
    }

    /// Bound every subsequent blocking read on this connection; a wait
    /// exceeding `timeout` surfaces as a transport error (the connection
    /// should be discarded — a late response would desynchronize the
    /// reply stream). `None` restores unbounded reads.
    pub fn set_read_timeout(&mut self, timeout: Option<std::time::Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    /// Set the relative deadline (milliseconds from server receipt)
    /// attached to every subsequently submitted request; `None` disables.
    /// Expired requests are answered `DEADLINE_EXCEEDED` without
    /// executing.
    pub fn set_deadline_ms(&mut self, deadline_ms: Option<u64>) {
        self.deadline_ms = deadline_ms;
    }

    /// Set the priority class attached to every subsequently submitted
    /// request; `None` (the default) submits unclassed — the server
    /// admits unclassed requests at full budget for compatibility.
    /// Under overload the server sheds `batch` and `replication` first
    /// and degrades `interactive` before shedding it.
    pub fn set_class(&mut self, class: Option<Class>) {
        self.class = class;
    }

    // ---------- pipelined core ----------

    /// Write one enveloped request and return its correlation id without
    /// reading anything back. Pair with [`GusClient::wait`].
    pub fn submit(&mut self, request: Request) -> Result<u64> {
        self.submit_op(request.to_wire())
    }

    /// Envelope + write an already-encoded op object. The one-shot
    /// wrappers go through here with the borrowing `protocol::wire`
    /// encoders, so they never deep-clone their inputs just to build a
    /// [`Request`] that is immediately serialized.
    fn submit_op(&mut self, op: Json) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let env = protocol::envelope_to_wire_classed(id, self.deadline_ms, self.class, op);
        self.writer.write_all(env.dump().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(id)
    }

    /// Block until the response for `id` arrives; responses for other
    /// in-flight ids encountered along the way are parked for their own
    /// `wait` calls. An error *response* becomes an `Err` carrying the
    /// server's code and message.
    pub fn wait(&mut self, id: u64) -> Result<Response> {
        Self::into_result(self.wait_response(id)?)
    }

    /// Like [`GusClient::wait`], but error *responses* come back as
    /// `Ok(Response::Error { .. })` so callers can branch on the error
    /// code (e.g. loadgen verification treats `NOT_FOUND` from a
    /// `query_id` probe as "point absent", not as a failure). `Err` is
    /// reserved for transport/protocol breakage.
    pub fn wait_response(&mut self, id: u64) -> Result<Response> {
        if let Some(resp) = self.parked.remove(&id) {
            return Ok(resp);
        }
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                bail!("server closed connection (backpressure refusal?)");
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let parsed = Json::parse(trimmed)
                .map_err(|e| anyhow!("bad response: {e}: {line}"))?;
            let (rid, resp) = Response::from_wire(&parsed)
                .map_err(|e| anyhow!("bad response: {e}: {line}"))?;
            match rid {
                Some(rid) if rid == id => return Ok(resp),
                Some(rid) => {
                    self.parked.insert(rid, resp);
                }
                None => {
                    // Connection-level response (e.g. an admission-control
                    // refusal before the server read our request).
                    return Ok(resp);
                }
            }
        }
    }

    fn into_result(resp: Response) -> Result<Response> {
        match resp {
            Response::Error { code, message, .. } => bail!("rpc error [{code}]: {message}"),
            other => Ok(other),
        }
    }

    /// Submit-and-wait with backpressure handling: when the server sheds
    /// the request with `OVERLOADED`, sleep its `retry_after_ms` hint
    /// (capped at [`RETRY_HINT_CAP_MS`]) and resubmit, up to `attempts`
    /// total tries. Any other error — and the final `OVERLOADED` — comes
    /// back as `Err`, exactly like [`GusClient::wait`].
    pub fn call_with_retry(&mut self, request: Request, attempts: usize) -> Result<Response> {
        let op = request.to_wire();
        let mut tries = 0usize;
        loop {
            tries += 1;
            let id = self.submit_op(op.clone())?;
            let resp = self.wait_response(id)?;
            match &resp {
                Response::Error { code: ErrorCode::Overloaded, retry_after_ms, .. }
                    if tries < attempts =>
                {
                    let hint = retry_after_ms
                        .unwrap_or(DEFAULT_RETRY_HINT_MS)
                        .clamp(1, RETRY_HINT_CAP_MS);
                    std::thread::sleep(std::time::Duration::from_millis(hint));
                }
                _ => return Self::into_result(resp),
            }
        }
    }

    // ---------- typed waits (pipelined decoding) ----------

    /// Wait for an `insert`/`delete` ack.
    pub fn wait_existed(&mut self, id: u64) -> Result<bool> {
        match self.wait(id)? {
            Response::Existed { existed } => Ok(existed),
            other => bail!("unexpected response {other:?} (wanted 'existed')"),
        }
    }

    /// Wait for a batch-mutation ack, checking the per-item count.
    pub fn wait_existed_batch(&mut self, id: u64, expected_len: usize) -> Result<Vec<bool>> {
        match self.wait(id)? {
            Response::ExistedBatch { existed } => {
                if existed.len() != expected_len {
                    bail!("existed length {} != batch length {expected_len}", existed.len());
                }
                Ok(existed)
            }
            other => bail!("unexpected response {other:?} (wanted batch 'existed')"),
        }
    }

    /// Wait for a `query`/`query_id` neighborhood.
    pub fn wait_neighbors(&mut self, id: u64) -> Result<Vec<ScoredNeighbor>> {
        match self.wait(id)? {
            Response::Neighbors { neighbors, .. } => Ok(neighbors),
            other => bail!("unexpected response {other:?} (wanted 'neighbors')"),
        }
    }

    /// Wait for a `query_batch` result set, checking the per-item count.
    pub fn wait_results(
        &mut self,
        id: u64,
        expected_len: usize,
    ) -> Result<Vec<Vec<ScoredNeighbor>>> {
        match self.wait(id)? {
            Response::Results { results, .. } => {
                if results.len() != expected_len {
                    bail!("results length {} != batch length {expected_len}", results.len());
                }
                Ok(results)
            }
            other => bail!("unexpected response {other:?} (wanted 'results')"),
        }
    }

    // ---------- blocking one-shots (wrappers) ----------

    /// Insert or update a point; returns true if it existed.
    pub fn insert(&mut self, p: &Point) -> Result<bool> {
        let id = self.submit_op(wire::insert(p))?;
        self.wait_existed(id)
    }

    /// Delete a point; returns true if it existed.
    pub fn delete(&mut self, id: u64) -> Result<bool> {
        let rid = self.submit_op(wire::delete(id))?;
        self.wait_existed(rid)
    }

    /// Insert or update a batch of points in one RPC; returns, per input
    /// position, whether the point existed. The server applies the batch
    /// through the parallel mutation path (one shard-lock acquisition per
    /// shard), so this is the high-throughput ingestion call.
    pub fn insert_batch(&mut self, points: &[Point]) -> Result<Vec<bool>> {
        let id = self.submit_op(wire::insert_batch(points))?;
        self.wait_existed_batch(id, points.len())
    }

    /// Delete a batch of points in one RPC; returns, per input position,
    /// whether the point was present.
    pub fn delete_batch(&mut self, ids: &[u64]) -> Result<Vec<bool>> {
        let id = self.submit_op(wire::delete_batch(ids))?;
        self.wait_existed_batch(id, ids.len())
    }

    /// Neighborhood of a (new or known) point.
    pub fn query(&mut self, p: &Point, k: usize) -> Result<Vec<ScoredNeighbor>> {
        let id = self.submit_op(wire::query(p, Some(k)))?;
        self.wait_neighbors(id)
    }

    /// Neighborhood of a known point by id.
    pub fn query_id(&mut self, id: u64, k: usize) -> Result<Vec<ScoredNeighbor>> {
        let rid = self.submit_op(wire::query_id(id, Some(k)))?;
        self.wait_neighbors(rid)
    }

    /// Neighborhoods of a batch of points in one RPC; result `i`
    /// corresponds to `points[i]` and matches what [`GusClient::query`]
    /// would return for it.
    pub fn query_batch(&mut self, points: &[Point], k: usize) -> Result<Vec<Vec<ScoredNeighbor>>> {
        let id = self.submit_op(wire::query_batch(points, Some(k)))?;
        self.wait_results(id, points.len())
    }

    /// Service stats.
    pub fn stats(&mut self) -> Result<Json> {
        let id = self.submit(Request::Stats)?;
        match self.wait(id)? {
            Response::Stats { stats } => Ok(stats),
            other => bail!("unexpected response {other:?} (wanted 'stats')"),
        }
    }

    /// Force an incremental checkpoint on a durable server (snapshot +
    /// WAL truncation); returns the WAL sequence number it covers.
    /// Errors if the server runs without `--wal-dir`.
    pub fn checkpoint(&mut self) -> Result<u64> {
        let id = self.submit(Request::Checkpoint)?;
        match self.wait(id)? {
            Response::Checkpoint { seq } => Ok(seq),
            other => bail!("unexpected response {other:?} (wanted 'seq')"),
        }
    }

    /// Promote a replicating follower to leader (failover); returns its
    /// durable WAL sequence number. Idempotent against a leader. Errors
    /// on a server running without `--replicate`.
    pub fn promote(&mut self) -> Result<u64> {
        let id = self.submit(Request::Promote)?;
        match self.wait(id)? {
            Response::Checkpoint { seq } => Ok(seq),
            other => bail!("unexpected response {other:?} (wanted 'seq')"),
        }
    }
}

// End-to-end client/server tests live in rust/tests/server_test.rs.
