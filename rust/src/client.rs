//! Blocking TCP client for the Dynamic GUS RPC protocol.
//!
//! One connection, pipelined line-at-a-time; see [`crate::server`] for the
//! wire format.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::ScoredNeighbor;
use crate::features::Point;
use crate::util::json::Json;

/// A connected client.
pub struct GusClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl GusClient {
    pub fn connect(addr: &str) -> Result<GusClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(GusClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.dump().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed connection (backpressure refusal?)");
        }
        let resp = Json::parse(line.trim())
            .map_err(|e| anyhow!("bad response: {e}: {line}"))?;
        if resp.get("ok").as_bool() != Some(true) {
            bail!(
                "rpc error: {}",
                resp.get("error").as_str().unwrap_or("<unknown>")
            );
        }
        Ok(resp)
    }

    /// Insert or update a point; returns true if it existed.
    pub fn insert(&mut self, p: &Point) -> Result<bool> {
        let req = Json::obj(vec![("op", Json::str("insert")), ("point", p.to_json())]);
        Ok(self.call(&req)?.get("existed").as_bool().unwrap_or(false))
    }

    /// Insert or update a batch of points in one RPC; returns, per input
    /// position, whether the point existed. The server applies the batch
    /// through the parallel mutation path (one shard-lock acquisition per
    /// shard), so this is the high-throughput ingestion call.
    pub fn insert_batch(&mut self, points: &[Point]) -> Result<Vec<bool>> {
        let req = Json::obj(vec![
            ("op", Json::str("insert_batch")),
            ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
        ]);
        let resp = self.call(&req)?;
        Self::parse_existed(&resp, points.len())
    }

    /// Delete a batch of points in one RPC; returns, per input position,
    /// whether the point was present.
    pub fn delete_batch(&mut self, ids: &[u64]) -> Result<Vec<bool>> {
        let req = Json::obj(vec![
            ("op", Json::str("delete_batch")),
            ("ids", Json::u64_arr(ids)),
        ]);
        let resp = self.call(&req)?;
        Self::parse_existed(&resp, ids.len())
    }

    /// Decode a batch response's `existed` array, checking its length
    /// against the request batch.
    fn parse_existed(resp: &Json, expected_len: usize) -> Result<Vec<bool>> {
        let arr = resp
            .get("existed")
            .as_arr()
            .ok_or_else(|| anyhow!("missing 'existed'"))?;
        if arr.len() != expected_len {
            bail!("existed length {} != batch length {expected_len}", arr.len());
        }
        arr.iter()
            .map(|j| j.as_bool().ok_or_else(|| anyhow!("bad 'existed' entry")))
            .collect()
    }

    /// Neighborhoods of a batch of points in one RPC; result `i`
    /// corresponds to `points[i]` and matches what [`GusClient::query`]
    /// would return for it.
    pub fn query_batch(&mut self, points: &[Point], k: usize) -> Result<Vec<Vec<ScoredNeighbor>>> {
        let req = Json::obj(vec![
            ("op", Json::str("query_batch")),
            ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
            ("k", Json::num(k as f64)),
        ]);
        let resp = self.call(&req)?;
        let results = resp
            .get("results")
            .as_arr()
            .ok_or_else(|| anyhow!("missing 'results'"))?;
        if results.len() != points.len() {
            bail!("results length {} != batch length {}", results.len(), points.len());
        }
        results.iter().map(Self::parse_neighbor_list).collect()
    }

    /// Delete a point; returns true if it existed.
    pub fn delete(&mut self, id: u64) -> Result<bool> {
        let req = Json::obj(vec![("op", Json::str("delete")), ("id", Json::u64(id))]);
        Ok(self.call(&req)?.get("existed").as_bool().unwrap_or(false))
    }

    /// Neighborhood of a (new or known) point.
    pub fn query(&mut self, p: &Point, k: usize) -> Result<Vec<ScoredNeighbor>> {
        let req = Json::obj(vec![
            ("op", Json::str("query")),
            ("point", p.to_json()),
            ("k", Json::num(k as f64)),
        ]);
        Self::parse_neighbors(&self.call(&req)?)
    }

    /// Neighborhood of a known point by id.
    pub fn query_id(&mut self, id: u64, k: usize) -> Result<Vec<ScoredNeighbor>> {
        let req = Json::obj(vec![
            ("op", Json::str("query_id")),
            ("id", Json::u64(id)),
            ("k", Json::num(k as f64)),
        ]);
        Self::parse_neighbors(&self.call(&req)?)
    }

    /// Service stats.
    pub fn stats(&mut self) -> Result<Json> {
        let req = Json::obj(vec![("op", Json::str("stats"))]);
        Ok(self.call(&req)?.get("stats").clone())
    }

    /// Force an incremental checkpoint on a durable server (snapshot +
    /// WAL truncation); returns the WAL sequence number it covers.
    /// Errors if the server runs without `--wal-dir`.
    pub fn checkpoint(&mut self) -> Result<u64> {
        let req = Json::obj(vec![("op", Json::str("checkpoint"))]);
        self.call(&req)?
            .get("seq")
            .as_u64()
            .ok_or_else(|| anyhow!("checkpoint response missing 'seq'"))
    }

    fn parse_neighbors(resp: &Json) -> Result<Vec<ScoredNeighbor>> {
        Self::parse_neighbor_list(resp.get("neighbors"))
    }

    /// Decode one JSON neighbor array (shared by the single and batch
    /// query paths).
    fn parse_neighbor_list(arr: &Json) -> Result<Vec<ScoredNeighbor>> {
        arr.as_arr()
            .ok_or_else(|| anyhow!("missing neighbors"))?
            .iter()
            .map(|n| {
                Ok(ScoredNeighbor {
                    id: n.get("id").as_u64().ok_or_else(|| anyhow!("bad id"))?,
                    score: n.get("score").as_f32().unwrap_or(0.0),
                    dot: n.get("dot").as_f32().unwrap_or(0.0),
                })
            })
            .collect()
    }
}

// End-to-end client/server tests live in rust/tests/server_test.rs.
