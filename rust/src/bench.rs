//! Micro-benchmark harness (no `criterion` offline).
//!
//! Used by the `rust/benches/*.rs` targets (all `harness = false`): each
//! bench is a plain `main` that registers closures with a [`Bencher`].
//! The harness warms up, then runs timed batches until a wall-clock budget
//! or iteration cap is reached, and reports mean/median/p95/p99 per
//! iteration plus throughput. Results can also be dumped as JSON for
//! EXPERIMENTS.md bookkeeping.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One benchmark's collected statistics (per-iteration latencies in ns).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    fn from_samples(name: &str, mut ns: Vec<f64>) -> BenchResult {
        ns.sort_by(|a, b| a.total_cmp(b));
        let n = ns.len().max(1);
        let pct = |p: f64| ns[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        BenchResult {
            name: name.to_string(),
            iters: ns.len(),
            mean_ns: ns.iter().sum::<f64>() / n as f64,
            median_ns: pct(0.5),
            p95_ns: pct(0.95),
            p99_ns: pct(0.99),
            min_ns: ns.first().copied().unwrap_or(0.0),
            max_ns: ns.last().copied().unwrap_or(0.0),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("median_ns", Json::num(self.median_ns)),
            ("p95_ns", Json::num(self.p95_ns)),
            ("p99_ns", Json::num(self.p99_ns)),
            ("min_ns", Json::num(self.min_ns)),
            ("max_ns", Json::num(self.max_ns)),
        ])
    }
}

/// Human-friendly duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark registry + runner.
pub struct Bencher {
    /// Wall-clock budget per benchmark.
    pub budget: Duration,
    /// Max timed iterations per benchmark.
    pub max_iters: usize,
    /// Warmup iterations.
    pub warmup_iters: usize,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        // `cargo bench -- <filter>` passes the filter as a positional arg.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        // Keep each bench target's total runtime modest: many targets.
        let budget_ms = std::env::var("GUS_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1500u64);
        Bencher {
            budget: Duration::from_millis(budget_ms),
            max_iters: 100_000,
            warmup_iters: 3,
            results: Vec::new(),
            filter,
        }
    }

    /// Run a benchmark: `f` is one iteration; its return value is
    /// black-boxed so the work is not optimized away.
    pub fn bench<T>(&mut self, name: &str, f: impl FnMut() -> T) {
        self.bench_batch(name, 1, f)
    }

    /// Like [`bench`](Bencher::bench), but one call of `f` processes
    /// `batch` items; collected stats are **per item**, so batched and
    /// unbatched rows of the same workload compare directly.
    pub fn bench_batch<T>(&mut self, name: &str, batch: usize, mut f: impl FnMut() -> T) {
        let per = batch.max(1) as f64;
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return;
            }
        }
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64 / per);
        }
        let r = BenchResult::from_samples(name, samples);
        println!(
            "{:<58} {:>10}/iter  (median {:>10}, p95 {:>10}, p99 {:>10}, n={})",
            r.name,
            fmt_ns(r.mean_ns),
            fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns),
            fmt_ns(r.p99_ns),
            r.iters
        );
        self.results.push(r);
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write results as a JSON file under `results/bench/` (best effort).
    pub fn dump_json(&self, target: &str) {
        let _ = std::fs::create_dir_all("results/bench");
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        let path = format!("results/bench/{target}.json");
        if std::fs::write(&path, arr.dump()).is_ok() {
            println!("[bench] wrote {path}");
        }
    }

    /// Merge this target's results into the repo-root `BENCH_index.json`
    /// — the cross-PR perf-trajectory file (one key per bench target;
    /// other targets' recorded entries are preserved). `extra` carries
    /// target-specific derived figures (e.g. postings/sec). Best effort:
    /// a malformed or missing file is replaced.
    pub fn dump_repo_summary(&self, target: &str, extra: Vec<(String, Json)>) {
        let path = repo_root().join("BENCH_index.json");
        let existing = std::fs::read_to_string(&path)
            .ok()
            .and_then(|s| Json::parse(&s).ok());
        let doc = merged_summary(existing, target, self.results(), extra);
        if std::fs::write(&path, doc.dump()).is_ok() {
            println!("[bench] updated {}", path.display());
        }
    }
}

/// The repo root: `BENCH_index.json` lives one level above the package
/// root. Resolved at compile time so running a bench binary directly
/// (outside `cargo bench`, where `CARGO_MANIFEST_DIR` is unset at
/// runtime) still targets the repo, not the current directory's parent.
fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

/// Pure merge step behind [`Bencher::dump_repo_summary`]: replace
/// `target`'s entry in the (possibly absent/malformed) existing summary,
/// preserving every other key.
fn merged_summary(
    existing: Option<Json>,
    target: &str,
    results: &[BenchResult],
    extra: Vec<(String, Json)>,
) -> Json {
    let mut map = match existing {
        Some(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    let mut entry = std::collections::BTreeMap::new();
    entry.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    entry.insert("recorded_unix_s".to_string(), Json::u64(unix_s));
    for (k, v) in extra {
        entry.insert(k, v);
    }
    map.insert(target.to_string(), Json::Obj(entry));
    Json::Obj(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut b = Bencher::new();
        b.budget = Duration::from_millis(30);
        b.warmup_iters = 1;
        b.filter = None;
        b.bench("noop", || 1 + 1);
        let r = &b.results()[0];
        assert!(r.iters > 10);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn bench_batch_reports_per_item() {
        let mut b = Bencher::new();
        b.budget = Duration::from_millis(30);
        b.warmup_iters = 1;
        b.filter = None;
        b.bench_batch("sleepy-batch", 10, || {
            std::thread::sleep(Duration::from_millis(1));
        });
        let r = &b.results()[0];
        // 1 ms per call over 10 items → ≈ 100 µs per item.
        assert!(r.mean_ns < 1e6, "not divided by batch: {}", r.mean_ns);
        assert!(r.mean_ns > 1e4, "divided too much: {}", r.mean_ns);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bencher::new();
        b.budget = Duration::from_millis(5);
        b.filter = Some("yes".to_string());
        b.bench("no-match", || 0);
        b.bench("yes-match", || 0);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].name, "yes-match");
    }

    #[test]
    fn repo_summary_merges_and_preserves_other_targets() {
        let prior = Json::parse(r#"{"other":{"results":[]},"hot_path":{"stale":true}}"#).unwrap();
        let results = vec![BenchResult::from_samples("scan", vec![10.0, 20.0, 30.0])];
        let merged = merged_summary(
            Some(prior),
            "hot_path",
            &results,
            vec![("postings_per_sec".to_string(), Json::num(1e8))],
        );
        assert!(!merged.get("other").is_null(), "unrelated target dropped");
        let entry = merged.get("hot_path");
        assert!(entry.get("stale").is_null(), "old entry not replaced");
        assert_eq!(entry.get("postings_per_sec").as_f64(), Some(1e8));
        let rows = entry.get("results").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").as_str(), Some("scan"));
        // Malformed/missing existing summaries are replaced, not fatal.
        let fresh = merged_summary(None, "t", &results, Vec::new());
        assert!(!fresh.get("t").get("results").is_null());
        let clobbered = merged_summary(Some(Json::Arr(vec![])), "t", &results, Vec::new());
        assert!(!clobbered.get("t").is_null());
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
