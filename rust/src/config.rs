//! Service configuration.
//!
//! Every knob of the paper's evaluation is a field here: `ScaNN-NN`
//! (`scann_nn`), `IDF-S` (`idf_s`), `Filter-P` (`filter_p`), plus the
//! deployment knobs (shards, scorer backend). Configs parse from JSON
//! files and/or CLI flags; [`GusConfig::apply_args`] layers CLI overrides
//! on top of file values so experiment sweeps stay one-liners.

use crate::util::cli::Args;
use crate::util::json::Json;

/// Scoring backend selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScorerKind {
    /// AOT XLA executable via PJRT (production path; needs `artifacts/`).
    Xla,
    /// Pure-Rust model (oracle / fallback).
    Native,
    /// Xla if artifacts exist, else Native.
    Auto,
}

impl ScorerKind {
    pub fn parse(s: &str) -> Result<ScorerKind, String> {
        match s {
            "xla" => Ok(ScorerKind::Xla),
            "native" => Ok(ScorerKind::Native),
            "auto" => Ok(ScorerKind::Auto),
            other => Err(format!("unknown scorer '{other}' (xla|native|auto)")),
        }
    }
}

/// Write-ahead-log fsync policy: when appended records are forced to
/// stable storage (see [`crate::coordinator::wal`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fsync` after every appended record: no acknowledged mutation is
    /// ever lost, even to power failure. Highest durability, lowest
    /// mutation throughput.
    Always,
    /// `fsync` once every N appended records: bounds the power-loss
    /// window to N mutations while amortizing the sync cost. A process
    /// crash (`kill -9`) still loses nothing — the records are already
    /// in the page cache.
    EveryN(usize),
    /// Never `fsync` from the hot path; the OS flushes on its own
    /// schedule. Process crashes still lose nothing; power loss may.
    Never,
}

impl FsyncPolicy {
    /// Parse `always` | `every_n` | `every_n:N` | `never`.
    pub fn parse(s: &str) -> Result<FsyncPolicy, String> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "never" => Ok(FsyncPolicy::Never),
            "every_n" => Ok(FsyncPolicy::EveryN(32)),
            other => match other.strip_prefix("every_n:") {
                Some(n) => {
                    let n: usize = n
                        .parse()
                        .map_err(|_| format!("bad fsync interval in '{other}'"))?;
                    if n == 0 {
                        return Err("fsync every_n interval must be >= 1".into());
                    }
                    Ok(FsyncPolicy::EveryN(n))
                }
                None => Err(format!(
                    "unknown fsync policy '{other}' (always|every_n[:N]|never)"
                )),
            },
        }
    }

    /// Inverse of [`FsyncPolicy::parse`].
    pub fn to_str(self) -> String {
        match self {
            FsyncPolicy::Always => "always".into(),
            FsyncPolicy::EveryN(n) => format!("every_n:{n}"),
            FsyncPolicy::Never => "never".into(),
        }
    }
}

/// Dynamic GUS service configuration.
#[derive(Debug, Clone)]
pub struct GusConfig {
    /// Default number of neighbors retrieved from the ANN index (ScaNN-NN).
    pub scann_nn: usize,
    /// IDF table size; 0 disables IDF (paper's IDF-S).
    pub idf_s: usize,
    /// Percentage of overly popular buckets filtered (paper's Filter-P).
    pub filter_p: f64,
    /// Index shards (1 = the paper's sequential setting).
    pub n_shards: usize,
    /// Scoring backend.
    pub scorer: ScorerKind,
    /// LSH seed (bucketing must be identical across restarts).
    pub lsh_seed: u64,
    /// Optional posting-scan budget (0 = exact; emulates ScaNN's
    /// approximation dial for ablations). The budget is global: the
    /// sharded index splits it across shards.
    pub max_postings: usize,
    /// Worker threads for the concurrent serving path (shard fan-out and
    /// the batch RPCs). 0 = auto (available cores, capped); 1 reproduces
    /// the paper's sequential setting. Thread count never changes results.
    pub query_threads: usize,
    /// Chunk size used when an op stream is grouped into batch RPCs
    /// (currently `gus replay --mode batch`; the batch endpoints
    /// themselves accept any length). Must be ≥ 1.
    pub batch_size: usize,
    /// Durability directory: when set, every accepted mutation is
    /// appended to `<wal_dir>/wal.log` before it is applied, and
    /// checkpoints (snapshot + WAL truncation) land in the same
    /// directory. `None` (the default) disables durability — the
    /// paper's in-memory setting.
    pub wal_dir: Option<String>,
    /// When the WAL forces appended records to stable storage.
    pub fsync: FsyncPolicy,
    /// Automatic checkpoint threshold: when this many mutations have
    /// accumulated in the WAL since the last checkpoint, the background
    /// checkpointer writes a new one — bounding both the log's size and
    /// the restart replay cost. 0 disables automatic checkpoints (manual
    /// `checkpoint` RPC / CLI only). Irrelevant while `wal_dir` is unset.
    pub checkpoint_every: u64,
    /// WAL retention: number of most-recent records kept in the log past
    /// a checkpoint (instead of truncating to empty). A bounded tail lets
    /// replication followers that fall behind by less than `wal_retain`
    /// records resume streaming instead of re-bootstrapping from a
    /// snapshot. 0 (the default) truncates fully at checkpoint, exactly
    /// the pre-replication behavior. Irrelevant while `wal_dir` is unset.
    pub wal_retain: u64,
    /// RPC server: connections admitted concurrently; excess connections
    /// get a final `OVERLOADED` response and are closed (clients retry).
    pub max_connections: usize,
    /// RPC server: worker threads executing enveloped (v1) requests.
    /// 0 = auto (available cores). A few pipelined connections can keep
    /// all workers busy; legacy requests run on their connection's
    /// reader thread and are unaffected.
    pub rpc_workers: usize,
    /// RPC server: bounded run-queue capacity. When the queue is full,
    /// requests are shed immediately with `OVERLOADED` instead of
    /// building unbounded backlog — admission control at the API
    /// boundary keeps admitted requests' tail latency flat.
    pub rpc_queue: usize,
    /// Adaptive admission: target run-queue sojourn in milliseconds. The
    /// pressure controller ([`crate::admission`]) sheds low-priority
    /// classes and degrades interactive budgets as the observed sojourn
    /// EWMA (and queue depth) climbs past this target. 0 disables the
    /// controller entirely — only the queue-full backstop sheds, exactly
    /// the pre-admission behavior.
    pub admission_target_ms: u64,
    /// Degraded-serving quality floor: the smallest `max_postings` budget
    /// fraction the server will serve an interactive query at. Below it
    /// the request is shed with `OVERLOADED` instead of answering with
    /// unusable recall. In (0, 1].
    pub min_budget_frac: f64,
    /// Disk fault-injection plan (`--fault-plan` flag or `GUS_FAULT_PLAN`
    /// env var), e.g. `wal_append:enospc@seq=1200;fsync:err@nth=3` — see
    /// [`crate::fault::FaultPlan`] for the grammar. Armed once per
    /// process at serve/follow startup; `None` (the default, and the
    /// only value a production deployment should ever see) injects
    /// nothing. Deliberately **not** persisted to config JSON: a fault
    /// plan is a per-run drill parameter, and writing it to disk would
    /// let one drill leak into every later restart from the same config.
    pub fault_plan: Option<String>,
}

impl Default for GusConfig {
    fn default() -> Self {
        GusConfig {
            scann_nn: 10,
            idf_s: 0,
            filter_p: 10.0,
            n_shards: 1,
            scorer: ScorerKind::Auto,
            lsh_seed: 0x677573,
            max_postings: 0,
            query_threads: 0,
            batch_size: 128,
            wal_dir: None,
            fsync: FsyncPolicy::Always,
            checkpoint_every: 10_000,
            wal_retain: 0,
            max_connections: 64,
            rpc_workers: 0,
            rpc_queue: 256,
            admission_target_ms: 50,
            min_budget_frac: 0.25,
            fault_plan: None,
        }
    }
}

impl GusConfig {
    /// Layer CLI overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> Result<GusConfig, String> {
        self.scann_nn = args.get_usize("scann-nn", self.scann_nn);
        self.idf_s = args.get_usize("idf-s", self.idf_s);
        self.filter_p = args.get_f64("filter-p", self.filter_p);
        self.n_shards = args.get_usize("shards", self.n_shards);
        self.lsh_seed = args.get_u64("lsh-seed", self.lsh_seed);
        self.max_postings = args.get_usize("max-postings", self.max_postings);
        self.query_threads = args.get_usize("query-threads", self.query_threads);
        self.batch_size = args.get_usize("batch-size", self.batch_size);
        if let Some(s) = args.opt_str("scorer") {
            self.scorer = ScorerKind::parse(&s)?;
        }
        if let Some(dir) = args.opt_str("wal-dir") {
            self.wal_dir = Some(dir);
        }
        if let Some(s) = args.opt_str("fsync") {
            self.fsync = FsyncPolicy::parse(&s)?;
        }
        self.checkpoint_every = args.get_u64("checkpoint-every", self.checkpoint_every);
        self.wal_retain = args.get_u64("wal-retain", self.wal_retain);
        self.max_connections = args.get_usize("max-connections", self.max_connections);
        self.rpc_workers = args.get_usize("rpc-workers", self.rpc_workers);
        self.rpc_queue = args.get_usize("rpc-queue", self.rpc_queue);
        self.admission_target_ms = args.get_u64("admission-target-ms", self.admission_target_ms);
        self.min_budget_frac = args.get_f64("min-budget-frac", self.min_budget_frac);
        // Flag beats env var beats nothing; an empty value means "off"
        // either way (lets a wrapper script unconditionally forward
        // GUS_FAULT_PLAN="").
        let plan = args
            .opt_str("fault-plan")
            .or_else(|| std::env::var("GUS_FAULT_PLAN").ok())
            .filter(|s| !s.trim().is_empty());
        if let Some(spec) = plan {
            crate::fault::FaultPlan::parse(&spec).map_err(|e| format!("--fault-plan: {e:#}"))?;
            self.fault_plan = Some(spec);
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.scann_nn == 0 {
            return Err("scann-nn must be >= 1".into());
        }
        if !(0.0..=100.0).contains(&self.filter_p) {
            return Err("filter-p must be in [0, 100]".into());
        }
        if self.n_shards == 0 {
            return Err("shards must be >= 1".into());
        }
        if self.batch_size == 0 {
            return Err("batch-size must be >= 1".into());
        }
        if self.max_connections == 0 {
            return Err("max-connections must be >= 1".into());
        }
        if self.rpc_queue == 0 {
            return Err("rpc-queue must be >= 1".into());
        }
        if !(self.min_budget_frac > 0.0 && self.min_budget_frac <= 1.0) {
            return Err("min-budget-frac must be in (0, 1]".into());
        }
        Ok(())
    }

    /// Resolved serving-path worker count: `query_threads`, or the machine
    /// default (available cores, capped) when 0.
    pub fn resolved_query_threads(&self) -> usize {
        if self.query_threads == 0 {
            crate::util::threadpool::default_parallelism()
        } else {
            self.query_threads
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scann_nn", Json::num(self.scann_nn as f64)),
            ("idf_s", Json::num(self.idf_s as f64)),
            ("filter_p", Json::num(self.filter_p)),
            ("n_shards", Json::num(self.n_shards as f64)),
            (
                "scorer",
                Json::str(match self.scorer {
                    ScorerKind::Xla => "xla",
                    ScorerKind::Native => "native",
                    ScorerKind::Auto => "auto",
                }),
            ),
            ("lsh_seed", Json::u64(self.lsh_seed)),
            ("max_postings", Json::num(self.max_postings as f64)),
            ("query_threads", Json::num(self.query_threads as f64)),
            ("batch_size", Json::num(self.batch_size as f64)),
            (
                "wal_dir",
                match &self.wal_dir {
                    Some(d) => Json::str(d.clone()),
                    None => Json::Null,
                },
            ),
            ("fsync", Json::str(self.fsync.to_str())),
            ("checkpoint_every", Json::u64(self.checkpoint_every)),
            ("wal_retain", Json::u64(self.wal_retain)),
            ("max_connections", Json::num(self.max_connections as f64)),
            ("rpc_workers", Json::num(self.rpc_workers as f64)),
            ("rpc_queue", Json::num(self.rpc_queue as f64)),
            ("admission_target_ms", Json::u64(self.admission_target_ms)),
            ("min_budget_frac", Json::num(self.min_budget_frac)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GusConfig, String> {
        let d = GusConfig::default();
        let cfg = GusConfig {
            scann_nn: j.get("scann_nn").as_usize().unwrap_or(d.scann_nn),
            idf_s: j.get("idf_s").as_usize().unwrap_or(d.idf_s),
            filter_p: j.get("filter_p").as_f64().unwrap_or(d.filter_p),
            n_shards: j.get("n_shards").as_usize().unwrap_or(d.n_shards),
            scorer: match j.get("scorer").as_str() {
                Some(s) => ScorerKind::parse(s)?,
                None => d.scorer,
            },
            lsh_seed: j.get("lsh_seed").as_u64().unwrap_or(d.lsh_seed),
            max_postings: j.get("max_postings").as_usize().unwrap_or(d.max_postings),
            query_threads: j.get("query_threads").as_usize().unwrap_or(d.query_threads),
            batch_size: j.get("batch_size").as_usize().unwrap_or(d.batch_size),
            wal_dir: j.get("wal_dir").as_str().map(|s| s.to_string()),
            fsync: match j.get("fsync").as_str() {
                Some(s) => FsyncPolicy::parse(s)?,
                None => d.fsync,
            },
            checkpoint_every: j.get("checkpoint_every").as_u64().unwrap_or(d.checkpoint_every),
            wal_retain: j.get("wal_retain").as_u64().unwrap_or(d.wal_retain),
            max_connections: j.get("max_connections").as_usize().unwrap_or(d.max_connections),
            rpc_workers: j.get("rpc_workers").as_usize().unwrap_or(d.rpc_workers),
            rpc_queue: j.get("rpc_queue").as_usize().unwrap_or(d.rpc_queue),
            admission_target_ms: j
                .get("admission_target_ms")
                .as_u64()
                .unwrap_or(d.admission_target_ms),
            min_budget_frac: j.get("min_budget_frac").as_f64().unwrap_or(d.min_budget_frac),
            // Never read from config JSON (see the field doc); even a
            // hand-edited "fault_plan" key is ignored.
            fault_plan: None,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a JSON config file.
    pub fn load(path: &std::path::Path) -> Result<GusConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        GusConfig::default().validate().unwrap();
    }

    #[test]
    fn args_override() {
        let args = Args::parse_from(
            ["--scann-nn=100", "--idf-s=1000000", "--filter-p=10", "--scorer=native"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = GusConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.scann_nn, 100);
        assert_eq!(cfg.idf_s, 1_000_000);
        assert_eq!(cfg.filter_p, 10.0);
        assert_eq!(cfg.scorer, ScorerKind::Native);
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = GusConfig::default();
        cfg.scann_nn = 1000;
        cfg.scorer = ScorerKind::Xla;
        cfg.query_threads = 6;
        cfg.batch_size = 32;
        let j = cfg.to_json().dump();
        let back = GusConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.scann_nn, 1000);
        assert_eq!(back.scorer, ScorerKind::Xla);
        assert_eq!(back.query_threads, 6);
        assert_eq!(back.batch_size, 32);
    }

    #[test]
    fn serving_knobs_parse_and_validate() {
        let args = Args::parse_from(
            ["--query-threads=4", "--batch-size=64"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = GusConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.query_threads, 4);
        assert_eq!(cfg.batch_size, 64);
        assert_eq!(cfg.resolved_query_threads(), 4);
        // 0 = auto resolves to at least one worker.
        assert!(GusConfig::default().resolved_query_threads() >= 1);
        let args = Args::parse_from(["--batch-size=0".to_string()]).unwrap();
        assert!(GusConfig::default().apply_args(&args).is_err());
    }

    #[test]
    fn fsync_policy_parses_and_roundtrips() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        assert_eq!(FsyncPolicy::parse("every_n").unwrap(), FsyncPolicy::EveryN(32));
        assert_eq!(FsyncPolicy::parse("every_n:7").unwrap(), FsyncPolicy::EveryN(7));
        for p in [FsyncPolicy::Always, FsyncPolicy::EveryN(5), FsyncPolicy::Never] {
            assert_eq!(FsyncPolicy::parse(&p.to_str()).unwrap(), p);
        }
        assert!(FsyncPolicy::parse("every_n:0").is_err());
        assert!(FsyncPolicy::parse("every_n:x").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
    }

    #[test]
    fn wal_knobs_cli_and_json() {
        let args = Args::parse_from(
            ["--wal-dir=/tmp/w", "--fsync=every_n:16", "--checkpoint-every=500"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = GusConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.wal_dir.as_deref(), Some("/tmp/w"));
        assert_eq!(cfg.fsync, FsyncPolicy::EveryN(16));
        assert_eq!(cfg.checkpoint_every, 500);
        // JSON round trip carries the durability knobs.
        let back = GusConfig::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.wal_dir.as_deref(), Some("/tmp/w"));
        assert_eq!(back.fsync, FsyncPolicy::EveryN(16));
        assert_eq!(back.checkpoint_every, 500);
        // Defaults: durability off; when it is enabled, fsync always and
        // auto-checkpoint every 10k mutations (a bounded WAL by default).
        let d = GusConfig::default();
        assert!(d.wal_dir.is_none());
        assert_eq!(d.fsync, FsyncPolicy::Always);
        assert_eq!(d.checkpoint_every, 10_000);
        let args = Args::parse_from(["--fsync=bogus".to_string()]).unwrap();
        assert!(GusConfig::default().apply_args(&args).is_err());
    }

    #[test]
    fn wal_retain_cli_and_json() {
        // Default keeps the pre-replication behavior: truncate fully.
        assert_eq!(GusConfig::default().wal_retain, 0);
        let args = Args::parse_from(["--wal-retain=5000".to_string()]).unwrap();
        let cfg = GusConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.wal_retain, 5000);
        let back = GusConfig::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.wal_retain, 5000);
        // Old configs (no wal_retain field) fall back to 0.
        let old = GusConfig::from_json(&Json::parse(r#"{"scann_nn":7}"#).unwrap()).unwrap();
        assert_eq!(old.wal_retain, 0);
    }

    #[test]
    fn rpc_knobs_cli_and_json() {
        let args = Args::parse_from(
            ["--max-connections=128", "--rpc-workers=8", "--rpc-queue=512"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = GusConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.max_connections, 128);
        assert_eq!(cfg.rpc_workers, 8);
        assert_eq!(cfg.rpc_queue, 512);
        let back = GusConfig::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.max_connections, 128);
        assert_eq!(back.rpc_workers, 8);
        assert_eq!(back.rpc_queue, 512);
        // Old configs (no rpc fields) fall back to defaults.
        let old = GusConfig::from_json(&Json::parse(r#"{"scann_nn":7}"#).unwrap()).unwrap();
        assert_eq!(old.max_connections, 64);
        assert_eq!(old.rpc_workers, 0);
        assert_eq!(old.rpc_queue, 256);
        // Degenerate values are rejected.
        for bad in ["--max-connections=0", "--rpc-queue=0"] {
            let args = Args::parse_from([bad.to_string()]).unwrap();
            assert!(GusConfig::default().apply_args(&args).is_err(), "{bad}");
        }
    }

    #[test]
    fn admission_knobs_cli_and_json() {
        // Defaults: controller on at a 50ms sojourn target, floor 0.25.
        let d = GusConfig::default();
        assert_eq!(d.admission_target_ms, 50);
        assert_eq!(d.min_budget_frac, 0.25);
        let args = Args::parse_from(
            ["--admission-target-ms=20", "--min-budget-frac=0.5"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = GusConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.admission_target_ms, 20);
        assert_eq!(cfg.min_budget_frac, 0.5);
        let back = GusConfig::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.admission_target_ms, 20);
        assert_eq!(back.min_budget_frac, 0.5);
        // Old configs (no admission fields) fall back to defaults.
        let old = GusConfig::from_json(&Json::parse(r#"{"scann_nn":7}"#).unwrap()).unwrap();
        assert_eq!(old.admission_target_ms, 50);
        assert_eq!(old.min_budget_frac, 0.25);
        // 0 disables the controller and is valid; a zero or >1 floor is not.
        let args = Args::parse_from(["--admission-target-ms=0".to_string()]).unwrap();
        assert_eq!(GusConfig::default().apply_args(&args).unwrap().admission_target_ms, 0);
        for bad in ["--min-budget-frac=0", "--min-budget-frac=1.5"] {
            let args = Args::parse_from([bad.to_string()]).unwrap();
            assert!(GusConfig::default().apply_args(&args).is_err(), "{bad}");
        }
    }

    #[test]
    fn fault_plan_cli_validates_and_is_not_serialized() {
        assert!(GusConfig::default().fault_plan.is_none());
        let args = Args::parse_from(
            ["--fault-plan=wal_append:enospc@seq=1200;fsync:err@nth=3"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = GusConfig::default().apply_args(&args).unwrap();
        assert_eq!(
            cfg.fault_plan.as_deref(),
            Some("wal_append:enospc@seq=1200;fsync:err@nth=3")
        );
        // A per-run drill parameter: never written to config JSON, and a
        // hand-planted key in a config file is ignored on load.
        assert!(cfg.to_json().get("fault_plan").is_null());
        let back = GusConfig::from_json(
            &Json::parse(r#"{"fault_plan":"fsync:crash"}"#).unwrap(),
        )
        .unwrap();
        assert!(back.fault_plan.is_none());
        // Bad specs are rejected at flag-parse time, not at first injection.
        for bad in ["--fault-plan=wal_append:bogus", "--fault-plan=fsync:torn"] {
            let args = Args::parse_from([bad.to_string()]).unwrap();
            assert!(GusConfig::default().apply_args(&args).is_err(), "{bad}");
        }
    }

    #[test]
    fn rejects_invalid() {
        let args =
            Args::parse_from(["--filter-p=150".to_string()]).unwrap();
        assert!(GusConfig::default().apply_args(&args).is_err());
        let args = Args::parse_from(["--scann-nn=0".to_string()]).unwrap();
        assert!(GusConfig::default().apply_args(&args).is_err());
        assert!(ScorerKind::parse("gpu").is_err());
    }
}
