//! Scatter/gather router: the single address clients talk to in a
//! multi-node deployment.
//!
//! The router speaks the same line protocol as a node (`gus serve`), so
//! every existing client — including `gus loadgen` — points at it
//! unchanged. Per request:
//!
//! - **Mutations** (and the other leader-only ops: `checkpoint`,
//!   `promote`, `query_id`, `stats`) are forwarded to the current
//!   leader. A `NOT_LEADER` refusal carries the node's leader hint,
//!   which the router chases before falling back to probing every
//!   target. A transport error *after* a mutation was written leaves
//!   its outcome unknown, so the client gets `UNAVAILABLE` rather than
//!   a silent retry — mutations are idempotent upserts, so the client
//!   retries safely.
//! - **Queries** (`query`, `query_batch`) scatter to every live
//!   replica and gather: per-query lists are merged by score (reusing
//!   the sharded-index merge), deduped by id, and truncated to `k`.
//!   Reads are idempotent, so each replica gets a bounded retry; one
//!   live replica is enough to answer.
//!
//! Failover is driven by [`super::health`]: a monitor thread probes
//! each target's `stats`, adopts whichever node reports itself leader,
//! and after enough consecutive leaderless probes promotes the live
//! follower with the highest durable WAL seq. In-order WAL shipping
//! makes that follower's log a superset of every acked record (see
//! [`super`] — the prefix property), so promotion loses nothing the
//! leader acknowledged.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::client::GusClient;
use crate::coordinator::ScoredNeighbor;
use crate::fault::Backoff;
use crate::index::sharded::merge_ranked;
use crate::metrics::monotonic_ms;
use crate::protocol::{decode_request, ErrorCode, Incoming, Request, Response};
use crate::util::hash::{hash_bytes, mix2};

/// Configuration for [`run_router`].
#[derive(Debug, Clone)]
pub struct RouterOpts {
    /// Address to listen on.
    pub listen: String,
    /// Node addresses (leader + followers, discovered by probing).
    pub targets: Vec<String>,
    /// Health-probe cadence.
    pub health_interval: Duration,
    /// Consecutive leaderless probe rounds before promoting a follower.
    pub fail_threshold: u32,
    /// Deadline attached to scattered queries, per target.
    pub deadline_ms: u64,
}

/// Bounded connect to a backend node.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Read timeout on backend connections: a node that stops answering is
/// treated as down (the request is retried elsewhere or refused), never
/// waited on indefinitely.
const BACKEND_READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Attempts per replica for an idempotent read (1 retry, reconnecting).
const READ_ATTEMPTS: usize = 2;

/// First pause before a read retry; doubles (with jitter seeded from the
/// replica address) up to [`RETRY_CAP`], and is always clipped to the
/// request's remaining deadline.
const RETRY_BASE: Duration = Duration::from_millis(20);

/// Largest read-retry pause (pre-jitter).
const RETRY_CAP: Duration = Duration::from_millis(200);

/// Shared router state: the target list is fixed at startup; the leader
/// is whatever the health monitor (or a successful forward) last
/// observed.
pub(crate) struct RouterState {
    pub(crate) targets: Vec<String>,
    leader: Mutex<Option<String>>,
    pub(crate) deadline_ms: u64,
}

impl RouterState {
    pub(crate) fn leader(&self) -> Option<String> {
        self.leader.lock().unwrap().clone()
    }

    /// Record a leader observation, logging transitions (the router's
    /// operator log is the failover audit trail).
    pub(crate) fn set_leader(&self, addr: &str) {
        let mut cur = self.leader.lock().unwrap();
        if cur.as_deref() != Some(addr) {
            eprintln!("[gus-router] leader -> {addr}");
            *cur = Some(addr.to_string());
        }
    }

    pub(crate) fn clear_leader(&self) {
        let mut cur = self.leader.lock().unwrap();
        if cur.is_some() {
            eprintln!("[gus-router] leader lost");
            *cur = None;
        }
    }
}

/// Run the router: bind, start the health monitor, serve connections
/// until the process dies. Each client connection gets a thread with its
/// own backend connections (the backend protocol is pipelined per
/// connection, so sharing one would serialize unrelated clients).
pub fn run_router(opts: RouterOpts) -> Result<()> {
    if opts.targets.is_empty() {
        anyhow::bail!("router needs at least one --targets address");
    }
    let state = Arc::new(RouterState {
        targets: opts.targets.clone(),
        leader: Mutex::new(None),
        deadline_ms: opts.deadline_ms,
    });
    let listener =
        TcpListener::bind(&opts.listen).with_context(|| format!("binding {}", opts.listen))?;
    // Stdout, matching `gus serve` — harnesses parse this line.
    println!("[gus] serving on {}", listener.local_addr()?);
    super::health::spawn_monitor(Arc::clone(&state), opts.health_interval, opts.fail_threshold);
    for conn in listener.incoming() {
        let Ok(stream) = conn else { continue };
        let state = Arc::clone(&state);
        std::thread::Builder::new()
            .name("gus-router-conn".into())
            .spawn(move || handle_conn(&state, stream))
            .context("spawning router connection thread")?;
    }
    Ok(())
}

/// Per-client-connection backend pool. Leader-forwarding connections are
/// keyed by address (the leader can move mid-connection); scatter
/// connections align with the target list.
struct Backends {
    forward: BTreeMap<String, GusClient>,
    scatter: Vec<Option<GusClient>>,
}

fn connect_backend(addr: &str, deadline_ms: Option<u64>) -> Option<GusClient> {
    let mut c = GusClient::connect_timeout(addr, CONNECT_TIMEOUT).ok()?;
    c.set_read_timeout(Some(BACKEND_READ_TIMEOUT)).ok()?;
    c.set_deadline_ms(deadline_ms);
    Some(c)
}

fn handle_conn(state: &RouterState, stream: TcpStream) {
    stream.set_nodelay(true).ok();
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let mut writer = std::io::BufWriter::new(write_half);
    let mut backends = Backends {
        forward: BTreeMap::new(),
        scatter: state.targets.iter().map(|_| None).collect(),
    };
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Ok(_) => {}
            Err(_) => return,
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (id, request) = match decode_request(trimmed) {
            Ok(Incoming::V1(env)) => (Some(env.id), env.request),
            Ok(Incoming::Legacy(req)) => (None, req),
            Err(de) => {
                let id = if de.v1 { de.id } else { None };
                let resp = Response::error(de.error.code, de.error.message);
                if write_response(&mut writer, &resp, id).is_err() {
                    return;
                }
                continue;
            }
        };
        let resp = dispatch(state, &mut backends, request);
        if write_response(&mut writer, &resp, id).is_err() {
            return;
        }
    }
}

fn write_response(
    writer: &mut impl Write,
    resp: &Response,
    id: Option<u64>,
) -> std::io::Result<()> {
    let mut out = resp.to_wire(id).dump();
    out.push('\n');
    writer.write_all(out.as_bytes())?;
    writer.flush()
}

fn dispatch(state: &RouterState, backends: &mut Backends, request: Request) -> Response {
    match request {
        Request::Query { point, k } => {
            match scatter_query_batch(state, backends, &[point], k) {
                Ok(mut results) => Response::Neighbors { neighbors: results.remove(0) },
                Err(resp) => resp,
            }
        }
        Request::QueryBatch { points, k } => {
            match scatter_query_batch(state, backends, &points, k) {
                Ok(results) => Response::Results { results },
                Err(resp) => resp,
            }
        }
        Request::WalSubscribe { .. } => Response::error(
            ErrorCode::BadRequest,
            "wal_subscribe must target a node directly, not the router",
        ),
        other => forward_to_leader(state, backends, other),
    }
}

// ---------- leader forwarding ----------

/// Forward a leader-only op, chasing `NOT_LEADER` hints. Transport
/// errors are retried on the next candidate for reads; for mutations a
/// failure after the request was written leaves the outcome unknown, so
/// the client gets `UNAVAILABLE` and decides (mutations are idempotent
/// upserts, so retrying is always safe).
fn forward_to_leader(state: &RouterState, backends: &mut Backends, request: Request) -> Response {
    let mutation = request.is_mutation();
    // The op itself tells us whether success proves we found the
    // leader: followers refuse mutations/checkpoint, but answer stats
    // and query_id happily, and promote succeeding *makes* a leader.
    let proves_leader = mutation || matches!(request, Request::Checkpoint | Request::Promote);
    let mut candidates: Vec<String> = Vec::new();
    if let Some(l) = state.leader() {
        candidates.push(l);
    }
    for t in &state.targets {
        if !candidates.contains(t) {
            candidates.push(t.clone());
        }
    }
    let mut tried: Vec<String> = Vec::new();
    let mut last_failure = String::from("no targets configured");
    while let Some(addr) = candidates.first().cloned() {
        candidates.remove(0);
        if tried.contains(&addr) {
            continue;
        }
        tried.push(addr.clone());
        if !backends.forward.contains_key(&addr) {
            match connect_backend(&addr, None) {
                Some(c) => {
                    backends.forward.insert(addr.clone(), c);
                }
                None => {
                    last_failure = format!("{addr}: connect failed");
                    continue;
                }
            }
        }
        let conn = backends.forward.get_mut(&addr).expect("just inserted");
        let outcome = conn
            .submit(request.clone())
            .and_then(|rid| conn.wait_response(rid));
        match outcome {
            Ok(Response::Error { code: ErrorCode::NotLeader, message }) => {
                if let Some(hint) = leader_hint(&message) {
                    if !tried.contains(&hint) {
                        candidates.insert(0, hint);
                    }
                }
                last_failure = format!("{addr}: {message}");
            }
            Ok(resp) => {
                if proves_leader && !resp.is_error() {
                    state.set_leader(&addr);
                }
                return resp;
            }
            Err(e) => {
                // The connection is desynchronized (or dead): drop it.
                backends.forward.remove(&addr);
                if mutation {
                    return Response::error(
                        ErrorCode::Unavailable,
                        format!(
                            "leader connection failed mid-request ({addr}: {e}); \
                             mutation outcome unknown — retry"
                        ),
                    );
                }
                last_failure = format!("{addr}: {e}");
            }
        }
    }
    Response::error(ErrorCode::Unavailable, format!("no leader reachable ({last_failure})"))
}

/// Extract the leader address from a `not leader; leader=ADDR` message.
fn leader_hint(message: &str) -> Option<String> {
    let (_, hint) = message.split_once("leader=")?;
    let hint = hint.trim();
    if hint.is_empty() || hint == "unknown" {
        None
    } else {
        Some(hint.to_string())
    }
}

// ---------- scatter/gather ----------

/// Scatter a query batch to every replica, gather per-query, merge by
/// score. Succeeds if at least one replica answers the full batch.
fn scatter_query_batch(
    state: &RouterState,
    backends: &mut Backends,
    points: &[crate::features::Point],
    k: Option<usize>,
) -> std::result::Result<Vec<Vec<ScoredNeighbor>>, Response> {
    let deadline = state.deadline_ms;
    let per_replica: Vec<Option<Vec<Vec<ScoredNeighbor>>>> = std::thread::scope(|s| {
        let handles: Vec<_> = backends
            .scatter
            .iter_mut()
            .zip(&state.targets)
            .map(|(slot, addr)| {
                s.spawn(move || replica_query(slot, addr, points, k, deadline))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(None)).collect()
    });
    let answered = per_replica.iter().flatten().count();
    if answered == 0 {
        return Err(Response::error(
            ErrorCode::Unavailable,
            format!("no replica answered ({} targets tried)", state.targets.len()),
        ));
    }
    // Transpose and merge: query i gathers each replica's list i.
    let merged = (0..points.len())
        .map(|i| {
            let lists: Vec<Vec<ScoredNeighbor>> = per_replica
                .iter()
                .flatten()
                .map(|results| results[i].clone())
                .collect();
            merge_replica_lists(lists, k)
        })
        .collect();
    Ok(merged)
}

/// One replica's attempt at the batch: bounded retry (reads are
/// idempotent), reconnecting on transport error. `None` drops this
/// replica from the gather.
///
/// `deadline_ms` is the *client's* budget for the whole scatter, not a
/// per-attempt allowance: every retry carries only what remains of it,
/// so a slow first attempt cannot double the worst case — when the
/// budget is spent the replica is dropped instead of asked again.
fn replica_query(
    slot: &mut Option<GusClient>,
    addr: &str,
    points: &[crate::features::Point],
    k: Option<usize>,
    deadline_ms: u64,
) -> Option<Vec<Vec<ScoredNeighbor>>> {
    let start = monotonic_ms();
    let mut backoff = Backoff::new(RETRY_BASE, RETRY_CAP, mix2(hash_bytes(addr.as_bytes()), 1));
    for attempt in 0..READ_ATTEMPTS {
        let remaining = deadline_ms.saturating_sub(monotonic_ms().saturating_sub(start));
        if remaining == 0 {
            return None;
        }
        if attempt > 0 {
            std::thread::sleep(backoff.next_delay().min(Duration::from_millis(remaining)));
        }
        let remaining = deadline_ms.saturating_sub(monotonic_ms().saturating_sub(start));
        if remaining == 0 {
            return None;
        }
        if slot.is_none() {
            *slot = connect_backend(addr, Some(remaining));
        }
        let Some(conn) = slot.as_mut() else { continue };
        conn.set_deadline_ms(Some(remaining));
        let outcome = conn
            .submit(Request::QueryBatch { points: points.to_vec(), k })
            .and_then(|rid| conn.wait_response(rid));
        match outcome {
            Ok(Response::Results { results }) if results.len() == points.len() => {
                return Some(results)
            }
            Ok(Response::Error {
                code: ErrorCode::Unavailable | ErrorCode::DeadlineExceeded,
                ..
            }) => continue, // transient: same connection, one more try
            Ok(_) => return None, // wrong shape or hard refusal: drop replica
            Err(_) => {
                *slot = None; // desynchronized: reconnect and retry
            }
        }
    }
    None
}

/// Merge per-replica neighbor lists for one query: best score first,
/// first occurrence of an id wins (it sorted highest), truncated to `k`.
/// Replicas at different WAL positions can disagree transiently; the
/// merge favors whichever replica scored a point higher, which is the
/// same contract a single node's sharded index already provides.
fn merge_replica_lists(lists: Vec<Vec<ScoredNeighbor>>, k: Option<usize>) -> Vec<ScoredNeighbor> {
    let limit = k.unwrap_or_else(|| lists.iter().map(Vec::len).max().unwrap_or(0));
    let merged = merge_ranked(lists, |a, b| {
        b.score.total_cmp(&a.score).then(a.id.cmp(&b.id))
    });
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut out = Vec::with_capacity(limit.min(merged.len()));
    for n in merged {
        if out.len() >= limit {
            break;
        }
        if seen.insert(n.id) {
            out.push(n);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n(id: u64, score: f32) -> ScoredNeighbor {
        ScoredNeighbor { id, score, dot: score }
    }

    #[test]
    fn merge_dedupes_and_ranks_across_replicas() {
        let a = vec![n(1, 0.9), n(2, 0.5)];
        let b = vec![n(2, 0.7), n(3, 0.6)];
        let merged = merge_replica_lists(vec![a, b], Some(3));
        let ids: Vec<u64> = merged.iter().map(|x| x.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        // Id 2 keeps its best score across replicas.
        assert!((merged[1].score - 0.7).abs() < 1e-6);
    }

    #[test]
    fn merge_truncates_to_k() {
        let a = vec![n(1, 0.9), n(2, 0.8), n(3, 0.7)];
        let merged = merge_replica_lists(vec![a], Some(2));
        assert_eq!(merged.len(), 2);
    }

    #[test]
    fn merge_default_k_is_widest_replica() {
        let a = vec![n(1, 0.9), n(2, 0.8)];
        let b = vec![n(3, 0.7)];
        let merged = merge_replica_lists(vec![a, b], None);
        assert_eq!(merged.len(), 2);
    }

    #[test]
    fn leader_hint_parses_server_message() {
        assert_eq!(
            leader_hint("not leader; leader=127.0.0.1:7717"),
            Some("127.0.0.1:7717".to_string())
        );
        assert_eq!(leader_hint("not leader; leader=unknown"), None);
        assert_eq!(leader_hint("some other error"), None);
    }

    #[test]
    fn router_state_tracks_leader_transitions() {
        let state = RouterState {
            targets: vec!["a".into(), "b".into()],
            leader: Mutex::new(None),
            deadline_ms: 1000,
        };
        assert_eq!(state.leader(), None);
        state.set_leader("a");
        assert_eq!(state.leader(), Some("a".to_string()));
        state.clear_leader();
        assert_eq!(state.leader(), None);
    }
}
