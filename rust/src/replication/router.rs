//! Hedged router: the single address clients talk to in a multi-node
//! deployment.
//!
//! The router speaks the same line protocol as a node (`gus serve`), so
//! every existing client — including `gus loadgen` — points at it
//! unchanged. Per request:
//!
//! - **Mutations** (and the other leader-only ops: `checkpoint`,
//!   `promote`, `query_id`, `stats`) are forwarded to the current
//!   leader. A `NOT_LEADER` refusal carries the node's leader hint,
//!   which the router chases before falling back to probing every
//!   target. A transport error *after* a mutation was written leaves
//!   its outcome unknown, so the client gets `UNAVAILABLE` rather than
//!   a silent retry — mutations are idempotent upserts, so the client
//!   retries safely.
//! - **Queries** (`query`, `query_batch`) are *hedged*: the router
//!   tracks a latency EWMA (and deviation) per replica, sends the read
//!   to the current best replica, and — if that primary has not
//!   answered within its own p95 estimate — fires one duplicate to the
//!   next-best replica. First answer wins; the loser is bounded by the
//!   request deadline and its connection is simply discarded. A replica
//!   that fails [`FAILURE_THRESHOLD`] reads in a row trips a circuit
//!   breaker: it is ejected for a [`fault::Backoff`]-scheduled window,
//!   re-admitted through a single half-open probe, and serves only
//!   hedges (never primaries) until it has proven itself again
//!   (slow-start). `stats` responses forwarded through the router gain
//!   a `"router"` section exposing all of this.
//!
//! Failover is driven by [`super::health`]: a monitor thread probes
//! each target's `stats`, adopts whichever node reports itself leader,
//! and after enough consecutive leaderless probes promotes the live
//! follower with the highest durable WAL seq. In-order WAL shipping
//! makes that follower's log a superset of every acked record (see
//! [`super`] — the prefix property), so promotion loses nothing the
//! leader acknowledged.
//!
//! [`fault::Backoff`]: crate::fault::Backoff

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::admission::Class;
use crate::client::GusClient;
use crate::coordinator::ScoredNeighbor;
use crate::fault::Backoff;
use crate::metrics::monotonic_ms;
use crate::protocol::{decode_request, ErrorCode, Incoming, Request, Response};
use crate::util::hash::{hash_bytes, mix2};
use crate::util::json::Json;

/// Configuration for [`run_router`].
#[derive(Debug, Clone)]
pub struct RouterOpts {
    /// Address to listen on.
    pub listen: String,
    /// Node addresses (leader + followers, discovered by probing).
    pub targets: Vec<String>,
    /// Health-probe cadence.
    pub health_interval: Duration,
    /// Consecutive leaderless probe rounds before promoting a follower.
    pub fail_threshold: u32,
    /// Deadline attached to routed reads (total per request, covering
    /// the primary, the hedge and any failover attempts).
    pub deadline_ms: u64,
}

/// Bounded connect to a backend node.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Read timeout on backend connections: a node that stops answering is
/// treated as down (the request fails over or is refused), never waited
/// on indefinitely. Also bounds how long a losing hedge thread lives.
const BACKEND_READ_TIMEOUT: Duration = Duration::from_secs(5);

// ---------- per-replica health & circuit breaker ----------

/// Consecutive read failures that open a replica's breaker.
const FAILURE_THRESHOLD: u32 = 3;

/// First ejection window when a breaker opens; doubles (with jitter
/// seeded from the replica address) up to [`BREAKER_OPEN_CAP`] while
/// half-open probes keep failing.
const BREAKER_OPEN_BASE: Duration = Duration::from_millis(200);

/// Largest ejection window (pre-jitter).
const BREAKER_OPEN_CAP: Duration = Duration::from_secs(5);

/// Successful reads after a breaker closes before the replica is
/// trusted as a primary again; until then it serves hedges only
/// (slow-start re-admission).
const SLOW_START_SUCCESSES: u32 = 3;

/// Floor for the hedge trigger and the latency prior before a replica
/// has samples: hedging below this doubles load for pure noise.
const HEDGE_FLOOR_MS: f64 = 10.0;

/// Smoothing factor for the latency EWMA and its deviation EWMA.
const LATENCY_ALPHA: f64 = 0.2;

/// Circuit-breaker position for one replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BreakerState {
    /// Serving normally.
    Closed,
    /// Ejected until `until_ms` (monotonic), then half-open.
    Open { until_ms: u64 },
    /// One re-admission probe is in flight.
    HalfOpen,
}

struct HealthInner {
    /// EWMA of successful read latencies (ms); `None` until a sample.
    ewma_ms: Option<f64>,
    /// EWMA of the absolute deviation from the latency EWMA (ms).
    dev_ms: f64,
    consecutive_failures: u32,
    state: BreakerState,
    /// Ejection-window schedule: doubles per failed probe, resets when
    /// the breaker closes. Seeded per address, so replicas
    /// desynchronize but each replays deterministically.
    backoff: Backoff,
    /// Successes since the breaker last closed; below
    /// [`SLOW_START_SUCCESSES`] the replica is hedge-only.
    since_close: u32,
}

/// What one replica can do for a read right now.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum Availability {
    /// Breaker closed; carries the latency estimate used for ranking
    /// and whether the replica is still in its slow-start window.
    Ready { p95_ms: f64, slow_start: bool },
    /// The ejection window just expired — this caller carries the one
    /// half-open probe.
    Probe,
    /// Ejected: breaker open, or a probe is already in flight.
    Ejected,
}

/// Per-replica read health: latency EWMAs, consecutive failures and the
/// circuit breaker. Shared across connection threads; every method
/// takes the one internal lock briefly.
pub(crate) struct ReplicaHealth {
    inner: Mutex<HealthInner>,
}

impl ReplicaHealth {
    pub(crate) fn new(addr: &str) -> ReplicaHealth {
        ReplicaHealth {
            inner: Mutex::new(HealthInner {
                ewma_ms: None,
                dev_ms: 0.0,
                consecutive_failures: 0,
                state: BreakerState::Closed,
                backoff: Backoff::new(
                    BREAKER_OPEN_BASE,
                    BREAKER_OPEN_CAP,
                    mix2(hash_bytes(addr.as_bytes()), 0xb7ea4e7),
                ),
                // A fresh replica is fully trusted — slow-start applies
                // only after a breaker re-admission.
                since_close: u32::MAX,
            }),
        }
    }

    /// p95 latency estimate: EWMA + 3 × deviation EWMA (≈ mean + 3σ·0.8
    /// for roughly-normal latencies — deliberately conservative so the
    /// hedge fires on genuine stragglers, not routine variance).
    fn p95_of(h: &HealthInner) -> f64 {
        let mean = h.ewma_ms.unwrap_or(HEDGE_FLOOR_MS * 2.0);
        (mean + 3.0 * h.dev_ms).max(HEDGE_FLOOR_MS)
    }

    pub(crate) fn p95_ms(&self) -> f64 {
        Self::p95_of(&self.inner.lock().unwrap())
    }

    /// Classify the replica for a read starting at `now_ms`. Expired
    /// ejection windows transition to half-open here, and exactly one
    /// caller observes [`Availability::Probe`] per window.
    pub(crate) fn availability(&self, now_ms: u64) -> Availability {
        let mut h = self.inner.lock().unwrap();
        match h.state {
            BreakerState::Closed => Availability::Ready {
                p95_ms: Self::p95_of(&h),
                slow_start: h.since_close < SLOW_START_SUCCESSES,
            },
            BreakerState::Open { until_ms } if now_ms >= until_ms => {
                h.state = BreakerState::HalfOpen;
                Availability::Probe
            }
            BreakerState::Open { .. } | BreakerState::HalfOpen => Availability::Ejected,
        }
    }

    /// A read answered in `latency_ms`: feed the EWMAs, clear the
    /// failure streak, close a half-open breaker (entering slow-start).
    pub(crate) fn record_success(&self, latency_ms: u64) {
        let mut h = self.inner.lock().unwrap();
        let x = latency_ms as f64;
        match h.ewma_ms {
            None => h.ewma_ms = Some(x),
            Some(m) => {
                h.dev_ms = (1.0 - LATENCY_ALPHA) * h.dev_ms + LATENCY_ALPHA * (x - m).abs();
                h.ewma_ms = Some(m + LATENCY_ALPHA * (x - m));
            }
        }
        h.consecutive_failures = 0;
        match h.state {
            BreakerState::HalfOpen => {
                h.state = BreakerState::Closed;
                h.backoff.reset();
                h.since_close = 1;
            }
            BreakerState::Closed => h.since_close = h.since_close.saturating_add(1),
            // A straggler success from before the ejection proves
            // nothing about the replica now: stay ejected.
            BreakerState::Open { .. } => {}
        }
    }

    /// A read failed (transport error or error response): extend the
    /// failure streak and open the breaker at the threshold. A failed
    /// half-open probe re-ejects immediately with a longer window.
    pub(crate) fn record_failure(&self, now_ms: u64) {
        let mut h = self.inner.lock().unwrap();
        h.consecutive_failures = h.consecutive_failures.saturating_add(1);
        let open = match h.state {
            BreakerState::HalfOpen => true,
            BreakerState::Closed => h.consecutive_failures >= FAILURE_THRESHOLD,
            BreakerState::Open { .. } => false,
        };
        if open {
            let window = h.backoff.next_delay().as_millis() as u64;
            h.state = BreakerState::Open { until_ms: now_ms.saturating_add(window) };
        }
    }

    /// The `"router"` stats entry for this replica.
    pub(crate) fn to_json(&self, addr: &str) -> Json {
        let h = self.inner.lock().unwrap();
        Json::obj(vec![
            ("addr", Json::str(addr)),
            (
                "breaker",
                Json::str(match h.state {
                    BreakerState::Closed => "closed",
                    BreakerState::Open { .. } => "open",
                    BreakerState::HalfOpen => "half-open",
                }),
            ),
            (
                "latency_ewma_ms",
                match h.ewma_ms {
                    Some(m) => Json::num(m),
                    None => Json::Null,
                },
            ),
            ("p95_ms", Json::num(Self::p95_of(&h))),
            ("consecutive_failures", Json::u64(h.consecutive_failures as u64)),
        ])
    }
}

/// Shared router state: the target list is fixed at startup; the leader
/// is whatever the health monitor (or a successful forward) last
/// observed; per-replica read health drives hedging and ejection.
pub(crate) struct RouterState {
    pub(crate) targets: Vec<String>,
    leader: Mutex<Option<String>>,
    pub(crate) deadline_ms: u64,
    /// Read health, aligned with `targets`.
    pub(crate) health: Vec<ReplicaHealth>,
    /// Hedged duplicate reads launched, and how many the hedge won.
    hedges: AtomicU64,
    hedge_wins: AtomicU64,
}

impl RouterState {
    pub(crate) fn new(targets: Vec<String>, deadline_ms: u64) -> RouterState {
        let health = targets.iter().map(|t| ReplicaHealth::new(t)).collect();
        RouterState {
            targets,
            leader: Mutex::new(None),
            deadline_ms,
            health,
            hedges: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
        }
    }

    pub(crate) fn leader(&self) -> Option<String> {
        self.leader.lock().unwrap().clone()
    }

    /// Record a leader observation, logging transitions (the router's
    /// operator log is the failover audit trail).
    pub(crate) fn set_leader(&self, addr: &str) {
        let mut cur = self.leader.lock().unwrap();
        if cur.as_deref() != Some(addr) {
            eprintln!("[gus-router] leader -> {addr}");
            *cur = Some(addr.to_string());
        }
    }

    pub(crate) fn clear_leader(&self) {
        let mut cur = self.leader.lock().unwrap();
        if cur.is_some() {
            eprintln!("[gus-router] leader lost");
            *cur = None;
        }
    }
}

/// Run the router: bind, start the health monitor, serve connections
/// until the process dies. Each client connection gets a thread with its
/// own backend connections (the backend protocol is pipelined per
/// connection, so sharing one would serialize unrelated clients).
pub fn run_router(opts: RouterOpts) -> Result<()> {
    if opts.targets.is_empty() {
        anyhow::bail!("router needs at least one --targets address");
    }
    let state = Arc::new(RouterState::new(opts.targets.clone(), opts.deadline_ms));
    let listener =
        TcpListener::bind(&opts.listen).with_context(|| format!("binding {}", opts.listen))?;
    // Stdout, matching `gus serve` — harnesses parse this line.
    println!("[gus] serving on {}", listener.local_addr()?);
    super::health::spawn_monitor(Arc::clone(&state), opts.health_interval, opts.fail_threshold);
    for conn in listener.incoming() {
        let Ok(stream) = conn else { continue };
        let state = Arc::clone(&state);
        std::thread::Builder::new()
            .name("gus-router-conn".into())
            .spawn(move || handle_conn(&state, stream))
            .context("spawning router connection thread")?;
    }
    Ok(())
}

/// Per-client-connection backend pool. Leader-forwarding connections are
/// keyed by address (the leader can move mid-connection); read
/// connections align with the target list. A read connection lent to a
/// hedge that lost stays with its (detached, deadline-bounded) thread
/// and is re-established on next use.
struct Backends {
    forward: BTreeMap<String, GusClient>,
    scatter: Vec<Option<GusClient>>,
}

fn connect_backend(addr: &str, deadline_ms: Option<u64>) -> Option<GusClient> {
    let mut c = GusClient::connect_timeout(addr, CONNECT_TIMEOUT).ok()?;
    c.set_read_timeout(Some(BACKEND_READ_TIMEOUT)).ok()?;
    c.set_deadline_ms(deadline_ms);
    Some(c)
}

fn handle_conn(state: &Arc<RouterState>, stream: TcpStream) {
    stream.set_nodelay(true).ok();
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let mut writer = std::io::BufWriter::new(write_half);
    let mut backends = Backends {
        forward: BTreeMap::new(),
        scatter: state.targets.iter().map(|_| None).collect(),
    };
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Ok(_) => {}
            Err(_) => return,
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (id, class, request) = match decode_request(trimmed) {
            Ok(Incoming::V1(env)) => (Some(env.id), env.class, env.request),
            Ok(Incoming::Legacy(req)) => (None, None, req),
            Err(de) => {
                let id = if de.v1 { de.id } else { None };
                let resp = Response::error(de.error.code, de.error.message);
                if write_response(&mut writer, &resp, id).is_err() {
                    return;
                }
                continue;
            }
        };
        let resp = dispatch(state, &mut backends, request, class);
        if write_response(&mut writer, &resp, id).is_err() {
            return;
        }
    }
}

fn write_response(
    writer: &mut impl Write,
    resp: &Response,
    id: Option<u64>,
) -> std::io::Result<()> {
    let mut out = resp.to_wire(id).dump();
    out.push('\n');
    writer.write_all(out.as_bytes())?;
    writer.flush()
}

fn dispatch(
    state: &Arc<RouterState>,
    backends: &mut Backends,
    request: Request,
    class: Option<Class>,
) -> Response {
    match request {
        Request::Query { point, k } => {
            match hedged_query_batch(state, backends, std::slice::from_ref(&point), k, class) {
                Ok((mut results, degraded)) => {
                    Response::Neighbors { neighbors: results.remove(0), degraded }
                }
                Err(resp) => resp,
            }
        }
        Request::QueryBatch { points, k } => {
            match hedged_query_batch(state, backends, &points, k, class) {
                Ok((results, degraded)) => Response::Results { results, degraded },
                Err(resp) => resp,
            }
        }
        Request::Stats => match forward_to_leader(state, backends, Request::Stats, class) {
            Response::Stats { stats } => Response::Stats { stats: annotate_stats(state, stats) },
            other => other,
        },
        Request::WalSubscribe { .. } => Response::error(
            ErrorCode::BadRequest,
            "wal_subscribe must target a node directly, not the router",
        ),
        other => forward_to_leader(state, backends, other, class),
    }
}

/// Append the router's own `"router"` section (replica health, breaker
/// positions, hedge counters) to a forwarded `stats` body.
fn annotate_stats(state: &RouterState, stats: Json) -> Json {
    match stats {
        Json::Obj(mut map) => {
            let replicas: Vec<Json> = state
                .targets
                .iter()
                .zip(&state.health)
                .map(|(addr, h)| h.to_json(addr))
                .collect();
            map.insert(
                "router".into(),
                Json::obj(vec![
                    ("replicas", Json::Arr(replicas)),
                    ("hedges", Json::u64(state.hedges.load(Ordering::Relaxed))),
                    ("hedge_wins", Json::u64(state.hedge_wins.load(Ordering::Relaxed))),
                ]),
            );
            Json::Obj(map)
        }
        other => other,
    }
}

// ---------- leader forwarding ----------

/// Forward a leader-only op, chasing `NOT_LEADER` hints. Transport
/// errors are retried on the next candidate for reads; for mutations a
/// failure after the request was written leaves the outcome unknown, so
/// the client gets `UNAVAILABLE` and decides (mutations are idempotent
/// upserts, so retrying is always safe).
fn forward_to_leader(
    state: &RouterState,
    backends: &mut Backends,
    request: Request,
    class: Option<Class>,
) -> Response {
    let mutation = request.is_mutation();
    // The op itself tells us whether success proves we found the
    // leader: followers refuse mutations/checkpoint, but answer stats
    // and query_id happily, and promote succeeding *makes* a leader.
    let proves_leader = mutation || matches!(request, Request::Checkpoint | Request::Promote);
    let mut candidates: Vec<String> = Vec::new();
    if let Some(l) = state.leader() {
        candidates.push(l);
    }
    for t in &state.targets {
        if !candidates.contains(t) {
            candidates.push(t.clone());
        }
    }
    let mut tried: Vec<String> = Vec::new();
    let mut last_failure = String::from("no targets configured");
    while let Some(addr) = candidates.first().cloned() {
        candidates.remove(0);
        if tried.contains(&addr) {
            continue;
        }
        tried.push(addr.clone());
        if !backends.forward.contains_key(&addr) {
            match connect_backend(&addr, None) {
                Some(c) => {
                    backends.forward.insert(addr.clone(), c);
                }
                None => {
                    last_failure = format!("{addr}: connect failed");
                    continue;
                }
            }
        }
        let conn = backends.forward.get_mut(&addr).expect("just inserted");
        conn.set_class(class);
        let outcome = conn
            .submit(request.clone())
            .and_then(|rid| conn.wait_response(rid));
        match outcome {
            Ok(Response::Error { code: ErrorCode::NotLeader, message, .. }) => {
                if let Some(hint) = leader_hint(&message) {
                    if !tried.contains(&hint) {
                        candidates.insert(0, hint);
                    }
                }
                last_failure = format!("{addr}: {message}");
            }
            Ok(resp) => {
                if proves_leader && !resp.is_error() {
                    state.set_leader(&addr);
                }
                return resp;
            }
            Err(e) => {
                // The connection is desynchronized (or dead): drop it.
                backends.forward.remove(&addr);
                if mutation {
                    return Response::error(
                        ErrorCode::Unavailable,
                        format!(
                            "leader connection failed mid-request ({addr}: {e}); \
                             mutation outcome unknown — retry"
                        ),
                    );
                }
                last_failure = format!("{addr}: {e}");
            }
        }
    }
    Response::error(ErrorCode::Unavailable, format!("no leader reachable ({last_failure})"))
}

/// Extract the leader address from a `not leader; leader=ADDR` message.
fn leader_hint(message: &str) -> Option<String> {
    let (_, hint) = message.split_once("leader=")?;
    let hint = hint.trim();
    if hint.is_empty() || hint == "unknown" {
        None
    } else {
        Some(hint.to_string())
    }
}

// ---------- hedged reads ----------

/// One read's replica plan: `ranked` is the serving order (primary
/// first, then hedge/failover candidates); `probes` are half-open
/// re-admission probes that MUST each be launched — `availability`
/// hands out exactly one [`Availability::Probe`] per ejection window,
/// so a probe the caller drops would leave its replica stuck half-open
/// (reported ejected) forever.
struct ReadPlan {
    ranked: Vec<usize>,
    probes: Vec<usize>,
}

/// Rank the replicas for one read starting at `now_ms`: closed breakers
/// by latency estimate (fully-trusted ones before slow-start
/// re-admissions); half-open probes ride separately. With no closed
/// replica, one probe is promoted to primary — the rest STAY in
/// `probes`, because the caller guarantees a launched read for every
/// probe but only for `ranked[0]`; moving them all into `ranked` would
/// strand any unlaunched entry half-open (reported ejected) until a
/// health recording that never comes. With nothing at all — every
/// breaker open mid-window — fall back to trying every target in
/// order: a read must never be refused while a replica might answer.
fn plan_reads(state: &RouterState, now_ms: u64) -> ReadPlan {
    let mut ready: Vec<(bool, f64, usize)> = Vec::new();
    let mut probes: Vec<usize> = Vec::new();
    for (i, h) in state.health.iter().enumerate() {
        match h.availability(now_ms) {
            Availability::Ready { p95_ms, slow_start } => ready.push((slow_start, p95_ms, i)),
            Availability::Probe => probes.push(i),
            Availability::Ejected => {}
        }
    }
    ready.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)).then(a.2.cmp(&b.2)));
    let mut ranked: Vec<usize> = ready.into_iter().map(|(_, _, i)| i).collect();
    if ranked.is_empty() && !probes.is_empty() {
        ranked.push(probes.remove(0));
    }
    if ranked.is_empty() {
        ranked.extend(0..state.targets.len());
    }
    ReadPlan { ranked, probes }
}

/// What one replica read attempt reports back to the hedging loop.
struct ReadOutcome {
    idx: usize,
    /// `Ok` carries the per-query lists plus the backend's degraded
    /// marker (propagated to the client — a hedged answer served under
    /// pressure is still a degraded answer). `Err(Some)` is a server
    /// refusal worth relaying; `Err(None)` a transport failure.
    result: std::result::Result<(Vec<Vec<ScoredNeighbor>>, Option<f64>), Option<Response>>,
    /// The backend connection, if still synchronized.
    conn: Option<GusClient>,
}

/// Fire one read attempt on a detached thread. The thread owns the
/// connection; health is recorded from inside it (latency on success,
/// failure streak otherwise). Losing hedges are bounded by `budget_ms`
/// server-side and [`BACKEND_READ_TIMEOUT`] client-side; their send
/// lands in a dropped channel and the connection is discarded.
#[allow(clippy::too_many_arguments)]
fn spawn_read(
    state: &Arc<RouterState>,
    idx: usize,
    conn: Option<GusClient>,
    points: Vec<crate::features::Point>,
    k: Option<usize>,
    class: Option<Class>,
    budget_ms: u64,
    tx: std::sync::mpsc::Sender<ReadOutcome>,
) {
    let thread_state = Arc::clone(state);
    let fail_tx = tx.clone();
    let spawned = std::thread::Builder::new()
        .name("gus-router-read".into())
        .spawn(move || {
            let state = thread_state;
            let addr = &state.targets[idx];
            let health = &state.health[idx];
            let t0 = monotonic_ms();
            let n_queries = points.len();
            let mut conn = conn.or_else(|| connect_backend(addr, None));
            let mut desynced = false;
            let result = match conn.as_mut() {
                None => Err(None),
                Some(c) => {
                    c.set_deadline_ms(Some(budget_ms));
                    c.set_class(class);
                    match c
                        .submit(Request::QueryBatch { points, k })
                        .and_then(|rid| c.wait_response(rid))
                    {
                        Ok(Response::Results { results, degraded })
                            if results.len() == n_queries =>
                        {
                            Ok((results, degraded))
                        }
                        Ok(resp) => Err(Some(resp)),
                        Err(_) => {
                            desynced = true;
                            Err(None)
                        }
                    }
                }
            };
            if desynced {
                conn = None;
            }
            let now = monotonic_ms();
            match &result {
                Ok(_) => health.record_success(now.saturating_sub(t0)),
                Err(_) => health.record_failure(now),
            }
            let _ = tx.send(ReadOutcome { idx, result, conn });
        });
    if spawned.is_err() {
        // Thread spawn failed: record the failure here — health is
        // normally recorded inside the thread that never started, and
        // without it a half-open probe replica would stay half-open
        // (reported ejected) forever — then surface it like a transport
        // failure so the hedging loop moves on to the next candidate.
        state.health[idx].record_failure(monotonic_ms());
        let _ = fail_tx.send(ReadOutcome { idx, result: Err(None), conn: None });
    }
}

/// Answer a query batch with a hedged read: primary = best replica by
/// the plan; if it has not answered within its own p95 estimate, one
/// duplicate goes to the next-best replica and the first answer wins.
/// A *failed* attempt (refusal or transport) fails over to the next
/// candidate instead — failing over is not hedging, so it does not
/// consume the single hedge slot. Gives up at the router deadline.
fn hedged_query_batch(
    state: &Arc<RouterState>,
    backends: &mut Backends,
    points: &[crate::features::Point],
    k: Option<usize>,
    class: Option<Class>,
) -> std::result::Result<(Vec<Vec<ScoredNeighbor>>, Option<f64>), Response> {
    let deadline = state.deadline_ms.max(1);
    let start = monotonic_ms();
    let ReadPlan { ranked: plan, probes } = plan_reads(state, start);
    let (tx, rx) = std::sync::mpsc::channel();
    let primary = plan[0];
    spawn_read(
        state,
        primary,
        backends.scatter[primary].take(),
        points.to_vec(),
        k,
        class,
        deadline,
        tx.clone(),
    );
    let mut in_flight = 1usize;
    // Half-open probes launch unconditionally alongside the primary —
    // each one is this window's single re-admission attempt, and its
    // result is what closes (or re-opens) the breaker. A probe that
    // answers first also wins the read; it costs one duplicate read per
    // ejection window, which is the intended re-admission price.
    for &pi in &probes {
        spawn_read(state, pi, backends.scatter[pi].take(), points.to_vec(), k, class, deadline, tx.clone());
        in_flight += 1;
    }
    // The hedge trigger: the primary's own p95 estimate (floored so a
    // cold estimate cannot hedge instantly), clipped to the deadline.
    let hedge_at_ms = (state.health[primary].p95_ms() as u64)
        .max(HEDGE_FLOOR_MS as u64)
        .min(deadline);
    let mut next = 1usize; // next ranked entry to launch
    let mut hedged = false; // the duplicate-read slot is single-use
    let mut last_refusal: Option<Response> = None;
    loop {
        let elapsed = monotonic_ms().saturating_sub(start);
        if elapsed >= deadline {
            break;
        }
        let hedge_armed = !hedged && next < plan.len() && in_flight > 0;
        let wait_limit = if hedge_armed { hedge_at_ms } else { deadline };
        if hedge_armed && elapsed >= wait_limit {
            // Primary exceeded its p95: fire the one hedged duplicate.
            let idx = plan[next];
            spawn_read(
                state,
                idx,
                backends.scatter[idx].take(),
                points.to_vec(),
                k,
                class,
                deadline - elapsed,
                tx.clone(),
            );
            next += 1;
            in_flight += 1;
            hedged = true;
            state.hedges.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        match rx.recv_timeout(Duration::from_millis(wait_limit - elapsed)) {
            Ok(ReadOutcome { idx, result: Ok(ok), conn }) => {
                backends.scatter[idx] = conn;
                if hedged && idx != primary {
                    state.hedge_wins.fetch_add(1, Ordering::Relaxed);
                }
                return Ok(ok);
            }
            Ok(ReadOutcome { idx, result: Err(refusal), conn }) => {
                backends.scatter[idx] = conn;
                if let Some(r) = refusal {
                    last_refusal = Some(r);
                }
                in_flight -= 1;
                if next < plan.len() {
                    let remaining =
                        deadline.saturating_sub(monotonic_ms().saturating_sub(start));
                    if remaining > 0 {
                        let idx = plan[next];
                        spawn_read(
                            state,
                            idx,
                            backends.scatter[idx].take(),
                            points.to_vec(),
                            k,
                            class,
                            remaining,
                            tx.clone(),
                        );
                        next += 1;
                        in_flight += 1;
                    }
                } else if in_flight == 0 {
                    break;
                }
            }
            Err(_) => {} // timeout: the loop re-evaluates hedge/deadline
        }
    }
    Err(last_refusal.unwrap_or_else(|| {
        Response::error(
            ErrorCode::Unavailable,
            format!("no replica answered within {deadline}ms ({next} tried)"),
        )
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Past any breaker window the address-seeded backoff could emit
    /// (jitter never exceeds 1.0 × the cap).
    const PAST_ANY_WINDOW: u64 = BREAKER_OPEN_CAP.as_millis() as u64 + 1;

    #[test]
    fn breaker_opens_probes_and_closes_into_slow_start() {
        let h = ReplicaHealth::new("10.0.0.1:7717");
        assert!(matches!(h.availability(0), Availability::Ready { .. }));
        // Failures below the threshold do not eject.
        for _ in 0..FAILURE_THRESHOLD - 1 {
            h.record_failure(100);
        }
        assert!(matches!(h.availability(101), Availability::Ready { .. }));
        // The threshold failure opens the breaker.
        h.record_failure(100);
        assert!(matches!(h.availability(101), Availability::Ejected));
        // After the window: exactly one caller gets the half-open probe.
        let t1 = 100 + PAST_ANY_WINDOW;
        assert_eq!(h.availability(t1), Availability::Probe);
        assert_eq!(h.availability(t1), Availability::Ejected);
        // A failed probe re-ejects.
        h.record_failure(t1);
        assert!(matches!(h.availability(t1 + 1), Availability::Ejected));
        // Next window, next probe — this one succeeds and closes the
        // breaker into slow-start.
        let t2 = t1 + PAST_ANY_WINDOW;
        assert_eq!(h.availability(t2), Availability::Probe);
        h.record_success(5);
        match h.availability(t2 + 1) {
            Availability::Ready { slow_start, .. } => {
                assert!(slow_start, "a just-closed breaker must slow-start")
            }
            other => panic!("expected Ready, got {other:?}"),
        }
        // Enough successes end the slow-start window.
        for _ in 0..SLOW_START_SUCCESSES {
            h.record_success(5);
        }
        match h.availability(t2 + 2) {
            Availability::Ready { slow_start, .. } => assert!(!slow_start),
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    #[test]
    fn latency_ewma_feeds_p95_estimate() {
        let h = ReplicaHealth::new("10.0.0.2:7717");
        // No samples: the estimate is the prior, never below the floor.
        assert!(h.p95_ms() >= HEDGE_FLOOR_MS);
        for _ in 0..20 {
            h.record_success(40);
        }
        let p95 = h.p95_ms();
        assert!(
            (40.0..=50.0).contains(&p95),
            "steady 40ms latencies should converge near 40 (got {p95})"
        );
        // A latency spike lifts the estimate above the old mean.
        for _ in 0..5 {
            h.record_success(400);
        }
        assert!(h.p95_ms() > p95, "spikes must raise the hedge trigger");
    }

    #[test]
    fn plan_prefers_fast_replicas_and_appends_probes() {
        let state = RouterState::new(vec!["a".into(), "b".into(), "c".into()], 1_000);
        for _ in 0..8 {
            state.health[0].record_success(80);
            state.health[1].record_success(5);
        }
        for _ in 0..FAILURE_THRESHOLD {
            state.health[2].record_failure(0);
        }
        // c is ejected: the plan is the closed replicas, fastest first.
        let plan = plan_reads(&state, 1);
        assert_eq!(plan.ranked, vec![1, 0]);
        assert_eq!(plan.probes, Vec::<usize>::new());
        // After c's window it re-enters as this window's probe.
        let plan = plan_reads(&state, PAST_ANY_WINDOW);
        assert_eq!(plan.ranked, vec![1, 0]);
        assert_eq!(plan.probes, vec![2]);
    }

    #[test]
    fn plan_ranks_slow_start_replicas_after_trusted_ones() {
        let state = RouterState::new(vec!["a".into(), "b".into()], 1_000);
        // a: slow but trusted. b: fast but freshly re-admitted.
        for _ in 0..8 {
            state.health[0].record_success(80);
        }
        for _ in 0..FAILURE_THRESHOLD {
            state.health[1].record_failure(0);
        }
        assert_eq!(state.health[1].availability(PAST_ANY_WINDOW), Availability::Probe);
        state.health[1].record_success(2);
        // b is Ready again but in slow-start: hedge-only, never primary.
        let plan = plan_reads(&state, PAST_ANY_WINDOW + 1);
        assert_eq!(plan.ranked, vec![0, 1]);
        assert!(plan.probes.is_empty());
    }

    #[test]
    fn plan_promotes_probes_to_serving_when_no_replica_is_closed() {
        // Both replicas ejected; past the window both come back as
        // probes. With nothing closed, a probe IS the read path — but
        // only one is promoted to primary; the rest must stay probes so
        // the caller still launches every one of them.
        let state = RouterState::new(vec!["a".into(), "b".into()], 1_000);
        for h in &state.health {
            for _ in 0..FAILURE_THRESHOLD {
                h.record_failure(10);
            }
        }
        let plan = plan_reads(&state, 10 + PAST_ANY_WINDOW);
        assert_eq!(plan.ranked, vec![0]);
        assert_eq!(plan.probes, vec![1]);
    }

    #[test]
    fn plan_guarantees_a_launch_for_every_probe_after_full_outage() {
        // Every half-open probe must land in the guaranteed-launch set:
        // ranked[0] (the primary) or `probes` (launched unconditionally).
        // Entries in ranked[1..] are only launched on hedge/failover, so
        // a probe parked there could stay half-open (reported ejected)
        // forever after a full-outage heal.
        let state = RouterState::new(vec!["a".into(), "b".into(), "c".into()], 1_000);
        for h in &state.health {
            for _ in 0..FAILURE_THRESHOLD {
                h.record_failure(10);
            }
        }
        let plan = plan_reads(&state, 10 + PAST_ANY_WINDOW);
        let mut launched = vec![plan.ranked[0]];
        launched.extend(&plan.probes);
        launched.sort_unstable();
        assert_eq!(launched, vec![0, 1, 2], "a probe was handed out without a launch slot");
        // Each probe read closes or re-opens its breaker; nothing is
        // left half-open once they are all recorded.
        for (i, h) in state.health.iter().enumerate() {
            if i == plan.ranked[0] {
                h.record_success(5);
            } else {
                h.record_failure(10 + PAST_ANY_WINDOW);
            }
        }
        assert!(matches!(
            state.health[plan.ranked[0]].availability(11 + PAST_ANY_WINDOW),
            Availability::Ready { .. }
        ));
        for &i in &plan.probes {
            // Re-opened, not stuck half-open: a fresh window eventually
            // hands out a new probe.
            assert_eq!(state.health[i].availability(10 + 2 * PAST_ANY_WINDOW), Availability::Probe);
        }
    }

    #[test]
    fn plan_falls_back_to_all_targets_when_everything_is_ejected() {
        let state = RouterState::new(vec!["a".into(), "b".into()], 1_000);
        for h in &state.health {
            for _ in 0..FAILURE_THRESHOLD {
                h.record_failure(10);
            }
        }
        let plan = plan_reads(&state, 11);
        assert_eq!(plan.ranked, vec![0, 1]);
        assert!(plan.probes.is_empty());
    }

    #[test]
    fn leader_hint_parses_server_message() {
        assert_eq!(
            leader_hint("not leader; leader=127.0.0.1:7717"),
            Some("127.0.0.1:7717".to_string())
        );
        assert_eq!(leader_hint("not leader; leader=unknown"), None);
        assert_eq!(leader_hint("some other error"), None);
    }

    #[test]
    fn router_state_tracks_leader_transitions() {
        let state = RouterState::new(vec!["a".into(), "b".into()], 1_000);
        assert_eq!(state.leader(), None);
        state.set_leader("a");
        assert_eq!(state.leader(), Some("a".to_string()));
        state.clear_leader();
        assert_eq!(state.leader(), None);
    }

    #[test]
    fn annotate_stats_appends_router_section() {
        let state = RouterState::new(vec!["a".into()], 1_000);
        state.health[0].record_success(12);
        let stats = Json::obj(vec![("points", Json::num(10.0))]);
        let out = annotate_stats(&state, stats);
        let router = out.get("router");
        assert_eq!(router.get("hedges").as_u64(), Some(0));
        let replicas = router.get("replicas").as_arr().unwrap();
        assert_eq!(replicas.len(), 1);
        assert_eq!(replicas[0].get("addr").as_str(), Some("a"));
        assert_eq!(replicas[0].get("breaker").as_str(), Some("closed"));
        assert!(replicas[0].get("latency_ewma_ms").as_f64().unwrap() > 0.0);
        // Non-object stats pass through untouched.
        assert_eq!(annotate_stats(&state, Json::Null), Json::Null);
    }
}
