//! Multi-node serving: WAL-shipping replication and failover.
//!
//! One leader accepts mutations and streams its write-ahead log to any
//! number of followers; followers persist the stream verbatim, apply it
//! through the same replay path as crash recovery, and serve read-only
//! queries. A hedged router ([`router`]) in front of the nodes forwards
//! mutations to the leader, routes each read to the lowest-latency
//! healthy replica (with one hedged duplicate past the primary's p95 and
//! per-replica circuit breakers), and promotes the most caught-up
//! follower when the leader dies.
//!
//! # Design
//!
//! The replication stream *is* the WAL: the leader ships the exact
//! `[len][seq][check][payload]` frames it appended
//! ([`crate::coordinator::wal`]), so a follower's log is byte-identical
//! to the leader's by construction, follower apply is the
//! crash-recovery replay path (no second apply implementation to drift),
//! and catch-up after a disconnect is just "resume at my last seq + 1".
//!
//! Frames are shipped strictly in order, so a follower's log is always a
//! *prefix* of the leader's. That prefix property is what makes failover
//! sound: the follower with the highest durable seq holds a superset of
//! every other follower's state, and promoting it loses nothing any
//! replica acknowledged.
//!
//! Durability of *client*-acknowledged mutations across failover is the
//! ack gate ([`NodeReplication::ack_gate`], wired through
//! [`crate::server::Replication`]): with `--ack-replicas N`, a mutation's
//! response is held until N followers have durably appended and applied
//! its WAL record (they report `{"ack":seq}` on the subscription socket).
//! A gate timeout turns the response into `UNAVAILABLE` — the client must
//! treat the mutation as unacknowledged (it may still survive; mutations
//! are idempotent upserts, so retrying is safe).
//!
//! The subscription wire protocol, bootstrap-by-snapshot path, and
//! failover rules are documented in `docs/REPLICATION.md`.
//!
//! # Module map
//!
//! - [`leader`] — serves `wal_subscribe` streams (snapshot bootstrap or
//!   log tail), reads follower acks.
//! - [`follower`] — bootstraps/recovers local state, tails the leader,
//!   applies + acks, reconnects, and stops cleanly on promotion.
//! - [`router`] — stateless proxy: mutations to the leader, scatter
//!   reads, merged top-k, read retries.
//! - [`health`] — the router's failure detector + automatic promotion.

pub mod follower;
pub mod health;
pub mod leader;
pub mod router;

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::DynamicGus;
use crate::metrics::ReplicationRole;
use crate::server::Replication;

pub use follower::{start_follower, FollowerOpts};
pub use router::{run_router, RouterOpts};

/// Default for how long a leader holds a mutation's ack waiting for
/// follower acks before answering `UNAVAILABLE` (semi-sync gate).
/// Configurable per node with `--ack-timeout-ms`.
pub const ACK_TIMEOUT: Duration = Duration::from_secs(5);

/// How long [`NodeReplication::promote`] waits for the follow loop to
/// stop streaming before giving up. Covers the follower's socket read
/// timeout plus scheduling slack.
const PROMOTE_TIMEOUT: Duration = Duration::from_secs(15);

/// What this node currently is. A follower becomes a leader exactly once
/// (promotion); a leader never demotes in-process — a deposed leader
/// rejoins by restarting as a fresh follower (see `docs/REPLICATION.md`).
enum RoleState {
    Leader,
    Follower {
        /// Where mutations should go instead (the `NOT_LEADER` hint).
        leader: String,
        /// True while the follow loop is applying the leader's stream.
        /// Promotion waits for this to drop so no frame is applied after
        /// the node starts accepting writes of its own.
        streaming: bool,
        /// Set by [`NodeReplication::promote`]; the follow loop polls it
        /// between frames and exits.
        promote: bool,
    },
}

/// Replication state for one serving node (leader or follower); the
/// concrete [`crate::server::Replication`] implementation.
pub struct NodeReplication {
    gus: Arc<DynamicGus>,
    /// Followers that must durably ack a mutation before the leader acks
    /// the client (0 = fully asynchronous replication).
    ack_replicas: usize,
    ack_timeout: Duration,
    role: Mutex<RoleState>,
    role_cond: Condvar,
    /// Per-subscriber highest acked seq, keyed by subscription id.
    acks: Mutex<BTreeMap<u64, u64>>,
    acks_cond: Condvar,
    next_sub: Mutex<u64>,
}

impl NodeReplication {
    /// Replication state for a node starting as the leader.
    /// `ack_timeout` bounds the semi-sync gate ([`ACK_TIMEOUT`] is the
    /// CLI default).
    pub fn leader(
        gus: Arc<DynamicGus>,
        ack_replicas: usize,
        ack_timeout: Duration,
    ) -> Arc<NodeReplication> {
        gus.metrics.replication.set_role(ReplicationRole::Leader);
        Arc::new(NodeReplication {
            gus,
            ack_replicas,
            ack_timeout,
            role: Mutex::new(RoleState::Leader),
            role_cond: Condvar::new(),
            acks: Mutex::new(BTreeMap::new()),
            acks_cond: Condvar::new(),
            next_sub: Mutex::new(0),
        })
    }

    /// Replication state for a node starting as a follower of `leader`.
    /// `ack_replicas` and `ack_timeout` only matter after a promotion.
    pub fn follower(
        gus: Arc<DynamicGus>,
        leader: String,
        ack_replicas: usize,
        ack_timeout: Duration,
    ) -> Arc<NodeReplication> {
        gus.metrics.replication.set_role(ReplicationRole::Follower);
        gus.metrics.replication.set_leader_hint(Some(leader.clone()));
        Arc::new(NodeReplication {
            gus,
            ack_replicas,
            ack_timeout,
            role: Mutex::new(RoleState::Follower {
                leader,
                streaming: false,
                promote: false,
            }),
            role_cond: Condvar::new(),
            acks: Mutex::new(BTreeMap::new()),
            acks_cond: Condvar::new(),
            next_sub: Mutex::new(0),
        })
    }

    /// The service this node replicates.
    pub fn gus(&self) -> &Arc<DynamicGus> {
        &self.gus
    }

    /// Is this node currently the leader?
    pub fn is_leader(&self) -> bool {
        matches!(*self.role.lock().unwrap(), RoleState::Leader)
    }

    // ---------- follow-loop coordination (follower role) ----------

    /// True once the follow loop must stop (promotion requested or
    /// already promoted). Polled between frames.
    pub(crate) fn stop_requested(&self) -> bool {
        match &*self.role.lock().unwrap() {
            RoleState::Leader => true,
            RoleState::Follower { promote, .. } => *promote,
        }
    }

    /// The follow loop entered/left its apply loop. Leaving notifies a
    /// pending [`NodeReplication::promote`].
    pub(crate) fn set_streaming(&self, on: bool) {
        if let RoleState::Follower { streaming, .. } = &mut *self.role.lock().unwrap() {
            *streaming = on;
        }
        if !on {
            self.role_cond.notify_all();
        }
    }

    /// The follow loop (re)connected to `addr`: update the hint embedded
    /// in `NOT_LEADER` answers and in `stats`.
    pub(crate) fn note_leader(&self, addr: &str) {
        if let RoleState::Follower { leader, .. } = &mut *self.role.lock().unwrap() {
            addr.clone_into(leader);
        }
        self.gus.metrics.replication.set_leader_hint(Some(addr.to_string()));
    }

    // ---------- subscriber ack table (leader role) ----------

    /// Register a new subscription stream; returns its id for
    /// [`NodeReplication::record_ack`] / `unregister_subscriber`.
    pub(crate) fn register_subscriber(&self) -> u64 {
        let id = {
            let mut next = self.next_sub.lock().unwrap();
            *next += 1;
            *next
        };
        self.acks.lock().unwrap().insert(id, 0);
        self.gus.metrics.replication.subscriber_connected();
        id
    }

    pub(crate) fn unregister_subscriber(&self, id: u64) {
        self.acks.lock().unwrap().remove(&id);
        // Wake gate waiters so they recount against the shrunk table.
        self.acks_cond.notify_all();
        // Stream ids are never reused, so the dead stream's ack-timeout
        // attribution is dropped with it (the aggregate counter stays).
        self.gus.metrics.replication.forget_subscriber(id);
        self.gus.metrics.replication.subscriber_disconnected();
    }

    /// A follower durably appended + applied through `seq`.
    pub(crate) fn record_ack(&self, id: u64, seq: u64) {
        let mut acks = self.acks.lock().unwrap();
        if let Some(entry) = acks.get_mut(&id) {
            if seq > *entry {
                *entry = seq;
            }
        }
        self.acks_cond.notify_all();
    }

    /// The highest seq subscriber `id` has acked (`None` once it has
    /// unregistered). The leader's shipping loop reads this to detect a
    /// subscriber whose ack back-channel has gone dark.
    pub(crate) fn subscriber_ack(&self, id: u64) -> Option<u64> {
        self.acks.lock().unwrap().get(&id).copied()
    }

    fn acked_replicas(acks: &BTreeMap<u64, u64>, seq: u64) -> usize {
        acks.values().filter(|&&a| a >= seq).count()
    }
}

impl Replication for NodeReplication {
    fn deny_mutations(&self) -> Option<String> {
        match &*self.role.lock().unwrap() {
            RoleState::Leader => None,
            RoleState::Follower { leader, .. } => Some(leader.clone()),
        }
    }

    fn ack_gate(&self, wal_seq: u64) -> std::result::Result<(), String> {
        if self.ack_replicas == 0 {
            return Ok(());
        }
        if !self.is_leader() {
            // Followers never reach here for mutations (denied above);
            // nothing to gate.
            return Ok(());
        }
        let need = self.ack_replicas;
        let guard = self.acks.lock().unwrap();
        let (acks, _timed_out) = self
            .acks_cond
            .wait_timeout_while(guard, self.ack_timeout, |acks| {
                Self::acked_replicas(acks, wal_seq) < need
            })
            .unwrap();
        let have = Self::acked_replicas(&acks, wal_seq);
        if have < need {
            // Attribute the timeout to the subscribers that were behind —
            // the per-replica counts in stats are how an operator tells
            // "one slow replica" from "replication is down".
            let laggards: Vec<u64> = acks
                .iter()
                .filter(|(_, &a)| a < wal_seq)
                .map(|(&id, _)| id)
                .collect();
            drop(acks);
            self.gus.metrics.replication.note_ack_timeout(&laggards);
            return Err(format!(
                "replication ack timeout at seq {wal_seq}: {have}/{need} replicas acked"
            ));
        }
        Ok(())
    }

    fn promote(&self) -> Result<u64> {
        let mut role = self.role.lock().unwrap();
        if matches!(*role, RoleState::Leader) {
            return Ok(self.gus.wal_seq());
        }
        if let RoleState::Follower { promote, .. } = &mut *role {
            *promote = true;
        }
        self.role_cond.notify_all();
        // Wait for the follow loop to observe the flag and stop applying;
        // no frame may land after this node starts taking writes.
        let (mut role, _timed_out) = self
            .role_cond
            .wait_timeout_while(role, PROMOTE_TIMEOUT, |r| {
                matches!(r, RoleState::Follower { streaming: true, .. })
            })
            .unwrap();
        if matches!(*role, RoleState::Follower { streaming: true, .. }) {
            bail!("promotion timed out waiting for the replication stream to stop");
        }
        *role = RoleState::Leader;
        drop(role);
        self.gus.metrics.replication.set_role(ReplicationRole::Leader);
        self.gus.metrics.replication.set_leader_hint(None);
        let seq = self.gus.wal_seq();
        eprintln!("[gus] promoted to leader at seq {seq}");
        Ok(seq)
    }

    fn subscribe(
        &self,
        from_seq: u64,
        id: Option<u64>,
        reader: BufReader<TcpStream>,
        stream: TcpStream,
    ) -> Result<()> {
        if let Some(hint) = self.deny_mutations() {
            // Followers do not re-replicate (no chained replication):
            // point the would-be subscriber at the leader and hang up.
            leader::refuse_not_leader(stream, id, &hint);
            return Ok(());
        }
        leader::serve_subscription(self, from_seq, id, reader, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GusConfig;
    use crate::features::Schema;

    fn test_gus() -> Arc<DynamicGus> {
        let schema = Schema::arxiv_like(4);
        let config = GusConfig::default();
        Arc::new(DynamicGus::bootstrap(schema, config, &[], 1).unwrap())
    }

    #[test]
    fn ack_gate_counts_replica_acks() {
        let rep = NodeReplication::leader(test_gus(), 1, ACK_TIMEOUT);
        // With no subscribers the gate must time out, not panic. Use a
        // short timeout via a direct wait: rely on the configured one
        // being bounded — here we only check the error shape by acking
        // first from a registered subscriber.
        let sub = rep.register_subscriber();
        rep.record_ack(sub, 9);
        assert!(rep.ack_gate(9).is_ok());
        assert!(rep.ack_gate(3).is_ok(), "acks are cumulative");
        rep.unregister_subscriber(sub);
        assert_eq!(rep.gus().metrics.replication.subscribers(), 0);
    }

    #[test]
    fn ack_gate_is_disabled_at_zero_replicas() {
        let rep = NodeReplication::leader(test_gus(), 0, ACK_TIMEOUT);
        assert!(rep.ack_gate(u64::MAX).is_ok());
    }

    #[test]
    fn ack_gate_timeout_is_configurable_and_attributes_laggards() {
        // A 30ms gate: the test stays fast, and the timeout is observably
        // the configured one rather than the 5s default.
        let rep = NodeReplication::leader(test_gus(), 1, Duration::from_millis(30));
        let sub = rep.register_subscriber();
        rep.record_ack(sub, 2);
        let t0 = crate::metrics::monotonic_ms();
        let err = rep.ack_gate(5).unwrap_err();
        let waited_ms = crate::metrics::monotonic_ms().saturating_sub(t0);
        assert!(waited_ms < 2_000, "gate used the default timeout ({waited_ms}ms)");
        assert!(err.contains("0/1"), "{err}");
        // The laggard subscriber is charged in the per-replica stats.
        assert_eq!(rep.gus().metrics.replication.ack_timeouts_for(sub), 1);
        let j = rep.gus().metrics.replication.to_json(5);
        assert_eq!(j.get("ack_timeouts").as_u64(), Some(1));
        assert_eq!(
            j.get("ack_timeouts_by_subscriber").get(&format!("{sub}")).as_u64(),
            Some(1)
        );
        rep.unregister_subscriber(sub);
        // Unregistering prunes the per-stream attribution row, so
        // reconnect churn cannot grow the stats map without bound.
        assert_eq!(rep.gus().metrics.replication.ack_timeouts_for(sub), 0);
        assert_eq!(
            rep.gus().metrics.replication.to_json(5).get("ack_timeouts").as_u64(),
            Some(1)
        );
    }

    #[test]
    fn follower_denies_and_promotes() {
        let rep = NodeReplication::follower(test_gus(), "10.1.2.3:7".into(), 0, ACK_TIMEOUT);
        assert_eq!(rep.deny_mutations(), Some("10.1.2.3:7".into()));
        assert!(!rep.is_leader());
        rep.note_leader("10.9.9.9:7");
        assert_eq!(rep.deny_mutations(), Some("10.9.9.9:7".into()));
        // Not streaming, so promotion completes immediately.
        let seq = rep.promote().unwrap();
        assert_eq!(seq, 0);
        assert!(rep.is_leader());
        assert_eq!(rep.deny_mutations(), None);
        assert_eq!(
            rep.gus().metrics.replication.role(),
            ReplicationRole::Leader
        );
        // Idempotent.
        assert!(rep.promote().is_ok());
    }

    #[test]
    fn promote_waits_for_streaming_to_stop() {
        let rep = NodeReplication::follower(test_gus(), "a:1".into(), 0, ACK_TIMEOUT);
        rep.set_streaming(true);
        let rep2 = Arc::clone(&rep);
        let handle = std::thread::spawn(move || {
            // Simulate the follow loop: poll the stop flag, then stop.
            while !rep2.stop_requested() {
                std::thread::sleep(Duration::from_millis(5));
            }
            rep2.set_streaming(false);
        });
        let seq = rep.promote().unwrap();
        assert_eq!(seq, 0);
        assert!(rep.is_leader());
        handle.join().unwrap();
    }
}
