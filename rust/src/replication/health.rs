//! Router-side health monitor and automatic failover.
//!
//! A single thread probes every target's `stats` each interval and
//! reads the `replication` section ([`crate::metrics::ReplicationGauges`]):
//! whichever live node reports role `leader` (or `single` — a
//! non-replicating node behind the router still serves everything) is
//! adopted as the forwarding target. After [`RouterOpts::fail_threshold`]
//! consecutive leaderless rounds, the monitor promotes the live
//! follower with the highest durable `wal_last_seq` — by the prefix
//! property of in-order WAL shipping, that follower holds every record
//! any follower acked, so no acknowledged mutation is lost.
//!
//! [`RouterOpts::fail_threshold`]: super::router::RouterOpts

use std::sync::Arc;
use std::time::Duration;

use crate::client::GusClient;

use super::router::RouterState;

/// Bounded connect per probe: a dead node costs this, not a TCP
/// handshake timeout.
const PROBE_CONNECT_TIMEOUT: Duration = Duration::from_secs(1);

/// A probe that takes longer than this is counted as down.
const PROBE_READ_TIMEOUT: Duration = Duration::from_secs(2);

/// Deadline attached to probe `stats` calls (server-side shedding).
const PROBE_DEADLINE_MS: u64 = 1_000;

/// Promotion waits for the follower to drain its in-flight stream
/// (bounded by the node's own 15s promote handshake timeout).
const PROMOTE_READ_TIMEOUT: Duration = Duration::from_secs(20);

/// One probe round's view of a target.
struct Probe {
    addr: String,
    role: String,
    wal_last_seq: u64,
}

/// Start the monitor thread. It never exits — the router owns it for
/// the life of the process.
pub(crate) fn spawn_monitor(state: Arc<RouterState>, interval: Duration, threshold: u32) {
    std::thread::Builder::new()
        .name("gus-router-health".into())
        .spawn(move || monitor_loop(&state, interval, threshold))
        .expect("spawning router health monitor");
}

fn monitor_loop(state: &RouterState, interval: Duration, threshold: u32) {
    let mut leaderless_rounds: u32 = 0;
    // Jitter each round's sleep by ±10% so multiple routers probing the
    // same cluster spread out instead of landing in lockstep. The stream
    // is seeded from the target list: deterministic per deployment, and
    // covered by the replay-determinism lint like the rest of this file.
    let mut jitter = crate::util::rng::Rng::seeded(crate::util::hash::hash_bytes(
        state.targets.join(",").as_bytes(),
    ));
    loop {
        let scale = 0.9 + 0.2 * jitter.f64();
        std::thread::sleep(interval.mul_f64(scale));
        let probes: Vec<Probe> =
            state.targets.iter().filter_map(|addr| probe_target(addr)).collect();
        if let Some(leader) = live_leader(state, &probes) {
            state.set_leader(&leader);
            leaderless_rounds = 0;
            continue;
        }
        leaderless_rounds += 1;
        if leaderless_rounds < threshold {
            continue;
        }
        // The cluster has been leaderless for `threshold` rounds: fail
        // over. Reset the counter either way so a failed promotion is
        // retried only after another full threshold of rounds (promotion
        // is idempotent, but hammering a struggling node helps nothing).
        leaderless_rounds = 0;
        state.clear_leader();
        let Some(best) = best_follower(&probes) else {
            eprintln!("[gus-router] no leader and no live follower to promote");
            continue;
        };
        eprintln!(
            "[gus-router] no leader for {threshold} rounds; promoting {} (wal_last_seq={})",
            best.addr, best.wal_last_seq
        );
        match promote(&best.addr) {
            Ok(seq) => {
                eprintln!("[gus-router] promoted {} at seq {seq}", best.addr);
                state.set_leader(&best.addr);
            }
            Err(e) => eprintln!("[gus-router] promoting {} failed: {e}", best.addr),
        }
    }
}

/// The live leader this round, preferring the currently adopted one
/// (avoids flapping between two nodes that both claim leadership during
/// a handover window).
fn live_leader(state: &RouterState, probes: &[Probe]) -> Option<String> {
    let leads = |p: &Probe| p.role == "leader" || p.role == "single";
    if let Some(cur) = state.leader() {
        if probes.iter().any(|p| p.addr == cur && leads(p)) {
            return Some(cur);
        }
    }
    probes.iter().find(|p| leads(p)).map(|p| p.addr.clone())
}

/// The promotion candidate: the live follower with the most durable WAL
/// (ties broken toward the lexicographically smallest address, so
/// concurrent monitors would pick the same node).
fn best_follower(probes: &[Probe]) -> Option<&Probe> {
    probes
        .iter()
        .filter(|p| p.role == "follower")
        .max_by_key(|p| (p.wal_last_seq, std::cmp::Reverse(p.addr.clone())))
}

/// One bounded `stats` probe. `None` means down (connect/read failed or
/// the response was not parseable).
fn probe_target(addr: &str) -> Option<Probe> {
    let mut c = GusClient::connect_timeout(addr, PROBE_CONNECT_TIMEOUT).ok()?;
    c.set_read_timeout(Some(PROBE_READ_TIMEOUT)).ok()?;
    c.set_deadline_ms(Some(PROBE_DEADLINE_MS));
    let stats = c.stats().ok()?;
    let rep = stats.get("replication");
    Some(Probe {
        addr: addr.to_string(),
        role: rep.get("role").as_str().unwrap_or("").to_string(),
        wal_last_seq: rep.get("wal_last_seq").as_u64().unwrap_or(0),
    })
}

/// Promote a follower (its own read path waits out the stream-drain
/// handshake, so this read timeout is generous).
fn promote(addr: &str) -> anyhow::Result<u64> {
    let mut c = GusClient::connect_timeout(addr, PROBE_CONNECT_TIMEOUT)?;
    c.set_read_timeout(Some(PROMOTE_READ_TIMEOUT))?;
    c.promote()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(addr: &str, role: &str, seq: u64) -> Probe {
        Probe { addr: addr.to_string(), role: role.to_string(), wal_last_seq: seq }
    }

    #[test]
    fn best_follower_prefers_highest_seq_then_lowest_addr() {
        let probes = vec![
            probe("c:1", "follower", 10),
            probe("a:1", "follower", 12),
            probe("b:1", "follower", 12),
        ];
        assert_eq!(best_follower(&probes).unwrap().addr, "a:1");
    }

    #[test]
    fn best_follower_ignores_non_followers() {
        let probes = vec![probe("a:1", "leader", 99), probe("b:1", "follower", 1)];
        assert_eq!(best_follower(&probes).unwrap().addr, "b:1");
        assert!(best_follower(&[probe("a:1", "single", 5)]).is_none());
    }
}
