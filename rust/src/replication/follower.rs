//! Follower side: bootstrap or recover local state, tail the leader's
//! WAL stream, apply + ack each record, reconnect on failure, and stop
//! cleanly when promoted.
//!
//! A follower is a durable node like any other: it persists the leader's
//! frames verbatim into its own `--wal-dir` (so its log is byte-identical
//! to the leader's prefix), applies them through the crash-recovery
//! replay path, and runs its own checkpointer. Restart is plain
//! [`wal::recover`] followed by "subscribe at my last seq + 1".
//!
//! The apply loop mirrors the coordinator's own log-before-apply
//! critical section: the WAL writer lock is held across append + apply,
//! so a checkpoint taken concurrently always records a `(store, seq)`
//! pair that is actually consistent.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::wal::{self, FrameError};
use crate::coordinator::DynamicGus;
use crate::fault::Backoff;
use crate::protocol::{wire, ErrorCode, Response};
use crate::util::hash::{hash_bytes, mix2};
use crate::util::json::Json;

use super::NodeReplication;

/// How a follower node is started (see `gus follow`).
pub struct FollowerOpts {
    /// Leader address to subscribe to first.
    pub leader: String,
    /// Other node addresses, cycled to rediscover the leader after a
    /// failover (the current hint is always tried first).
    pub peers: Vec<String>,
    /// This follower's own durability directory.
    pub wal_dir: PathBuf,
    /// Apply/query thread count.
    pub threads: usize,
    /// Followers that must ack this node's own mutations if it is ever
    /// promoted (semi-sync; 0 = async).
    pub ack_replicas: usize,
    /// How long the ack gate waits for those acks before answering
    /// `UNAVAILABLE` (see `--ack-timeout-ms`).
    pub ack_timeout: Duration,
}

/// Socket read timeout while tailing. The leader heartbeats every
/// [`super::leader::HEARTBEAT`], so this only fires when the leader is
/// dead or the link has stalled — either way, reconnect.
const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// First reconnect delay; doubles (with seeded jitter) up to
/// [`RECONNECT_CAP`] while the leader stays unreachable. A fixed pause
/// here made every follower hammer a dead leader in lockstep; the jitter
/// seed is derived from the follower's own WAL dir, so distinct nodes
/// desynchronize while each node replays its own delay sequence
/// deterministically.
const RECONNECT_BASE: Duration = Duration::from_millis(100);

/// Largest reconnect delay (pre-jitter) once the backoff saturates.
const RECONNECT_CAP: Duration = Duration::from_secs(5);

/// Backoff cap during initial bootstrap: tighter than the steady-state
/// cap so a follower racing its leader's startup keeps probing briskly.
const BOOTSTRAP_CAP: Duration = Duration::from_secs(1);

/// Connect timeout per subscription attempt.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Reconnect cycles during initial bootstrap before giving up (the
/// leader may still be starting); with the bootstrap backoff cap this
/// bounds the wait to roughly a minute.
const BOOTSTRAP_CYCLES: usize = 60;

/// The jitter seed for one node's backoff streams: stable across
/// restarts (same WAL dir → same sequence) but distinct between nodes.
fn backoff_seed(wal_dir: &Path, stream: u64) -> u64 {
    mix2(hash_bytes(wal_dir.to_string_lossy().as_bytes()), stream)
}

/// One established subscription stream, positioned at the first byte
/// after the header line.
struct Stream {
    /// Read side (header already consumed; files/frames follow).
    reader: BufReader<TcpStream>,
    /// Write side (acks go here).
    sock: TcpStream,
    /// First WAL seq the frame stream will carry.
    resume_seq: u64,
    /// `(name, bytes)` files to receive before the frames (snapshot
    /// bootstrap); empty in tail mode.
    files: Vec<(String, u64)>,
    snapshot: bool,
}

/// Outcome of one `wal_subscribe` attempt against one address.
enum Attempt {
    Stream(Stream),
    /// The node answered `NOT_LEADER`, possibly with a better address.
    NotLeader(Option<String>),
    /// Connect/handshake failure (node down, timeout, bad header).
    Failed(String),
}

fn connect(addr: &str) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let sa = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr} resolved to no address"))?;
    let stream = TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    Ok(stream)
}

/// Extract the leader hint from a `not leader; leader=<addr>` message.
fn leader_hint(message: &str) -> Option<String> {
    let (_, addr) = message.split_once("leader=")?;
    let addr = addr.trim();
    (!addr.is_empty()).then(|| addr.to_string())
}

/// Try one `wal_subscribe {from_seq}` handshake against `addr`.
fn try_subscribe(addr: &str, from_seq: u64) -> Attempt {
    let mut sock = match connect(addr) {
        Ok(s) => s,
        Err(e) => return Attempt::Failed(format!("{e:#}")),
    };
    let mut reader = match sock.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => return Attempt::Failed(format!("cloning socket: {e}")),
    };
    let mut line = wire::wal_subscribe(from_seq).dump();
    line.push('\n');
    if let Err(e) = sock.write_all(line.as_bytes()) {
        return Attempt::Failed(format!("sending wal_subscribe: {e}"));
    }
    let mut header = String::new();
    match reader.read_line(&mut header) {
        Ok(0) => return Attempt::Failed("connection closed before header".into()),
        Ok(_) => {}
        Err(e) => return Attempt::Failed(format!("reading header: {e}")),
    }
    let j = match Json::parse(header.trim()) {
        Ok(j) => j,
        Err(e) => return Attempt::Failed(format!("bad subscription header: {e}")),
    };
    if !j.get("error").is_null() {
        return match Response::from_wire(&j) {
            Ok((_, Response::Error { code: ErrorCode::NotLeader, message, .. })) => {
                Attempt::NotLeader(leader_hint(&message))
            }
            Ok((_, Response::Error { code, message, .. })) => {
                Attempt::Failed(format!("subscription refused [{code}]: {message}"))
            }
            _ => Attempt::Failed("unintelligible subscription refusal".into()),
        };
    }
    let mode = j.get("mode").as_str().unwrap_or("").to_string();
    let Some(resume_seq) = j.get("resume_seq").as_u64() else {
        return Attempt::Failed("subscription header missing resume_seq".into());
    };
    let mut files = Vec::new();
    if let Json::Arr(listed) = j.get("files") {
        for f in listed {
            let name = f.get("name").as_str().unwrap_or("").to_string();
            let Some(bytes) = f.get("bytes").as_u64() else {
                return Attempt::Failed("subscription header file missing byte count".into());
            };
            // The names land in our local state directory: refuse
            // anything that could escape it.
            if name.is_empty() || name.contains('/') || name.contains('\\') || name.contains("..")
            {
                return Attempt::Failed(format!("unsafe snapshot file name {name:?}"));
            }
            files.push((name, bytes));
        }
    }
    Attempt::Stream(Stream {
        reader,
        sock,
        resume_seq,
        files,
        snapshot: mode == "snapshot",
    })
}

/// One full cycle over the candidate addresses (current hint first, then
/// the configured peers, following any fresher hints the nodes return).
/// `from_seq` is re-evaluated per attempt via the closure so reconnects
/// always resume at the current durable seq.
fn subscribe_cycle(
    hint: &mut Option<String>,
    primary: &str,
    peers: &[String],
    from_seq: impl Fn() -> u64,
) -> Result<(String, Stream), String> {
    let mut queue: Vec<String> = Vec::new();
    if let Some(h) = hint.clone() {
        queue.push(h);
    }
    queue.push(primary.to_string());
    queue.extend(peers.iter().cloned());
    let mut tried: Vec<String> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    while let Some(addr) = queue.iter().find(|a| !tried.contains(a)).cloned() {
        tried.push(addr.clone());
        match try_subscribe(&addr, from_seq()) {
            Attempt::Stream(s) => {
                *hint = Some(addr.clone());
                return Ok((addr, s));
            }
            Attempt::NotLeader(h) => {
                failures.push(format!("{addr}: not leader"));
                if let Some(h) = h {
                    // Fresher knowledge than our static list: try it next.
                    queue.insert(0, h);
                }
            }
            Attempt::Failed(e) => failures.push(format!("{addr}: {e}")),
        }
    }
    Err(failures.join("; "))
}

/// Receive the bootstrap files into `dir` (created if needed), in listed
/// order — the leader lists the corpus before `snapshot.json`, so a
/// crash mid-bootstrap leaves nothing recovery would mistake for state.
fn receive_files(reader: &mut BufReader<TcpStream>, dir: &Path, files: &[(String, u64)]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    for (name, bytes) in files {
        let mut data = vec![
            0u8;
            usize::try_from(*bytes).map_err(|_| anyhow!("snapshot file {name} too large"))?
        ];
        reader
            .read_exact(&mut data)
            .with_context(|| format!("receiving snapshot file {name} ({bytes} bytes)"))?;
        std::fs::write(dir.join(name), data)
            .with_context(|| format!("writing snapshot file {name}"))?;
    }
    Ok(())
}

/// Remove every piece of service state in `dir` (before a re-bootstrap).
fn wipe_state(dir: &Path) -> Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(anyhow!(e).context(format!("listing {}", dir.display()))),
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let stale = name == wal::WAL_FILE
            || name == wal::META_FILE
            || name == crate::coordinator::snapshot::SNAPSHOT_META
            || name == "points.jsonl"
            || (name.starts_with("points-") && name.ends_with(".jsonl"))
            || name.ends_with(".tmp");
        if stale {
            std::fs::remove_file(entry.path())
                .with_context(|| format!("removing {}", entry.path().display()))?;
        }
    }
    Ok(())
}

/// Start a follower: bootstrap (or recover) local state from the leader,
/// spawn the follow thread, and return the service + replication hooks
/// for the caller to serve with. Blocks until the node has a consistent
/// local corpus and a live subscription.
pub fn start_follower(opts: FollowerOpts) -> Result<(Arc<DynamicGus>, Arc<NodeReplication>)> {
    // Recover whatever the previous incarnation left.
    let mut local: Option<DynamicGus> = if wal::has_state(&opts.wal_dir) {
        let rec = wal::recover(&opts.wal_dir, opts.threads)?;
        eprintln!(
            "[gus] follower recovered {} points (+{} WAL records) from {}",
            rec.snapshot_points,
            rec.replayed,
            opts.wal_dir.display()
        );
        Some(rec.gus)
    } else {
        None
    };

    // First subscription; may bootstrap from a snapshot. Retries while
    // the leader is still starting up.
    let mut hint: Option<String> = None;
    let mut established: Option<(String, Stream)> = None;
    let mut backoff = Backoff::new(RECONNECT_BASE, BOOTSTRAP_CAP, backoff_seed(&opts.wal_dir, 0));
    for cycle in 0..BOOTSTRAP_CYCLES {
        if cycle > 0 {
            std::thread::sleep(backoff.next_delay());
        }
        let from = || local.as_ref().map(|g| g.wal_seq() + 1).unwrap_or(0);
        match subscribe_cycle(&mut hint, &opts.leader, &opts.peers, from) {
            Ok(ok) => {
                established = Some(ok);
                break;
            }
            Err(why) => eprintln!("[gus] follower cannot subscribe yet: {why}"),
        }
    }
    let Some((leader_addr, mut stream)) = established else {
        bail!(
            "could not subscribe to a leader at {} after {BOOTSTRAP_CYCLES} attempts",
            opts.leader
        );
    };

    if stream.snapshot {
        // (Re-)bootstrap: replace whatever we had with the leader's
        // checkpoint. Only possible before the server starts — the
        // service object is rebuilt from disk.
        drop(local.take());
        wipe_state(&opts.wal_dir)?;
        receive_files(&mut stream.reader, &opts.wal_dir, &stream.files)?;
        let rec = wal::recover(&opts.wal_dir, opts.threads)
            .context("recovering from the shipped snapshot")?;
        eprintln!(
            "[gus] follower bootstrapped {} points from {leader_addr}",
            rec.snapshot_points
        );
        local = Some(rec.gus);
    }
    let gus = Arc::new(local.ok_or_else(|| {
        anyhow!("leader answered tail mode but this follower has no local state")
    })?);
    // Seed the stream gauges with the durable seq: everything up to here
    // is already applied (via snapshot or recovery), so `stats` reports
    // lag 0 instead of a bogus backlog until the first frame arrives.
    let durable = gus.wal_seq();
    gus.metrics.replication.note_received(durable);
    gus.metrics.replication.note_applied(durable);
    let expect = durable + 1;
    if stream.resume_seq != expect {
        bail!(
            "subscription resumes at seq {} but local state expects {expect}",
            stream.resume_seq
        );
    }

    let rep = NodeReplication::follower(
        Arc::clone(&gus),
        leader_addr.clone(),
        opts.ack_replicas,
        opts.ack_timeout,
    );
    let thread_rep = Arc::clone(&rep);
    let primary = opts.leader.clone();
    let peers = opts.peers.clone();
    let threads = opts.threads;
    let reconnect_seed = backoff_seed(&opts.wal_dir, 1);
    std::thread::Builder::new()
        .name("gus-follower".into())
        .spawn(move || follow_loop(thread_rep, stream, primary, peers, threads, reconnect_seed))
        .context("spawning follow loop")?;
    Ok((gus, rep))
}

/// Why the apply loop stopped.
enum StreamEnd {
    /// Promotion requested: stop applying for good.
    Stop,
    /// Connection lost / stream ended: reconnect and resume.
    Disconnect,
}

/// Tail + apply until promoted, reconnecting (and re-resolving the
/// leader) whenever the stream drops.
fn follow_loop(
    rep: Arc<NodeReplication>,
    stream: Stream,
    primary: String,
    peers: Vec<String>,
    threads: usize,
    reconnect_seed: u64,
) {
    let mut hint: Option<String> = rep.gus().metrics.replication.leader_hint();
    let mut conn = Some(stream);
    let mut backoff = Backoff::new(RECONNECT_BASE, RECONNECT_CAP, reconnect_seed);
    while !rep.stop_requested() {
        let stream = match conn.take() {
            Some(s) => s,
            None => {
                let from = {
                    let gus = Arc::clone(rep.gus());
                    move || gus.wal_seq() + 1
                };
                match subscribe_cycle(&mut hint, &primary, &peers, from) {
                    Ok((addr, s)) => {
                        if s.snapshot {
                            // Mid-life re-bootstrap is impossible: the
                            // service object is shared with the server.
                            // Keep serving stale reads, keep retrying, and
                            // tell the operator what to do.
                            eprintln!(
                                "[gus] leader retention passed this follower; it can no \
                                 longer catch up from the log — stop it, remove its \
                                 --wal-dir, and restart to re-bootstrap"
                            );
                            std::thread::sleep(backoff.next_delay());
                            continue;
                        }
                        rep.note_leader(&addr);
                        backoff.reset();
                        eprintln!("[gus] follower resumed from {addr} at seq {}", s.resume_seq);
                        s
                    }
                    Err(why) => {
                        eprintln!("[gus] follower reconnect failed: {why}");
                        std::thread::sleep(backoff.next_delay());
                        continue;
                    }
                }
            }
        };
        rep.set_streaming(true);
        let end = apply_stream(&rep, stream, threads);
        rep.set_streaming(false);
        match end {
            Ok(StreamEnd::Stop) => break,
            Ok(StreamEnd::Disconnect) => {}
            Err(e) => eprintln!("[gus] follower stream error: {e:#}"),
        }
    }
    // A no-op unless a promotion is waiting on the flag.
    rep.set_streaming(false);
    eprintln!("[gus] follower stream stopped");
}

/// Apply one subscription stream: for each frame, append the leader's
/// bytes verbatim, apply through the recovery path, then ack. Heartbeats
/// (seq 0) are progress markers only.
fn apply_stream(rep: &NodeReplication, stream: Stream, threads: usize) -> Result<StreamEnd> {
    let gus = rep.gus();
    let handle = gus
        .wal()
        .ok_or_else(|| anyhow!("follower service has no WAL attached"))?;
    let Stream { mut reader, mut sock, .. } = stream;
    loop {
        if rep.stop_requested() {
            return Ok(StreamEnd::Stop);
        }
        match wal::read_frame_raw(&mut reader) {
            Ok(Some((0, _))) => continue, // heartbeat
            Ok(Some((seq, frame))) => {
                let payload = wal::frame_payload(&frame);
                let text = std::str::from_utf8(payload)
                    .map_err(|_| anyhow!("non-UTF-8 WAL payload at seq {seq}"))?;
                let json = Json::parse(text)
                    .map_err(|e| anyhow!("undecodable WAL payload at seq {seq}: {e}"))?;
                gus.metrics.replication.note_received(seq);
                {
                    // Log-before-apply under the writer lock, exactly like
                    // the leader's own mutation path: checkpoints see a
                    // consistent (store, seq) pair.
                    let mut writer = handle.lock_writer();
                    writer.append_raw(seq, payload)?;
                    gus.apply_logged(&json, threads)
                        .with_context(|| format!("applying replicated record seq={seq}"))
                        .map(|n| handle.add_pending(n))?;
                }
                gus.metrics.replication.note_applied(seq);
                let ack = format!("{{\"ack\":{seq}}}\n");
                if sock.write_all(ack.as_bytes()).is_err() {
                    return Ok(StreamEnd::Disconnect);
                }
            }
            Ok(None) | Err(FrameError::Torn) => return Ok(StreamEnd::Disconnect),
            Err(FrameError::Io(_)) => return Ok(StreamEnd::Disconnect),
        }
    }
}
