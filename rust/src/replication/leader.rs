//! Leader side of WAL shipping: serve one `wal_subscribe` stream.
//!
//! A subscription takes over its TCP connection. The leader answers with
//! one JSON header line, then raw bytes:
//!
//! ```text
//! {"mode":"tail","resume_seq":S,"files":[]}\n
//! <WAL frames, byte-identical to the on-disk log, from seq S>
//! ```
//!
//! or, when `from_seq` predates the retained log tail (or is 0 — a fresh
//! follower), a snapshot bootstrap:
//!
//! ```text
//! {"mode":"snapshot","resume_seq":S,"files":[{"name":..,"bytes":N},..]}\n
//! <each file's N raw bytes, in listed order>
//! <WAL frames from seq S>
//! ```
//!
//! The follower writes `{"ack":seq}` lines back on the same socket after
//! each durable append + apply; a reader thread feeds them into the
//! leader's ack table (the semi-sync gate,
//! [`super::NodeReplication::ack_gate`]).
//!
//! When the stream is idle the leader ships a heartbeat frame (seq 0)
//! every [`HEARTBEAT`], so a follower can tell "leader idle" from
//! "leader dead" with nothing but a socket read timeout.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::snapshot::SNAPSHOT_META;
use crate::coordinator::wal::{self, TailSignal, WalHandle, WalTailer};
use crate::coordinator::DynamicGus;
use crate::protocol::{ErrorCode, Response};
use crate::util::json::Json;

use super::NodeReplication;

/// Idle-stream heartbeat cadence (a seq-0 frame; never appended by the
/// follower). Keeps the binary stream self-delimiting — no JSON can be
/// injected mid-stream.
pub(crate) const HEARTBEAT: Duration = Duration::from_millis(500);

/// Ship at most this many bytes per write (bounds per-iteration memory).
const MAX_CHUNK_BYTES: usize = 1 << 20;

/// A stalled follower is cut off after this long; it reconnects and
/// resumes from its own durable seq, so nothing is lost.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// A subscriber whose acks stop advancing while frames keep shipping is
/// cut off after this long. The write timeout above only catches a
/// wedged *forward* path; a one-way blackhole on the ack back-channel
/// leaves shipping healthy while every semi-sync mutation eats the full
/// ack-gate timeout — cutting the stream forces a reconnect, which
/// re-establishes both directions (the follower resumes at its durable
/// seq, so nothing is lost).
const ACK_STALL: Duration = Duration::from_secs(10);

/// Attempts to pair a snapshot read with a tail start before giving up
/// (each retry observes a newer checkpoint).
const SNAPSHOT_RETRIES: usize = 10;

/// The heartbeat frame: seq 0 is below every real record (records start
/// at 1), so followers recognize and skip it.
pub(crate) fn heartbeat_frame() -> Vec<u8> {
    wal::encode_frame(0, b"hb")
}

fn write_json_line(stream: &mut TcpStream, j: &Json) -> std::io::Result<()> {
    let mut line = j.dump();
    line.push('\n');
    stream.write_all(line.as_bytes())
}

/// Answer a `wal_subscribe` that landed on a follower: `NOT_LEADER` with
/// the hint, then hang up (followers do not chain-replicate).
pub(crate) fn refuse_not_leader(mut stream: TcpStream, id: Option<u64>, hint: &str) {
    let resp = Response::error(ErrorCode::NotLeader, format!("not leader; leader={hint}"));
    let _ = write_json_line(&mut stream, &resp.to_wire(id));
}

/// The subscription header line. `files` ship before the WAL frames, in
/// listed order, as raw bytes of the listed lengths.
fn header_json(mode: &str, resume_seq: u64, files: &[(String, Vec<u8>)]) -> Json {
    let listed = files
        .iter()
        .map(|(name, bytes)| {
            Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("bytes", Json::u64(bytes.len() as u64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("mode", Json::str(mode)),
        ("resume_seq", Json::u64(resume_seq)),
        ("files", Json::arr(listed)),
    ])
}

/// Pick the snapshot files + the tail start for a bootstrap. Retries
/// around concurrent checkpoints: a checkpoint can replace the points
/// file or raise the log floor between our reads, in which case the next
/// attempt simply reads the newer (strictly more complete) checkpoint.
fn snapshot_bootstrap(
    gus: &DynamicGus,
    handle: &WalHandle,
    signal: &TailSignal,
) -> Result<(Json, Vec<(String, Vec<u8>)>, WalTailer)> {
    let dir = handle.dir();
    for attempt in 0..SNAPSHOT_RETRIES {
        if attempt > 0 {
            std::thread::sleep(Duration::from_millis(50));
        }
        if !dir.join(SNAPSHOT_META).exists() {
            // WAL-only incarnation (recovered without a checkpoint):
            // force one so there is a corpus to ship.
            gus.checkpoint().context("forcing a checkpoint for snapshot bootstrap")?;
        }
        let Ok(meta_bytes) = std::fs::read(dir.join(SNAPSHOT_META)) else {
            continue;
        };
        let Ok(meta_text) = std::str::from_utf8(&meta_bytes).map(str::to_owned) else {
            continue;
        };
        let Ok(meta) = Json::parse(&meta_text) else {
            continue;
        };
        let last_seq = meta.get("last_seq").as_u64().unwrap_or(0);
        let points_file = meta
            .get("points_file")
            .as_str()
            .unwrap_or("points.jsonl")
            .to_string();
        let Ok(points_bytes) = std::fs::read(dir.join(&points_file)) else {
            continue; // replaced by a newer checkpoint mid-read
        };
        let state = signal.snapshot();
        let Ok(tailer) = WalTailer::new(dir, last_seq + 1, state) else {
            continue; // a newer checkpoint raised the floor past this one
        };
        // Points before metadata: a follower crash mid-bootstrap leaves
        // no snapshot.json, which recovery treats as "nothing here" and
        // the next start re-bootstraps cleanly.
        let files = vec![(points_file, points_bytes), (SNAPSHOT_META.to_string(), meta_bytes)];
        let header = header_json("snapshot", last_seq + 1, &files);
        return Ok((header, files, tailer));
    }
    bail!("snapshot bootstrap kept racing checkpoints ({SNAPSHOT_RETRIES} attempts)")
}

/// Serve one subscription stream until the connection drops. Runs on the
/// connection's reader thread (handed over by the server); spawns one
/// ack-reader thread for the back-channel.
pub(crate) fn serve_subscription(
    rep: &NodeReplication,
    from_seq: u64,
    id: Option<u64>,
    reader: BufReader<TcpStream>,
    mut stream: TcpStream,
) -> Result<()> {
    let gus = rep.gus().as_ref();
    let Some(handle) = gus.wal() else {
        let resp = Response::error(
            ErrorCode::BadRequest,
            "replication requires durability (serve with --wal-dir)",
        );
        let _ = write_json_line(&mut stream, &resp.to_wire(id));
        return Ok(());
    };
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    if from_seq > handle.seq() + 1 {
        // A subscriber ahead of its leader means diverged history (e.g. a
        // deposed leader trying to follow without re-bootstrapping).
        let resp = Response::error(
            ErrorCode::BadRequest,
            format!(
                "subscriber resumes at seq {from_seq} but this leader is at seq {}; \
                 diverged history — re-bootstrap the follower (wipe its --wal-dir)",
                handle.seq()
            ),
        );
        let _ = write_json_line(&mut stream, &resp.to_wire(id));
        return Ok(());
    }
    let signal = handle.tail_signal();
    let state = signal.snapshot();
    let (mut header, files, mut tailer) = if from_seq == 0 || from_seq <= state.floor_seq {
        snapshot_bootstrap(gus, handle, &signal)?
    } else {
        let tailer = WalTailer::new(handle.dir(), from_seq, state)?;
        (header_json("tail", from_seq, &[]), Vec::new(), tailer)
    };
    if let Some(id) = id {
        // Echo the envelope so pipelined clients can correlate.
        header = crate::protocol::envelope_to_wire(id, None, header);
    }
    write_json_line(&mut stream, &header)?;
    for (_name, bytes) in &files {
        stream.write_all(bytes)?;
    }
    drop(files);

    // Back-channel: `{"ack":seq}` lines from the follower feed the
    // semi-sync gate. A scoped reader thread borrows `rep`; when the
    // shipping loop ends we shut the socket down so the reader unblocks
    // and the scope can join it.
    let sub = rep.register_subscriber();
    let _unreg = SubscriberGuard { rep, sub };
    let hb = heartbeat_frame();
    std::thread::scope(|s| -> Result<()> {
        let acks = std::thread::Builder::new()
            .name("gus-repl-acks".into())
            .spawn_scoped(s, move || ack_reader(rep, sub, reader))
            .context("spawning replication ack reader")?;
        let shipped = ship_frames(gus, rep, sub, &signal, &mut tailer, &mut stream, &hb);
        let _ = stream.shutdown(std::net::Shutdown::Both);
        let _ = acks.join();
        shipped
    })
}

/// Ship frames until the connection drops or the subscriber's acks stall
/// (see [`ACK_STALL`]); heartbeat when idle so the follower's read
/// timeout only fires on a dead leader.
fn ship_frames(
    gus: &DynamicGus,
    rep: &NodeReplication,
    sub: u64,
    signal: &TailSignal,
    tailer: &mut WalTailer,
    stream: &mut TcpStream,
    hb: &[u8],
) -> Result<()> {
    let mut buf: Vec<u8> = Vec::with_capacity(MAX_CHUNK_BYTES);
    let mut last_acked = rep.subscriber_ack(sub).unwrap_or(0);
    let mut last_progress_ms = crate::metrics::monotonic_ms();
    loop {
        let state = signal.snapshot();
        buf.clear();
        let shipped = tailer.fill(state, &mut buf, MAX_CHUNK_BYTES)?;
        if shipped == 0 {
            let newer = signal.wait_change(state, HEARTBEAT);
            if newer == state {
                stream.write_all(hb)?;
            }
            // Idle: nothing newly owed, so the stall clock restarts.
            last_progress_ms = crate::metrics::monotonic_ms();
            continue;
        }
        stream.write_all(&buf)?;
        gus.metrics.replication.note_shipped(shipped as u64);
        let acked = rep.subscriber_ack(sub).unwrap_or(0);
        let now_ms = crate::metrics::monotonic_ms();
        if acked > last_acked {
            last_acked = acked;
            last_progress_ms = now_ms;
        } else if now_ms.saturating_sub(last_progress_ms) > ACK_STALL.as_millis() as u64 {
            bail!(
                "subscriber ack stalled at seq {last_acked} for {}s while frames keep \
                 shipping; cutting the stream so the follower reconnects",
                ACK_STALL.as_secs()
            );
        }
    }
}

/// Removes the subscription from the ack table when the stream ends,
/// however it ends.
struct SubscriberGuard<'a> {
    rep: &'a NodeReplication,
    sub: u64,
}

impl Drop for SubscriberGuard<'_> {
    fn drop(&mut self) {
        self.rep.unregister_subscriber(self.sub);
    }
}

/// Read `{"ack":seq}` lines until the socket closes, feeding the ack
/// table. Tolerates read timeouts (the server may have armed one on the
/// connection) by retrying; everything else ends the thread.
fn ack_reader(rep: &NodeReplication, sub: u64, mut reader: BufReader<TcpStream>) {
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => return,
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(trimmed) else {
            return; // garbage on the back-channel: drop the stream's acks
        };
        if let Some(seq) = j.get("ack").as_u64() {
            rep.record_ack(sub, seq);
        }
    }
}
