//! # Dynamic GUS — Dynamic Grale Using ScaNN
//!
//! Reproduction of *"Large-Scale Graph Building in Dynamic Environments:
//! Low Latency and High Quality"* (Google, CS.DC 2025).
//!
//! Dynamic GUS maintains a Grale-quality similarity graph under a continuous
//! stream of point insertions, updates and deletions, answering neighborhood
//! queries with tens-of-milliseconds latency. The pipeline per query:
//!
//! 1. **Embedding generation** ([`embed`]): the point's features are hashed
//!    into LSH bucket IDs ([`lsh`]); the bucket IDs become the non-zero
//!    dimensions of a sparse embedding, optionally IDF-weighted with overly
//!    popular buckets filtered (§4.1–4.2 of the paper).
//! 2. **Neighbor candidates** ([`index`]): a dynamic sparse ANN index (the
//!    ScaNN substitute) retrieves the top-NN closest points under
//!    `Dist(p,q) = -M(p)·M(q)`.
//! 3. **Similarity scoring** ([`scorer`]): a trained pairwise model (2-layer
//!    MLP) scores the query against each candidate. The model runs either
//!    natively or through an AOT-compiled XLA executable ([`runtime`])
//!    produced by the python/JAX/Pallas build pipeline.
//!
//! The [`coordinator`] module owns the serving loop; [`grale`] implements
//! the offline Grale baseline the paper compares against; [`data`] provides
//! the synthetic multimodal datasets standing in for ogbn-arxiv /
//! ogbn-products (offline environment — see DESIGN.md for the substitution
//! table); [`eval`] regenerates every figure/table of the paper.

pub mod admission;
pub mod bench;
pub mod client;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod embed;
pub mod eval;
pub mod fault;
pub mod grale;
pub mod graph;
pub mod index;
pub mod loadgen;
pub mod preprocess;
pub mod protocol;
pub mod replication;
pub mod runtime;
pub mod scorer;
pub mod server;
pub mod features;
pub mod lsh;
pub mod sparse;
pub mod metrics;
pub mod testing;
pub mod util;
