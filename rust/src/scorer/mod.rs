//! Pairwise similarity scoring (§3.2 "Similarity Computation").
//!
//! The paper scores candidate pairs with a pre-trained model over the two
//! points' features — its experiments use a two-layer neural network with 10
//! hidden units per layer. This module provides:
//!
//! - [`featurize::PairFeaturizer`]: the deterministic pairwise feature map
//!   φ(q, c) shared (by specification, and checked by golden tests) with the
//!   python training/AOT pipeline;
//! - [`MlpWeights`]: the trained parameters, loaded from
//!   `artifacts/weights_<dataset>.json` as exported by
//!   `python/compile/train.py`;
//! - [`native::NativeScorer`]: a pure-Rust implementation — the numeric
//!   oracle for the XLA path, the scorer for the Grale baseline, and the
//!   fallback when artifacts are absent;
//! - [`xla::XlaScorer`]: the production path — an AOT-compiled XLA/Pallas
//!   executable run through PJRT ([`crate::runtime`]).
//!
//! Both scorers implement [`PairScorer`].

pub mod featurize;
pub mod native;
pub mod xla;

use crate::features::Point;
use crate::util::json::Json;

pub use featurize::PairFeaturizer;
pub use native::NativeScorer;
pub use xla::XlaScorer;

/// Hidden width of the paper's model (§5 "Model training": two layers, 10
/// hidden units per layer).
pub const HIDDEN: usize = 10;

/// A pairwise similarity scorer: query point vs a batch of candidates,
/// returning one score in [0, 1] per candidate.
pub trait PairScorer: Send + Sync {
    /// Score `q` against each candidate.
    fn score_batch(&self, q: &Point, cands: &[&Point]) -> Vec<f32>;

    /// Convenience: single pair.
    fn score(&self, q: &Point, c: &Point) -> f32 {
        self.score_batch(q, &[c])[0]
    }
}

/// MLP parameters: `score = σ(relu(relu(φ·W1 + b1)·W2 + b2)·w3 + b3)`.
///
/// `W1` is `[input_dim × HIDDEN]` row-major; `input_dim = 2·d_dense + ke`
/// where the first `d_dense` rows correspond to the elementwise-product
/// block, the next `d_dense` to the |difference| block, and the last `ke`
/// to the extra (token/scalar) features — the row split the Pallas kernel
/// uses to avoid materializing φ.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpWeights {
    pub input_dim: usize,
    pub hidden: usize,
    pub w1: Vec<f32>, // [input_dim][hidden]
    pub b1: Vec<f32>, // [hidden]
    pub w2: Vec<f32>, // [hidden][hidden]
    pub b2: Vec<f32>, // [hidden]
    pub w3: Vec<f32>, // [hidden]
    pub b3: f32,
}

impl MlpWeights {
    /// Random (Xavier-ish) initialization — used in tests and as the
    /// fallback when no trained artifact exists.
    pub fn random(input_dim: usize, hidden: usize, seed: u64) -> MlpWeights {
        let mut rng = crate::util::rng::Rng::seeded(seed);
        let s1 = (2.0 / (input_dim + hidden) as f64).sqrt();
        let s2 = (2.0 / (2 * hidden) as f64).sqrt();
        MlpWeights {
            input_dim,
            hidden,
            w1: (0..input_dim * hidden)
                .map(|_| (rng.normal() * s1) as f32)
                .collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden * hidden).map(|_| (rng.normal() * s2) as f32).collect(),
            b2: vec![0.0; hidden],
            w3: (0..hidden).map(|_| (rng.normal() * s2) as f32).collect(),
            b3: 0.0,
        }
    }

    /// Validate dimensions.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.w1.len() == self.input_dim * self.hidden, "w1 size");
        anyhow::ensure!(self.b1.len() == self.hidden, "b1 size");
        anyhow::ensure!(self.w2.len() == self.hidden * self.hidden, "w2 size");
        anyhow::ensure!(self.b2.len() == self.hidden, "b2 size");
        anyhow::ensure!(self.w3.len() == self.hidden, "w3 size");
        let all_finite = self
            .w1
            .iter()
            .chain(&self.b1)
            .chain(&self.w2)
            .chain(&self.b2)
            .chain(&self.w3)
            .all(|x| x.is_finite())
            && self.b3.is_finite();
        anyhow::ensure!(all_finite, "non-finite weights");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("input_dim", Json::num(self.input_dim as f64)),
            ("hidden", Json::num(self.hidden as f64)),
            ("w1", Json::f32_arr(&self.w1)),
            ("b1", Json::f32_arr(&self.b1)),
            ("w2", Json::f32_arr(&self.w2)),
            ("b2", Json::f32_arr(&self.b2)),
            ("w3", Json::f32_arr(&self.w3)),
            ("b3", Json::num(self.b3 as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<MlpWeights> {
        let get_arr = |k: &str| -> anyhow::Result<Vec<f32>> {
            j.get(k)
                .to_f32_vec()
                .ok_or_else(|| anyhow::anyhow!("weights json: missing/invalid '{k}'"))
        };
        let w = MlpWeights {
            input_dim: j
                .get("input_dim")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing input_dim"))?,
            hidden: j
                .get("hidden")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing hidden"))?,
            w1: get_arr("w1")?,
            b1: get_arr("b1")?,
            w2: get_arr("w2")?,
            b2: get_arr("b2")?,
            w3: get_arr("w3")?,
            b3: j
                .get("b3")
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("missing b3"))?,
        };
        w.validate()?;
        Ok(w)
    }

    /// Load from a JSON file written by `python/compile/train.py`.
    pub fn load(path: &std::path::Path) -> anyhow::Result<MlpWeights> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_validate() {
        let w = MlpWeights::random(20, HIDDEN, 1);
        w.validate().unwrap();
        assert_eq!(w.w1.len(), 200);
    }

    #[test]
    fn json_roundtrip() {
        let w = MlpWeights::random(6, 4, 2);
        let j = w.to_json().dump();
        let w2 = MlpWeights::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(w.input_dim, w2.input_dim);
        for (a, b) in w.w1.iter().zip(&w2.w1) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(w.b3, w2.b3);
    }

    #[test]
    fn from_json_rejects_bad_sizes() {
        let w = MlpWeights::random(6, 4, 2);
        let mut j = w.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("b1".into(), Json::f32_arr(&[1.0])); // wrong length
        }
        assert!(MlpWeights::from_json(&j).is_err());
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(MlpWeights::load(std::path::Path::new("/nonexistent/w.json")).is_err());
    }
}
