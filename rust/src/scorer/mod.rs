//! Pairwise similarity scoring (§3.2 "Similarity Computation").
//!
//! The paper scores candidate pairs with a pre-trained model over the two
//! points' features — its experiments use a two-layer neural network with 10
//! hidden units per layer. This module provides:
//!
//! - [`featurize::PairFeaturizer`]: the deterministic pairwise feature map
//!   φ(q, c) shared (by specification, and checked by golden tests) with the
//!   python training/AOT pipeline;
//! - [`MlpWeights`]: the trained parameters, loaded from
//!   `artifacts/weights_<dataset>.json` as exported by
//!   `python/compile/train.py`;
//! - [`native::NativeScorer`]: a pure-Rust implementation — the numeric
//!   oracle for the XLA path, the scorer for the Grale baseline, and the
//!   fallback when artifacts are absent;
//! - [`xla::XlaScorer`]: the production path — an AOT-compiled XLA/Pallas
//!   executable run through PJRT ([`crate::runtime`]).
//!
//! Both scorers implement [`PairScorer`].

pub mod featurize;
pub mod native;
pub mod packed;
pub mod xla;

use crate::features::Point;
use crate::util::json::Json;

pub use featurize::{PairFeaturizer, QueryPrep};
pub use native::NativeScorer;
pub use packed::{PackedWeights, TILE};
pub use xla::XlaScorer;

/// Hidden width of the paper's model (§5 "Model training": two layers, 10
/// hidden units per layer).
pub const HIDDEN: usize = 10;

/// Reusable per-worker scoring state. Everything the allocation-free entry
/// point [`PairScorer::score_into`] needs between calls lives here: the
/// lane-major φ tile, the per-pair extras staging buffer, the query-side
/// precomputation ([`QueryPrep`]) and the chunk output buffer the parallel
/// splitter uses. `Default` is an empty scratch; buffers grow to the
/// high-water mark and stay.
#[derive(Debug, Default)]
pub struct ScorerScratch {
    /// Lane-major φ tile (`phi[feature * B + lane]`).
    pub(crate) phi: Vec<f32>,
    /// Per-candidate extras staging (written by the featurizer, scattered
    /// into the tile).
    pub(crate) extras: Vec<f32>,
    /// Query-side precomputation, rebuilt per query.
    pub(crate) prep: QueryPrep,
    /// Per-chunk score buffer for [`score_into_parallel`] workers.
    pub(crate) chunk_out: Vec<f32>,
}

/// A pairwise similarity scorer: query point vs a batch of candidates,
/// one score in [0, 1] per candidate.
pub trait PairScorer: Send + Sync {
    /// Allocation-free entry point: score `q` against each candidate,
    /// **appending** `cands.len()` scores to `out` in candidate order.
    /// `scratch` is reused across calls (pool it per worker); a scratch
    /// carries no query state between calls, so any scratch works with any
    /// query of any schema.
    fn score_into(
        &self,
        q: &Point,
        cands: &[&Point],
        scratch: &mut ScorerScratch,
        out: &mut Vec<f32>,
    );

    /// Compatibility wrapper over [`score_into`](PairScorer::score_into)
    /// with a throwaway scratch. Prefer `score_into` on hot paths.
    fn score_batch(&self, q: &Point, cands: &[&Point]) -> Vec<f32> {
        let mut scratch = ScorerScratch::default();
        let mut out = Vec::with_capacity(cands.len());
        self.score_into(q, cands, &mut scratch, &mut out);
        out
    }

    /// Convenience: single pair.
    fn score(&self, q: &Point, c: &Point) -> f32 {
        self.score_batch(q, &[c])[0]
    }

    /// Whether [`score_into_parallel`] may split one candidate list across
    /// workers for this scorer. The native tile kernel scales linearly
    /// with chunks; a scorer that serializes internally (the XLA actor)
    /// gains nothing from a split and only pays extra batch padding and
    /// queueing, so it opts out.
    fn parallel_chunking(&self) -> bool {
        true
    }
}

/// Free-list pool of [`ScorerScratch`]es (see [`crate::util::pool::Pool`]:
/// `take` never blocks, the pool converges to peak worker concurrency).
pub type ScratchPool = crate::util::pool::Pool<ScorerScratch>;

/// Candidate lists below this size are scored serially: tiling already
/// saturates one core's vector units, and forking scoped workers costs more
/// than it buys until the list is a few hundred pairs.
pub const SCORE_PAR_MIN: usize = 512;

/// Target pairs per parallel chunk (bounds the worker count for mid-size
/// lists so each chunk amortizes its spawn).
pub const SCORE_PAR_CHUNK: usize = 256;

/// Score a candidate list, splitting it across up to `threads` scoped
/// workers when it is large enough ([`SCORE_PAR_MIN`]) — a single query's
/// scoring then parallelizes the way `query_batch` already parallelizes
/// across queries. Appends to `out` in candidate order; results are
/// identical to the serial path (the tile kernel's per-lane math is
/// independent of how the list is chunked). Scratches come from `pool`,
/// one per worker.
pub fn score_into_parallel(
    scorer: &dyn PairScorer,
    q: &Point,
    cands: &[&Point],
    pool: &ScratchPool,
    threads: usize,
    out: &mut Vec<f32>,
) {
    let n_chunks = if threads <= 1 || cands.len() < SCORE_PAR_MIN || !scorer.parallel_chunking() {
        1
    } else {
        threads.min(cands.len().div_ceil(SCORE_PAR_CHUNK))
    };
    if n_chunks <= 1 {
        let mut scratch = pool.take();
        scorer.score_into(q, cands, &mut scratch, out);
        pool.put(scratch);
        return;
    }
    let chunk = cands.len().div_ceil(n_chunks);
    let parts = crate::util::threadpool::parallel_map(n_chunks, threads, |ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(cands.len());
        let mut scratch = pool.take();
        let mut local = std::mem::take(&mut scratch.chunk_out);
        local.clear();
        scorer.score_into(q, &cands[lo..hi], &mut scratch, &mut local);
        (scratch, local)
    });
    out.reserve(cands.len());
    for (mut scratch, local) in parts {
        out.extend_from_slice(&local);
        scratch.chunk_out = local;
        pool.put(scratch);
    }
}

/// A recyclable allocation for `Vec<&Point>` candidate lists: the capacity
/// survives across calls while the borrows inside never outlive one call.
/// Backed by `Vec<usize>` (same size/alignment as `&Point`, and `Send`, so
/// scratch holding one can sit in a shared pool).
#[derive(Debug, Default)]
pub struct CandRefs {
    spare: Vec<usize>,
}

// The recycling cast below is only sound while these hold.
const _: () = assert!(
    std::mem::size_of::<&Point>() == std::mem::size_of::<usize>()
        && std::mem::align_of::<&Point>() == std::mem::align_of::<usize>()
);

impl CandRefs {
    /// Take the (empty) buffer as a `Vec<&Point>` for this call's lifetime.
    pub fn take<'a>(&mut self) -> Vec<&'a Point> {
        let v = std::mem::take(&mut self.spare);
        debug_assert!(v.is_empty());
        debug_assert_eq!(std::mem::size_of::<&Point>(), std::mem::size_of::<usize>());
        debug_assert_eq!(std::mem::align_of::<&Point>(), std::mem::align_of::<usize>());
        let mut v = std::mem::ManuallyDrop::new(v);
        // SAFETY: `v` is empty (len 0) and `usize` and `&Point` have
        // identical size and alignment (asserted above), so the allocation
        // layout is unchanged and no element is ever reinterpreted.
        unsafe { Vec::from_raw_parts(v.as_mut_ptr() as *mut &'a Point, 0, v.capacity()) }
    }

    /// Return the buffer, clearing it (dropping only `&` refs) and keeping
    /// the capacity for the next call.
    pub fn put(&mut self, mut v: Vec<&Point>) {
        v.clear();
        debug_assert_eq!(std::mem::size_of::<&Point>(), std::mem::size_of::<usize>());
        debug_assert_eq!(std::mem::align_of::<&Point>(), std::mem::align_of::<usize>());
        let mut v = std::mem::ManuallyDrop::new(v);
        // SAFETY: cleared above; layouts match as in `take`.
        self.spare = unsafe { Vec::from_raw_parts(v.as_mut_ptr() as *mut usize, 0, v.capacity()) };
    }
}

/// MLP parameters: `score = σ(relu(relu(φ·W1 + b1)·W2 + b2)·w3 + b3)`.
///
/// `W1` is `[input_dim × HIDDEN]` row-major; `input_dim = 2·d_dense + ke`
/// where the first `d_dense` rows correspond to the elementwise-product
/// block, the next `d_dense` to the |difference| block, and the last `ke`
/// to the extra (token/scalar) features — the row split the Pallas kernel
/// uses to avoid materializing φ.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpWeights {
    pub input_dim: usize,
    pub hidden: usize,
    pub w1: Vec<f32>, // [input_dim][hidden]
    pub b1: Vec<f32>, // [hidden]
    pub w2: Vec<f32>, // [hidden][hidden]
    pub b2: Vec<f32>, // [hidden]
    pub w3: Vec<f32>, // [hidden]
    pub b3: f32,
}

impl MlpWeights {
    /// Random (Xavier-ish) initialization — used in tests and as the
    /// fallback when no trained artifact exists.
    pub fn random(input_dim: usize, hidden: usize, seed: u64) -> MlpWeights {
        let mut rng = crate::util::rng::Rng::seeded(seed);
        let s1 = (2.0 / (input_dim + hidden) as f64).sqrt();
        let s2 = (2.0 / (2 * hidden) as f64).sqrt();
        MlpWeights {
            input_dim,
            hidden,
            w1: (0..input_dim * hidden)
                .map(|_| (rng.normal() * s1) as f32)
                .collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden * hidden).map(|_| (rng.normal() * s2) as f32).collect(),
            b2: vec![0.0; hidden],
            w3: (0..hidden).map(|_| (rng.normal() * s2) as f32).collect(),
            b3: 0.0,
        }
    }

    /// Validate dimensions.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.w1.len() == self.input_dim * self.hidden, "w1 size");
        anyhow::ensure!(self.b1.len() == self.hidden, "b1 size");
        anyhow::ensure!(self.w2.len() == self.hidden * self.hidden, "w2 size");
        anyhow::ensure!(self.b2.len() == self.hidden, "b2 size");
        anyhow::ensure!(self.w3.len() == self.hidden, "w3 size");
        let all_finite = self
            .w1
            .iter()
            .chain(&self.b1)
            .chain(&self.w2)
            .chain(&self.b2)
            .chain(&self.w3)
            .all(|x| x.is_finite())
            && self.b3.is_finite();
        anyhow::ensure!(all_finite, "non-finite weights");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("input_dim", Json::num(self.input_dim as f64)),
            ("hidden", Json::num(self.hidden as f64)),
            ("w1", Json::f32_arr(&self.w1)),
            ("b1", Json::f32_arr(&self.b1)),
            ("w2", Json::f32_arr(&self.w2)),
            ("b2", Json::f32_arr(&self.b2)),
            ("w3", Json::f32_arr(&self.w3)),
            ("b3", Json::num(self.b3 as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<MlpWeights> {
        let get_arr = |k: &str| -> anyhow::Result<Vec<f32>> {
            j.get(k)
                .to_f32_vec()
                .ok_or_else(|| anyhow::anyhow!("weights json: missing/invalid '{k}'"))
        };
        let w = MlpWeights {
            input_dim: j
                .get("input_dim")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing input_dim"))?,
            hidden: j
                .get("hidden")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing hidden"))?,
            w1: get_arr("w1")?,
            b1: get_arr("b1")?,
            w2: get_arr("w2")?,
            b2: get_arr("b2")?,
            w3: get_arr("w3")?,
            b3: j
                .get("b3")
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("missing b3"))?,
        };
        w.validate()?;
        Ok(w)
    }

    /// Load from a JSON file written by `python/compile/train.py`.
    pub fn load(path: &std::path::Path) -> anyhow::Result<MlpWeights> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_validate() {
        let w = MlpWeights::random(20, HIDDEN, 1);
        w.validate().unwrap();
        assert_eq!(w.w1.len(), 200);
    }

    #[test]
    fn json_roundtrip() {
        let w = MlpWeights::random(6, 4, 2);
        let j = w.to_json().dump();
        let w2 = MlpWeights::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(w.input_dim, w2.input_dim);
        for (a, b) in w.w1.iter().zip(&w2.w1) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(w.b3, w2.b3);
    }

    #[test]
    fn from_json_rejects_bad_sizes() {
        let w = MlpWeights::random(6, 4, 2);
        let mut j = w.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("b1".into(), Json::f32_arr(&[1.0])); // wrong length
        }
        assert!(MlpWeights::from_json(&j).is_err());
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(MlpWeights::load(std::path::Path::new("/nonexistent/w.json")).is_err());
    }

    #[test]
    fn cand_refs_recycles_capacity() {
        let p1 = Point::new(1, vec![]);
        let p2 = Point::new(2, vec![]);
        let mut cr = CandRefs::default();
        let mut v = cr.take();
        v.push(&p1);
        v.push(&p2);
        assert_eq!(v[1].id, 2);
        let cap = v.capacity();
        cr.put(v);
        // A fresh point with a different lifetime reuses the allocation.
        let p3 = Point::new(3, vec![]);
        let mut v = cr.take();
        assert!(v.is_empty());
        assert_eq!(v.capacity(), cap, "allocation not recycled");
        v.push(&p3);
        assert_eq!(v[0].id, 3);
        cr.put(v);
    }

    #[test]
    fn scratch_pool_recycles() {
        let pool = ScratchPool::new();
        let mut s = pool.take();
        s.phi.resize(64, 0.0);
        pool.put(s);
        let s = pool.take();
        assert_eq!(s.phi.len(), 64, "pooled scratch not returned");
        assert!(pool.take().phi.is_empty(), "empty pool must hand out fresh scratch");
    }
}
