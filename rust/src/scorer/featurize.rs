//! Pairwise feature map φ(q, c) — the model's input.
//!
//! The layout is a frozen cross-language contract with
//! `python/compile/model.py` (which trains on and AOT-compiles exactly this
//! map); golden tests on both sides pin the same values:
//!
//! ```text
//! φ(q, c) = [ q_dense * c_dense          (d values, elementwise product)
//!           , |q_dense - c_dense|        (d values, absolute difference)
//!           , extras...                  (ke values, in channel order) ]
//! ```
//!
//! Extras, per non-primary channel in schema order:
//! - `Tokens`: `[jaccard(q, c), ln(1 + |q ∩ c|)]`
//! - `Scalar`: `[|q - c| / SCALAR_SCALE]`
//! - additional `Dense` channels: `[cosine(q, c)]`
//!
//! The dense product/difference blocks are computed *inside* the Pallas
//! kernel (never materialized in HBM); the extras are computed here on the
//! Rust side for both the native and the XLA paths.

use crate::features::{FeatureKind, FeatureValue, Point, Schema};

/// Scale for scalar |difference| features (years differ by ~0–30).
pub const SCALAR_SCALE: f32 = 10.0;

/// The featurizer for a schema.
#[derive(Debug, Clone)]
pub struct PairFeaturizer {
    schema: Schema,
    primary_dense: usize,
    extra_dim: usize,
}

impl PairFeaturizer {
    pub fn new(schema: &Schema) -> PairFeaturizer {
        let primary_dense = schema
            .primary_dense_channel()
            .expect("schema needs a dense channel for the scorer");
        let extra_dim = schema
            .channels
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != primary_dense)
            .map(|(_, c)| match c.kind {
                FeatureKind::Tokens => 2,
                FeatureKind::Scalar => 1,
                FeatureKind::Dense => 1,
            })
            .sum();
        PairFeaturizer {
            schema: schema.clone(),
            primary_dense,
            extra_dim,
        }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Index of the primary dense channel (the kernel's q/C input).
    pub fn primary_dense_channel(&self) -> usize {
        self.primary_dense
    }

    /// d = primary dense dimension.
    pub fn dense_dim(&self) -> usize {
        self.schema.channels[self.primary_dense].dim
    }

    /// ke = number of extra features.
    pub fn extra_dim(&self) -> usize {
        self.extra_dim
    }

    /// Total φ dimension: `2·d + ke`.
    pub fn input_dim(&self) -> usize {
        2 * self.dense_dim() + self.extra_dim
    }

    /// Append the extra features of the pair (token/scalar channels) to
    /// `out`. Exactly `extra_dim()` values, deterministic channel order.
    pub fn extras_into(&self, q: &Point, c: &Point, out: &mut Vec<f32>) {
        for (i, ch) in self.schema.channels.iter().enumerate() {
            if i == self.primary_dense {
                continue;
            }
            match (&q.features[i], &c.features[i]) {
                (FeatureValue::Tokens(a), FeatureValue::Tokens(b)) => {
                    let (inter, na, nb) = set_overlap(a, b);
                    let union = na + nb - inter;
                    let jaccard = if union == 0 {
                        0.0
                    } else {
                        inter as f32 / union as f32
                    };
                    out.push(jaccard);
                    out.push((1.0 + inter as f32).ln());
                }
                (FeatureValue::Scalar(a), FeatureValue::Scalar(b)) => {
                    out.push((a - b).abs() / SCALAR_SCALE);
                }
                (FeatureValue::Dense(a), FeatureValue::Dense(b)) => {
                    out.push(cosine(a, b));
                }
                _ => panic!("channel {i} ({}): mismatched kinds", ch.name),
            }
        }
    }

    /// Extra features as a fresh vector.
    pub fn extras(&self, q: &Point, c: &Point) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.extra_dim);
        self.extras_into(q, c, &mut out);
        out
    }

    /// Precompute the query side of the extras for `q`: sorted/deduped
    /// token sets and dense squared norms are computed once per query here
    /// instead of once per pair inside [`extras_into`]. `prep`'s buffers
    /// are recycled across queries (and across schemas: the layout is
    /// rebuilt in place when it does not match).
    ///
    /// [`extras_into`]: PairFeaturizer::extras_into
    pub fn prepare(&self, q: &Point, prep: &mut QueryPrep) {
        let mut ei = 0usize;
        for (i, _ch) in self.schema.channels.iter().enumerate() {
            if i == self.primary_dense {
                continue;
            }
            match &q.features[i] {
                FeatureValue::Tokens(t) => {
                    // Two steps (probe, then reuse) so the slot's Vec
                    // allocation is recycled without borrowing `entries`
                    // across the insert.
                    if !matches!(prep.entries.get(ei), Some(PrepEntry::Tokens(_))) {
                        set_entry(&mut prep.entries, ei, PrepEntry::Tokens(Vec::new()));
                    }
                    if let Some(PrepEntry::Tokens(set)) = prep.entries.get_mut(ei) {
                        set.clear();
                        set.extend_from_slice(t);
                        set.sort_unstable();
                        set.dedup();
                    }
                }
                FeatureValue::Scalar(x) => set_entry(&mut prep.entries, ei, PrepEntry::Scalar(*x)),
                FeatureValue::Dense(v) => {
                    // Same accumulation order as `cosine`'s `na`, so the
                    // prepped path is bit-identical to the per-pair one.
                    let mut na = 0.0f32;
                    for &x in v {
                        na += x * x;
                    }
                    set_entry(&mut prep.entries, ei, PrepEntry::Dense(na));
                }
            }
            ei += 1;
        }
        prep.entries.truncate(ei);
    }

    /// [`extras_into`], but with the query side taken from a [`QueryPrep`]
    /// built by [`prepare`] for the same `q`. Produces bit-identical values
    /// (pinned by tests) while skipping the per-pair query-side work.
    ///
    /// [`extras_into`]: PairFeaturizer::extras_into
    /// [`prepare`]: PairFeaturizer::prepare
    pub fn extras_into_prepped(
        &self,
        prep: &mut QueryPrep,
        q: &Point,
        c: &Point,
        out: &mut Vec<f32>,
    ) {
        let QueryPrep { entries, tok_buf } = prep;
        let mut ei = 0usize;
        for (i, ch) in self.schema.channels.iter().enumerate() {
            if i == self.primary_dense {
                continue;
            }
            match (&entries[ei], &c.features[i]) {
                (PrepEntry::Tokens(qset), FeatureValue::Tokens(b)) => {
                    // Candidate side still sorts per pair; the query side is
                    // already a set. Merge-count like `set_overlap`.
                    tok_buf.clear();
                    tok_buf.extend_from_slice(b);
                    tok_buf.sort_unstable();
                    tok_buf.dedup();
                    let (mut x, mut y, mut inter) = (0usize, 0usize, 0usize);
                    while x < qset.len() && y < tok_buf.len() {
                        match qset[x].cmp(&tok_buf[y]) {
                            std::cmp::Ordering::Less => x += 1,
                            std::cmp::Ordering::Greater => y += 1,
                            std::cmp::Ordering::Equal => {
                                inter += 1;
                                x += 1;
                                y += 1;
                            }
                        }
                    }
                    let union = qset.len() + tok_buf.len() - inter;
                    let jaccard = if union == 0 {
                        0.0
                    } else {
                        inter as f32 / union as f32
                    };
                    out.push(jaccard);
                    out.push((1.0 + inter as f32).ln());
                }
                (PrepEntry::Scalar(a), FeatureValue::Scalar(b)) => {
                    out.push((a - b).abs() / SCALAR_SCALE);
                }
                (PrepEntry::Dense(na), FeatureValue::Dense(bv)) => {
                    let av = match &q.features[i] {
                        FeatureValue::Dense(v) => v,
                        _ => unreachable!("prep entry built from a dense channel"),
                    };
                    let (mut dot, mut nb) = (0.0f32, 0.0f32);
                    for (x, y) in av.iter().zip(bv) {
                        dot += x * y;
                        nb += y * y;
                    }
                    out.push(if *na == 0.0 || nb == 0.0 {
                        0.0
                    } else {
                        dot / (na.sqrt() * nb.sqrt())
                    });
                }
                _ => panic!("channel {i} ({}): mismatched kinds", ch.name),
            }
            ei += 1;
        }
    }

    /// The full φ(q, c) — used by the native scorer and tests. The XLA path
    /// never materializes this (dense blocks are fused in the kernel).
    pub fn full_into(&self, q: &Point, c: &Point, out: &mut Vec<f32>) {
        let qd = q.dense(self.primary_dense);
        let cd = c.dense(self.primary_dense);
        assert_eq!(qd.len(), cd.len(), "dense dim mismatch");
        for (a, b) in qd.iter().zip(cd) {
            out.push(a * b);
        }
        for (a, b) in qd.iter().zip(cd) {
            out.push((a - b).abs());
        }
        self.extras_into(q, c, out);
    }

    /// Full φ as a fresh vector.
    pub fn full(&self, q: &Point, c: &Point) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.input_dim());
        self.full_into(q, c, &mut out);
        out
    }
}

/// Query-side extras precomputation: what [`PairFeaturizer::extras_into`]
/// would otherwise redo for every candidate of the same query (sorting the
/// query's token sets, squaring its dense norms). Built by
/// [`PairFeaturizer::prepare`]; consumed by
/// [`PairFeaturizer::extras_into_prepped`]. All buffers are recycled across
/// queries, so steady-state preparation is allocation-free.
#[derive(Debug, Default, Clone)]
pub struct QueryPrep {
    /// One entry per non-primary channel, in schema order.
    entries: Vec<PrepEntry>,
    /// Candidate-side token scratch (sorted + deduped per pair).
    tok_buf: Vec<u64>,
}

#[derive(Debug, Clone)]
enum PrepEntry {
    /// Sorted, deduplicated token set of the query channel.
    Tokens(Vec<u64>),
    /// Query scalar value.
    Scalar(f32),
    /// Query-side squared norm of a non-primary dense channel.
    Dense(f32),
}

/// Overwrite `entries[ei]` (or push when extending), reusing the slot.
fn set_entry(entries: &mut Vec<PrepEntry>, ei: usize, e: PrepEntry) {
    if ei < entries.len() {
        entries[ei] = e;
    } else {
        entries.push(e);
    }
}

/// `(|a ∩ b|, |a|, |b|)` with set semantics (duplicates count once).
fn set_overlap(a: &[u64], b: &[u64]) -> (usize, usize, usize) {
    // Token lists are small (tens); sort-merge on copies.
    let mut aa: Vec<u64> = a.to_vec();
    let mut bb: Vec<u64> = b.to_vec();
    aa.sort_unstable();
    aa.dedup();
    bb.sort_unstable();
    bb.dedup();
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < aa.len() && j < bb.len() {
        match aa[i].cmp(&bb[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    (n, aa.len(), bb.len())
}

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let (mut dot, mut na, mut nb) = (0.0f32, 0.0f32, 0.0f32);
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::Schema;

    fn arxiv_pair() -> (PairFeaturizer, Point, Point) {
        let schema = Schema::arxiv_like(3);
        let f = PairFeaturizer::new(&schema);
        let q = Point::new(
            1,
            vec![
                FeatureValue::Dense(vec![1.0, -2.0, 0.5]),
                FeatureValue::Scalar(2020.0),
            ],
        );
        let c = Point::new(
            2,
            vec![
                FeatureValue::Dense(vec![2.0, 1.0, 0.5]),
                FeatureValue::Scalar(2015.0),
            ],
        );
        (f, q, c)
    }

    #[test]
    fn golden_arxiv_like() {
        // GOLDEN VALUES — mirrored in python/tests/test_featurize_contract.py.
        let (f, q, c) = arxiv_pair();
        assert_eq!(f.dense_dim(), 3);
        assert_eq!(f.extra_dim(), 1);
        assert_eq!(f.input_dim(), 7);
        let phi = f.full(&q, &c);
        assert_eq!(
            phi,
            vec![
                2.0, -2.0, 0.25, // q*c
                1.0, 3.0, 0.0, // |q-c|
                0.5, // |2020-2015|/10
            ]
        );
    }

    #[test]
    fn golden_products_like() {
        // GOLDEN VALUES — mirrored in python/tests/test_featurize_contract.py.
        let schema = Schema::products_like(2);
        let f = PairFeaturizer::new(&schema);
        let q = Point::new(
            1,
            vec![
                FeatureValue::Dense(vec![1.0, 0.0]),
                FeatureValue::Tokens(vec![10, 20, 30]),
            ],
        );
        let c = Point::new(
            2,
            vec![
                FeatureValue::Dense(vec![0.5, 0.5]),
                FeatureValue::Tokens(vec![20, 30, 40, 50]),
            ],
        );
        let phi = f.full(&q, &c);
        // extras: jaccard = 2/5 = 0.4, ln(1+2) = 1.0986123.
        assert_eq!(phi.len(), 6);
        assert_eq!(&phi[..4], &[0.5, 0.0, 0.5, 0.5]);
        assert!((phi[4] - 0.4).abs() < 1e-6);
        assert!((phi[5] - 3.0f32.ln()).abs() < 1e-6);
    }

    #[test]
    fn symmetric() {
        let (f, q, c) = arxiv_pair();
        assert_eq!(f.full(&q, &c), f.full(&c, &q));
    }

    #[test]
    fn identical_points_zero_diff() {
        let (f, q, _) = arxiv_pair();
        let phi = f.full(&q, &q);
        // |q-q| block all zeros, scalar extra 0.
        assert_eq!(&phi[3..6], &[0.0, 0.0, 0.0]);
        assert_eq!(phi[6], 0.0);
    }

    #[test]
    fn token_edge_cases() {
        let schema = Schema::products_like(1);
        let f = PairFeaturizer::new(&schema);
        let mk = |tokens: Vec<u64>| {
            Point::new(
                0,
                vec![FeatureValue::Dense(vec![1.0]), FeatureValue::Tokens(tokens)],
            )
        };
        // Both empty: jaccard 0 (not NaN).
        let e = f.extras(&mk(vec![]), &mk(vec![]));
        assert_eq!(e, vec![0.0, 0.0]);
        // Duplicate tokens count once (set semantics).
        let e = f.extras(&mk(vec![5, 5, 5]), &mk(vec![5]));
        assert!((e[0] - 1.0).abs() < 1e-6, "jaccard of identical sets is 1");
    }

    #[test]
    fn extras_match_full_suffix() {
        let (f, q, c) = arxiv_pair();
        let full = f.full(&q, &c);
        let extras = f.extras(&q, &c);
        assert_eq!(&full[full.len() - extras.len()..], extras.as_slice());
    }

    #[test]
    fn prepped_extras_bit_identical() {
        // Every channel kind, including edge cases: duplicate tokens,
        // empty token sets, zero-norm dense extras.
        let schema = Schema {
            name: "mixed".to_string(),
            channels: vec![
                crate::features::ChannelSchema {
                    name: "emb".into(),
                    kind: crate::features::FeatureKind::Dense,
                    dim: 3,
                },
                crate::features::ChannelSchema {
                    name: "tags".into(),
                    kind: crate::features::FeatureKind::Tokens,
                    dim: 0,
                },
                crate::features::ChannelSchema {
                    name: "year".into(),
                    kind: crate::features::FeatureKind::Scalar,
                    dim: 1,
                },
                crate::features::ChannelSchema {
                    name: "aux".into(),
                    kind: crate::features::FeatureKind::Dense,
                    dim: 2,
                },
            ],
        };
        let f = PairFeaturizer::new(&schema);
        let mk = |toks: Vec<u64>, year: f32, aux: Vec<f32>| {
            Point::new(
                0,
                vec![
                    FeatureValue::Dense(vec![0.3, -1.2, 4.0]),
                    FeatureValue::Tokens(toks),
                    FeatureValue::Scalar(year),
                    FeatureValue::Dense(aux),
                ],
            )
        };
        let q = mk(vec![7, 7, 3, 9], 2020.0, vec![0.5, -0.25]);
        let cands = [
            mk(vec![3, 11], 2004.0, vec![1.0, 1.0]),
            mk(vec![], 2020.0, vec![0.0, 0.0]),
            mk(vec![9, 3, 7], 1999.5, vec![-0.5, 0.25]),
        ];
        let mut prep = QueryPrep::default();
        // Prepare twice to exercise buffer reuse.
        f.prepare(&q, &mut prep);
        f.prepare(&q, &mut prep);
        let mut got = Vec::new();
        for c in &cands {
            got.clear();
            f.extras_into_prepped(&mut prep, &q, c, &mut got);
            let want = f.extras(&q, c);
            assert_eq!(got, want, "prepped extras diverged");
        }
    }

    #[test]
    fn prep_relayout_across_schemas() {
        // The same QueryPrep reused across schemas with different extras
        // layouts must rebuild in place.
        let s1 = Schema::products_like(2);
        let s2 = Schema::arxiv_like(2);
        let f1 = PairFeaturizer::new(&s1);
        let f2 = PairFeaturizer::new(&s2);
        let p1 = Point::new(
            1,
            vec![FeatureValue::Dense(vec![1.0, 0.0]), FeatureValue::Tokens(vec![4, 2])],
        );
        let c1 = Point::new(
            2,
            vec![FeatureValue::Dense(vec![0.0, 1.0]), FeatureValue::Tokens(vec![2])],
        );
        let p2 = Point::new(
            3,
            vec![FeatureValue::Dense(vec![1.0, 1.0]), FeatureValue::Scalar(2001.0)],
        );
        let c2 = Point::new(
            4,
            vec![FeatureValue::Dense(vec![1.0, -1.0]), FeatureValue::Scalar(2011.0)],
        );
        let mut prep = QueryPrep::default();
        let mut out = Vec::new();
        f1.prepare(&p1, &mut prep);
        f1.extras_into_prepped(&mut prep, &p1, &c1, &mut out);
        assert_eq!(out, f1.extras(&p1, &c1));
        out.clear();
        f2.prepare(&p2, &mut prep);
        f2.extras_into_prepped(&mut prep, &p2, &c2, &mut out);
        assert_eq!(out, f2.extras(&p2, &c2));
    }

    #[test]
    fn cosine_helper() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert_eq!(cosine(&[0.0], &[1.0]), 0.0);
    }
}
