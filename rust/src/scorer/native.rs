//! Pure-Rust scorer: the reference implementation of the model.
//!
//! Serves three roles: (1) numeric oracle for the XLA/Pallas path (parity
//! asserted in `rust/tests/runtime_parity.rs`), (2) the scorer inside the
//! offline Grale baseline, (3) fallback when `artifacts/` has not been
//! built.
//!
//! Two paths implement the same math:
//!
//! - [`NativeScorer::score_batch_scalar`] — the scalar **oracle**: one pair
//!   at a time, blockwise over W1's three row-blocks in φ order (product
//!   block, |difference| block, extras), φ never materialized.
//! - [`PairScorer::score_into`] — the **hot path**: candidates scored in
//!   [`TILE`]-wide lane-parallel tiles against [`PackedWeights`]
//!   (unit-major W1), with query-side extras precomputation and zero
//!   steady-state allocation. Per-lane accumulation order matches the
//!   oracle exactly, so the two paths are bit-identical (pinned by
//!   `rust/tests/scorer_parity.rs`; the acceptance bound is 1e-5, bitwise
//!   at tile width 1).

use super::featurize::PairFeaturizer;
use super::packed::{PackedWeights, TILE};
use super::{MlpWeights, PairScorer, ScorerScratch};
use crate::features::Point;

/// Native (CPU, pure Rust) pairwise scorer.
pub struct NativeScorer {
    featurizer: PairFeaturizer,
    weights: MlpWeights,
    packed: PackedWeights,
}

impl NativeScorer {
    pub fn new(featurizer: PairFeaturizer, weights: MlpWeights) -> NativeScorer {
        assert_eq!(
            weights.input_dim,
            featurizer.input_dim(),
            "weights trained for input_dim {}, featurizer produces {}",
            weights.input_dim,
            featurizer.input_dim()
        );
        let packed = PackedWeights::pack(&weights, featurizer.dense_dim(), featurizer.extra_dim());
        NativeScorer { featurizer, weights, packed }
    }

    pub fn featurizer(&self) -> &PairFeaturizer {
        &self.featurizer
    }

    pub fn weights(&self) -> &MlpWeights {
        &self.weights
    }

    /// The tile-kernel weights (benches, diagnostics).
    pub fn packed(&self) -> &PackedWeights {
        &self.packed
    }

    /// Scalar oracle: score one candidate given the query's dense slice +
    /// extras buffer. Accumulates in φ order (product block, then
    /// |difference| block, then extras) — the exact order the packed tile
    /// kernel uses per lane, which is what makes the two paths bit-exact.
    fn score_one_scalar(&self, qd: &[f32], cd: &[f32], extras: &[f32]) -> f32 {
        let w = &self.weights;
        let h = w.hidden;
        let d = qd.len();
        let mut z1 = [0.0f32; 64];
        debug_assert!(h <= 64);
        let z1 = &mut z1[..h];
        z1.copy_from_slice(&w.b1);
        for (j, (&a, &b)) in qd.iter().zip(cd).enumerate() {
            let prod = a * b;
            let row_p = &w.w1[j * h..(j + 1) * h];
            for k in 0..h {
                z1[k] += prod * row_p[k];
            }
        }
        for (j, (&a, &b)) in qd.iter().zip(cd).enumerate() {
            let diff = (a - b).abs();
            let row_d = &w.w1[(d + j) * h..(d + j + 1) * h];
            for k in 0..h {
                z1[k] += diff * row_d[k];
            }
        }
        for (j, &e) in extras.iter().enumerate() {
            let row = &w.w1[(2 * d + j) * h..(2 * d + j + 1) * h];
            for k in 0..h {
                z1[k] += e * row[k];
            }
        }
        for v in z1.iter_mut() {
            *v = v.max(0.0);
        }
        // z2 = relu(z1·W2 + b2)
        let mut z2 = [0.0f32; 64];
        let z2 = &mut z2[..h];
        z2.copy_from_slice(&w.b2);
        for (j, &x) in z1.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let row = &w.w2[j * h..(j + 1) * h];
            for k in 0..h {
                z2[k] += x * row[k];
            }
        }
        let mut logit = w.b3;
        for k in 0..h {
            logit += z2[k].max(0.0) * w.w3[k];
        }
        sigmoid(logit)
    }

    /// Scalar reference path: the pre-tile implementation, kept as the
    /// numeric oracle for parity tests and as the baseline `scorer_bench`
    /// compares the packed kernel against.
    pub fn score_batch_scalar(&self, q: &Point, cands: &[&Point]) -> Vec<f32> {
        let ch = self.featurizer.primary_dense_channel();
        let qd = q.dense(ch);
        let mut extras = Vec::with_capacity(self.featurizer.extra_dim());
        cands
            .iter()
            .map(|c| {
                extras.clear();
                self.featurizer.extras_into(q, c, &mut extras);
                self.score_one_scalar(qd, c.dense(ch), &extras)
            })
            .collect()
    }

    /// Materialize the lane-major φ tile for `tile` (≤ `B` candidates) in
    /// `phi`. Pad lanes of a partial tile are zeroed.
    fn fill_tile<const B: usize>(
        &self,
        qd: &[f32],
        q: &Point,
        tile: &[&Point],
        scratch: &mut ScorerScratch,
    ) {
        let d = qd.len();
        let ke = self.featurizer.extra_dim();
        let ch = self.featurizer.primary_dense_channel();
        let need = (2 * d + ke) * B;
        if scratch.phi.len() < need {
            scratch.phi.resize(need, 0.0);
        }
        let phi = &mut scratch.phi[..need];
        if tile.len() < B {
            phi.fill(0.0);
        }
        for (l, c) in tile.iter().enumerate() {
            let cd = c.dense(ch);
            for j in 0..d {
                let a = qd[j];
                let b = cd[j];
                phi[j * B + l] = a * b;
                phi[(d + j) * B + l] = (a - b).abs();
            }
            scratch.extras.clear();
            let (prep, extras) = (&mut scratch.prep, &mut scratch.extras);
            self.featurizer.extras_into_prepped(prep, q, c, extras);
            for (j, &e) in scratch.extras.iter().enumerate() {
                phi[(2 * d + j) * B + l] = e;
            }
        }
    }

    /// [`PairScorer::score_into`] with an explicit tile width `B` (1 ≤ B ≤
    /// [`TILE`]). The default entry point uses `B = TILE`; `B = 1` exists
    /// for the bit-exactness pin in the parity suite and for benchmarks.
    /// Results are identical at every width (per-lane math does not depend
    /// on how the list is tiled).
    pub fn score_into_tiled<const B: usize>(
        &self,
        q: &Point,
        cands: &[&Point],
        scratch: &mut ScorerScratch,
        out: &mut Vec<f32>,
    ) {
        if cands.is_empty() {
            return;
        }
        let ch = self.featurizer.primary_dense_channel();
        let qd = q.dense(ch);
        self.featurizer.prepare(q, &mut scratch.prep);
        out.reserve(cands.len());
        let mut tile_out = [0.0f32; TILE];
        for tile in cands.chunks(B) {
            self.fill_tile::<B>(qd, q, tile, scratch);
            self.packed.score_tile::<B>(&scratch.phi, &mut tile_out);
            out.extend_from_slice(&tile_out[..tile.len()]);
        }
    }
}

#[inline]
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl PairScorer for NativeScorer {
    fn score_into(
        &self,
        q: &Point,
        cands: &[&Point],
        scratch: &mut ScorerScratch,
        out: &mut Vec<f32>,
    ) {
        self.score_into_tiled::<TILE>(q, cands, scratch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureValue, Schema};
    use crate::scorer::HIDDEN;
    use crate::util::rng::Rng;

    fn setup() -> (NativeScorer, Vec<Point>) {
        let schema = Schema::arxiv_like(8);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(f.input_dim(), HIDDEN, 7);
        let scorer = NativeScorer::new(f, w);
        let mut rng = Rng::seeded(5);
        let pts = (0..10)
            .map(|i| {
                Point::new(
                    i,
                    vec![
                        FeatureValue::Dense(rng.normal_vec_f32(8)),
                        FeatureValue::Scalar(2010.0 + rng.below(20) as f32),
                    ],
                )
            })
            .collect();
        (scorer, pts)
    }

    /// Oracle: materialize φ and run the MLP naively.
    fn naive_score(s: &NativeScorer, q: &Point, c: &Point) -> f32 {
        let phi = s.featurizer().full(q, c);
        let w = s.weights();
        let h = w.hidden;
        let mut z1 = w.b1.clone();
        for (j, &x) in phi.iter().enumerate() {
            for k in 0..h {
                z1[k] += x * w.w1[j * h + k];
            }
        }
        for v in z1.iter_mut() {
            *v = v.max(0.0);
        }
        let mut z2 = w.b2.clone();
        for (j, &x) in z1.iter().enumerate() {
            for k in 0..h {
                z2[k] += x * w.w2[j * h + k];
            }
        }
        let mut logit = w.b3;
        for k in 0..h {
            logit += z2[k].max(0.0) * w.w3[k];
        }
        sigmoid(logit)
    }

    #[test]
    fn packed_matches_naive() {
        let (scorer, pts) = setup();
        for q in &pts {
            let cands: Vec<&Point> = pts.iter().collect();
            let got = scorer.score_batch(q, &cands);
            for (c, g) in pts.iter().zip(&got) {
                let want = naive_score(&scorer, q, c);
                assert!((g - want).abs() < 1e-5, "packed {g} vs naive {want}");
            }
        }
    }

    #[test]
    fn scalar_oracle_matches_naive() {
        let (scorer, pts) = setup();
        let cands: Vec<&Point> = pts.iter().collect();
        let got = scorer.score_batch_scalar(&pts[3], &cands);
        for (c, g) in pts.iter().zip(&got) {
            let want = naive_score(&scorer, &pts[3], c);
            // Both accumulate in φ order: bit-identical.
            assert_eq!(*g, want, "scalar oracle diverged from naive");
        }
    }

    #[test]
    fn packed_bit_exact_vs_scalar() {
        let (scorer, pts) = setup();
        let cands: Vec<&Point> = pts.iter().collect();
        let mut scratch = ScorerScratch::default();
        let mut got = Vec::new();
        scorer.score_into(&pts[0], &cands, &mut scratch, &mut got);
        assert_eq!(got, scorer.score_batch_scalar(&pts[0], &cands));
        // Width 1: the acceptance criterion's bit-exactness pin.
        got.clear();
        scorer.score_into_tiled::<1>(&pts[0], &cands, &mut scratch, &mut got);
        assert_eq!(got, scorer.score_batch_scalar(&pts[0], &cands));
    }

    #[test]
    fn scores_in_unit_interval() {
        let (scorer, pts) = setup();
        let cands: Vec<&Point> = pts.iter().collect();
        for s in scorer.score_batch(&pts[0], &cands) {
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn symmetric_scoring() {
        let (scorer, pts) = setup();
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                let a = scorer.score(&pts[i], &pts[j]);
                let b = scorer.score(&pts[j], &pts[i]);
                assert!((a - b).abs() < 1e-6, "asymmetric: {a} vs {b}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let (scorer, pts) = setup();
        let a = scorer.score(&pts[0], &pts[1]);
        let b = scorer.score(&pts[0], &pts[1]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let schema = Schema::arxiv_like(8);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(5, HIDDEN, 7); // wrong input_dim
        let _ = NativeScorer::new(f, w);
    }

    #[test]
    fn empty_batch() {
        let (scorer, pts) = setup();
        assert!(scorer.score_batch(&pts[0], &[]).is_empty());
    }

    #[test]
    fn partial_tiles_match_full() {
        // Every batch size around the tile boundary agrees with the oracle.
        let (scorer, pts) = setup();
        let mut scratch = ScorerScratch::default();
        for n in 0..pts.len() {
            let cands: Vec<&Point> = pts[..n].iter().collect();
            let mut got = Vec::new();
            scorer.score_into(&pts[9], &cands, &mut scratch, &mut got);
            assert_eq!(got, scorer.score_batch_scalar(&pts[9], &cands), "n={n}");
        }
    }

    #[test]
    fn sigmoid_sane() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(100.0) > 0.999);
        assert!(sigmoid(-100.0) < 0.001);
    }
}
