//! Pure-Rust scorer: the reference implementation of the model.
//!
//! Serves three roles: (1) numeric oracle for the XLA/Pallas path (parity
//! asserted in `rust/tests/runtime_parity.rs`), (2) the scorer inside the
//! offline Grale baseline, (3) fallback when `artifacts/` has not been
//! built. The hot loop is written blockwise over W1's three row-blocks so
//! φ is never materialized — mirroring the Pallas kernel's structure.

use super::featurize::PairFeaturizer;
use super::{MlpWeights, PairScorer};
use crate::features::Point;

/// Native (CPU, pure Rust) pairwise scorer.
pub struct NativeScorer {
    featurizer: PairFeaturizer,
    weights: MlpWeights,
}

impl NativeScorer {
    pub fn new(featurizer: PairFeaturizer, weights: MlpWeights) -> NativeScorer {
        assert_eq!(
            weights.input_dim,
            featurizer.input_dim(),
            "weights trained for input_dim {}, featurizer produces {}",
            weights.input_dim,
            featurizer.input_dim()
        );
        NativeScorer { featurizer, weights }
    }

    pub fn featurizer(&self) -> &PairFeaturizer {
        &self.featurizer
    }

    pub fn weights(&self) -> &MlpWeights {
        &self.weights
    }

    /// Score one candidate given the query's dense slice + extras buffer.
    fn score_one(&self, qd: &[f32], cd: &[f32], extras: &[f32]) -> f32 {
        let w = &self.weights;
        let h = w.hidden;
        let d = qd.len();
        // z1 = relu( (q*c)·W1p + |q-c|·W1d + e·W1e + b1 ), blockwise:
        let mut z1 = [0.0f32; 64];
        debug_assert!(h <= 64);
        let z1 = &mut z1[..h];
        z1.copy_from_slice(&w.b1);
        for (j, (&a, &b)) in qd.iter().zip(cd).enumerate() {
            let prod = a * b;
            let diff = (a - b).abs();
            let row_p = &w.w1[j * h..(j + 1) * h];
            let row_d = &w.w1[(d + j) * h..(d + j + 1) * h];
            for k in 0..h {
                z1[k] += prod * row_p[k] + diff * row_d[k];
            }
        }
        for (j, &e) in extras.iter().enumerate() {
            let row = &w.w1[(2 * d + j) * h..(2 * d + j + 1) * h];
            for k in 0..h {
                z1[k] += e * row[k];
            }
        }
        for v in z1.iter_mut() {
            *v = v.max(0.0);
        }
        // z2 = relu(z1·W2 + b2)
        let mut z2 = [0.0f32; 64];
        let z2 = &mut z2[..h];
        z2.copy_from_slice(&w.b2);
        for (j, &x) in z1.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let row = &w.w2[j * h..(j + 1) * h];
            for k in 0..h {
                z2[k] += x * row[k];
            }
        }
        let mut logit = w.b3;
        for k in 0..h {
            logit += z2[k].max(0.0) * w.w3[k];
        }
        sigmoid(logit)
    }
}

#[inline]
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl PairScorer for NativeScorer {
    fn score_batch(&self, q: &Point, cands: &[&Point]) -> Vec<f32> {
        let ch = self.featurizer.primary_dense_channel();
        let qd = q.dense(ch);
        let mut extras = Vec::with_capacity(self.featurizer.extra_dim());
        cands
            .iter()
            .map(|c| {
                extras.clear();
                self.featurizer.extras_into(q, c, &mut extras);
                self.score_one(qd, c.dense(ch), &extras)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureValue, Schema};
    use crate::scorer::HIDDEN;
    use crate::util::rng::Rng;

    fn setup() -> (NativeScorer, Vec<Point>) {
        let schema = Schema::arxiv_like(8);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(f.input_dim(), HIDDEN, 7);
        let scorer = NativeScorer::new(f, w);
        let mut rng = Rng::seeded(5);
        let pts = (0..10)
            .map(|i| {
                Point::new(
                    i,
                    vec![
                        FeatureValue::Dense(rng.normal_vec_f32(8)),
                        FeatureValue::Scalar(2010.0 + rng.below(20) as f32),
                    ],
                )
            })
            .collect();
        (scorer, pts)
    }

    /// Oracle: materialize φ and run the MLP naively.
    fn naive_score(s: &NativeScorer, q: &Point, c: &Point) -> f32 {
        let phi = s.featurizer().full(q, c);
        let w = s.weights();
        let h = w.hidden;
        let mut z1 = w.b1.clone();
        for (j, &x) in phi.iter().enumerate() {
            for k in 0..h {
                z1[k] += x * w.w1[j * h + k];
            }
        }
        for v in z1.iter_mut() {
            *v = v.max(0.0);
        }
        let mut z2 = w.b2.clone();
        for (j, &x) in z1.iter().enumerate() {
            for k in 0..h {
                z2[k] += x * w.w2[j * h + k];
            }
        }
        let mut logit = w.b3;
        for k in 0..h {
            logit += z2[k].max(0.0) * w.w3[k];
        }
        sigmoid(logit)
    }

    #[test]
    fn blockwise_matches_naive() {
        let (scorer, pts) = setup();
        for q in &pts {
            let cands: Vec<&Point> = pts.iter().collect();
            let got = scorer.score_batch(q, &cands);
            for (c, g) in pts.iter().zip(&got) {
                let want = naive_score(&scorer, q, c);
                assert!(
                    (g - want).abs() < 1e-5,
                    "blockwise {g} vs naive {want}"
                );
            }
        }
    }

    #[test]
    fn scores_in_unit_interval() {
        let (scorer, pts) = setup();
        let cands: Vec<&Point> = pts.iter().collect();
        for s in scorer.score_batch(&pts[0], &cands) {
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn symmetric_scoring() {
        let (scorer, pts) = setup();
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                let a = scorer.score(&pts[i], &pts[j]);
                let b = scorer.score(&pts[j], &pts[i]);
                assert!((a - b).abs() < 1e-6, "asymmetric: {a} vs {b}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let (scorer, pts) = setup();
        let a = scorer.score(&pts[0], &pts[1]);
        let b = scorer.score(&pts[0], &pts[1]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let schema = Schema::arxiv_like(8);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(5, HIDDEN, 7); // wrong input_dim
        let _ = NativeScorer::new(f, w);
    }

    #[test]
    fn empty_batch() {
        let (scorer, pts) = setup();
        assert!(scorer.score_batch(&pts[0], &[]).is_empty());
    }

    #[test]
    fn sigmoid_sane() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(100.0) > 0.999);
        assert!(sigmoid(-100.0) < 0.001);
    }
}
