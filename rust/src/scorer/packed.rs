//! Blocked, SIMD-friendly scoring kernel: packed weights + candidate tiles.
//!
//! [`MlpWeights`] stores `W1` row-major `[input_dim][hidden]` — the natural
//! export layout from training, but a scalar walk of it computes each
//! hidden unit's pre-activation with a stride-`hidden` gather the compiler
//! cannot vectorize. [`PackedWeights`] transposes `W1` to **unit-major**
//! `[hidden][input_dim]` (each hidden unit's weights contiguous, in φ
//! order: product block, |difference| block, extras) with rows padded to a
//! [`TILE`]-float boundary, and transposes `W2` the same way.
//!
//! [`PackedWeights::score_tile`] then scores a tile of up to [`TILE`]
//! candidates at once against a **lane-major** φ buffer
//! (`phi[feature * B + lane]`): every inner loop is `B` independent
//! per-lane accumulators updated with one broadcast weight — the shape
//! LLVM auto-vectorizes without any float reassociation. Because each
//! lane's additions happen in exactly the order the scalar oracle
//! (`NativeScorer::score_batch_scalar`) uses, the packed kernel is
//! bit-exact to the scalar path at every tile width — pinned by the
//! parity suite in `rust/tests/scorer_parity.rs` (bitwise at tile width
//! 1, ≤ 1e-5 everywhere by the acceptance criteria).

use super::native::sigmoid;
use super::MlpWeights;

/// Candidate tile width of the packed kernel: 8 lanes fill a 256-bit
/// vector register with f32s, and the remainder tile is zero-padded (pad
/// lanes cost nothing extra and their outputs are discarded).
pub const TILE: usize = 8;

/// Maximum supported hidden width (the paper's model uses 10; stack
/// scratch in the kernel is sized for this bound).
pub const MAX_HIDDEN: usize = 64;

/// [`MlpWeights`] repacked for the tile kernel. Construction is O(|W|)
/// and done once per scorer; see the module docs for the layout.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    d: usize,
    ke: usize,
    hidden: usize,
    /// Padded unit-row length: `2·d + ke` rounded up to a [`TILE`] multiple
    /// so every unit's row starts 32-byte aligned relative to the buffer.
    stride: usize,
    /// `[hidden][stride]`; row `k` = `[W1p[:,k] | W1d[:,k] | W1e[:,k] | 0-pad]`.
    w1t: Vec<f32>,
    b1: Vec<f32>,
    /// `[hidden][hidden]` transposed: `w2t[k2*h + k1] = w2[k1*h + k2]`.
    w2t: Vec<f32>,
    b2: Vec<f32>,
    w3: Vec<f32>,
    b3: f32,
}

impl PackedWeights {
    /// Pack `w` for a featurizer with dense dim `d` and `ke` extras.
    /// Panics if the dimensions disagree or `hidden > MAX_HIDDEN` (the
    /// same contract `NativeScorer::new` enforces).
    pub fn pack(w: &MlpWeights, d: usize, ke: usize) -> PackedWeights {
        assert_eq!(w.input_dim, 2 * d + ke, "weights/featurizer dim mismatch");
        assert!(
            w.hidden <= MAX_HIDDEN,
            "hidden {} exceeds kernel bound {MAX_HIDDEN}",
            w.hidden
        );
        assert_eq!(w.w1.len(), w.input_dim * w.hidden, "w1 size");
        let h = w.hidden;
        let input_dim = 2 * d + ke;
        let stride = input_dim.div_ceil(TILE) * TILE;
        let mut w1t = vec![0.0f32; h * stride];
        for k in 0..h {
            let row = &mut w1t[k * stride..k * stride + input_dim];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = w.w1[j * h + k];
            }
        }
        let mut w2t = vec![0.0f32; h * h];
        for k2 in 0..h {
            for k1 in 0..h {
                w2t[k2 * h + k1] = w.w2[k1 * h + k2];
            }
        }
        PackedWeights {
            d,
            ke,
            hidden: h,
            stride,
            w1t,
            b1: w.b1.clone(),
            w2t,
            b2: w.b2.clone(),
            w3: w.w3.clone(),
            b3: w.b3,
        }
    }

    /// φ dimension (`2·d + ke`).
    pub fn input_dim(&self) -> usize {
        2 * self.d + self.ke
    }

    /// Padded unit-row length.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Lane-major φ buffer length one tile of width `B` needs.
    pub fn tile_len(&self, b: usize) -> usize {
        self.input_dim() * b
    }

    /// Score one tile of `B ≤ TILE` candidates. `phi` is lane-major
    /// (`phi[j*B + lane]`, `j` in φ order) of length ≥ [`tile_len`]`(B)`;
    /// `out[..B]` receives the scores. Pad lanes (zero φ) produce garbage
    /// scores the caller discards.
    ///
    /// [`tile_len`]: PackedWeights::tile_len
    pub fn score_tile<const B: usize>(&self, phi: &[f32], out: &mut [f32; TILE]) {
        assert!(B >= 1 && B <= TILE, "tile width {B} out of range");
        let h = self.hidden;
        let input_dim = self.input_dim();
        debug_assert!(phi.len() >= input_dim * B);
        // Layer 1: z1[k][lane] = relu(b1[k] + Σ_j φ[j][lane] · w1t[k][j]).
        // Per-lane accumulation order is φ order — identical to the scalar
        // oracle's, so each lane is bit-exact to `score_one_scalar`.
        let mut z1 = [0.0f32; MAX_HIDDEN * TILE];
        for k in 0..h {
            let row = &self.w1t[k * self.stride..k * self.stride + input_dim];
            let mut acc = [self.b1[k]; B];
            for (j, &w) in row.iter().enumerate() {
                let lanes = &phi[j * B..j * B + B];
                for l in 0..B {
                    acc[l] += lanes[l] * w;
                }
            }
            for l in 0..B {
                z1[k * B + l] = acc[l].max(0.0);
            }
        }
        // Layer 2: z2[k2][lane] = relu(b2[k2] + Σ_k1 z1[k1][lane] · w2[k1][k2]).
        let mut z2 = [0.0f32; MAX_HIDDEN * TILE];
        for k2 in 0..h {
            let row = &self.w2t[k2 * h..(k2 + 1) * h];
            let mut acc = [self.b2[k2]; B];
            for (k1, &w) in row.iter().enumerate() {
                let lanes = &z1[k1 * B..k1 * B + B];
                for l in 0..B {
                    acc[l] += lanes[l] * w;
                }
            }
            for l in 0..B {
                z2[k2 * B + l] = acc[l].max(0.0);
            }
        }
        // Output: σ(z2 · w3 + b3) per lane.
        for l in 0..B {
            let mut logit = self.b3;
            for k in 0..h {
                logit += z2[k * B + l] * self.w3[k];
            }
            out[l] = sigmoid(logit);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_layout_golden() {
        // d=2, ke=1, h=2: input_dim 5, stride rounds to 8.
        let w = MlpWeights {
            input_dim: 5,
            hidden: 2,
            // w1 row-major [input][hidden]: row j = [j*10, j*10+1].
            w1: (0..5).flat_map(|j| [j as f32 * 10.0, j as f32 * 10.0 + 1.0]).collect(),
            b1: vec![0.5, -0.5],
            w2: vec![1.0, 2.0, 3.0, 4.0],
            b2: vec![0.0, 0.0],
            w3: vec![1.0, 1.0],
            b3: 0.0,
        };
        let p = PackedWeights::pack(&w, 2, 1);
        assert_eq!(p.input_dim(), 5);
        assert_eq!(p.stride(), 8);
        // Unit 0's row: column 0 of w1 across all 5 inputs, then zero pad.
        assert_eq!(&p.w1t[..8], &[0.0, 10.0, 20.0, 30.0, 40.0, 0.0, 0.0, 0.0]);
        // Unit 1's row: column 1.
        assert_eq!(&p.w1t[8..16], &[1.0, 11.0, 21.0, 31.0, 41.0, 0.0, 0.0, 0.0]);
        // w2 transposed: w2t[k2*h+k1] == w2[k1*h+k2].
        assert_eq!(p.w2t, vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn tile_widths_agree() {
        // The per-lane math is tile-width independent: B=1 and B=8 must
        // produce identical bits for the same φ columns.
        let w = MlpWeights::random(7, 10, 3);
        let p = PackedWeights::pack(&w, 3, 1);
        let mut rng = crate::util::rng::Rng::seeded(9);
        let phis: Vec<Vec<f32>> = (0..TILE).map(|_| rng.normal_vec_f32(7)).collect();
        // Lane-major tile of all 8 φs.
        let mut tile = vec![0.0f32; 7 * TILE];
        for (l, phi) in phis.iter().enumerate() {
            for j in 0..7 {
                tile[j * TILE + l] = phi[j];
            }
        }
        let mut out8 = [0.0f32; TILE];
        p.score_tile::<TILE>(&tile, &mut out8);
        for (l, phi) in phis.iter().enumerate() {
            let mut out1 = [0.0f32; TILE];
            p.score_tile::<1>(phi, &mut out1);
            assert_eq!(out1[0], out8[l], "lane {l} diverged between widths");
        }
    }

    #[test]
    #[should_panic]
    fn pack_rejects_dim_mismatch() {
        let w = MlpWeights::random(7, 4, 1);
        let _ = PackedWeights::pack(&w, 4, 1); // 2*4+1 != 7
    }
}
