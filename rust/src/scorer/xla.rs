//! XLA-backed scorer: the production scoring path.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`:
//!
//! - `artifacts/scorer_<schema>_b<B>.hlo.txt` — one compiled variant per
//!   candidate batch size `B` (the graph has static shapes; the scorer pads
//!   the candidate set to the smallest variant that fits and truncates the
//!   output);
//! - `artifacts/weights_<schema>.json` — trained MLP parameters, passed as
//!   execute-time buffers so periodic retraining (§4.3) swaps a JSON file
//!   without recompiling HLO.
//!
//! Graph signature (frozen contract with `aot.py`):
//!
//! ```text
//! scorer(q[d], C[B,d], E[B,ke],
//!        w1p[d,H], w1d[d,H], w1e[ke,H], b1[H], w2[H,H], b2[H], w3[H], b3[])
//!   -> scores[B]
//! ```
//!
//! `PjRtClient` is not `Send`/`Sync`, so the engine lives on a dedicated
//! actor thread owning the executables and pre-uploaded weight buffers;
//! [`XlaScorer`] is a `Send + Sync` handle that ships batches over a
//! channel. Weights are uploaded to the device once, candidate tensors per
//! call.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::featurize::PairFeaturizer;
use super::{MlpWeights, PairScorer};
use crate::features::Point;
use crate::runtime::Engine;

/// Candidate batch sizes compiled by `aot.py` (must match `BATCH_SIZES`
/// there).
pub const BATCH_SIZES: [usize; 4] = [32, 128, 512, 2048];

enum Req {
    Score {
        qd: Vec<f32>,
        cd_flat: Vec<f32>,
        extras_flat: Vec<f32>,
        n: usize,
        resp: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Send + Sync handle to the XLA scoring actor.
pub struct XlaScorer {
    featurizer: PairFeaturizer,
    tx: Mutex<mpsc::Sender<Req>>,
    join: Option<std::thread::JoinHandle<()>>,
    batch_sizes: Vec<usize>,
}

impl XlaScorer {
    /// Artifact path for a scorer variant.
    pub fn variant_path(dir: &Path, schema_name: &str, b: usize) -> PathBuf {
        dir.join(format!("scorer_{schema_name}_b{b}.hlo.txt"))
    }

    /// Artifact path for trained weights.
    pub fn weights_path(dir: &Path, schema_name: &str) -> PathBuf {
        dir.join(format!("weights_{schema_name}.json"))
    }

    /// True if at least one variant + weights exist for this schema.
    pub fn artifacts_available(dir: &Path, schema_name: &str) -> bool {
        Self::weights_path(dir, schema_name).exists()
            && BATCH_SIZES
                .iter()
                .any(|&b| Self::variant_path(dir, schema_name, b).exists())
    }

    /// Load weights + all available variants for `featurizer.schema()` and
    /// spawn the actor thread.
    pub fn load(featurizer: PairFeaturizer, dir: &Path) -> Result<XlaScorer> {
        let schema_name = featurizer.schema().name.clone();
        let weights = MlpWeights::load(&Self::weights_path(dir, &schema_name))?;
        Self::with_weights(featurizer, dir, weights)
    }

    /// Load with explicit weights (tests; custom deployments).
    pub fn with_weights(
        featurizer: PairFeaturizer,
        dir: &Path,
        weights: MlpWeights,
    ) -> Result<XlaScorer> {
        weights.validate()?;
        let d = featurizer.dense_dim();
        let ke = featurizer.extra_dim();
        if weights.input_dim != featurizer.input_dim() {
            bail!(
                "weights input_dim {} != featurizer {}",
                weights.input_dim,
                featurizer.input_dim()
            );
        }
        let schema_name = featurizer.schema().name.clone();
        let variants: Vec<(usize, PathBuf)> = BATCH_SIZES
            .iter()
            .map(|&b| (b, Self::variant_path(dir, &schema_name, b)))
            .filter(|(_, p)| p.exists())
            .collect();
        if variants.is_empty() {
            bail!(
                "no scorer artifacts for schema '{schema_name}' in {} — run `make artifacts`",
                dir.display()
            );
        }
        let batch_sizes: Vec<usize> = variants.iter().map(|&(b, _)| b).collect();

        // Boot the actor; report load errors synchronously.
        let (tx, rx) = mpsc::channel::<Req>();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("gus-xla-scorer".into())
            .spawn(move || actor_main(variants, weights, d, ke, rx, boot_tx))
            .expect("spawn scorer actor");
        boot_rx
            .recv()
            .map_err(|_| anyhow!("scorer actor died during startup"))??;
        Ok(XlaScorer {
            featurizer,
            tx: Mutex::new(tx),
            join: Some(join),
            batch_sizes,
        })
    }

    pub fn featurizer(&self) -> &PairFeaturizer {
        &self.featurizer
    }

    /// Batch sizes of the loaded variants (ascending).
    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    fn call(
        &self,
        qd: Vec<f32>,
        cd_flat: Vec<f32>,
        extras_flat: Vec<f32>,
        n: usize,
    ) -> Result<Vec<f32>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Req::Score { qd, cd_flat, extras_flat, n, resp: resp_tx })
                .map_err(|_| anyhow!("scorer actor gone"))?;
        }
        resp_rx.recv().map_err(|_| anyhow!("scorer actor dropped response"))?
    }

    /// Score a batch, propagating runtime errors (the `PairScorer` impl
    /// panics on error; prefer this in fallible contexts).
    pub fn try_score_batch(&self, q: &Point, cands: &[&Point]) -> Result<Vec<f32>> {
        if cands.is_empty() {
            return Ok(Vec::new());
        }
        let ch = self.featurizer.primary_dense_channel();
        let d = self.featurizer.dense_dim();
        let ke = self.featurizer.extra_dim();
        let qd = q.dense(ch).to_vec();
        let mut cd_flat = Vec::with_capacity(cands.len() * d);
        let mut extras_flat = Vec::with_capacity(cands.len() * ke);
        for c in cands {
            cd_flat.extend_from_slice(c.dense(ch));
            self.featurizer.extras_into(q, c, &mut extras_flat);
        }
        self.call(qd, cd_flat, extras_flat, cands.len())
    }
}

impl PairScorer for XlaScorer {
    /// The XLA path keeps its own pipeline (the actor thread needs owned
    /// buffers shipped over a channel, so the shared scratch is unused) but
    /// speaks the same allocation-aware entry point as the native scorer.
    fn score_into(
        &self,
        q: &Point,
        cands: &[&Point],
        _scratch: &mut crate::scorer::ScorerScratch,
        out: &mut Vec<f32>,
    ) {
        let scores = self.try_score_batch(q, cands).expect("xla scorer failed");
        out.extend_from_slice(&scores);
    }

    fn score_batch(&self, q: &Point, cands: &[&Point]) -> Vec<f32> {
        self.try_score_batch(q, cands).expect("xla scorer failed")
    }

    /// All calls serialize on the single actor thread, and each chunk
    /// would be padded to a compiled batch variant separately — splitting
    /// a list across workers only adds overhead here.
    fn parallel_chunking(&self) -> bool {
        false
    }
}

impl Drop for XlaScorer {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Req::Shutdown);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Per-variant state on the actor thread.
struct Variant {
    b: usize,
    exe: crate::runtime::Executable,
}

fn actor_main(
    variant_paths: Vec<(usize, PathBuf)>,
    weights: MlpWeights,
    d: usize,
    ke: usize,
    rx: mpsc::Receiver<Req>,
    boot_tx: mpsc::Sender<Result<()>>,
) {
    // --- startup: engine, executables, weight buffers ---
    let boot = (|| -> Result<(Engine, Vec<Variant>, Vec<xla::PjRtBuffer>)> {
        let engine = Engine::cpu()?;
        let mut variants = Vec::new();
        for (b, path) in &variant_paths {
            let exe = engine
                .load_hlo_text(path)
                .with_context(|| format!("loading variant b={b}"))?;
            variants.push(Variant { b: *b, exe });
        }
        variants.sort_by_key(|v| v.b);
        let h = weights.hidden;
        // Split W1's rows into the three kernel blocks (see module docs).
        let w1p = &weights.w1[..d * h];
        let w1d = &weights.w1[d * h..2 * d * h];
        let w1e = &weights.w1[2 * d * h..(2 * d + ke) * h];
        let wbufs = vec![
            engine.buffer_f32(w1p, &[d, h])?,
            engine.buffer_f32(w1d, &[d, h])?,
            engine.buffer_f32(w1e, &[ke, h])?,
            engine.buffer_f32(&weights.b1, &[h])?,
            engine.buffer_f32(&weights.w2, &[h, h])?,
            engine.buffer_f32(&weights.b2, &[h])?,
            engine.buffer_f32(&weights.w3, &[h])?,
            engine.buffer_f32(&[weights.b3], &[])?,
        ];
        Ok((engine, variants, wbufs))
    })();

    let (engine, variants, wbufs) = match boot {
        Ok(x) => {
            let _ = boot_tx.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = boot_tx.send(Err(e));
            return;
        }
    };

    // --- serve ---
    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Score { qd, cd_flat, extras_flat, n, resp } => {
                let r =
                    score_padded(&engine, &variants, &wbufs, &qd, &cd_flat, &extras_flat, n, d, ke);
                let _ = resp.send(r);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn score_padded(
    engine: &Engine,
    variants: &[Variant],
    wbufs: &[xla::PjRtBuffer],
    qd: &[f32],
    cd_flat: &[f32],
    extras_flat: &[f32],
    n: usize,
    d: usize,
    ke: usize,
) -> Result<Vec<f32>> {
    debug_assert_eq!(cd_flat.len(), n * d);
    debug_assert_eq!(extras_flat.len(), n * ke);
    // Pick the variant minimizing total padded elements ceil(n/v)·v (ties →
    // larger v = fewer calls). Padding a batch of 1000 to the 2048 variant
    // costs 2048 scored rows; two 512-variant calls cost 1024 — measured
    // ~7× faster end-to-end (EXPERIMENTS.md §Perf).
    let mut chunk_b = 0usize;
    let mut best_cost = usize::MAX;
    for v in variants {
        // variants are sorted ascending: `<=` prefers the larger batch
        // (fewer calls) among equal-cost choices.
        let cost = n.div_ceil(v.b) * v.b;
        if cost <= best_cost {
            best_cost = cost;
            chunk_b = v.b;
        }
    }
    if chunk_b == 0 {
        bail!("no variants loaded");
    }
    let mut out = Vec::with_capacity(n);
    let mut offset = 0usize;
    while offset < n {
        let chunk = (n - offset).min(chunk_b);
        let variant = variants
            .iter()
            .find(|v| v.b >= chunk)
            .ok_or_else(|| anyhow!("no variant for chunk {chunk}"))?;
        let b = variant.b;
        let qbuf = engine.buffer_f32(qd, &[d])?;
        let (cbuf, ebuf);
        if chunk == b {
            cbuf = engine.buffer_f32(&cd_flat[offset * d..(offset + chunk) * d], &[b, d])?;
            ebuf = engine.buffer_f32(&extras_flat[offset * ke..(offset + chunk) * ke], &[b, ke])?;
        } else {
            // Pad with zero rows up to the variant's static batch.
            let mut cpad = vec![0.0f32; b * d];
            cpad[..chunk * d].copy_from_slice(&cd_flat[offset * d..(offset + chunk) * d]);
            let mut epad = vec![0.0f32; b * ke];
            epad[..chunk * ke]
                .copy_from_slice(&extras_flat[offset * ke..(offset + chunk) * ke]);
            cbuf = engine.buffer_f32(&cpad, &[b, d])?;
            ebuf = engine.buffer_f32(&epad, &[b, ke])?;
        }
        let args: Vec<&xla::PjRtBuffer> = [&qbuf, &cbuf, &ebuf]
            .into_iter()
            .chain(wbufs.iter())
            .collect();
        let scores = variant.exe.run_buffers(&args)?;
        if scores.len() != b {
            bail!(
                "variant b={b} returned {} scores (artifact/schema mismatch?)",
                scores.len()
            );
        }
        out.extend_from_slice(&scores[..chunk]);
        offset += chunk;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::Schema;

    #[test]
    fn variant_paths() {
        let dir = Path::new("artifacts");
        assert_eq!(
            XlaScorer::variant_path(dir, "arxiv_like", 128),
            PathBuf::from("artifacts/scorer_arxiv_like_b128.hlo.txt")
        );
        assert_eq!(
            XlaScorer::weights_path(dir, "arxiv_like"),
            PathBuf::from("artifacts/weights_arxiv_like.json")
        );
    }

    #[test]
    fn load_without_artifacts_errors() {
        let schema = Schema::arxiv_like(8);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(f.input_dim(), super::super::HIDDEN, 1);
        let tmp = std::env::temp_dir().join("gus-empty-artifacts");
        let _ = std::fs::create_dir_all(&tmp);
        let err = match XlaScorer::with_weights(f, &tmp, w) {
            Ok(_) => panic!("expected error"),
            Err(e) => e,
        };
        assert!(format!("{err}").contains("make artifacts"), "{err}");
    }

    // Full numeric parity vs NativeScorer lives in
    // rust/tests/runtime_parity.rs (requires `make artifacts`).
}
