//! TCP JSON-lines RPC server.
//!
//! The paper's system is an RPC service (§3.1: Mutation RPCs and the
//! Neighborhood RPC). This server exposes both over a newline-delimited
//! JSON protocol (the offline build has no gRPC stack; the RPC *semantics*
//! are the same):
//!
//! ```text
//! → {"op":"insert","point":{"id":1,"features":[...]}}
//! ← {"ok":true,"existed":false}
//! → {"op":"delete","id":1}
//! ← {"ok":true,"existed":true}
//! → {"op":"query","k":10,"point":{...}}        # new or known point
//! → {"op":"query_id","k":10,"id":1}            # known point by id
//! ← {"ok":true,"neighbors":[{"id":4,"score":0.93,"dot":3.0},...]}
//! → {"op":"insert_batch","points":[{...},{...}]}
//! ← {"ok":true,"existed":[false,true]}
//! → {"op":"delete_batch","ids":[1,2,3]}
//! ← {"ok":true,"existed":[true,true,false]}
//! → {"op":"query_batch","k":10,"points":[{...},{...}]}
//! ← {"ok":true,"results":[[{"id":4,...},...],[...]]}
//! → {"op":"checkpoint"}
//! ← {"ok":true,"seq":1041}
//! → {"op":"stats"}
//! ← {"ok":true,"stats":{...}}
//! ```
//!
//! The full wire contract (field types, error shapes, durability
//! semantics) is specified in `docs/PROTOCOL.md`.
//!
//! The batch ops map to [`DynamicGus::insert_batch`] /
//! [`DynamicGus::query_batch`], which parallelize across items on the
//! serving workers — one RPC amortizes framing, locking and scheduling
//! over the whole batch. `checkpoint` maps to [`DynamicGus::checkpoint`]
//! (durable services only — see [`crate::coordinator::wal`]).
//!
//! Connections are handled by a fixed worker pool with a bounded backlog —
//! the backpressure strategy is "refuse new connections when saturated"
//! (clients retry), keeping tail latency of admitted requests flat.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::DynamicGus;
use crate::features::Point;
use crate::util::json::Json;

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_concurrent_connections: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_concurrent_connections: 64 }
    }
}

/// Handle to a running server (for tests and embedding).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown and wait for the accept loop to exit.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so accept() returns.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start serving `gus` on `addr` (e.g. "127.0.0.1:0" for an ephemeral
/// port). Returns immediately with a handle.
pub fn serve(gus: Arc<DynamicGus>, addr: &str, config: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let active = Arc::new(AtomicUsize::new(0));
    let join = std::thread::Builder::new()
        .name("gus-server-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if active.load(Ordering::SeqCst) >= config.max_concurrent_connections {
                    // Backpressure: refuse (client sees EOF and retries).
                    drop(stream);
                    continue;
                }
                active.fetch_add(1, Ordering::SeqCst);
                let gus = Arc::clone(&gus);
                let active = Arc::clone(&active);
                let _ = std::thread::Builder::new()
                    .name("gus-server-conn".into())
                    .spawn(move || {
                        let _ = handle_connection(&gus, stream);
                        active.fetch_sub(1, Ordering::SeqCst);
                    });
            }
        })?;
    Ok(ServerHandle { addr: local, stop, join: Some(join) })
}

fn handle_connection(gus: &DynamicGus, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = dispatch(gus, &line);
        writer.write_all(response.dump().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

/// Decode one request line, execute, encode the response.
pub fn dispatch(gus: &DynamicGus, line: &str) -> Json {
    match dispatch_inner(gus, line) {
        Ok(j) => j,
        Err(e) => {
            gus.metrics
                .counters
                .errors
                .fetch_add(1, Ordering::Relaxed);
            Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(format!("{e}"))),
            ])
        }
    }
}

fn dispatch_inner(gus: &DynamicGus, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let op = req
        .get("op")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("missing 'op'"))?;
    match op {
        "insert" | "update" => {
            let p = Point::from_json(req.get("point"))
                .ok_or_else(|| anyhow::anyhow!("missing/bad 'point'"))?;
            let existed = gus.insert(p)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("existed", Json::Bool(existed)),
            ]))
        }
        "delete" => {
            let id = req
                .get("id")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("missing 'id'"))?;
            let existed = gus.delete(id)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("existed", Json::Bool(existed)),
            ]))
        }
        "query" | "query_id" => {
            let k = req.get("k").as_usize().unwrap_or(gus.config().scann_nn);
            let neighbors = if op == "query" {
                let p = Point::from_json(req.get("point"))
                    .ok_or_else(|| anyhow::anyhow!("missing/bad 'point'"))?;
                gus.query(&p, k)?
            } else {
                let id = req
                    .get("id")
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("missing 'id'"))?;
                gus.query_by_id(id, k)?
            };
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("neighbors", neighbors_json(&neighbors)),
            ]))
        }
        "insert_batch" => {
            let points = parse_points(&req)?;
            let existed = gus.insert_batch(points)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("existed", Json::Arr(existed.into_iter().map(Json::Bool).collect())),
            ]))
        }
        "delete_batch" => {
            let ids = req
                .get("ids")
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("missing/bad 'ids'"))?
                .iter()
                .map(|j| j.as_u64().ok_or_else(|| anyhow::anyhow!("bad id in 'ids'")))
                .collect::<Result<Vec<u64>>>()?;
            let existed = gus.delete_batch(&ids)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("existed", Json::Arr(existed.into_iter().map(Json::Bool).collect())),
            ]))
        }
        "query_batch" => {
            let k = req.get("k").as_usize().unwrap_or(gus.config().scann_nn);
            let points = parse_points(&req)?;
            let results = gus.query_batch(&points, k)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("results", Json::Arr(results.iter().map(|r| neighbors_json(r)).collect())),
            ]))
        }
        "checkpoint" => {
            let seq = gus.checkpoint()?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("seq", Json::u64(seq)),
            ]))
        }
        "stats" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("stats", gus.stats_json()),
        ])),
        other => anyhow::bail!("unknown op '{other}'"),
    }
}

/// Decode the `points` array of a batch request.
fn parse_points(req: &Json) -> Result<Vec<Point>> {
    req.get("points")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("missing/bad 'points'"))?
        .iter()
        .map(|j| Point::from_json(j).ok_or_else(|| anyhow::anyhow!("bad point in 'points'")))
        .collect()
}

/// Encode a scored-neighbor list.
fn neighbors_json(neighbors: &[crate::coordinator::ScoredNeighbor]) -> Json {
    Json::Arr(
        neighbors
            .iter()
            .map(|n| {
                Json::obj(vec![
                    ("id", Json::u64(n.id)),
                    ("score", Json::num(n.score as f64)),
                    ("dot", Json::num(n.dot as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GusConfig, ScorerKind};
    use crate::data::synthetic::SyntheticConfig;

    fn boot() -> (Arc<DynamicGus>, crate::data::Dataset) {
        let ds = SyntheticConfig::arxiv_like(150, 31).generate();
        let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap();
        (Arc::new(gus), ds)
    }

    #[test]
    fn dispatch_query_and_mutations() {
        let (gus, ds) = boot();
        // Query by id.
        let resp = dispatch(&gus, &format!(r#"{{"op":"query_id","id":{},"k":5}}"#, 3));
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        assert!(!resp.get("neighbors").as_arr().unwrap().is_empty());
        // Insert a new point via JSON.
        let mut p = ds.points[0].clone();
        p.id = 50_000;
        let req = Json::obj(vec![("op", Json::str("insert")), ("point", p.to_json())]);
        let resp = dispatch(&gus, &req.dump());
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert_eq!(resp.get("existed").as_bool(), Some(false));
        // Delete it.
        let resp = dispatch(&gus, r#"{"op":"delete","id":50000}"#);
        assert_eq!(resp.get("existed").as_bool(), Some(true));
        // Stats.
        let resp = dispatch(&gus, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("stats").get("points").as_usize(), Some(150));
    }

    #[test]
    fn dispatch_batch_ops() {
        let (gus, ds) = boot();
        // Insert a batch of fresh points.
        let mut pts = Vec::new();
        for (i, p) in ds.points.iter().take(5).enumerate() {
            let mut p = p.clone();
            p.id = 60_000 + i as u64;
            pts.push(p.to_json());
        }
        let req = Json::obj(vec![
            ("op", Json::str("insert_batch")),
            ("points", Json::Arr(pts)),
        ]);
        let resp = dispatch(&gus, &req.dump());
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        let existed = resp.get("existed").as_arr().unwrap();
        assert_eq!(existed.len(), 5);
        assert!(existed.iter().all(|j| j.as_bool() == Some(false)));
        assert_eq!(gus.len(), 155);

        // Batch query: one result list per input point, matching singles.
        let req = Json::obj(vec![
            ("op", Json::str("query_batch")),
            ("k", Json::num(5.0)),
            (
                "points",
                Json::Arr(ds.points.iter().take(3).map(|p| p.to_json()).collect()),
            ),
        ]);
        let resp = dispatch(&gus, &req.dump());
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        let results = resp.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 3);
        for (i, r) in results.iter().enumerate() {
            let single = gus.query(&ds.points[i], 5).unwrap();
            let got: Vec<u64> =
                r.as_arr().unwrap().iter().map(|n| n.get("id").as_u64().unwrap()).collect();
            let want: Vec<u64> = single.iter().map(|n| n.id).collect();
            assert_eq!(got, want, "batch result {i} diverged");
        }

        // Batch delete removes the freshly inserted points.
        let resp = dispatch(
            &gus,
            r#"{"op":"delete_batch","ids":[60000,60001,60002,60003,60004,61111]}"#,
        );
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        let existed: Vec<bool> = resp
            .get("existed")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_bool().unwrap())
            .collect();
        assert_eq!(existed, vec![true, true, true, true, true, false]);
        assert_eq!(gus.len(), 150);

        // Malformed batches are structured errors.
        for bad in [
            r#"{"op":"insert_batch"}"#,
            r#"{"op":"insert_batch","points":[{"id":1}]}"#,
            r#"{"op":"query_batch","points":42}"#,
            r#"{"op":"delete_batch"}"#,
            r#"{"op":"delete_batch","ids":[true]}"#,
        ] {
            let resp = dispatch(&gus, bad);
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{bad}");
        }
    }

    #[test]
    fn dispatch_checkpoint() {
        // Without a WAL, checkpoint is a structured error.
        let (gus, ds) = boot();
        let resp = dispatch(&gus, r#"{"op":"checkpoint"}"#);
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert!(resp.get("error").as_str().unwrap().contains("WAL"));

        // With one, it reports the sequence number it covers.
        let dir = std::env::temp_dir().join("gus-server-tests").join("checkpoint");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = GusConfig {
            scorer: ScorerKind::Native,
            fsync: crate::config::FsyncPolicy::Never,
            ..GusConfig::default()
        };
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points[..50], 2).unwrap();
        crate::coordinator::wal::init_fresh(&gus, &dir).unwrap();
        gus.insert(ds.points[60].clone()).unwrap();
        gus.insert(ds.points[61].clone()).unwrap();
        let resp = dispatch(&gus, r#"{"op":"checkpoint"}"#);
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get("seq").as_u64(), Some(2));
        // The stats RPC reports the durability state.
        let resp = dispatch(&gus, r#"{"op":"stats"}"#);
        let wal = resp.get("stats").get("wal");
        assert_eq!(wal.get("seq").as_u64(), Some(2));
        assert_eq!(wal.get("pending").as_u64(), Some(0));
    }

    #[test]
    fn dispatch_errors_are_structured() {
        let (gus, _) = boot();
        for bad in [
            "not json",
            r#"{"no_op":1}"#,
            r#"{"op":"unknown"}"#,
            r#"{"op":"delete"}"#,
            r#"{"op":"query_id","id":987654321}"#,
        ] {
            let resp = dispatch(&gus, bad);
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{bad}");
            assert!(resp.get("error").as_str().is_some());
        }
        assert!(gus.metrics.counters.errors.load(Ordering::Relaxed) >= 5);
    }
}
