//! TCP JSON-lines RPC server: pipelined, multiplexed, deadline-aware.
//!
//! The paper's system is an RPC service (§3.1: Mutation RPCs and the
//! Neighborhood RPC) answering in tens of milliseconds under heavy
//! dynamic traffic. This server carries that contract over a
//! newline-delimited JSON protocol (the offline build has no gRPC stack;
//! the RPC *semantics* are the same). All request/response shapes are
//! owned by [`crate::protocol`] — this module only schedules and
//! executes; `docs/PROTOCOL.md` is the full wire spec.
//!
//! # Execution model
//!
//! ```text
//!                    ┌────────────┐   bounded run queue   ┌─────────┐
//! conn A ──reader──▶ │  decode    │ ──▶ [ job | job | … ] ─▶ worker 1 │──┐
//! conn B ──reader──▶ │ (protocol) │          │              worker …  │──┼─▶ per-conn
//! conn C ──reader──▶ │            │          ▼              worker W  │──┘   writer
//!                    └────────────┘   full → OVERLOADED   └─────────┘   (id-matched)
//! ```
//!
//! - One lightweight **reader** thread per connection decodes lines and
//!   enqueues v1 requests onto a server-wide **fixed worker pool** with a
//!   **bounded run queue** — a few connections can keep every core busy.
//! - Workers execute concurrently and complete **out of order**; each
//!   response is written under the connection's writer lock and matched
//!   to its request by the envelope `id`.
//! - **Mutations (and `checkpoint`) on one connection still apply in
//!   submission order**: a per-connection ticket gate parks
//!   not-yet-runnable *jobs*, never worker threads, and the finisher of
//!   each turn chain-executes parked successors; queries overtake freely.
//! - A request whose **deadline** already expired is answered
//!   `DEADLINE_EXCEEDED` *before* touching the index.
//! - When the run queue is full, the request is shed immediately with an
//!   `OVERLOADED` response — admitted work keeps its flat tail latency.
//! - A client that stops reading responses is bounded by a socket write
//!   timeout: the connection is marked dead and dropped rather than
//!   stalling the shared workers.
//! - **Legacy** (un-enveloped) requests execute inline on the reader,
//!   strictly serially and in order, with legacy-shaped responses —
//!   exactly the pre-envelope behavior, on the same port, detectable per
//!   line (so one connection may even mix dialects).
//! - Connections beyond the concurrency cap receive one final
//!   `OVERLOADED` response before the socket closes (counted in the
//!   `refused` stat) instead of a silent drop.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::admission::controller::ControllerSnapshot;
use crate::admission::{AdmissionConfig, Class, Controller, Decision};
use crate::coordinator::{DegradeSpec, DynamicGus};
use crate::protocol::{decode_request, Envelope, ErrorCode, Incoming, Request, Response};
use crate::util::json::Json;

/// Hooks the replication subsystem installs into the server. Defined
/// here (not under `replication/`) so the server stays ignorant of the
/// subsystem's internals; [`crate::replication`] provides the
/// implementations for leader and follower roles.
pub trait Replication: Send + Sync {
    /// `Some(leader_hint)` when this node must refuse mutations (it is a
    /// follower): ordered ops are answered `NOT_LEADER` with the hint
    /// embedded as `not leader; leader=<hint>` so routers/clients can
    /// redirect. `None` on a leader (mutations proceed).
    fn deny_mutations(&self) -> Option<String>;

    /// Gate one executed mutation's ack on replication (semi-sync):
    /// blocks until the mutation's WAL seq is durably acknowledged by
    /// the configured number of followers, or a bounded wait expires.
    /// `Err(message)` turns the (already applied) mutation's response
    /// into `UNAVAILABLE` — the client must treat it as unacknowledged.
    /// Implementations record the timeout in the replication gauges
    /// themselves (they know which subscribers lagged); the server only
    /// classifies the client-visible error.
    fn ack_gate(&self, wal_seq: u64) -> std::result::Result<(), String>;

    /// Promote this node to leader (failover). Idempotent on a leader.
    /// Returns the node's durable WAL seq (the promotion criterion).
    fn promote(&self) -> Result<u64>;

    /// Serve one `wal_subscribe` stream. Takes over the connection: the
    /// implementation writes the header response (echoing `id` when the
    /// request was enveloped) followed by raw WAL frames on `stream`,
    /// and reads `{"ack":seq}` lines from `reader` until disconnect.
    fn subscribe(
        &self,
        from_seq: u64,
        id: Option<u64>,
        reader: BufReader<TcpStream>,
        stream: TcpStream,
    ) -> Result<()>;
}

/// Server tuning.
#[derive(Clone)]
pub struct ServerConfig {
    /// Connections admitted concurrently; excess connections get a final
    /// `OVERLOADED` response and are closed (clients retry).
    pub max_concurrent_connections: usize,
    /// Worker threads executing requests (0 = auto: available cores).
    pub worker_threads: usize,
    /// Bounded run-queue capacity; when full, new requests are shed with
    /// `OVERLOADED` instead of queueing unboundedly.
    pub queue_capacity: usize,
    /// Adaptive admission knobs (see [`crate::admission`]): sojourn
    /// target and degraded-serving quality floor. `target_sojourn_ms: 0`
    /// disables the controller — only the queue-full backstop sheds.
    pub admission: AdmissionConfig,
    /// Replication hooks (leader or follower role). `None` = single-node
    /// serving: `wal_subscribe`/`promote` answer `BAD_REQUEST` and
    /// mutations are never denied or gated.
    pub replication: Option<Arc<dyn Replication>>,
}

impl std::fmt::Debug for ServerConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerConfig")
            .field("max_concurrent_connections", &self.max_concurrent_connections)
            .field("worker_threads", &self.worker_threads)
            .field("queue_capacity", &self.queue_capacity)
            .field("admission", &self.admission)
            .field("replication", &self.replication.is_some())
            .finish()
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_concurrent_connections: 64,
            worker_threads: 0,
            queue_capacity: 256,
            admission: AdmissionConfig::default(),
            replication: None,
        }
    }
}

impl ServerConfig {
    /// Derive the server knobs from a service config (the CLI path).
    pub fn from_gus(cfg: &crate::config::GusConfig) -> ServerConfig {
        ServerConfig {
            max_concurrent_connections: cfg.max_connections,
            worker_threads: cfg.rpc_workers,
            queue_capacity: cfg.rpc_queue,
            admission: AdmissionConfig {
                target_sojourn_ms: cfg.admission_target_ms,
                min_budget_frac: cfg.min_budget_frac,
            },
            replication: None,
        }
    }

    fn resolved_workers(&self) -> usize {
        if self.worker_threads == 0 {
            crate::util::threadpool::default_parallelism()
        } else {
            self.worker_threads
        }
    }
}

/// Handle to a running server (for tests and embedding).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<RunQueue>,
    join: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown and wait for the accept loop and workers to exit.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so accept() returns.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.queue.stop();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

// ---------- run queue + jobs ----------

/// One unit of work: a decoded v1 request bound to its connection.
struct Job {
    conn: Arc<ConnShared>,
    envelope: Envelope,
    /// When the request was read off the socket (deadlines are relative
    /// to this instant).
    received: Instant,
    /// Per-connection ordering ticket (mutations + checkpoint).
    order_ticket: Option<u64>,
    /// Degraded-serving budget decided at admission (interactive class
    /// under pressure). `None` = full budget, responses unmarked.
    degrade: Option<DegradeSpec>,
}

/// Bounded MPMC run queue shared by every connection reader and worker.
struct RunQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    capacity: usize,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    stopped: bool,
}

/// Why a push was rejected.
enum PushRefusal {
    /// Queue at capacity: shed with `OVERLOADED`.
    Full,
    /// Server shutting down: shed with `UNAVAILABLE`.
    Stopped,
}

impl RunQueue {
    fn new(capacity: usize) -> RunQueue {
        RunQueue {
            inner: Mutex::new(QueueInner { jobs: VecDeque::new(), stopped: false }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking admission: enqueue or refuse immediately — shedding
    /// at the door is what keeps admitted requests' tail latency flat.
    fn try_push(&self, job: Job) -> std::result::Result<(), (Job, PushRefusal)> {
        let mut g = self.inner.lock().unwrap();
        if g.stopped {
            return Err((job, PushRefusal::Stopped));
        }
        if g.jobs.len() >= self.capacity {
            return Err((job, PushRefusal::Full));
        }
        g.jobs.push_back(job);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once stopped *and* drained (workers finish
    /// accepted work before exiting).
    fn pop(&self) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                return Some(job);
            }
            if g.stopped {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn stop(&self) {
        self.inner.lock().unwrap().stopped = true;
        self.cv.notify_all();
    }

    /// Instantaneous depth (the controller's fast pressure signal).
    fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }
}

/// Server-wide admission state: the pressure controller plus the queue
/// capacity its depth signal is normalized against. The controller
/// itself is clock-free; this wrapper owns the lock and the capacity so
/// readers (decide) and workers (observe) share one EWMA.
struct AdmissionShared {
    controller: Mutex<Controller>,
    capacity: usize,
}

impl AdmissionShared {
    fn new(cfg: AdmissionConfig, capacity: usize) -> AdmissionShared {
        AdmissionShared {
            controller: Mutex::new(Controller::new(cfg)),
            capacity: capacity.max(1),
        }
    }

    fn decide(&self, class: Option<Class>, depth: usize) -> Decision {
        self.controller.lock().unwrap().decide(class, depth, self.capacity)
    }

    fn observe_sojourn(&self, sojourn_ms: u64) {
        self.controller.lock().unwrap().observe_sojourn(sojourn_ms);
    }

    fn snapshot(&self, depth: usize) -> ControllerSnapshot {
        self.controller.lock().unwrap().snapshot(depth, self.capacity)
    }
}

/// Per-connection state shared between its reader and the workers.
struct ConnShared {
    gus: Arc<DynamicGus>,
    /// Replication hooks (from [`ServerConfig::replication`]).
    replication: Option<Arc<dyn Replication>>,
    /// Server-wide admission state (sojourn EWMA + pressure tiers).
    admission: Arc<AdmissionShared>,
    /// The shared run queue (for the stats snapshot's depth signal).
    queue: Arc<RunQueue>,
    writer: Mutex<BufWriter<TcpStream>>,
    gate: OrderGate,
    /// Set after a write failure (client gone, or a non-reading client
    /// whose socket timed out): further responses to this connection are
    /// dropped instead of stalling shared workers on a dead socket.
    dead: AtomicBool,
}

/// Ticket gate serializing one connection's ordered ops (mutations +
/// checkpoint) in submission order — **without parking worker threads**.
/// Tickets are handed out by the (single) reader thread in read order
/// and only for admitted requests, so they are dense. A worker whose
/// job's turn has not yet come *parks the job* (not itself) and moves
/// on; whoever finishes the current turn chains parked successors.
struct OrderGate {
    inner: Mutex<GateInner>,
    /// Wakes the legacy inline path, which (alone) blocks for its turn.
    cv: Condvar,
}

struct GateInner {
    /// The ticket whose turn it is now.
    next: u64,
    /// Jobs dequeued before their turn, keyed by ticket.
    parked: std::collections::BTreeMap<u64, Job>,
}

impl OrderGate {
    fn new() -> OrderGate {
        OrderGate {
            inner: Mutex::new(GateInner { next: 0, parked: std::collections::BTreeMap::new() }),
            cv: Condvar::new(),
        }
    }

    /// Non-blocking turn claim: hands the job back if it is `ticket`'s
    /// turn right now, otherwise parks it for the current turn holder to
    /// chain (see [`OrderGate::advance`]) and returns `None`.
    fn claim_or_park(&self, ticket: u64, job: Job) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        if g.next == ticket {
            Some(job)
        } else {
            g.parked.insert(ticket, job);
            None
        }
    }

    /// Block until `ticket`'s turn (legacy inline path only — the reader
    /// thread may block, shared workers never do).
    fn wait_turn(&self, ticket: u64) {
        let mut g = self.inner.lock().unwrap();
        while g.next != ticket {
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Finish the current turn: advance, wake a blocked legacy reader,
    /// and hand back the successor's job if it was already parked — the
    /// caller chain-executes it so no ordered op is ever orphaned.
    fn advance(&self) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        g.next += 1;
        let turn = g.next;
        let chained = g.parked.remove(&turn);
        drop(g);
        self.cv.notify_all();
        chained
    }
}

/// Default socket write timeout: bounds what one non-reading client can
/// cost a shared worker.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Floor for the deadline-derived write bound: zero timeouts are
/// rejected by the socket API, and even an expired budget deserves one
/// best-effort write attempt.
const MIN_WRITE_TIMEOUT: Duration = Duration::from_millis(5);

impl ConnShared {
    /// Serialize + write one response line. Failures (client gone, or a
    /// non-reading client hitting the socket write timeout) mark the
    /// connection dead so shared workers stop paying for it; the reader
    /// then observes EOF/error and winds the connection down.
    fn send(&self, wire: &Json) {
        self.send_bounded(wire, None)
    }

    /// [`ConnShared::send`] with the socket write additionally bounded by
    /// the request's remaining `deadline_ms` budget: a stalled client
    /// never holds a worker past the point its response stops being
    /// useful. `None` keeps the connection's default write timeout.
    fn send_bounded(&self, wire: &Json, budget: Option<Duration>) {
        // RELAXED: `dead` is an advisory flag — the writer mutex orders
        // the flagging store with the failed write; a stale read costs at
        // most one extra write attempt, never a correctness violation.
        if self.dead.load(Ordering::Relaxed) {
            return;
        }
        let mut w = self.writer.lock().unwrap();
        // RELAXED: re-check under the writer lock; the mutex acquire
        // synchronizes with the store made by whichever sender failed.
        if self.dead.load(Ordering::Relaxed) {
            return;
        }
        let bounded = budget.map(|b| b.clamp(MIN_WRITE_TIMEOUT, WRITE_TIMEOUT));
        if let Some(t) = bounded {
            w.get_ref().set_write_timeout(Some(t)).ok();
        }
        let ok = w
            .write_all(wire.dump().as_bytes())
            .and_then(|()| w.write_all(b"\n"))
            .and_then(|()| w.flush());
        if bounded.is_some() {
            w.get_ref().set_write_timeout(Some(WRITE_TIMEOUT)).ok();
        }
        if ok.is_err() {
            // RELAXED: published under the writer lock held above; later
            // senders observe it via the lock or via the advisory fast path.
            self.dead.store(true, Ordering::Relaxed);
        }
    }
}

// ---------- serving ----------

/// Start serving `gus` on `addr` (e.g. "127.0.0.1:0" for an ephemeral
/// port). Returns immediately with a handle.
pub fn serve(gus: Arc<DynamicGus>, addr: &str, config: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let queue = Arc::new(RunQueue::new(config.queue_capacity));
    let admission = Arc::new(AdmissionShared::new(config.admission, config.queue_capacity));

    let workers = (0..config.resolved_workers())
        .map(|i| {
            let queue = Arc::clone(&queue);
            std::thread::Builder::new()
                .name(format!("gus-server-worker-{i}"))
                .spawn(move || {
                    while let Some(job) = queue.pop() {
                        run_job(job);
                    }
                })
        })
        .collect::<std::io::Result<Vec<_>>>()?;

    let stop2 = Arc::clone(&stop);
    let queue2 = Arc::clone(&queue);
    let active = Arc::new(AtomicUsize::new(0));
    let join = std::thread::Builder::new()
        .name("gus-server-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if active.load(Ordering::SeqCst) >= config.max_concurrent_connections {
                    refuse_connection(&gus, stream);
                    continue;
                }
                active.fetch_add(1, Ordering::SeqCst);
                let gus = Arc::clone(&gus);
                let active = Arc::clone(&active);
                let queue = Arc::clone(&queue2);
                let admission = Arc::clone(&admission);
                let replication = config.replication.clone();
                let _ = std::thread::Builder::new()
                    .name("gus-server-conn".into())
                    .spawn(move || {
                        let _ = handle_connection(gus, replication, admission, queue, stream);
                        active.fetch_sub(1, Ordering::SeqCst);
                    });
            }
        })?;
    Ok(ServerHandle { addr: local, stop, queue, join: Some(join), workers })
}

/// Over the connection cap: answer with one final `OVERLOADED` error
/// (connection-level, so no `id`) and close — a structured refusal the
/// client can distinguish from a network failure — and count it.
fn refuse_connection(gus: &DynamicGus, stream: TcpStream) {
    gus.metrics.counters.refused.fetch_add(1, Ordering::Relaxed);
    let resp = Response::error(
        ErrorCode::Overloaded,
        "connection refused: server at max_concurrent_connections; retry",
    );
    let mut w = BufWriter::new(stream);
    let _ = w.write_all(resp.to_wire(None).dump().as_bytes());
    let _ = w.write_all(b"\n");
    let _ = w.flush();
    // Dropping `w` closes the socket.
}

/// Per-connection reader loop: decode each line, execute legacy requests
/// inline (serial, in order), enqueue v1 requests on the worker pool.
/// A `wal_subscribe` request hands the whole connection (reader + raw
/// socket) to the replication subsystem and ends this loop.
fn handle_connection(
    gus: Arc<DynamicGus>,
    replication: Option<Arc<dyn Replication>>,
    admission: Arc<AdmissionShared>,
    queue: Arc<RunQueue>,
    stream: TcpStream,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Response writes happen on shared workers; a client that stops
    // reading must cost at most one bounded stall, not a wedged pool —
    // the first timed-out write marks the connection dead (see
    // [`ConnShared::send`]). Deadline-carrying requests tighten this
    // per-write (see [`ConnShared::send_bounded`]).
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let conn = Arc::new(ConnShared {
        gus: Arc::clone(&gus),
        replication,
        admission,
        queue: Arc::clone(&queue),
        writer: Mutex::new(BufWriter::new(stream)),
        gate: OrderGate::new(),
        dead: AtomicBool::new(false),
    });
    // Next mutation ticket; only the reader assigns tickets, and only
    // for admitted requests, so the gate sequence has no holes.
    let mut next_ticket = 0u64;
    let mut linebuf = String::new();
    loop {
        linebuf.clear();
        if reader.read_line(&mut linebuf)? == 0 {
            break; // EOF
        }
        let line = linebuf.trim_end_matches(['\n', '\r']);
        if line.trim().is_empty() {
            continue;
        }
        let received = Instant::now();
        let incoming = match decode_request(line) {
            Err(e) => {
                // When the envelope header was readable, echo its id so a
                // pipelined client can match the failure; otherwise the
                // error is connection-level (legacy-shaped).
                gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                let resp = Response::error(e.error.code, e.error.message);
                conn.send(&resp.to_wire(e.id));
                continue;
            }
            Ok(incoming) => incoming,
        };
        // `wal_subscribe` (either dialect) switches the connection to
        // streaming mode: the replication subsystem owns the socket from
        // here and no further request lines are read.
        let subscribe = match &incoming {
            Incoming::Legacy(Request::WalSubscribe { from_seq }) => Some((*from_seq, None)),
            Incoming::V1(env) => match env.request {
                Request::WalSubscribe { from_seq } => Some((from_seq, Some(env.id))),
                _ => None,
            },
            _ => None,
        };
        if let Some((from_seq, id)) = subscribe {
            match conn.replication.as_ref() {
                Some(rep) => {
                    let raw = conn.writer.lock().unwrap().get_ref().try_clone()?;
                    return Arc::clone(rep).subscribe(from_seq, id, reader, raw);
                }
                None => {
                    gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                    let resp = Response::error(
                        ErrorCode::BadRequest,
                        "replication not enabled on this server (serve with --replicate)",
                    );
                    conn.send(&resp.to_wire(id));
                    continue;
                }
            }
        }
        match incoming {
            Incoming::Legacy(request) => {
                // Legacy dialect: strictly serial, in-order, on this
                // thread — byte-compatible with the pre-envelope server.
                // Ordered ops still take a gate ticket so their order
                // holds even against pipelined v1 mutations. The reader
                // (alone) may block for its turn; it then also chains
                // any parked v1 successors.
                let ticket = request.is_ordered().then(|| {
                    let t = next_ticket;
                    next_ticket += 1;
                    t
                });
                if let Some(t) = ticket {
                    conn.gate.wait_turn(t);
                }
                let resp = execute_replicated(&gus, conn.replication.as_deref(), request);
                conn.send(&resp.to_wire(None));
                if ticket.is_some() {
                    finish_ordered_turn(&conn);
                }
            }
            Incoming::V1(envelope) => {
                let id = envelope.id;
                // Adaptive admission: classed requests consult the
                // pressure controller before the queue-full backstop —
                // shedding lowest-class-first with a retry hint, or
                // admitting interactive work at a reduced budget.
                let degrade = match conn.admission.decide(envelope.class, queue.len()) {
                    Decision::Shed { retry_after_ms } => {
                        note_shed(&gus, envelope.class);
                        gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                        let msg = format!(
                            "shed by admission control (class={}); retry",
                            envelope.class.map(Class::as_str).unwrap_or("none"),
                        );
                        conn.send(&Response::overloaded(msg, retry_after_ms).to_wire(Some(id)));
                        continue;
                    }
                    Decision::Admit { budget_frac, skip_refine } => {
                        (budget_frac < 1.0 || skip_refine)
                            .then_some(DegradeSpec { budget_frac, skip_refine })
                    }
                };
                let order_ticket = envelope.request.is_ordered().then_some(next_ticket);
                let job =
                    Job { conn: Arc::clone(&conn), envelope, received, order_ticket, degrade };
                match queue.try_push(job) {
                    Ok(()) => {
                        if order_ticket.is_some() {
                            next_ticket += 1;
                        }
                    }
                    Err((job, refusal)) => {
                        // Refused jobs never took a ticket, so the gate
                        // sequence stays dense.
                        let (code, msg) = match refusal {
                            PushRefusal::Full => {
                                gus.metrics
                                    .counters
                                    .overloaded
                                    .fetch_add(1, Ordering::Relaxed);
                                (ErrorCode::Overloaded, "run queue full; retry (server saturated)")
                            }
                            PushRefusal::Stopped => {
                                (ErrorCode::Unavailable, "server shutting down")
                            }
                        };
                        gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                        job.conn.send(&Response::error(code, msg).to_wire(Some(id)));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Run one admitted v1 job on a worker. Unordered ops execute
/// immediately; ordered ops (mutations + checkpoint) execute when their
/// per-connection turn arrives — a job whose turn is pending is parked
/// on the gate (the worker moves on to other work) and chain-executed by
/// whoever finishes the preceding turn.
fn run_job(job: Job) {
    let Some(ticket) = job.order_ticket else {
        execute_and_send(job);
        return;
    };
    let conn = Arc::clone(&job.conn);
    let Some(job) = conn.gate.claim_or_park(ticket, job) else { return };
    execute_and_send(job);
    finish_ordered_turn(&conn);
}

/// Finish an ordered op's turn on `conn`: advance the gate and
/// chain-execute any parked successors whose turns arrive.
fn finish_ordered_turn(conn: &ConnShared) {
    let mut chained = conn.gate.advance();
    while let Some(job) = chained {
        execute_and_send(job);
        chained = conn.gate.advance();
    }
}

/// Deadline-check, execute, and answer one v1 job (no gate logic).
fn execute_and_send(job: Job) {
    let gus = &job.conn.gus;
    // Sojourn: how long this job sat between socket read and execution —
    // the controller's primary pressure signal. Parked (ordered) jobs
    // count their park time too; that delay is just as real to clients.
    job.conn.admission.observe_sojourn(job.received.elapsed().as_millis() as u64);
    // `checked_add`: an absurd deadline_ms must saturate to "never
    // expires", not panic the worker.
    let expired = match job.envelope.deadline_ms {
        None => false,
        Some(ms) => job
            .received
            .checked_add(Duration::from_millis(ms))
            .is_some_and(|deadline| Instant::now() >= deadline),
    };
    let mut resp = if expired {
        gus.metrics.counters.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
        gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
        Response::error(
            ErrorCode::DeadlineExceeded,
            format!(
                "deadline of {}ms expired before execution",
                job.envelope.deadline_ms.unwrap_or(0)
            ),
        )
    } else if let Some(spec) = job.degrade {
        execute_degraded(gus, job.conn.replication.as_deref(), job.envelope.request, spec)
    } else {
        execute_replicated(gus, job.conn.replication.as_deref(), job.envelope.request)
    };
    // The served-path stats response carries the controller's state; the
    // coordinator can't add this section because the server owns the
    // controller (legacy/inline stats stay byte-identical to before).
    if let Response::Stats { stats } = &mut resp {
        if let Json::Obj(map) = stats {
            let snap = job.conn.admission.snapshot(job.conn.queue.len());
            map.insert("admission".into(), snap.to_json());
        }
    }
    // Bound the writer by whatever deadline budget remains.
    let budget = job
        .envelope
        .deadline_ms
        .map(|ms| Duration::from_millis(ms).saturating_sub(job.received.elapsed()));
    job.conn.send_bounded(&resp.to_wire(Some(job.envelope.id)), budget);
}

/// Route one admission shed to its per-class counter. Unclassed requests
/// are never shed by the controller (only the queue-full backstop, which
/// counts `overloaded`), but route them as interactive for safety.
fn note_shed(gus: &DynamicGus, class: Option<Class>) {
    let c = match class {
        Some(Class::Replication) => &gus.metrics.counters.shed_replication,
        Some(Class::Batch) => &gus.metrics.counters.shed_batch,
        Some(Class::Interactive) | None => &gus.metrics.counters.shed_interactive,
    };
    c.fetch_add(1, Ordering::Relaxed);
}

/// Execute one admitted-but-degraded request: queries run with a scaled
/// posting budget (and optionally without scoring refinement) and their
/// responses are marked `degraded`; every other op is unaffected by
/// degradation and executes normally.
fn execute_degraded(
    gus: &DynamicGus,
    rep: Option<&dyn Replication>,
    req: Request,
    spec: DegradeSpec,
) -> Response {
    let default_k = gus.config().scann_nn;
    let frac = spec.budget_frac;
    let result = match req {
        Request::Query { point, k } => gus
            .query_degraded(&point, k.unwrap_or(default_k), spec)
            .map(|neighbors| Response::Neighbors { neighbors, degraded: Some(frac) }),
        Request::QueryId { id, k } => gus
            .query_by_id_degraded(id, k.unwrap_or(default_k), spec)
            .map(|neighbors| Response::Neighbors { neighbors, degraded: Some(frac) }),
        Request::QueryBatch { points, k } => gus
            .query_batch_degraded(&points, k.unwrap_or(default_k), spec)
            .map(|results| Response::Results { results, degraded: Some(frac) }),
        other => return execute_replicated(gus, rep, other),
    };
    match result {
        Ok(resp) => {
            gus.metrics.counters.degraded_responses.fetch_add(1, Ordering::Relaxed);
            resp
        }
        Err(e) => {
            gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
            let msg = format!("{e}");
            Response::error(classify_error(&msg), msg)
        }
    }
}

// ---------- typed dispatch ----------

/// Execute one request with the replication hooks applied around it:
/// followers deny ordered ops with `NOT_LEADER` + a leader hint,
/// `promote` dispatches to the subsystem, and a leader's mutation acks
/// are gated on replication (semi-sync). With no hooks this is exactly
/// [`execute`].
fn execute_replicated(gus: &DynamicGus, rep: Option<&dyn Replication>, req: Request) -> Response {
    let Some(rep) = rep else { return execute(gus, req) };
    // Ordered ops (mutations + checkpoint) only run on the leader. The
    // `leader=<addr>` marker is a stable format routers parse.
    if req.is_ordered() {
        if let Some(hint) = rep.deny_mutations() {
            gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
            return Response::error(
                ErrorCode::NotLeader,
                format!("not leader; leader={hint}"),
            );
        }
    }
    if matches!(req, Request::Promote) {
        return match rep.promote() {
            Ok(seq) => Response::Checkpoint { seq },
            Err(e) => {
                gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                Response::error(ErrorCode::Unavailable, format!("promote failed: {e}"))
            }
        };
    }
    let gate = req.is_mutation();
    let resp = execute(gus, req);
    if gate && !resp.is_error() {
        // The mutation is applied and logged locally; hold its ack until
        // enough followers have it durably. On timeout the client gets
        // UNAVAILABLE and must treat the mutation as unacknowledged
        // (it may still survive — at-least-once, like any retried RPC).
        if let Err(msg) = rep.ack_gate(gus.wal_seq()) {
            // The implementation counts the timeout (it knows which
            // subscribers lagged); we only classify the client error.
            gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
            return Response::error(ErrorCode::Unavailable, msg);
        }
    }
    resp
}

/// Execute one decoded request against the service. Every failure is a
/// structured [`Response::Error`]; the `errors` counter advances once
/// per failure.
pub fn execute(gus: &DynamicGus, req: Request) -> Response {
    let resp = match execute_inner(gus, req) {
        Ok(resp) => resp,
        Err(e) => {
            let msg = format!("{e}");
            Response::error(classify_error(&msg), msg)
        }
    };
    if resp.is_error() {
        gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
    }
    resp
}

fn execute_inner(gus: &DynamicGus, req: Request) -> Result<Response> {
    let default_k = gus.config().scann_nn;
    match req {
        Request::Insert { point } => {
            Ok(Response::Existed { existed: gus.insert(point)? })
        }
        Request::Delete { id } => Ok(Response::Existed { existed: gus.delete(id)? }),
        Request::Query { point, k } => Ok(Response::Neighbors {
            neighbors: gus.query(&point, k.unwrap_or(default_k))?,
            degraded: None,
        }),
        Request::QueryId { id, k } => Ok(Response::Neighbors {
            neighbors: gus.query_by_id(id, k.unwrap_or(default_k))?,
            degraded: None,
        }),
        Request::InsertBatch { points } => {
            Ok(Response::ExistedBatch { existed: gus.insert_batch(points)? })
        }
        Request::DeleteBatch { ids } => {
            Ok(Response::ExistedBatch { existed: gus.delete_batch(&ids)? })
        }
        Request::QueryBatch { points, k } => Ok(Response::Results {
            results: gus.query_batch(&points, k.unwrap_or(default_k))?,
            degraded: None,
        }),
        // Checkpoint failures are the server's state/fault (no WAL
        // attached, disk full, I/O error) — always UNAVAILABLE, never
        // left to message-based classification.
        Request::Checkpoint => Ok(match gus.checkpoint() {
            Ok(seq) => Response::Checkpoint { seq },
            Err(e) => Response::error(ErrorCode::Unavailable, format!("{e}")),
        }),
        Request::Stats => Ok(Response::Stats { stats: gus.stats_json() }),
        Request::RefreshTables => {
            anyhow::bail!("'refresh_tables' is WAL-internal, not a wire op")
        }
        // Replication ops reaching plain dispatch mean the node has no
        // replication hooks installed (structured refusal, client's
        // fault): on a served socket with hooks, `wal_subscribe` is
        // intercepted by the reader and `promote` by
        // [`execute_replicated`] before this point.
        Request::WalSubscribe { .. } => Ok(Response::error(
            ErrorCode::BadRequest,
            "replication not enabled on this server (serve with --replicate)",
        )),
        Request::Promote => Ok(Response::error(
            ErrorCode::BadRequest,
            "replication not enabled on this server (serve with --replicate)",
        )),
    }
}

/// Map a coordinator error message onto a protocol error code. The
/// vendored `anyhow` has no downcasting, so classification keys on the
/// stable message markers; everything else — schema violations,
/// malformed fields — is the caller's fault. "injected fault" is the
/// marker [`crate::fault::injector::injected_error`] plants: an injected
/// disk fault is server-side trouble, not a bad request, so clients see
/// `UNAVAILABLE` exactly as they would for the real failure.
fn classify_error(msg: &str) -> ErrorCode {
    if msg.contains("unknown point") {
        ErrorCode::NotFound
    } else if msg.contains("WAL") || msg.contains("injected fault") {
        ErrorCode::Unavailable
    } else {
        ErrorCode::BadRequest
    }
}

/// Decode one request line in either dialect, execute it, and encode the
/// response in the matching dialect. This is the serial reference path
/// (unit tests, tools); the served path adds scheduling around the same
/// `decode → execute → encode` pipeline.
pub fn dispatch(gus: &DynamicGus, line: &str) -> Json {
    match decode_request(line) {
        Err(e) => {
            gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
            Response::error(e.error.code, e.error.message).to_wire(e.id)
        }
        Ok(Incoming::Legacy(request)) => execute(gus, request).to_wire(None),
        Ok(Incoming::V1(envelope)) => {
            let expired = envelope.deadline_ms == Some(0);
            let resp = if expired {
                gus.metrics.counters.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                gus.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                Response::error(ErrorCode::DeadlineExceeded, "deadline of 0ms expired")
            } else {
                execute(gus, envelope.request)
            };
            resp.to_wire(Some(envelope.id))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GusConfig, ScorerKind};
    use crate::data::synthetic::SyntheticConfig;

    fn boot() -> (Arc<DynamicGus>, crate::data::Dataset) {
        let ds = SyntheticConfig::arxiv_like(150, 31).generate();
        let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 2).unwrap();
        (Arc::new(gus), ds)
    }

    #[test]
    fn dispatch_query_and_mutations() {
        let (gus, ds) = boot();
        // Query by id.
        let resp = dispatch(&gus, &format!(r#"{{"op":"query_id","id":{},"k":5}}"#, 3));
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        assert!(!resp.get("neighbors").as_arr().unwrap().is_empty());
        // Insert a new point via JSON.
        let mut p = ds.points[0].clone();
        p.id = 50_000;
        let req = Json::obj(vec![("op", Json::str("insert")), ("point", p.to_json())]);
        let resp = dispatch(&gus, &req.dump());
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert_eq!(resp.get("existed").as_bool(), Some(false));
        // Delete it.
        let resp = dispatch(&gus, r#"{"op":"delete","id":50000}"#);
        assert_eq!(resp.get("existed").as_bool(), Some(true));
        // Stats.
        let resp = dispatch(&gus, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("stats").get("points").as_usize(), Some(150));
        // Legacy responses never carry the v1 header.
        assert!(resp.get("v").is_null());
        assert!(resp.get("id").is_null());
    }

    #[test]
    fn dispatch_v1_envelope_echoes_id() {
        let (gus, _ds) = boot();
        let resp = dispatch(&gus, r#"{"v":1,"id":7,"req":{"op":"query_id","id":3,"k":5}}"#);
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get("v").as_u64(), Some(1));
        assert_eq!(resp.get("id").as_u64(), Some(7));
        assert!(!resp.get("neighbors").as_arr().unwrap().is_empty());
        // Errors echo the id too, with a machine-readable code.
        let resp = dispatch(&gus, r#"{"v":1,"id":8,"req":{"op":"query_id","id":987654321}}"#);
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert_eq!(resp.get("id").as_u64(), Some(8));
        assert_eq!(resp.get("code").as_str(), Some("NOT_FOUND"));
    }

    #[test]
    fn dispatch_batch_ops() {
        let (gus, ds) = boot();
        // Insert a batch of fresh points.
        let mut pts = Vec::new();
        for (i, p) in ds.points.iter().take(5).enumerate() {
            let mut p = p.clone();
            p.id = 60_000 + i as u64;
            pts.push(p.to_json());
        }
        let req = Json::obj(vec![
            ("op", Json::str("insert_batch")),
            ("points", Json::Arr(pts)),
        ]);
        let resp = dispatch(&gus, &req.dump());
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        let existed = resp.get("existed").as_arr().unwrap();
        assert_eq!(existed.len(), 5);
        assert!(existed.iter().all(|j| j.as_bool() == Some(false)));
        assert_eq!(gus.len(), 155);

        // Batch query: one result list per input point, matching singles.
        let req = Json::obj(vec![
            ("op", Json::str("query_batch")),
            ("k", Json::num(5.0)),
            (
                "points",
                Json::Arr(ds.points.iter().take(3).map(|p| p.to_json()).collect()),
            ),
        ]);
        let resp = dispatch(&gus, &req.dump());
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        let results = resp.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 3);
        for (i, r) in results.iter().enumerate() {
            let single = gus.query(&ds.points[i], 5).unwrap();
            let got: Vec<u64> =
                r.as_arr().unwrap().iter().map(|n| n.get("id").as_u64().unwrap()).collect();
            let want: Vec<u64> = single.iter().map(|n| n.id).collect();
            assert_eq!(got, want, "batch result {i} diverged");
        }

        // Batch delete removes the freshly inserted points.
        let resp = dispatch(
            &gus,
            r#"{"op":"delete_batch","ids":[60000,60001,60002,60003,60004,61111]}"#,
        );
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        let existed: Vec<bool> = resp
            .get("existed")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_bool().unwrap())
            .collect();
        assert_eq!(existed, vec![true, true, true, true, true, false]);
        assert_eq!(gus.len(), 150);

        // Malformed batches are structured errors.
        for bad in [
            r#"{"op":"insert_batch"}"#,
            r#"{"op":"insert_batch","points":[{"id":1}]}"#,
            r#"{"op":"query_batch","points":42}"#,
            r#"{"op":"delete_batch"}"#,
            r#"{"op":"delete_batch","ids":[true]}"#,
        ] {
            let resp = dispatch(&gus, bad);
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{bad}");
            assert_eq!(resp.get("code").as_str(), Some("BAD_REQUEST"), "{bad}");
        }
    }

    #[test]
    fn dispatch_checkpoint() {
        // Without a WAL, checkpoint is a structured error.
        let (gus, ds) = boot();
        let resp = dispatch(&gus, r#"{"op":"checkpoint"}"#);
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert!(resp.get("error").as_str().unwrap().contains("WAL"));
        assert_eq!(resp.get("code").as_str(), Some("UNAVAILABLE"));

        // With one, it reports the sequence number it covers.
        let dir = std::env::temp_dir().join("gus-server-tests").join("checkpoint");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = GusConfig {
            scorer: ScorerKind::Native,
            fsync: crate::config::FsyncPolicy::Never,
            ..GusConfig::default()
        };
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points[..50], 2).unwrap();
        crate::coordinator::wal::init_fresh(&gus, &dir).unwrap();
        gus.insert(ds.points[60].clone()).unwrap();
        gus.insert(ds.points[61].clone()).unwrap();
        let resp = dispatch(&gus, r#"{"op":"checkpoint"}"#);
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get("seq").as_u64(), Some(2));
        // The stats RPC reports the durability state.
        let resp = dispatch(&gus, r#"{"op":"stats"}"#);
        let wal = resp.get("stats").get("wal");
        assert_eq!(wal.get("seq").as_u64(), Some(2));
        assert_eq!(wal.get("pending").as_u64(), Some(0));
    }

    #[test]
    fn dispatch_errors_are_structured() {
        let (gus, _) = boot();
        for bad in [
            "not json",
            r#"{"no_op":1}"#,
            r#"{"op":"unknown"}"#,
            r#"{"op":"delete"}"#,
            r#"{"op":"query_id","id":987654321}"#,
        ] {
            let resp = dispatch(&gus, bad);
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{bad}");
            assert!(resp.get("error").as_str().is_some());
            assert!(resp.get("code").as_str().is_some(), "{bad}");
        }
        assert!(gus.metrics.counters.errors.load(Ordering::Relaxed) >= 5);
    }

    #[test]
    fn dispatch_k_bounds_are_rejected() {
        let (gus, _) = boot();
        for bad in [
            r#"{"op":"query_id","id":3,"k":0}"#,
            r#"{"op":"query_id","id":3,"k":100000000}"#,
            r#"{"v":1,"id":2,"req":{"op":"query_id","id":3,"k":0}}"#,
        ] {
            let resp = dispatch(&gus, bad);
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{bad}");
            assert_eq!(resp.get("code").as_str(), Some("BAD_REQUEST"), "{bad}");
        }
        // The index was never touched: no queries counted.
        assert_eq!(gus.metrics.counters.queries.load(Ordering::Relaxed), 0);
        // refresh_tables is WAL-internal, not a wire op.
        let resp = dispatch(&gus, r#"{"op":"refresh_tables"}"#);
        assert_eq!(resp.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn replication_ops_without_hooks_are_refused() {
        let (gus, _) = boot();
        for bad in [r#"{"op":"promote"}"#, r#"{"op":"wal_subscribe","from_seq":0}"#] {
            let resp = dispatch(&gus, bad);
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{bad}");
            assert_eq!(resp.get("code").as_str(), Some("BAD_REQUEST"), "{bad}");
            assert!(resp.get("error").as_str().unwrap().contains("--replicate"));
        }
    }

    #[test]
    fn follower_hooks_deny_ordered_ops_with_leader_hint() {
        struct Deny;
        impl Replication for Deny {
            fn deny_mutations(&self) -> Option<String> {
                Some("10.0.0.1:4242".into())
            }
            fn ack_gate(&self, _seq: u64) -> std::result::Result<(), String> {
                Ok(())
            }
            fn promote(&self) -> Result<u64> {
                Ok(7)
            }
            fn subscribe(
                &self,
                _from_seq: u64,
                _id: Option<u64>,
                _reader: BufReader<TcpStream>,
                _stream: TcpStream,
            ) -> Result<()> {
                Ok(())
            }
        }
        let (gus, ds) = boot();
        let rep = Deny;
        // Mutations and checkpoint bounce with the parseable leader hint.
        let mut p = ds.points[0].clone();
        p.id = 90_000;
        for req in [Request::Insert { point: p }, Request::Delete { id: 3 }, Request::Checkpoint] {
            let resp = execute_replicated(&gus, Some(&rep), req);
            match resp {
                Response::Error { code, message, .. } => {
                    assert_eq!(code, ErrorCode::NotLeader);
                    assert!(message.contains("leader=10.0.0.1:4242"), "{message}");
                }
                other => panic!("expected NOT_LEADER, got {other:?}"),
            }
        }
        assert!(!gus.contains(90_000), "denied mutation touched the index");
        // Reads are still served locally.
        let resp =
            execute_replicated(&gus, Some(&rep), Request::QueryId { id: ds.points[1].id, k: Some(5) });
        assert!(!resp.is_error(), "{resp:?}");
        let resp = execute_replicated(&gus, Some(&rep), Request::Stats);
        assert!(!resp.is_error());
        // Promote dispatches to the hooks and answers in checkpoint shape.
        match execute_replicated(&gus, Some(&rep), Request::Promote) {
            Response::Checkpoint { seq } => assert_eq!(seq, 7),
            other => panic!("expected checkpoint shape, got {other:?}"),
        }
    }

    #[test]
    fn leader_ack_gate_failure_turns_ack_into_unavailable() {
        struct SlowReplicas(Arc<DynamicGus>);
        impl Replication for SlowReplicas {
            fn deny_mutations(&self) -> Option<String> {
                None
            }
            fn ack_gate(&self, seq: u64) -> std::result::Result<(), String> {
                // Real implementations count their own timeouts (they know
                // which subscribers lagged); the mock mirrors that contract.
                self.0.metrics.replication.note_ack_timeout(&[]);
                Err(format!("replication ack timeout at seq {seq}"))
            }
            fn promote(&self) -> Result<u64> {
                Ok(0)
            }
            fn subscribe(
                &self,
                _from_seq: u64,
                _id: Option<u64>,
                _reader: BufReader<TcpStream>,
                _stream: TcpStream,
            ) -> Result<()> {
                Ok(())
            }
        }
        let (gus, ds) = boot();
        let rep = SlowReplicas(Arc::clone(&gus));
        let mut p = ds.points[0].clone();
        p.id = 91_000;
        let resp = execute_replicated(&gus, Some(&rep), Request::Insert { point: p });
        match resp {
            Response::Error { code, message, .. } => {
                assert_eq!(code, ErrorCode::Unavailable);
                assert!(message.contains("ack timeout"), "{message}");
            }
            other => panic!("expected UNAVAILABLE, got {other:?}"),
        }
        // The mutation applied locally (at-least-once semantics) but the
        // client was told it is unacknowledged; the gauge counted it.
        assert!(gus.contains(91_000));
        let j = gus.stats_json();
        assert_eq!(j.get("replication").get("ack_timeouts").as_u64(), Some(1));
        // Queries are not gated.
        let resp = execute_replicated(&gus, Some(&rep), Request::QueryId { id: 91_000, k: Some(3) });
        assert!(!resp.is_error());
    }

    #[test]
    fn dispatch_expired_deadline_skips_execution() {
        let (gus, ds) = boot();
        let mut p = ds.points[0].clone();
        p.id = 70_000;
        let req = Envelope {
            id: 5,
            deadline_ms: Some(0),
            class: None,
            request: Request::Insert { point: p },
        };
        let resp = dispatch(&gus, &req.to_wire().dump());
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert_eq!(resp.get("code").as_str(), Some("DEADLINE_EXCEEDED"));
        assert_eq!(resp.get("id").as_u64(), Some(5));
        assert_eq!(gus.len(), 150, "expired mutation touched the index");
        assert_eq!(gus.metrics.counters.deadline_exceeded.load(Ordering::Relaxed), 1);
    }
}
