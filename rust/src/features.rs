//! Point features and dataset schemas.
//!
//! Dynamic GUS operates on *multimodal* points: each point carries several
//! features of different kinds (the paper's motivating examples are video
//! visual/audio/text signals; its experiments use dense embeddings plus a
//! publication year for ogbn-arxiv and a co-purchase set for ogbn-products).
//!
//! A [`Schema`] declares, per dataset, the ordered list of feature channels
//! and how each is bucketed by LSH and featurized for the pairwise model;
//! [`Point`] is a concrete point.

use crate::util::json::Json;

/// One feature value. The three kinds cover the paper's datasets:
/// - `Dense`: a fixed-dimension real embedding (arxiv title/abstract
///   embedding, products bag-of-words PCA),
/// - `Tokens`: a set of discrete token ids (products co-purchase list),
/// - `Scalar`: a single real value (arxiv publication year).
#[derive(Debug, Clone, PartialEq)]
pub enum FeatureValue {
    Dense(Vec<f32>),
    Tokens(Vec<u64>),
    Scalar(f32),
}

impl FeatureValue {
    pub fn kind(&self) -> FeatureKind {
        match self {
            FeatureValue::Dense(_) => FeatureKind::Dense,
            FeatureValue::Tokens(_) => FeatureKind::Tokens,
            FeatureValue::Scalar(_) => FeatureKind::Scalar,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            FeatureValue::Dense(v) => {
                Json::obj(vec![("dense", Json::f32_arr(v))])
            }
            FeatureValue::Tokens(t) => {
                Json::obj(vec![("tokens", Json::u64_arr(t))])
            }
            FeatureValue::Scalar(x) => Json::obj(vec![("scalar", Json::num(*x as f64))]),
        }
    }

    pub fn from_json(j: &Json) -> Option<FeatureValue> {
        if let Some(v) = j.get("dense").to_f32_vec() {
            if !j.get("dense").is_null() {
                return Some(FeatureValue::Dense(v));
            }
        }
        if !j.get("tokens").is_null() {
            return Some(FeatureValue::Tokens(j.get("tokens").to_u64_vec()?));
        }
        if let Some(x) = j.get("scalar").as_f32() {
            return Some(FeatureValue::Scalar(x));
        }
        None
    }
}

/// Feature kind tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureKind {
    Dense,
    Tokens,
    Scalar,
}

/// External point identifier (user-facing, stable). Internally the index
/// assigns compact slots; the coordinator maps between the two.
pub type PointId = u64;

/// A point: id + one value per schema channel.
#[derive(Debug, Clone, PartialEq)]
pub struct Point {
    pub id: PointId,
    pub features: Vec<FeatureValue>,
}

impl Point {
    pub fn new(id: PointId, features: Vec<FeatureValue>) -> Point {
        Point { id, features }
    }

    /// The dense feature at channel `ch` (panics on kind mismatch —
    /// schema validation happens at ingest).
    pub fn dense(&self, ch: usize) -> &[f32] {
        match &self.features[ch] {
            FeatureValue::Dense(v) => v,
            other => panic!("channel {ch} is not dense: {:?}", other.kind()),
        }
    }

    pub fn tokens(&self, ch: usize) -> &[u64] {
        match &self.features[ch] {
            FeatureValue::Tokens(t) => t,
            other => panic!("channel {ch} is not tokens: {:?}", other.kind()),
        }
    }

    pub fn scalar(&self, ch: usize) -> f32 {
        match &self.features[ch] {
            FeatureValue::Scalar(x) => *x,
            other => panic!("channel {ch} is not scalar: {:?}", other.kind()),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            (
                "features",
                Json::Arr(self.features.iter().map(|f| f.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Point> {
        let id = j.get("id").as_u64()?;
        let features = j
            .get("features")
            .as_arr()?
            .iter()
            .map(FeatureValue::from_json)
            .collect::<Option<Vec<_>>>()?;
        Some(Point { id, features })
    }
}

/// Per-channel schema entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelSchema {
    pub name: String,
    pub kind: FeatureKind,
    /// Dimension for dense channels (validation + featurizer sizing).
    pub dim: usize,
}

/// Dataset schema: the ordered channels every point must carry.
#[derive(Debug, Clone, PartialEq)]
pub struct Schema {
    pub name: String,
    pub channels: Vec<ChannelSchema>,
}

/// Schema validation failure.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SchemaError {
    #[error("point {id}: expected {expected} channels, got {got}")]
    ChannelCount { id: PointId, expected: usize, got: usize },
    #[error("point {id} channel {channel} ({name}): expected {expected:?}, got {got:?}")]
    KindMismatch {
        id: PointId,
        channel: usize,
        name: String,
        expected: FeatureKind,
        got: FeatureKind,
    },
    #[error("point {id} channel {channel} ({name}): expected dim {expected}, got {got}")]
    DimMismatch {
        id: PointId,
        channel: usize,
        name: String,
        expected: usize,
        got: usize,
    },
    #[error("point {id} channel {channel} ({name}): non-finite value")]
    NonFinite { id: PointId, channel: usize, name: String },
}

impl Schema {
    /// The `ogbn-arxiv`-shaped schema: 128-d dense embedding + year scalar.
    pub fn arxiv_like(dim: usize) -> Schema {
        Schema {
            name: "arxiv_like".to_string(),
            channels: vec![
                ChannelSchema {
                    name: "embedding".to_string(),
                    kind: FeatureKind::Dense,
                    dim,
                },
                ChannelSchema {
                    name: "year".to_string(),
                    kind: FeatureKind::Scalar,
                    dim: 1,
                },
            ],
        }
    }

    /// The `ogbn-products`-shaped schema: 100-d dense embedding +
    /// co-purchase token set.
    pub fn products_like(dim: usize) -> Schema {
        Schema {
            name: "products_like".to_string(),
            channels: vec![
                ChannelSchema {
                    name: "embedding".to_string(),
                    kind: FeatureKind::Dense,
                    dim,
                },
                ChannelSchema {
                    name: "copurchase".to_string(),
                    kind: FeatureKind::Tokens,
                    dim: 0,
                },
            ],
        }
    }

    /// Index of the first dense channel (the scorer kernel's `q`/`C` input).
    pub fn primary_dense_channel(&self) -> Option<usize> {
        self.channels.iter().position(|c| c.kind == FeatureKind::Dense)
    }

    /// Dense dimension of the primary dense channel (0 if none).
    pub fn primary_dense_dim(&self) -> usize {
        self.primary_dense_channel()
            .map(|i| self.channels[i].dim)
            .unwrap_or(0)
    }

    /// Validate a point against this schema.
    pub fn validate(&self, p: &Point) -> Result<(), SchemaError> {
        if p.features.len() != self.channels.len() {
            return Err(SchemaError::ChannelCount {
                id: p.id,
                expected: self.channels.len(),
                got: p.features.len(),
            });
        }
        for (i, (f, c)) in p.features.iter().zip(&self.channels).enumerate() {
            if f.kind() != c.kind {
                return Err(SchemaError::KindMismatch {
                    id: p.id,
                    channel: i,
                    name: c.name.clone(),
                    expected: c.kind,
                    got: f.kind(),
                });
            }
            match f {
                FeatureValue::Dense(v) => {
                    if v.len() != c.dim {
                        return Err(SchemaError::DimMismatch {
                            id: p.id,
                            channel: i,
                            name: c.name.clone(),
                            expected: c.dim,
                            got: v.len(),
                        });
                    }
                    if v.iter().any(|x| !x.is_finite()) {
                        return Err(SchemaError::NonFinite {
                            id: p.id,
                            channel: i,
                            name: c.name.clone(),
                        });
                    }
                }
                FeatureValue::Scalar(x) => {
                    if !x.is_finite() {
                        return Err(SchemaError::NonFinite {
                            id: p.id,
                            channel: i,
                            name: c.name.clone(),
                        });
                    }
                }
                FeatureValue::Tokens(_) => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arxiv_point(id: u64, dim: usize) -> Point {
        Point::new(
            id,
            vec![
                FeatureValue::Dense(vec![0.5; dim]),
                FeatureValue::Scalar(2020.0),
            ],
        )
    }

    #[test]
    fn validate_ok() {
        let s = Schema::arxiv_like(8);
        s.validate(&arxiv_point(1, 8)).unwrap();
    }

    #[test]
    fn validate_channel_count() {
        let s = Schema::arxiv_like(8);
        let p = Point::new(1, vec![FeatureValue::Scalar(1.0)]);
        assert!(matches!(
            s.validate(&p),
            Err(SchemaError::ChannelCount { expected: 2, got: 1, .. })
        ));
    }

    #[test]
    fn validate_kind_mismatch() {
        let s = Schema::arxiv_like(8);
        let p = Point::new(
            1,
            vec![FeatureValue::Tokens(vec![1]), FeatureValue::Scalar(1.0)],
        );
        assert!(matches!(s.validate(&p), Err(SchemaError::KindMismatch { .. })));
    }

    #[test]
    fn validate_dim_mismatch() {
        let s = Schema::arxiv_like(8);
        assert!(matches!(
            s.validate(&arxiv_point(1, 7)),
            Err(SchemaError::DimMismatch { expected: 8, got: 7, .. })
        ));
    }

    #[test]
    fn validate_non_finite() {
        let s = Schema::arxiv_like(2);
        let p = Point::new(
            1,
            vec![
                FeatureValue::Dense(vec![1.0, f32::NAN]),
                FeatureValue::Scalar(2020.0),
            ],
        );
        assert!(matches!(s.validate(&p), Err(SchemaError::NonFinite { .. })));
    }

    #[test]
    fn json_roundtrip_point() {
        let p = Point::new(
            7,
            vec![
                FeatureValue::Dense(vec![1.0, -2.5, 0.0]),
                FeatureValue::Tokens(vec![3, 5, 8]),
                FeatureValue::Scalar(2021.0),
            ],
        );
        let j = p.to_json().dump();
        let p2 = Point::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn accessors() {
        let p = Point::new(
            1,
            vec![
                FeatureValue::Dense(vec![1.0, 2.0]),
                FeatureValue::Tokens(vec![9]),
                FeatureValue::Scalar(3.0),
            ],
        );
        assert_eq!(p.dense(0), &[1.0, 2.0]);
        assert_eq!(p.tokens(1), &[9]);
        assert_eq!(p.scalar(2), 3.0);
    }

    #[test]
    #[should_panic]
    fn accessor_panics_on_wrong_kind() {
        let p = Point::new(1, vec![FeatureValue::Scalar(3.0)]);
        let _ = p.dense(0);
    }

    #[test]
    fn schemas_have_primary_dense() {
        assert_eq!(Schema::arxiv_like(128).primary_dense_dim(), 128);
        assert_eq!(Schema::products_like(100).primary_dense_dim(), 100);
        assert_eq!(Schema::arxiv_like(128).primary_dense_channel(), Some(0));
    }
}
