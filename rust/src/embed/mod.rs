//! Sparse embedding generation (§4.1–4.2 of the paper).
//!
//! The embedding `M(p)` has one non-zero dimension per bucket ID of `p`.
//! Base weights are 1.0; two optional refinements improve quality:
//!
//! - **Filtering** ([`filter::PopularFilter`]): the `Filter-P`% of buckets
//!   with the highest cardinality are ignored entirely — overly popular
//!   buckets (the "the"/"a" analogue) are not a reliable similarity signal
//!   and blow up candidate sets.
//! - **Inverse Document Frequency** ([`idf::IdfTable`]): dimension `b` gets
//!   weight `log(|P| / N(b))`; the table is bounded to the `IDF-S` buckets
//!   with the highest IDF, all other buckets defaulting to the `IDF-S`-th
//!   highest weight (paper §5, "Second experiment").
//!
//! Both are computed offline from an initial corpus ([`stats::BucketStats`])
//! and refreshed periodically (§4.3) — never on the request path.

pub mod filter;
pub mod idf;
pub mod stats;

use crate::features::Point;
use crate::lsh::Bucketer;
use crate::sparse::SparseVec;

pub use filter::PopularFilter;
pub use idf::IdfTable;
pub use stats::BucketStats;

/// The Embedding Generator (§3.2): buckets → filtered, weighted sparse vec.
///
/// Latency-critical: operates on purely local information plus two
/// precomputed in-memory tables.
pub struct EmbeddingGenerator {
    bucketer: Bucketer,
    idf: Option<IdfTable>,
    filter: Option<PopularFilter>,
}

impl EmbeddingGenerator {
    pub fn new(
        bucketer: Bucketer,
        idf: Option<IdfTable>,
        filter: Option<PopularFilter>,
    ) -> EmbeddingGenerator {
        EmbeddingGenerator { bucketer, idf, filter }
    }

    /// Plain generator: weights 1.0, no filtering (the baseline of §4.1).
    pub fn plain(bucketer: Bucketer) -> EmbeddingGenerator {
        EmbeddingGenerator::new(bucketer, None, None)
    }

    pub fn bucketer(&self) -> &Bucketer {
        &self.bucketer
    }

    pub fn idf(&self) -> Option<&IdfTable> {
        self.idf.as_ref()
    }

    pub fn filter(&self) -> Option<&PopularFilter> {
        self.filter.as_ref()
    }

    /// Swap in freshly recomputed tables (periodic reload, §4.3).
    pub fn reload(&mut self, idf: Option<IdfTable>, filter: Option<PopularFilter>) {
        self.idf = idf;
        self.filter = filter;
    }

    /// Compute the sparse embedding of a point.
    ///
    /// Every retained bucket ID becomes a dimension with strictly positive
    /// weight, so Lemma 4.1 (`Dist < 0 ⇔ shared bucket`) holds with or
    /// without IDF/filtering — see `sparse::tests::prop_lemma41_core`.
    pub fn embed(&self, p: &Point) -> SparseVec {
        let mut buckets = Vec::with_capacity(32);
        self.bucketer.buckets_into(p, &mut buckets);
        self.embed_buckets(&buckets)
    }

    /// Embedding from precomputed bucket IDs (sorted, deduplicated).
    pub fn embed_buckets(&self, buckets: &[u64]) -> SparseVec {
        let mut pairs = Vec::with_capacity(buckets.len());
        for &b in buckets {
            if let Some(f) = &self.filter {
                if f.is_banned(b) {
                    continue;
                }
            }
            let w = match &self.idf {
                Some(t) => t.weight(b),
                None => 1.0,
            };
            pairs.push((b, w));
        }
        SparseVec::from_pairs(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureValue, Point, Schema};
    use crate::util::rng::Rng;

    fn generator_with(
        idf: Option<IdfTable>,
        filter: Option<PopularFilter>,
    ) -> EmbeddingGenerator {
        let schema = Schema::arxiv_like(8);
        let bucketer = Bucketer::with_defaults(&schema, 3);
        EmbeddingGenerator::new(bucketer, idf, filter)
    }

    fn pt(rng: &mut Rng) -> Point {
        Point::new(
            rng.below(1 << 30),
            vec![
                FeatureValue::Dense(rng.normal_vec_f32(8)),
                FeatureValue::Scalar(2000.0 + rng.below(20) as f32),
            ],
        )
    }

    #[test]
    fn plain_embedding_has_unit_weights() {
        let g = generator_with(None, None);
        let mut rng = Rng::seeded(1);
        let p = pt(&mut rng);
        let v = g.embed(&p);
        assert!(!v.is_empty());
        assert!(v.weights().iter().all(|&w| w == 1.0));
        // Dimensions are exactly the bucket IDs.
        assert_eq!(v.dims(), g.bucketer().buckets(&p).as_slice());
    }

    #[test]
    fn filter_removes_banned_dims() {
        let g0 = generator_with(None, None);
        let mut rng = Rng::seeded(2);
        let p = pt(&mut rng);
        let buckets = g0.bucketer().buckets(&p);
        let banned = vec![buckets[0], buckets[2]];
        let g = generator_with(None, Some(PopularFilter::from_banned(banned.clone())));
        let v = g.embed(&p);
        assert_eq!(v.nnz(), buckets.len() - 2);
        for b in banned {
            assert_eq!(v.get(b), 0.0);
        }
    }

    #[test]
    fn idf_weights_applied() {
        let g0 = generator_with(None, None);
        let mut rng = Rng::seeded(3);
        let p = pt(&mut rng);
        let buckets = g0.bucketer().buckets(&p);
        // Fake corpus stats: bucket[0] very common, others rare.
        let mut stats = BucketStats::new();
        for _ in 0..100 {
            stats.add_buckets(&[buckets[0]]);
        }
        stats.add_buckets(&buckets); // every bucket appears once more
        let idf = IdfTable::from_stats(&stats, usize::MAX);
        let g = generator_with(Some(idf), None);
        let v = g.embed(&p);
        let w_common = v.get(buckets[0]);
        let w_rare = v.get(buckets[1]);
        assert!(w_common < w_rare, "common bucket must get lower weight");
        assert!(v.weights().iter().all(|&w| w > 0.0), "weights stay positive");
    }

    #[test]
    fn lemma41_preserved_under_idf_and_filter() {
        // Shared-retained-bucket ⇔ negative distance, for any tables.
        let mut rng = Rng::seeded(4);
        let g0 = generator_with(None, None);
        let mut stats = BucketStats::new();
        let points: Vec<Point> = (0..40).map(|_| pt(&mut rng)).collect();
        for p in &points {
            stats.add_buckets(&g0.bucketer().buckets(p));
        }
        let idf = IdfTable::from_stats(&stats, 10);
        let filter = PopularFilter::from_stats(&stats, 10.0);
        let g = generator_with(Some(idf), Some(filter));
        for i in 0..points.len() {
            for j in (i + 1)..points.len() {
                let a = g.embed(&points[i]);
                let b = g.embed(&points[j]);
                let share = a.shared_dims(&b) > 0;
                assert_eq!(share, a.dist(&b) < 0.0);
            }
        }
    }

    #[test]
    fn reload_swaps_tables() {
        let mut g = generator_with(None, None);
        let mut rng = Rng::seeded(5);
        let p = pt(&mut rng);
        let before = g.embed(&p);
        let banned = vec![before.dims()[0]];
        g.reload(None, Some(PopularFilter::from_banned(banned)));
        let after = g.embed(&p);
        assert_eq!(after.nnz(), before.nnz() - 1);
    }
}
