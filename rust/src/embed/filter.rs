//! Overly-popular bucket filtering (§4.2).
//!
//! `Filter-P = x` bans the `x`% of distinct buckets with the highest
//! cardinality. Banned buckets contribute no embedding dimension at all —
//! they are dropped both at indexing and at query time, shrinking posting
//! lists and candidate sets (the paper observes this also improves latency
//! and memory, Figs. 9–10).

use super::stats::BucketStats;
use crate::util::hash::FxHashSet;
use crate::util::json::Json;

/// Set of banned (overly popular) bucket IDs.
#[derive(Debug, Clone, Default)]
pub struct PopularFilter {
    banned: FxHashSet<u64>,
}

impl PopularFilter {
    /// Ban the top `percent`% of distinct buckets by cardinality
    /// (deterministic tie-breaking via `BucketStats::by_count_desc`).
    pub fn from_stats(stats: &BucketStats, percent: f64) -> PopularFilter {
        assert!((0.0..=100.0).contains(&percent), "Filter-P out of range");
        let n_ban = ((stats.num_buckets() as f64) * percent / 100.0).floor() as usize;
        let banned = stats
            .by_count_desc()
            .into_iter()
            .take(n_ban)
            .map(|(b, _)| b)
            .collect();
        PopularFilter { banned }
    }

    /// Ban an explicit set (tests, manual configuration).
    pub fn from_banned(banned: Vec<u64>) -> PopularFilter {
        PopularFilter { banned: banned.into_iter().collect() }
    }

    #[inline]
    pub fn is_banned(&self, bucket: u64) -> bool {
        self.banned.contains(&bucket)
    }

    pub fn len(&self) -> usize {
        self.banned.len()
    }

    pub fn is_empty(&self) -> bool {
        self.banned.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut v: Vec<u64> = self.banned.iter().copied().collect();
        v.sort_unstable();
        Json::obj(vec![("banned", Json::u64_arr(&v))])
    }

    pub fn from_json(j: &Json) -> Option<PopularFilter> {
        Some(PopularFilter {
            banned: j.get("banned").to_u64_vec()?.into_iter().collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_stats() -> BucketStats {
        // 10 buckets; bucket i appears in 2^(10-i) points (bucket 0 hottest).
        let mut s = BucketStats::new();
        for i in 0..10u64 {
            for _ in 0..(1u64 << (10 - i)) {
                s.add_buckets(&[i]);
            }
        }
        s
    }

    #[test]
    fn bans_top_percent() {
        let s = skewed_stats();
        let f = PopularFilter::from_stats(&s, 20.0);
        assert_eq!(f.len(), 2);
        assert!(f.is_banned(0));
        assert!(f.is_banned(1));
        assert!(!f.is_banned(2));
        assert!(!f.is_banned(9));
    }

    #[test]
    fn zero_percent_bans_nothing() {
        let f = PopularFilter::from_stats(&skewed_stats(), 0.0);
        assert!(f.is_empty());
        assert!(!f.is_banned(0));
    }

    #[test]
    fn hundred_percent_bans_all() {
        let f = PopularFilter::from_stats(&skewed_stats(), 100.0);
        assert_eq!(f.len(), 10);
    }

    #[test]
    fn rounding_floors() {
        // 10 buckets, 15% → floor(1.5) = 1 banned.
        let f = PopularFilter::from_stats(&skewed_stats(), 15.0);
        assert_eq!(f.len(), 1);
        assert!(f.is_banned(0));
    }

    #[test]
    #[should_panic]
    fn out_of_range_percent_panics() {
        let _ = PopularFilter::from_stats(&skewed_stats(), 101.0);
    }

    #[test]
    fn json_roundtrip() {
        let f = PopularFilter::from_stats(&skewed_stats(), 30.0);
        let j = f.to_json().dump();
        let f2 = PopularFilter::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(f.len(), f2.len());
        for b in 0..10u64 {
            assert_eq!(f.is_banned(b), f2.is_banned(b));
        }
    }
}
