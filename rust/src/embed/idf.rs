//! Bounded Inverse Document Frequency table (§4.2).
//!
//! Weight of bucket `b`: `log(|P| / N(b))`. The paper bounds the table to
//! the `IDF-S` buckets with the **highest** IDF (the rarest buckets); every
//! other bucket defaults to the `IDF-S`-th highest retained weight, keeping
//! the table's memory footprint proportional to `IDF-S` regardless of how
//! many distinct buckets exist.

use super::stats::BucketStats;
use crate::util::hash::FxHashMap;
use crate::util::json::Json;

/// Bounded IDF table.
#[derive(Debug, Clone)]
pub struct IdfTable {
    weights: FxHashMap<u64, f32>,
    /// Weight for buckets not in the table (the IDF-S-th highest weight).
    default_weight: f32,
}

impl IdfTable {
    /// Build from corpus stats, keeping the `size` buckets with the highest
    /// IDF (ties broken deterministically by bucket id). `size = 0` is not
    /// meaningful here — the paper's `IDF-S = 0` means "IDF disabled", which
    /// callers express by passing `None` for the table.
    pub fn from_stats(stats: &BucketStats, size: usize) -> IdfTable {
        assert!(size > 0, "IDF-S=0 means IDF disabled: pass None instead");
        let total = stats.num_points().max(1) as f64;
        // Highest IDF = lowest count: ascending count order.
        let mut by_count: Vec<(u64, u64)> = stats.iter().collect();
        by_count.sort_unstable_by(|a, b| a.1.cmp(&b.1).then(a.0.cmp(&b.0)));
        by_count.truncate(size);
        let mut weights = FxHashMap::default();
        let mut min_weight = f32::INFINITY;
        for (b, c) in by_count {
            let w = (total / c.max(1) as f64).ln().max(0.0) as f32;
            // Keep weights strictly positive so Lemma 4.1 still holds: a
            // bucket carried by every point gets a tiny but non-zero weight.
            let w = w.max(MIN_POSITIVE_WEIGHT);
            min_weight = min_weight.min(w);
            weights.insert(b, w);
        }
        let default_weight = if weights.is_empty() {
            1.0
        } else {
            min_weight
        };
        IdfTable { weights, default_weight }
    }

    /// Weight for a bucket (default for out-of-table buckets).
    #[inline]
    pub fn weight(&self, bucket: u64) -> f32 {
        self.weights.get(&bucket).copied().unwrap_or(self.default_weight)
    }

    /// Number of stored entries.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    pub fn default_weight(&self) -> f32 {
        self.default_weight
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(u64, f32)> =
            self.weights.iter().map(|(&b, &w)| (b, w)).collect();
        pairs.sort_unstable_by_key(|p| p.0);
        Json::obj(vec![
            (
                "buckets",
                Json::u64_arr(&pairs.iter().map(|p| p.0).collect::<Vec<_>>()),
            ),
            (
                "weights",
                Json::f32_arr(&pairs.iter().map(|p| p.1).collect::<Vec<_>>()),
            ),
            ("default_weight", Json::num(self.default_weight as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<IdfTable> {
        let buckets = j.get("buckets").to_u64_vec()?;
        let ws = j.get("weights").to_f32_vec()?;
        if buckets.len() != ws.len() {
            return None;
        }
        let mut weights = FxHashMap::default();
        for (b, w) in buckets.into_iter().zip(ws) {
            weights.insert(b, w);
        }
        Some(IdfTable {
            weights,
            default_weight: j.get("default_weight").as_f32()?,
        })
    }
}

/// Floor for IDF weights: keeps every dimension strictly positive.
const MIN_POSITIVE_WEIGHT: f32 = 1e-4;

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_abc() -> BucketStats {
        // bucket 1: 4 points; bucket 2: 2 points; bucket 3: 1 point; |P|=4.
        let mut s = BucketStats::new();
        s.add_buckets(&[1, 2, 3]);
        s.add_buckets(&[1, 2]);
        s.add_buckets(&[1]);
        s.add_buckets(&[1]);
        s
    }

    #[test]
    fn weights_are_log_ratio() {
        let t = IdfTable::from_stats(&stats_abc(), 100);
        assert!((t.weight(3) - (4.0f32 / 1.0).ln()).abs() < 1e-6);
        assert!((t.weight(2) - (4.0f32 / 2.0).ln()).abs() < 1e-6);
        // Bucket in every point: floored at MIN_POSITIVE_WEIGHT, not 0.
        assert!(t.weight(1) > 0.0);
        assert!(t.weight(1) <= 1e-4 + 1e-9);
    }

    #[test]
    fn rarer_is_heavier() {
        let t = IdfTable::from_stats(&stats_abc(), 100);
        assert!(t.weight(3) > t.weight(2));
        assert!(t.weight(2) > t.weight(1));
    }

    #[test]
    fn bounded_size_keeps_highest_idf() {
        let t = IdfTable::from_stats(&stats_abc(), 2);
        assert_eq!(t.len(), 2);
        // Retained: buckets 3 (count 1) and 2 (count 2) — the rarest.
        assert!((t.weight(3) - 4.0f32.ln()).abs() < 1e-6);
        assert!((t.weight(2) - 2.0f32.ln()).abs() < 1e-6);
        // Out-of-table bucket 1 defaults to the 2nd-highest weight = ln 2.
        assert!((t.weight(1) - 2.0f32.ln()).abs() < 1e-6);
        assert_eq!(t.default_weight(), t.weight(2));
    }

    #[test]
    fn unseen_bucket_gets_default() {
        let t = IdfTable::from_stats(&stats_abc(), 2);
        assert_eq!(t.weight(999), t.default_weight());
    }

    #[test]
    #[should_panic]
    fn zero_size_panics() {
        let _ = IdfTable::from_stats(&stats_abc(), 0);
    }

    #[test]
    fn json_roundtrip() {
        let t = IdfTable::from_stats(&stats_abc(), 2);
        let j = t.to_json().dump();
        let t2 = IdfTable::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(t.len(), t2.len());
        for b in [1u64, 2, 3, 999] {
            assert!((t.weight(b) - t2.weight(b)).abs() < 1e-6);
        }
    }

    #[test]
    fn all_weights_strictly_positive() {
        let mut s = BucketStats::new();
        for _ in 0..1000 {
            s.add_buckets(&[42]);
        }
        let t = IdfTable::from_stats(&s, 10);
        assert!(t.weight(42) > 0.0, "Lemma 4.1 requires positive weights");
    }
}
