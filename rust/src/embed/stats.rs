//! Corpus bucket statistics: the shared substrate for IDF tables and
//! popular-bucket filters (§4.3 offline preprocessing).

use crate::util::hash::FxHashMap;
use crate::util::json::Json;

/// Bucket cardinalities over a corpus: `N(b)` = number of points carrying
/// bucket `b`, plus the corpus size `|P|`.
#[derive(Debug, Clone, Default)]
pub struct BucketStats {
    counts: FxHashMap<u64, u64>,
    num_points: u64,
}

impl BucketStats {
    pub fn new() -> BucketStats {
        BucketStats::default()
    }

    /// Record one point's (deduplicated) bucket IDs.
    pub fn add_buckets(&mut self, buckets: &[u64]) {
        self.num_points += 1;
        for &b in buckets {
            *self.counts.entry(b).or_insert(0) += 1;
        }
    }

    /// Merge another stats object (parallel preprocessing).
    pub fn merge(&mut self, other: &BucketStats) {
        self.num_points += other.num_points;
        for (&b, &c) in &other.counts {
            *self.counts.entry(b).or_insert(0) += c;
        }
    }

    /// Corpus size |P|.
    pub fn num_points(&self) -> u64 {
        self.num_points
    }

    /// Number of distinct buckets observed.
    pub fn num_buckets(&self) -> usize {
        self.counts.len()
    }

    /// N(b), or 0 if unseen.
    pub fn count(&self, bucket: u64) -> u64 {
        self.counts.get(&bucket).copied().unwrap_or(0)
    }

    /// Iterate `(bucket, count)`.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.counts.iter().map(|(&b, &c)| (b, c))
    }

    /// Buckets sorted by descending count (ties by bucket id, so the order —
    /// and hence Filter-P / IDF-S cutoffs — is deterministic).
    pub fn by_count_desc(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self.iter().collect();
        v.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v
    }

    pub fn to_json(&self) -> Json {
        let pairs = self.by_count_desc();
        Json::obj(vec![
            ("num_points", Json::num(self.num_points as f64)),
            (
                "buckets",
                Json::u64_arr(&pairs.iter().map(|p| p.0).collect::<Vec<_>>()),
            ),
            (
                "counts",
                Json::u64_arr(&pairs.iter().map(|p| p.1).collect::<Vec<_>>()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<BucketStats> {
        let num_points = j.get("num_points").as_u64()?;
        let buckets = j.get("buckets").to_u64_vec()?;
        let counts = j.get("counts").to_u64_vec()?;
        if buckets.len() != counts.len() {
            return None;
        }
        let mut map = FxHashMap::default();
        for (b, c) in buckets.into_iter().zip(counts) {
            map.insert(b, c);
        }
        Some(BucketStats { counts: map, num_points })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let mut s = BucketStats::new();
        s.add_buckets(&[1, 2, 3]);
        s.add_buckets(&[2, 3]);
        s.add_buckets(&[3]);
        assert_eq!(s.num_points(), 3);
        assert_eq!(s.count(1), 1);
        assert_eq!(s.count(2), 2);
        assert_eq!(s.count(3), 3);
        assert_eq!(s.count(99), 0);
        assert_eq!(s.num_buckets(), 3);
    }

    #[test]
    fn merge_adds() {
        let mut a = BucketStats::new();
        a.add_buckets(&[1, 2]);
        let mut b = BucketStats::new();
        b.add_buckets(&[2, 3]);
        a.merge(&b);
        assert_eq!(a.num_points(), 2);
        assert_eq!(a.count(2), 2);
        assert_eq!(a.count(3), 1);
    }

    #[test]
    fn by_count_desc_deterministic() {
        let mut s = BucketStats::new();
        s.add_buckets(&[5, 9]);
        s.add_buckets(&[5, 7]);
        let v = s.by_count_desc();
        assert_eq!(v[0], (5, 2));
        // Tie between 7 and 9 broken by bucket id ascending.
        assert_eq!(v[1], (7, 1));
        assert_eq!(v[2], (9, 1));
    }

    #[test]
    fn json_roundtrip() {
        let mut s = BucketStats::new();
        s.add_buckets(&[10, 20]);
        s.add_buckets(&[20]);
        let j = s.to_json().dump();
        let s2 = BucketStats::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(s2.num_points(), 2);
        assert_eq!(s2.count(20), 2);
        assert_eq!(s2.count(10), 1);
    }
}
