//! Write-ahead log: durable dynamic serving.
//!
//! The paper's industrial deployments treat restart as "bootstrap from the
//! previous incarnation's corpus" (§4.3). A plain periodic snapshot makes
//! that lossy — every mutation since the last snapshot dies with the
//! process. This module closes the gap: when [`crate::config::GusConfig`]
//! sets `wal_dir`, every **accepted** mutation (insert / delete, single and
//! batch, plus table refreshes) is appended to a length-prefixed,
//! checksummed log *before* it is applied, so a `kill -9` at any moment
//! loses nothing the service acknowledged, and restart cost is
//! O(checkpoint delta), not O(corpus).
//!
//! # On-disk layout (`wal_dir/`)
//!
//! ```text
//! wal_meta.json         schema + config — lets WAL-only recovery boot an
//!                       empty service before any checkpoint exists
//! wal.log               the live log (record framing below)
//! snapshot.json         latest checkpoint metadata (config, tables,
//!                       points_file, last_seq) — renamed into place
//!                       atomically; its presence commits a checkpoint
//! points-<seq>.jsonl    the checkpoint's corpus (referenced by meta)
//! ```
//!
//! # Record framing
//!
//! Each record is `[len: u32 LE][seq: u64 LE][check: u64 LE][payload]`.
//! `seq` increases by one per record and never resets (checkpoints truncate
//! the file but keep the counter). `check` is a stable 64-bit checksum over
//! `(seq, payload)` (see [`crate::util::hash`]). The payload is the same
//! JSON the RPC layer speaks (`{"op":"insert","point":{..}}`, …; see
//! `docs/PROTOCOL.md`), so a WAL is also a replayable op trace.
//!
//! A **torn tail** — a record cut short by a crash mid-append, or trailing
//! bytes whose checksum does not match — terminates the scan: everything
//! before it is replayed, the tail is truncated away, and appends resume
//! cleanly. A torn record was by construction never applied (log-before-
//! apply) *and* never acknowledged, so dropping it is correct. That
//! justification only holds at the *end* of the log: if valid records
//! follow the bad region (a bad sector mid-file, not a crash), recovery
//! refuses to truncate and fails loudly instead.
//!
//! # Checkpoints
//!
//! [`DynamicGus::checkpoint`] writes the corpus + tables as a snapshot
//! committed by an atomic rename, then truncates the log. The snapshot
//! records `last_seq`; recovery replays only records with `seq >
//! last_seq`, which makes the snapshot-then-truncate pair crash-safe at
//! every intermediate step. The [`Checkpointer`] runs this automatically
//! whenever `checkpoint_every` mutations have accumulated.
//!
//! # Consistency
//!
//! Mutations hold the WAL lock across log **and** apply, so a checkpoint
//! (which takes the same lock) always observes a store consistent with the
//! sequence number it records, and recovery replays exactly the acknowledged
//! suffix. Concurrent mutations to the *same* point id have no defined
//! order (they race in the live service too); recovery preserves the WAL
//! order.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::FsyncPolicy;
use crate::coordinator::{snapshot, DynamicGus};
use crate::fault::injector::{enact_crash, injected_error};
use crate::fault::{FaultInjector, FaultKind, FaultSite};
use crate::features::{Point, PointId};
use crate::util::hash::{hash_bytes, mix2};
use crate::util::json::Json;

/// Log file name inside the WAL directory.
pub const WAL_FILE: &str = "wal.log";
/// Bootstrap metadata file name inside the WAL directory.
pub const META_FILE: &str = "wal_meta.json";

/// Record header: `[len: u32][seq: u64][check: u64]`.
const HEADER_BYTES: usize = 4 + 8 + 8;
/// Sanity cap on a single record's payload (1 GiB) — anything larger is
/// treated as corruption rather than an allocation request.
const MAX_RECORD_BYTES: u32 = 1 << 30;

/// Stable checksum over a record's sequence number and payload.
#[inline]
fn record_check(seq: u64, payload: &[u8]) -> u64 {
    mix2(hash_bytes(payload), seq)
}

/// Encode one record frame (`[len][seq][check][payload]`). The single
/// framing encoder: the writer's appends and the replication stream both
/// go through here, so a shipped frame is byte-identical to the on-disk
/// record by construction.
pub fn encode_frame(seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut frame = Vec::with_capacity(HEADER_BYTES + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&seq.to_le_bytes());
    frame.extend_from_slice(&record_check(seq, payload).to_le_bytes());
    frame.extend_from_slice(payload);
    frame
}

// ---------- payload encoding ----------
//
// WAL payloads ARE the typed protocol's op objects (`crate::protocol`),
// byte-for-byte: the same `wire::*` encoders serve the RPC layer, so a
// WAL doubles as a replayable op trace and recovery decodes through the
// same `Request::from_wire` path as the server (see `apply_logged`).

pub(crate) fn insert_payload(p: &Point) -> Json {
    crate::protocol::wire::insert(p)
}

pub(crate) fn delete_payload(id: PointId) -> Json {
    crate::protocol::wire::delete(id)
}

pub(crate) fn insert_batch_payload(points: &[Point]) -> Json {
    crate::protocol::wire::insert_batch(points)
}

pub(crate) fn delete_batch_payload(ids: &[PointId]) -> Json {
    crate::protocol::wire::delete_batch(ids)
}

pub(crate) fn refresh_payload() -> Json {
    crate::protocol::wire::refresh_tables()
}

// ---------- tail signal (replication subscribers) ----------

/// What a log-tail observer can see of the writer's progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TailState {
    /// Sequence number of the most recently appended record.
    pub last_seq: u64,
    /// Sequence number of the last record *not* in the file: the file
    /// holds exactly `floor_seq + 1 ..= last_seq`. A subscriber asking to
    /// resume at `from_seq <= floor_seq` needs a snapshot bootstrap.
    pub floor_seq: u64,
    /// Bumped whenever the file is rewritten (checkpoint truncation), so
    /// tailing readers know to reopen and rescan.
    pub generation: u64,
}

/// Condvar-backed progress signal for WAL tailers (the replication
/// leader's subscription streams). The writer notifies on every append
/// and rewrite; tailers block in [`TailSignal::wait_change`].
pub struct TailSignal {
    state: Mutex<TailState>,
    cond: Condvar,
}

impl TailSignal {
    fn new(last_seq: u64, floor_seq: u64) -> TailSignal {
        TailSignal {
            state: Mutex::new(TailState { last_seq, floor_seq, generation: 0 }),
            cond: Condvar::new(),
        }
    }

    /// Current progress snapshot.
    pub fn snapshot(&self) -> TailState {
        *self.state.lock().unwrap()
    }

    fn note_append(&self, seq: u64) {
        let mut st = self.state.lock().unwrap();
        st.last_seq = seq;
        self.cond.notify_all();
    }

    fn note_rewrite(&self, floor_seq: u64, last_seq: u64) {
        let mut st = self.state.lock().unwrap();
        st.floor_seq = floor_seq;
        st.last_seq = last_seq;
        st.generation += 1;
        self.cond.notify_all();
    }

    /// Block until the state differs from `seen` (new append or rewrite)
    /// or `timeout` elapses; returns the latest state either way.
    pub fn wait_change(&self, seen: TailState, timeout: Duration) -> TailState {
        let guard = self.state.lock().unwrap();
        let (st, _timed_out) = self
            .cond
            .wait_timeout_while(guard, timeout, |st| *st == seen)
            .unwrap();
        *st
    }
}

// ---------- writer ----------

/// Appender over the log file. Owned by [`WalHandle`] behind a mutex; the
/// coordinator holds that mutex across log **and** apply (see module docs).
pub struct WalWriter {
    file: File,
    path: PathBuf,
    policy: FsyncPolicy,
    seq: u64,
    /// Progress signal shared with tailing readers (see [`TailSignal`]).
    signal: Arc<TailSignal>,
    /// Byte length of the valid log (the rollback point for a failed
    /// append — a partial frame followed by later valid records would
    /// read as unrecoverable mid-file corruption).
    offset: u64,
    appends_since_sync: usize,
    /// Set when a failed append could not be rolled back (the log may end
    /// in a partial frame) or an fsync failed (the kernel's dirty-page
    /// state is unknowable after a failed fsync — fsyncgate): further
    /// appends must be refused, loudly, until a restart re-scans the log.
    poisoned: bool,
    /// Fault injector captured once at open time (`None` = passthrough —
    /// the hot path pays one `Option` test). Tests hand a private
    /// injector to one writer via [`WalWriter::set_fault_injector`] so
    /// parallel `cargo test` processes never share firing state.
    faults: Option<Arc<FaultInjector>>,
}

impl WalWriter {
    /// Open (creating if absent) the log at `path` for appending.
    /// `start_seq` is the sequence number of the last record already
    /// durable anywhere (snapshot or log); new records continue from it.
    /// The tail floor is assumed equal to `start_seq` (empty/truncated
    /// file); use [`WalWriter::open_with_floor`] when reopening a log
    /// that still holds records.
    pub fn open(path: &Path, policy: FsyncPolicy, start_seq: u64) -> Result<WalWriter> {
        Self::open_with_floor(path, policy, start_seq, start_seq)
    }

    /// [`WalWriter::open`] with an explicit tail floor: the file holds
    /// records `floor_seq + 1 ..= start_seq` (recovery computes this from
    /// its scan).
    pub fn open_with_floor(
        path: &Path,
        policy: FsyncPolicy,
        start_seq: u64,
        floor_seq: u64,
    ) -> Result<WalWriter> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        let offset = file.metadata()?.len();
        Ok(WalWriter {
            file,
            path: path.to_path_buf(),
            policy,
            seq: start_seq,
            signal: Arc::new(TailSignal::new(start_seq, floor_seq)),
            offset,
            appends_since_sync: 0,
            poisoned: false,
            faults: crate::fault::global(),
        })
    }

    /// Replace the fault injector this writer consults (`None` disables
    /// injection). Tests use this to target one writer without arming the
    /// process-global plan.
    pub fn set_fault_injector(&mut self, faults: Option<Arc<FaultInjector>>) {
        self.faults = faults;
    }

    /// The injector this writer consults, if any — the checkpoint path
    /// passes it along to the snapshot commit site so
    /// `checkpoint_rename` rules fire against the right injector.
    pub fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        self.faults.clone()
    }

    /// Sequence number of the most recently appended record.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The progress signal tailing readers wait on.
    pub fn signal(&self) -> &Arc<TailSignal> {
        &self.signal
    }

    /// Append one record; returns its sequence number. The record is in
    /// the OS page cache when this returns (a process crash cannot lose
    /// it); the fsync policy decides when it also survives power loss.
    ///
    /// A failed write (ENOSPC, I/O error) is rolled back to the previous
    /// record boundary so the log stays parseable; if even the rollback
    /// fails the writer poisons itself and refuses further appends —
    /// otherwise the next successful append would follow garbage bytes
    /// and turn an I/O blip into unrecoverable mid-file corruption.
    pub fn append(&mut self, payload: &Json) -> Result<u64> {
        let bytes = payload.dump().into_bytes();
        self.append_frame(self.seq + 1, &bytes)
    }

    /// Append a record whose payload bytes (and sequence number) were
    /// produced elsewhere — the replication follower's path: it persists
    /// the leader's frames verbatim, so its log stays byte-identical to
    /// the stream. `seq` must continue the sequence exactly.
    pub fn append_raw(&mut self, seq: u64, payload: &[u8]) -> Result<u64> {
        anyhow::ensure!(
            seq == self.seq + 1,
            "replication stream gap: record {seq} follows local seq {}",
            self.seq
        );
        self.append_frame(seq, payload)
    }

    fn append_frame(&mut self, seq: u64, payload: &[u8]) -> Result<u64> {
        anyhow::ensure!(
            !self.poisoned,
            "WAL {} is poisoned after an unsafe write or fsync failure; \
             restart (recovery truncates any partial record)",
            self.path.display()
        );
        anyhow::ensure!(payload.len() as u64 <= MAX_RECORD_BYTES as u64, "WAL record too large");
        let frame = encode_frame(seq, payload);
        if let Some(kind) = self.faults.as_ref().and_then(|f| f.check(FaultSite::WalAppend, seq)) {
            if kind == FaultKind::Crash {
                enact_crash(FaultSite::WalAppend);
            }
            // Model the failure faithfully: enospc/torn leave a partial
            // frame on disk before the error surfaces (a short write),
            // `err` writes nothing. Either way the rollback path below
            // must restore the record boundary.
            let partial = match kind {
                FaultKind::Enospc | FaultKind::Torn => frame.len() / 2,
                _ => 0,
            };
            if partial > 0 {
                let _ = self.file.write_all(&frame[..partial]);
            }
            if self.file.set_len(self.offset).is_err() {
                self.poisoned = true;
            }
            return Err(injected_error(FaultSite::WalAppend, kind)
                .context(format!("appending to WAL {}", self.path.display())));
        }
        if let Err(e) = self.file.write_all(&frame) {
            // Trim any partial frame; seq stays unchanged so the next
            // attempt reuses it (no gap in the sequence). The file is in
            // append mode, so the next write lands at the restored EOF.
            if self.file.set_len(self.offset).is_err() {
                self.poisoned = true;
            }
            return Err(anyhow!(e)
                .context(format!("appending to WAL {}", self.path.display())));
        }
        self.seq = seq;
        self.offset += frame.len() as u64;
        match self.policy {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::EveryN(n) => {
                self.appends_since_sync += 1;
                if self.appends_since_sync >= n {
                    self.sync()?;
                }
            }
            FsyncPolicy::Never => {}
        }
        self.signal.note_append(self.seq);
        Ok(self.seq)
    }

    /// Force everything appended so far to stable storage.
    ///
    /// A failed fsync **poisons the writer**: after fsync returns an
    /// error, the kernel may have dropped the dirty pages it could not
    /// write, so "retry the fsync" silently loses data (fsyncgate). The
    /// only honest reaction is to refuse further appends and force a
    /// restart, which re-scans the log and recovers the true durable
    /// prefix.
    pub fn sync(&mut self) -> Result<()> {
        if let Some(kind) = self.faults.as_ref().and_then(|f| f.check(FaultSite::Fsync, self.seq)) {
            if kind == FaultKind::Crash {
                enact_crash(FaultSite::Fsync);
            }
            self.poisoned = true;
            return Err(injected_error(FaultSite::Fsync, kind)
                .context(format!("fsync {}", self.path.display())));
        }
        if let Err(e) = self.file.sync_data() {
            self.poisoned = true;
            return Err(anyhow!(e).context(format!("fsync {}", self.path.display())));
        }
        self.appends_since_sync = 0;
        Ok(())
    }

    /// Drop all records (after a checkpoint made them redundant). The
    /// sequence counter is preserved — it must stay monotonic so snapshot
    /// `last_seq` comparisons remain meaningful across checkpoints. Also
    /// clears a poisoned state: the partial frame (if any) is gone.
    pub fn truncate(&mut self) -> Result<()> {
        self.truncate_retaining(0)
    }

    /// Post-checkpoint truncation keeping a bounded tail: the most recent
    /// `retain` records stay in the file so replication followers lagging
    /// by less than `retain` records can resume from the log instead of
    /// re-bootstrapping from a snapshot. `retain == 0` drops everything
    /// (the classic behavior). The kept tail is copied byte-for-byte into
    /// a temp file and renamed into place, so concurrently tailing
    /// readers (which hold the old inode) never observe a torn file —
    /// they reopen on the generation bump.
    pub fn truncate_retaining(&mut self, retain: u64) -> Result<()> {
        if let Some(kind) =
            self.faults.as_ref().and_then(|f| f.check(FaultSite::WalTruncate, self.seq))
        {
            // The crash-between-checkpoint-commit-and-truncate window:
            // the snapshot rename has already committed when the
            // coordinator calls this, so dying (or erroring) here leaves
            // a committed checkpoint plus a stale log — recovery must
            // replay only `seq > last_seq` and end up in exactly the
            // checkpointed state.
            if kind == FaultKind::Crash {
                enact_crash(FaultSite::WalTruncate);
            }
            return Err(injected_error(FaultSite::WalTruncate, kind)
                .context(format!("truncating WAL {}", self.path.display())));
        }
        let cut_seq = self.seq.saturating_sub(retain);
        let floor = self.signal.snapshot().floor_seq;
        if retain > 0 && cut_seq <= floor {
            // Fewer than `retain` records in the file: nothing to drop.
            // (Also covers poisoned/torn tails conservatively: a rewrite
            // below copies only checksum-valid frames anyway.)
            if !self.poisoned {
                return Ok(());
            }
        }
        if retain == 0 || self.offset == 0 {
            self.file
                .set_len(0)
                .with_context(|| format!("truncating WAL {}", self.path.display()))?;
            self.file.sync_all().ok();
            self.offset = 0;
            self.appends_since_sync = 0;
            self.poisoned = false;
            self.signal.note_rewrite(self.seq, self.seq);
            return Ok(());
        }
        // Walk the valid prefix collecting the byte range of the retained
        // tail (frames with seq > cut_seq), then rewrite via tmp + rename.
        let mut reader = std::io::BufReader::new(
            File::open(&self.path)
                .with_context(|| format!("reopening WAL {}", self.path.display()))?,
        );
        let mut cut_offset = 0u64;
        let mut walked = 0u64;
        loop {
            match read_frame_raw(&mut reader) {
                Ok(Some((seq, frame))) => {
                    walked += frame.len() as u64;
                    if seq <= cut_seq {
                        cut_offset = walked;
                    }
                    if walked >= self.offset {
                        break;
                    }
                }
                Ok(None) => break,
                Err(FrameError::Torn) => break,
                Err(FrameError::Io(e)) => {
                    return Err(anyhow!(e)
                        .context(format!("scanning WAL {} for retention", self.path.display())))
                }
            }
        }
        let data = std::fs::read(&self.path)?;
        let keep = &data[cut_offset as usize..(self.offset as usize).min(data.len())];
        let tmp = self.path.with_extension("log.tmp");
        std::fs::write(&tmp, keep).with_context(|| format!("writing {}", tmp.display()))?;
        File::open(&tmp).and_then(|f| f.sync_all()).ok();
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("committing retained WAL tail {}", self.path.display()))?;
        self.file = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("reopening WAL {}", self.path.display()))?;
        self.offset = keep.len() as u64;
        self.appends_since_sync = 0;
        self.poisoned = false;
        self.signal.note_rewrite(cut_seq, self.seq);
        Ok(())
    }
}

impl Drop for WalWriter {
    fn drop(&mut self) {
        // Clean shutdown gets power-loss durability regardless of policy.
        let _ = self.file.sync_data();
    }
}

// ---------- scanning / replay ----------

/// Summary of a streamed log scan.
pub struct ScanSummary {
    /// Number of valid records streamed to the sink.
    pub records: usize,
    /// Sequence number of the last valid record (0 if none).
    pub last_seq: u64,
    /// Byte length of the valid prefix (everything after is a torn tail).
    pub good_bytes: u64,
    /// Whether a torn tail was found (and excluded).
    pub torn: bool,
}

/// Why a frame failed to decode: the file ends (or goes bad) mid-record,
/// or the underlying read itself errored.
pub(crate) enum FrameError {
    Torn,
    Io(std::io::Error),
}

/// Read one checksum-validated frame from `reader`, returning `(seq,
/// frame_bytes)` — the *complete* frame, header included, exactly as it
/// sits in the file (the replication stream ships these verbatim).
/// `Ok(None)` = clean EOF at a record boundary.
pub(crate) fn read_frame_raw(
    reader: &mut impl Read,
) -> std::result::Result<Option<(u64, Vec<u8>)>, FrameError> {
    let mut header = [0u8; HEADER_BYTES];
    let mut filled = 0usize;
    while filled < HEADER_BYTES {
        match reader.read(&mut header[filled..]) {
            Ok(0) if filled == 0 => return Ok(None), // clean boundary
            Ok(0) => return Err(FrameError::Torn),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap());
    let seq = u64::from_le_bytes(header[4..12].try_into().unwrap());
    let check = u64::from_le_bytes(header[12..20].try_into().unwrap());
    if len > MAX_RECORD_BYTES {
        return Err(FrameError::Torn);
    }
    let mut frame = vec![0u8; HEADER_BYTES + len as usize];
    frame[..HEADER_BYTES].copy_from_slice(&header);
    let mut filled = HEADER_BYTES;
    while filled < frame.len() {
        match reader.read(&mut frame[filled..]) {
            Ok(0) => return Err(FrameError::Torn),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    if record_check(seq, &frame[HEADER_BYTES..]) != check {
        return Err(FrameError::Torn);
    }
    Ok(Some((seq, frame)))
}

/// Split a raw frame (from [`read_frame_raw`] or the replication stream)
/// into its payload byte range.
pub(crate) fn frame_payload(frame: &[u8]) -> &[u8] {
    &frame[HEADER_BYTES..]
}

/// Decode one frame (`(seq, payload, frame_bytes)`) from `reader`.
/// `Ok(None)` = clean EOF at a record boundary.
fn read_frame(
    reader: &mut impl std::io::Read,
) -> std::result::Result<Option<(u64, Json, u64)>, FrameError> {
    let Some((seq, frame)) = read_frame_raw(reader)? else {
        return Ok(None);
    };
    let json = std::str::from_utf8(frame_payload(&frame))
        .ok()
        .and_then(|t| Json::parse(t).ok())
        .ok_or(FrameError::Torn)?;
    Ok(Some((seq, json, frame.len() as u64)))
}

/// Does any complete, checksum-valid record start in `data`? Used to tell
/// a genuine torn *tail* (nothing valid follows — safe to truncate) from
/// mid-file corruption (valid records follow — truncating would destroy
/// acknowledged mutations, so recovery must fail loudly instead).
fn contains_valid_record(data: &[u8]) -> bool {
    if data.len() < HEADER_BYTES {
        return false;
    }
    for pos in 0..=(data.len() - HEADER_BYTES) {
        let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        if len as u64 > MAX_RECORD_BYTES as u64 || data.len() - pos - HEADER_BYTES < len {
            continue;
        }
        let seq = u64::from_le_bytes(data[pos + 4..pos + 12].try_into().unwrap());
        let check = u64::from_le_bytes(data[pos + 12..pos + 20].try_into().unwrap());
        let payload = &data[pos + HEADER_BYTES..pos + HEADER_BYTES + len];
        if record_check(seq, payload) == check {
            return true;
        }
    }
    false
}

/// Stream a log file's records into `sink`, tolerating a torn tail.
/// Memory use is bounded by one record, not the file. A missing file
/// scans as empty. Errors if the log is corrupted *mid-file* (valid
/// records follow the bad region — see [`contains_valid_record`]) or if
/// the sink errors.
pub fn scan_apply(
    path: &Path,
    mut sink: impl FnMut(u64, Json) -> Result<()>,
) -> Result<ScanSummary> {
    let file = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(ScanSummary { records: 0, last_seq: 0, good_bytes: 0, torn: false })
        }
        Err(e) => return Err(anyhow!(e).context(format!("opening WAL {}", path.display()))),
    };
    let file_len = file.metadata()?.len();
    let mut reader = std::io::BufReader::new(file);
    let mut summary = ScanSummary { records: 0, last_seq: 0, good_bytes: 0, torn: false };
    loop {
        match read_frame(&mut reader) {
            Ok(None) => break,
            Ok(Some((seq, json, frame_bytes))) => {
                sink(seq, json)?;
                summary.records += 1;
                summary.last_seq = seq;
                summary.good_bytes += frame_bytes;
            }
            Err(FrameError::Io(e)) => {
                return Err(anyhow!(e).context(format!("reading WAL {}", path.display())))
            }
            Err(FrameError::Torn) => {
                summary.torn = true;
                break;
            }
        }
    }
    if summary.torn && summary.good_bytes + 1 < file_len {
        // Distinguish a torn tail from mid-file corruption: re-read the
        // suspect region (error path only) and look for valid records
        // beyond it.
        let data = std::fs::read(path)?;
        let tail = &data[(summary.good_bytes as usize + 1).min(data.len())..];
        if contains_valid_record(tail) {
            bail!(
                "WAL {} is corrupted at byte {} with valid records after the bad region; \
                 refusing to truncate acknowledged mutations — inspect or repair the log \
                 manually",
                path.display(),
                summary.good_bytes
            );
        }
    }
    Ok(summary)
}

/// Result of scanning a log file into memory (tests, tooling; prefer
/// [`scan_apply`] for recovery-sized logs).
pub struct WalScan {
    /// Decoded `(seq, payload)` records in append order.
    pub records: Vec<(u64, Json)>,
    /// Byte length of the valid prefix (everything after is a torn tail).
    pub good_bytes: u64,
    /// Whether a torn tail was found (and excluded).
    pub torn: bool,
}

/// Scan a log file, collecting all records. See [`scan_apply`].
pub fn scan(path: &Path) -> Result<WalScan> {
    let mut records = Vec::new();
    let summary = scan_apply(path, |seq, json| {
        records.push((seq, json));
        Ok(())
    })?;
    Ok(WalScan { records, good_bytes: summary.good_bytes, torn: summary.torn })
}

// ---------- tailing reader (replication streaming) ----------

/// A cursor over a live, growing (and occasionally rewritten) log file,
/// yielding raw frames with `seq >= next_seq` in order. The replication
/// leader runs one per subscriber.
///
/// Concurrency contract: appends are whole-frame `write_all`s, so a read
/// that lands mid-append parses as a torn tail — the tailer simply does
/// not advance and retries after the writer's [`TailSignal`] fires.
/// Checkpoint rewrites replace the file via rename; this handle keeps
/// reading its (stable, no-longer-growing) old inode until the
/// generation bump tells it to reopen the path.
pub struct WalTailer {
    path: PathBuf,
    file: Option<File>,
    /// Byte offset of the next unread frame in the *current* inode.
    offset: u64,
    /// Sequence number the next yielded frame must have.
    next_seq: u64,
    /// Generation of the inode `file` points at.
    generation: u64,
}

impl WalTailer {
    /// Tail `dir/wal.log` starting at sequence number `next_seq`, against
    /// the writer's current `state` (from [`TailSignal::snapshot`]).
    /// Errors if the log no longer holds `next_seq` (`<= floor_seq`) —
    /// the caller must fall back to a snapshot bootstrap.
    pub fn new(dir: &Path, next_seq: u64, state: TailState) -> Result<WalTailer> {
        anyhow::ensure!(
            next_seq > state.floor_seq,
            "WAL tail starts at seq {} but {} was requested; snapshot bootstrap required",
            state.floor_seq + 1,
            next_seq
        );
        Ok(WalTailer {
            path: dir.join(WAL_FILE),
            file: None,
            offset: 0,
            next_seq,
            generation: state.generation,
        })
    }

    /// Sequence number of the next frame [`WalTailer::fill`] will yield.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Append available frames (`seq >= next_seq`, in order, raw bytes)
    /// to `buf`, up to ~`max_bytes` per call. Returns the number of
    /// frames appended; `0` means "caught up — wait on the signal".
    /// `state` must be a fresh [`TailSignal::snapshot`].
    pub fn fill(&mut self, state: TailState, buf: &mut Vec<u8>, max_bytes: usize) -> Result<usize> {
        if state.generation != self.generation || self.file.is_none() {
            // The file was rewritten under us (or this is the first
            // read): reopen the path and rescan from the top, skipping
            // frames already delivered. If the rewrite dropped our
            // position, the stream cannot continue.
            anyhow::ensure!(
                self.next_seq > state.floor_seq,
                "WAL retention passed this subscriber (needs seq {}, floor is {}); \
                 snapshot bootstrap required",
                self.next_seq,
                state.floor_seq
            );
            self.file = Some(
                File::open(&self.path)
                    .with_context(|| format!("reopening WAL {}", self.path.display()))?,
            );
            self.offset = 0;
            self.generation = state.generation;
        }
        let file = self.file.as_mut().unwrap();
        file.seek(SeekFrom::Start(self.offset))?;
        let mut reader = std::io::BufReader::new(file);
        let mut appended = 0usize;
        while buf.len() < max_bytes {
            match read_frame_raw(&mut reader) {
                Ok(Some((seq, frame))) => {
                    self.offset += frame.len() as u64;
                    if seq < self.next_seq {
                        continue; // retained tail we already have
                    }
                    anyhow::ensure!(
                        seq == self.next_seq,
                        "WAL tail gap: expected seq {}, found {seq}",
                        self.next_seq
                    );
                    self.next_seq = seq + 1;
                    buf.extend_from_slice(&frame);
                    appended += 1;
                }
                // Clean EOF or a mid-append partial frame: caught up for
                // now (do not advance past it — the writer will finish
                // the frame and the signal will fire).
                Ok(None) | Err(FrameError::Torn) => break,
                Err(FrameError::Io(e)) => {
                    return Err(anyhow!(e)
                        .context(format!("tailing WAL {}", self.path.display())))
                }
            }
        }
        Ok(appended)
    }
}

// ---------- bootstrap metadata ----------

/// Write `wal_meta.json` (schema + config + corpus size at the time) so
/// a WAL whose checkpoint is later lost can either be recovered (empty
/// bootstrap — the log is the full history) or refused loudly (non-empty
/// bootstrap — the log alone cannot reconstruct it). No-op if the file
/// already exists.
fn ensure_meta(gus: &DynamicGus, dir: &Path) -> Result<()> {
    let path = dir.join(META_FILE);
    if path.exists() {
        return Ok(());
    }
    let meta = Json::obj(vec![
        ("schema", Json::str(gus.schema().name.clone())),
        ("dense_dim", Json::num(gus.schema().primary_dense_dim() as f64)),
        ("config", gus.config().to_json()),
        ("points_at_init", Json::num(gus.len() as f64)),
    ]);
    std::fs::write(&path, meta.dump())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Boot an empty service from `wal_meta.json` (checkpoint lost). Only
/// sound when the service started empty: WAL replay then reproduces the
/// entire history. A non-empty bootstrap corpus cannot be reconstructed
/// from the log, so that case is a loud error, not a silent partial
/// recovery.
fn boot_from_meta(dir: &Path, threads: usize) -> Result<DynamicGus> {
    let path = dir.join(META_FILE);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let meta = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let points_at_init = meta.get("points_at_init").as_usize().unwrap_or(0);
    if points_at_init > 0 {
        bail!(
            "checkpoint missing from {} and the service was initialized with \
             {points_at_init} points — the WAL alone cannot reconstruct them; \
             restore the snapshot files from backup",
            dir.display()
        );
    }
    let config = crate::config::GusConfig::from_json(meta.get("config"))
        .map_err(|e| anyhow!("wal_meta config: {e}"))?;
    let name = meta
        .get("schema")
        .as_str()
        .ok_or_else(|| anyhow!("wal_meta missing schema"))?;
    let dense_dim = meta
        .get("dense_dim")
        .as_usize()
        .ok_or_else(|| anyhow!("wal_meta missing dense_dim"))?;
    let schema = snapshot::schema_by_name(name, dense_dim)?;
    DynamicGus::bootstrap(schema, config, &[], threads)
}

// ---------- handle (attached to a DynamicGus) ----------

/// The durability state a [`DynamicGus`] carries once WAL logging is
/// enabled: the writer, the directory checkpoints land in, and the count
/// of mutations logged since the last checkpoint.
pub struct WalHandle {
    pub(crate) writer: Mutex<WalWriter>,
    dir: PathBuf,
    pending: AtomicU64,
}

impl WalHandle {
    pub fn new(writer: WalWriter, dir: PathBuf) -> WalHandle {
        WalHandle { writer, dir, pending: AtomicU64::new(0) }
    }

    /// Directory holding the log and checkpoints.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Mutations logged since the last checkpoint (drives the
    /// [`Checkpointer`]).
    pub fn pending(&self) -> u64 {
        self.pending.load(Ordering::Relaxed)
    }

    pub(crate) fn add_pending(&self, n: u64) {
        self.pending.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn reset_pending(&self) {
        self.pending.store(0, Ordering::Relaxed);
    }

    /// Sequence number of the most recently logged mutation.
    pub fn seq(&self) -> u64 {
        self.writer.lock().unwrap().seq()
    }

    /// Swap the writer's fault injector (tests and drills; `None`
    /// restores passthrough). Takes the WAL lock briefly.
    pub fn set_fault_injector(&self, faults: Option<Arc<FaultInjector>>) {
        self.writer.lock().unwrap().set_fault_injector(faults);
    }

    /// The writer's tail-progress signal (replication subscribers wait on
    /// this; cloned out so waiting never touches the writer mutex).
    pub fn tail_signal(&self) -> Arc<TailSignal> {
        Arc::clone(self.writer.lock().unwrap().signal())
    }

    /// Lock the writer — the replication follower's append+apply critical
    /// section (mirrors the coordinator's own log-before-apply locking).
    pub fn lock_writer(&self) -> std::sync::MutexGuard<'_, WalWriter> {
        self.writer.lock().unwrap()
    }
}

// ---------- lifecycle: init / recover ----------

/// Does `dir` hold a previous incarnation's state?
pub fn has_state(dir: &Path) -> bool {
    dir.join(snapshot::SNAPSHOT_META).exists()
        || dir.join(WAL_FILE).exists()
        || dir.join(META_FILE).exists()
}

/// Enable durability on a freshly bootstrapped service: create `dir`,
/// attach the log, write checkpoint 0 (so the bootstrap corpus itself is
/// never WAL-only), and only then the bootstrap metadata — so a crash
/// mid-init leaves a directory that recovery *rejects loudly* rather
/// than one that silently recovers as an empty corpus. Fails if `dir`
/// already holds state — recover that instead with [`recover`].
pub fn init_fresh(gus: &DynamicGus, dir: &Path) -> Result<()> {
    if has_state(dir) {
        bail!(
            "{} already holds service state; use wal::recover instead of init_fresh \
             (or remove the directory to start fresh)",
            dir.display()
        );
    }
    std::fs::create_dir_all(dir)?;
    let writer = WalWriter::open(&dir.join(WAL_FILE), gus.config().fsync, 0)?;
    gus.attach_wal(WalHandle::new(writer, dir.to_path_buf()))?;
    gus.checkpoint()?;
    ensure_meta(gus, dir)?;
    Ok(())
}

/// What [`recover`] found and did.
pub struct Recovery {
    /// The restored, WAL-attached service.
    pub gus: DynamicGus,
    /// Points restored from the checkpoint (0 if recovery was WAL-only).
    pub snapshot_points: usize,
    /// WAL records replayed on top of the checkpoint.
    pub replayed: usize,
    /// Whether a torn tail was found (and truncated away).
    pub torn_tail: bool,
}

/// Restore a durable service from `dir`: latest checkpoint + WAL replay.
///
/// Every acknowledged mutation survives; a torn final record (crash
/// mid-append — necessarily unacknowledged) is dropped and truncated so
/// the log is clean for new appends. Mid-file corruption (valid records
/// after a bad region) is an error, never a silent truncation. The
/// returned service has the WAL attached and continues logging where the
/// previous incarnation stopped.
pub fn recover(dir: &Path, threads: usize) -> Result<Recovery> {
    recover_with(dir, threads, None)
}

/// [`recover`], optionally overriding the persisted fsync policy for the
/// re-attached log (e.g. the operator passed `--fsync` on restart). The
/// override applies to the new incarnation's appends only; the persisted
/// config is otherwise authoritative.
pub fn recover_with(
    dir: &Path,
    threads: usize,
    fsync_override: Option<FsyncPolicy>,
) -> Result<Recovery> {
    let (gus, last_seq) = if dir.join(snapshot::SNAPSHOT_META).exists() {
        snapshot::restore_with_seq(dir, threads)?
    } else if dir.join(META_FILE).exists() {
        (boot_from_meta(dir, threads)?, 0)
    } else {
        bail!(
            "nothing to recover in {}: no {} or {} (crash during init? \
             remove the directory to start fresh)",
            dir.display(),
            snapshot::SNAPSHOT_META,
            META_FILE
        );
    };
    let snapshot_points = gus.len();

    // Stream the log tail into the service: memory stays bounded by one
    // record no matter how long the previous incarnation ran between
    // checkpoints. Appends are strictly sequential, so any gap in the
    // sequence — within the file, or between the checkpoint's last_seq
    // and the file's first record — means acknowledged history is
    // missing, and recovery must fail rather than serve partial state.
    let wal_path = dir.join(WAL_FILE);
    let mut replayed = 0usize;
    let mut pending_mutations = 0u64;
    let mut prev_seq: Option<u64> = None;
    let summary = scan_apply(&wal_path, |seq, payload| {
        match prev_seq {
            Some(p) if seq != p + 1 => bail!(
                "WAL sequence gap: record {seq} follows record {p}; \
                 acknowledged history is missing"
            ),
            None if seq > last_seq + 1 => bail!(
                "WAL starts at record {seq} but the checkpoint only covers \
                 up to {last_seq}; records {}..{} are missing (lost \
                 checkpoint?)",
                last_seq + 1,
                seq - 1
            ),
            _ => {}
        }
        prev_seq = Some(seq);
        if seq <= last_seq {
            // Already folded into the checkpoint (crash landed between
            // snapshot commit and WAL truncation).
            return Ok(());
        }
        pending_mutations += gus
            .apply_logged(&payload, threads)
            .with_context(|| format!("replaying WAL record seq={seq}"))?;
        replayed += 1;
        Ok(())
    })?;
    let max_seq = last_seq.max(summary.last_seq);
    if summary.torn {
        // Drop the unacknowledged tail so new appends follow a valid record.
        let f = OpenOptions::new()
            .write(true)
            .open(&wal_path)
            .with_context(|| format!("truncating torn WAL {}", wal_path.display()))?;
        f.set_len(summary.good_bytes)?;
        f.sync_all().ok();
    }

    ensure_meta(&gus, dir)?;
    let policy = fsync_override.unwrap_or_else(|| gus.config().fsync);
    // The (possibly retained) file holds `floor + 1 ..= last_seq`; tell
    // the writer so replication subscribers see the correct tail floor.
    let floor = if summary.records == 0 {
        max_seq
    } else {
        summary.last_seq - summary.records as u64
    };
    let writer = WalWriter::open_with_floor(&wal_path, policy, max_seq, floor)?;
    let handle = WalHandle::new(writer, dir.to_path_buf());
    // Mutations not yet folded into a checkpoint count as pending —
    // weighted like live logging (a batch record counts its items) — so
    // the background checkpointer compacts them promptly.
    handle.add_pending(pending_mutations);
    gus.attach_wal(handle)?;
    Ok(Recovery { gus, snapshot_points, replayed, torn_tail: summary.torn })
}

// ---------- background checkpointer ----------

/// Background thread that checkpoints the service whenever
/// `checkpoint_every` mutations have accumulated in the WAL. Stops (and
/// joins) on [`Checkpointer::stop`] or drop.
pub struct Checkpointer {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Checkpointer {
    /// Spawn the trigger thread. `every` must be ≥ 1 (callers gate on
    /// `checkpoint_every > 0`); `poll` is how often the threshold is
    /// checked — checkpoints themselves happen only when it is crossed.
    pub fn spawn(gus: Arc<DynamicGus>, every: u64, poll: Duration) -> Checkpointer {
        assert!(every >= 1, "checkpoint_every must be >= 1 to spawn a Checkpointer");
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("gus-checkpointer".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    std::thread::sleep(poll);
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    if gus.wal_pending() >= every {
                        match gus.checkpoint() {
                            Ok(seq) => {
                                eprintln!("[gus] background checkpoint at seq {seq}")
                            }
                            Err(e) => eprintln!("[gus] background checkpoint failed: {e}"),
                        }
                    }
                }
            })
            .expect("spawning checkpointer thread");
        Checkpointer { stop, join: Some(join) }
    }

    /// Signal the thread and wait for it to exit.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("gus-wal-unit").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn payload(i: u64) -> Json {
        Json::obj(vec![("op", Json::str("delete")), ("id", Json::u64(i))])
    }

    #[test]
    fn append_scan_round_trip() {
        let dir = tmpdir("roundtrip");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::EveryN(2), 0).unwrap();
        for i in 0..5 {
            assert_eq!(w.append(&payload(i)).unwrap(), i + 1);
        }
        drop(w);
        let s = scan(&path).unwrap();
        assert!(!s.torn);
        assert_eq!(s.records.len(), 5);
        assert_eq!(s.good_bytes, std::fs::metadata(&path).unwrap().len());
        for (i, (seq, j)) in s.records.iter().enumerate() {
            assert_eq!(*seq, i as u64 + 1);
            assert_eq!(j.get("id").as_u64(), Some(i as u64));
        }
        // Reopen continues the sequence.
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 5).unwrap();
        assert_eq!(w.append(&payload(99)).unwrap(), 6);
        drop(w);
        assert_eq!(scan(&path).unwrap().records.len(), 6);
    }

    #[test]
    fn missing_file_scans_empty() {
        let dir = tmpdir("missing");
        let s = scan(&dir.join(WAL_FILE)).unwrap();
        assert!(s.records.is_empty());
        assert_eq!(s.good_bytes, 0);
        assert!(!s.torn);
    }

    #[test]
    fn torn_tail_is_detected_and_bounded() {
        let dir = tmpdir("torn");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 0).unwrap();
        for i in 0..3 {
            w.append(&payload(i)).unwrap();
        }
        let good_two = {
            // Length after two records, recomputed from a fresh scan.
            let s = scan(&path).unwrap();
            assert_eq!(s.records.len(), 3);
            let full = std::fs::metadata(&path).unwrap().len();
            drop(w);
            // Chop into the middle of the third record.
            let f = OpenOptions::new().write(true).open(&path).unwrap();
            f.set_len(full - 5).unwrap();
            drop(f);
            let s = scan(&path).unwrap();
            assert!(s.torn);
            assert_eq!(s.records.len(), 2);
            s.good_bytes
        };
        // good_bytes points at the end of record 2: truncating there and
        // appending again yields a clean 3-record log.
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(good_two).unwrap();
        drop(f);
        let mut w = WalWriter::open(&path, FsyncPolicy::Always, 2).unwrap();
        w.append(&payload(7)).unwrap();
        drop(w);
        let s = scan(&path).unwrap();
        assert!(!s.torn);
        assert_eq!(s.records.len(), 3);
        assert_eq!(s.records[2].0, 3);
        assert_eq!(s.records[2].1.get("id").as_u64(), Some(7));
    }

    #[test]
    fn corrupted_byte_stops_scan() {
        let dir = tmpdir("corrupt");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 0).unwrap();
        for i in 0..3 {
            w.append(&payload(i)).unwrap();
        }
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one payload byte in the *last* record: indistinguishable
        // from a torn tail, so it scans as one.
        let n = bytes.len();
        bytes[n - 2] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let s = scan(&path).unwrap();
        assert!(s.torn);
        assert_eq!(s.records.len(), 2);
    }

    #[test]
    fn mid_file_corruption_is_an_error_not_a_truncation() {
        let dir = tmpdir("mid-corrupt");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 0).unwrap();
        let first_len = {
            w.append(&payload(0)).unwrap();
            std::fs::metadata(&path).unwrap().len()
        };
        for i in 1..4 {
            w.append(&payload(i)).unwrap();
        }
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a payload byte in the *first* record: valid, acknowledged
        // records follow the bad region, so treating it as a torn tail
        // would silently destroy them. The scan must fail loudly instead.
        bytes[first_len as usize - 2] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = scan(&path).unwrap_err();
        assert!(format!("{err}").contains("corrupted"), "{err}");
    }

    /// Drain a tailer into (seq, payload-json) pairs, non-blocking.
    fn drain_tailer(t: &mut WalTailer, sig: &TailSignal) -> Vec<(u64, Json)> {
        let mut buf = Vec::new();
        while t.fill(sig.snapshot(), &mut buf, usize::MAX).unwrap() > 0 {}
        let mut out = Vec::new();
        let mut reader = std::io::Cursor::new(buf);
        while let Ok(Some((seq, frame))) = read_frame_raw(&mut reader) {
            let j = Json::parse(std::str::from_utf8(frame_payload(&frame)).unwrap()).unwrap();
            out.push((seq, j));
        }
        out
    }

    #[test]
    fn truncate_retaining_keeps_bounded_tail() {
        let dir = tmpdir("retain");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 0).unwrap();
        for i in 0..10 {
            w.append(&payload(i)).unwrap();
        }
        w.truncate_retaining(3).unwrap();
        let st = w.signal().snapshot();
        assert_eq!(st.floor_seq, 7, "file should hold 8..=10");
        assert_eq!(st.last_seq, 10);
        assert_eq!(st.generation, 1);
        let s = scan(&path).unwrap();
        assert!(!s.torn);
        assert_eq!(
            s.records.iter().map(|(seq, _)| *seq).collect::<Vec<_>>(),
            vec![8, 9, 10]
        );
        // Appends continue the sequence on the rewritten file.
        assert_eq!(w.append(&payload(99)).unwrap(), 11);
        drop(w);
        assert_eq!(scan(&path).unwrap().records.len(), 4);
    }

    #[test]
    fn truncate_retaining_more_than_present_is_a_no_op() {
        let dir = tmpdir("retain-noop");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 0).unwrap();
        for i in 0..4 {
            w.append(&payload(i)).unwrap();
        }
        let len = std::fs::metadata(&path).unwrap().len();
        w.truncate_retaining(100).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), len);
        assert_eq!(w.signal().snapshot().generation, 0, "no rewrite happened");
    }

    #[test]
    fn append_raw_enforces_continuity_and_matches_append_bytes() {
        let dir = tmpdir("raw");
        let a = dir.join("a.log");
        let b = dir.join("b.log");
        let mut wa = WalWriter::open(&a, FsyncPolicy::Never, 0).unwrap();
        let mut wb = WalWriter::open(&b, FsyncPolicy::Never, 0).unwrap();
        for i in 0..3 {
            let p = payload(i);
            let seq = wa.append(&p).unwrap();
            wb.append_raw(seq, p.dump().as_bytes()).unwrap();
        }
        assert!(wb.append_raw(7, b"x").is_err(), "gap must be rejected");
        drop(wa);
        drop(wb);
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    }

    #[test]
    fn tailer_follows_appends_and_rewrites() {
        let dir = tmpdir("tailer");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 0).unwrap();
        for i in 0..5 {
            w.append(&payload(i)).unwrap();
        }
        let sig = Arc::clone(w.signal());
        let mut t = WalTailer::new(&dir, 3, sig.snapshot()).unwrap();
        let got = drain_tailer(&mut t, &sig);
        assert_eq!(got.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![3, 4, 5]);
        // Rewrite under the tailer (checkpoint with retention), then more
        // appends: the tailer reopens and resumes without gaps or dupes.
        w.truncate_retaining(2).unwrap();
        for i in 5..8 {
            w.append(&payload(i)).unwrap();
        }
        let got = drain_tailer(&mut t, &sig);
        assert_eq!(got.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![6, 7, 8]);
        // A tailer whose position was dropped by retention must error.
        w.truncate_retaining(1).unwrap();
        let behind = WalTailer::new(&dir, 1, sig.snapshot());
        assert!(behind.is_err(), "below-floor tail must demand a snapshot");
        let mut stale = WalTailer::new(&dir, 9, sig.snapshot()).unwrap();
        w.truncate_retaining(0).unwrap();
        let mut buf = Vec::new();
        assert!(
            stale.fill(sig.snapshot(), &mut buf, usize::MAX).is_ok(),
            "at-floor tailer (needs only future records) keeps working"
        );
    }

    #[test]
    fn truncate_keeps_sequence() {
        let dir = tmpdir("truncate");
        let path = dir.join(WAL_FILE);
        let mut w = WalWriter::open(&path, FsyncPolicy::Never, 0).unwrap();
        for i in 0..4 {
            w.append(&payload(i)).unwrap();
        }
        w.truncate().unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        assert_eq!(w.append(&payload(9)).unwrap(), 5, "seq must survive truncation");
        drop(w);
        let s = scan(&path).unwrap();
        assert_eq!(s.records.len(), 1);
        assert_eq!(s.records[0].0, 5);
    }
}
