//! Data-freshness tracking.
//!
//! The paper's requirement: "data freshness is within seconds for the 99th
//! percentile of queries" — i.e. the time between a mutation arriving and
//! its effect being visible to queries must be bounded. In this
//! implementation mutations are applied synchronously before the ack, so
//! visibility latency *is* the mutation latency; the tracker still exists
//! as a first-class metric so alternative designs (batched/async apply,
//! replication) can be measured against the same SLO.

use std::time::Duration;

use crate::metrics::LatencyHistogram;

/// Tracks mutation→visibility intervals.
#[derive(Default)]
pub struct StalenessTracker {
    hist: LatencyHistogram,
}

impl StalenessTracker {
    pub fn new() -> StalenessTracker {
        StalenessTracker::default()
    }

    /// Record that a mutation became visible `d` after arrival.
    pub fn record_visible(&self, d: Duration) {
        self.hist.record(d);
    }

    /// 99th-percentile staleness in milliseconds (the paper's SLO metric).
    pub fn p99_ms(&self) -> f64 {
        self.hist.quantile_ns(0.99) as f64 / 1e6
    }

    pub fn p50_ms(&self) -> f64 {
        self.hist.quantile_ns(0.50) as f64 / 1e6
    }

    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    /// Check the paper's SLO: p99 within `budget`.
    pub fn within_slo(&self, budget: Duration) -> bool {
        self.count() == 0 || self.hist.quantile_ns(0.99) <= budget.as_nanos() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let t = StalenessTracker::new();
        for ms in [1u64, 2, 3, 50] {
            t.record_visible(Duration::from_millis(ms));
        }
        assert_eq!(t.count(), 4);
        assert!(t.p50_ms() >= 1.0 && t.p50_ms() <= 4.0);
        assert!(t.p99_ms() >= 40.0);
    }

    #[test]
    fn slo_check() {
        let t = StalenessTracker::new();
        assert!(t.within_slo(Duration::from_secs(1)), "vacuous when empty");
        t.record_visible(Duration::from_millis(10));
        assert!(t.within_slo(Duration::from_secs(5)));
        assert!(!t.within_slo(Duration::from_micros(1)));
    }

    /// Property: the tracker's p50/p99 agree with a naive sort oracle up
    /// to the histogram's bucket resolution. The histogram uses 8
    /// sub-buckets per octave and quantiles return the bucket *lower*
    /// bound, so the estimate never exceeds the true order statistic and
    /// the true value never exceeds the estimate's bucket ceiling
    /// (`est + est/8`).
    #[test]
    fn quantiles_track_sort_oracle() {
        use crate::testing::{gen_usize, proptest};
        proptest(|rng| {
            let t = StalenessTracker::new();
            let n = gen_usize(rng, 1, 400);
            // Durations spanning many octaves, 1 ns .. ~8 s.
            let mut ns: Vec<u64> = (0..n)
                .map(|_| rng.below(1u64 << gen_usize(rng, 1, 34)) + 1)
                .collect();
            for &v in &ns {
                t.record_visible(Duration::from_nanos(v));
            }
            ns.sort_unstable();
            for (q, est_ms) in [(0.50, t.p50_ms()), (0.99, t.p99_ms())] {
                let est = (est_ms * 1e6).round() as u64;
                let target = ((q * n as f64).ceil() as usize).max(1);
                let oracle = ns[target - 1];
                crate::prop_assert!(
                    est <= oracle,
                    "q{q}: estimate {est} ns above oracle {oracle} ns (n={n})"
                );
                crate::prop_assert!(
                    oracle <= est + (est >> 3),
                    "q{q}: oracle {oracle} ns above bucket ceiling of estimate {est} ns (n={n})"
                );
            }
        });
    }

    /// Property: `within_slo` is inclusive exactly at the reported p99
    /// and fails one nanosecond below it.
    #[test]
    fn within_slo_boundary_is_inclusive() {
        use crate::testing::{gen_usize, proptest_cases};
        proptest_cases(32, |rng| {
            let t = StalenessTracker::new();
            let n = gen_usize(rng, 1, 100);
            for _ in 0..n {
                t.record_visible(Duration::from_nanos(rng.below(1u64 << 30) + 1));
            }
            let p99_ns = (t.p99_ms() * 1e6).round() as u64;
            crate::prop_assert!(t.within_slo(Duration::from_nanos(p99_ns)));
            crate::prop_assert!(
                p99_ns == 0 || !t.within_slo(Duration::from_nanos(p99_ns - 1)),
                "SLO passed 1 ns below the reported p99 ({p99_ns} ns)"
            );
        });
    }
}
