//! Data-freshness tracking.
//!
//! The paper's requirement: "data freshness is within seconds for the 99th
//! percentile of queries" — i.e. the time between a mutation arriving and
//! its effect being visible to queries must be bounded. In this
//! implementation mutations are applied synchronously before the ack, so
//! visibility latency *is* the mutation latency; the tracker still exists
//! as a first-class metric so alternative designs (batched/async apply,
//! replication) can be measured against the same SLO.

use std::time::Duration;

use crate::metrics::LatencyHistogram;

/// Tracks mutation→visibility intervals.
#[derive(Default)]
pub struct StalenessTracker {
    hist: LatencyHistogram,
}

impl StalenessTracker {
    pub fn new() -> StalenessTracker {
        StalenessTracker::default()
    }

    /// Record that a mutation became visible `d` after arrival.
    pub fn record_visible(&self, d: Duration) {
        self.hist.record(d);
    }

    /// 99th-percentile staleness in milliseconds (the paper's SLO metric).
    pub fn p99_ms(&self) -> f64 {
        self.hist.quantile_ns(0.99) as f64 / 1e6
    }

    pub fn p50_ms(&self) -> f64 {
        self.hist.quantile_ns(0.50) as f64 / 1e6
    }

    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    /// Check the paper's SLO: p99 within `budget`.
    pub fn within_slo(&self, budget: Duration) -> bool {
        self.count() == 0 || self.hist.quantile_ns(0.99) <= budget.as_nanos() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let t = StalenessTracker::new();
        for ms in [1u64, 2, 3, 50] {
            t.record_visible(Duration::from_millis(ms));
        }
        assert_eq!(t.count(), 4);
        assert!(t.p50_ms() >= 1.0 && t.p50_ms() <= 4.0);
        assert!(t.p99_ms() >= 40.0);
    }

    #[test]
    fn slo_check() {
        let t = StalenessTracker::new();
        assert!(t.within_slo(Duration::from_secs(1)), "vacuous when empty");
        t.record_visible(Duration::from_millis(10));
        assert!(t.within_slo(Duration::from_secs(5)));
        assert!(!t.within_slo(Duration::from_micros(1)));
    }
}
